"""Serving launcher: batched generation with the continuous-batching
engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 6 --max-new 8

Production deployments pass --serve-sharding tp to use the serve-time
resharded weight layout (no per-step data-axis gathers; EXPERIMENTS.md
Sec. Perf).
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--serve-sharding", choices=("train", "tp"),
                    default="train")
    args = ap.parse_args()

    import jax
    from repro.configs import get_config, get_smoke_config
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.models.lm import LM
    from repro.parallel import sharding as sh
    from repro.serve.engine import Request, ServeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    mesh = make_debug_mesh() if args.smoke else make_production_mesh()
    lm = LM(cfg)
    with mesh, sh.use_mesh(mesh):
        p_sh = sh.tree_shardings(
            jax.eval_shape(lm.init, jax.random.PRNGKey(0)), mesh,
            serve=args.serve_sharding == "tp")
        params = jax.jit(lm.init, out_shardings=p_sh)(
            jax.random.PRNGKey(0))

    eng = ServeEngine(cfg, params, batch=args.batch,
                      max_len=args.max_len, mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab, rng.integers(3, 9),
                                        dtype=np.int64).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    results = eng.generate(reqs)
    for uid in sorted(results):
        print(f"req {uid}: {results[uid]}")


if __name__ == "__main__":
    main()
