"""jit-able train / prefill / decode steps + abstract input specs.

These are the exact functions the dry-run lowers and compiles for every
(architecture x input-shape x mesh) cell, and the trainer/server execute
for real.  All sharding decisions live here + parallel/sharding.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, SHAPES, ShapeConfig
from repro.models.lm import LM
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding as sh


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one shape cell."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.embed_input:
            inputs = sds((B, S, cfg.d_model), jnp.bfloat16)
        else:
            inputs = sds((B, S), jnp.int32)
        return {"inputs": inputs, "labels": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.embed_input:
            return {"inputs": sds((B, S, cfg.d_model), jnp.bfloat16)}
        return {"inputs": sds((B, S), jnp.int32)}
    # decode: one new token against a seq_len-deep cache
    lm = LM(cfg)
    cache = jax.eval_shape(functools.partial(lm.init_cache, B, S))
    return {"tokens": sds((B, 1), jnp.int32), "cache": cache}


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------

def cache_pspecs(cache_shapes, mesh: Mesh):
    la = sh.logical_axes(mesh)
    dp, tp = la["dp"], la["tp"]

    def spec(path, leaf):
        name = sh._path_str(path).split("/")[-1]
        r = len(leaf.shape)
        if name in ("k", "v"):
            # KV cache: batch over data, SEQUENCE over model.  Sharding
            # the (few) kv heads never divides 16, and leaving the cache
            # replicated makes GSPMD gather the whole (B,S,H,D) tensor per
            # decode step; sequence sharding turns that into per-step
            # all-reduces of (B,1,H) softmax stats + (B,1,H,D) partial
            # outputs (flash-decoding style) -- see EXPERIMENTS.md Perf.
            entries = [None] * (r - 4) + [dp, tp, None, None]
        elif name in ("k_scale", "v_scale"):
            entries = [None] * (r - 3) + [dp, tp, None]
        elif name == "conv":
            entries = [None] * (r - 3) + [dp, None, tp]
        elif name == "state":
            entries = [None] * (r - 4) + [dp, tp, None, None]
        elif name.startswith("x_prev"):
            entries = [None] * (r - 3) + [dp, None, None]
        else:
            entries = [None] * r
        return sh._guard(mesh, entries, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def batch_shardings(cfg, shape, mesh):
    specs = {}
    for k, v in input_specs(cfg, shape).items():
        if k == "cache":
            specs[k] = cache_pspecs(v, mesh)
        else:
            specs[k] = sh.batch_pspec(mesh, len(v.shape), 0, v.shape[0])
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        specs, is_leaf=lambda s: isinstance(s, P))


def _named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

def abstract_state(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None):
    """(params, opt_state) as ShapeDtypeStructs -- no allocation."""
    lm = LM(cfg)
    params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    opt = None
    if opt_cfg is not None:
        opt = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg),
                             params)
    return params, opt


def effective_microbatches(cfg: ModelConfig, global_batch: int,
                           mesh: Optional[Mesh]) -> int:
    """Clamp cfg.microbatch so each microbatch still divides the data
    axes (otherwise activations fall back to replicated)."""
    n = max(1, cfg.microbatch)
    dp = 1
    if mesh is not None:
        la = sh.logical_axes(mesh)
        dp = sh._axis_size(mesh, la["dp"])
    while n > 1 and (global_batch % n or (global_batch // n) % dp):
        n -= 1
    return n


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    n_micro: int = 1):
    lm = LM(cfg)
    cdt = cfg.compute_dtype

    def _precast(p):
        # Cast weight matrices to the compute dtype BEFORE the FSDP
        # all-gathers and PIN the bf16 copy to the parameter sharding:
        # without the constraint XLA sinks the convert into the layer
        # loop and the partitioner gathers the fp32 master instead
        # (measured, EXPERIMENTS.md Sec. Perf change T2).  Norm scales /
        # biases (ndim < 2) stay fp32; gradients flow through the cast
        # and accumulate in fp32.
        mesh = sh._state().mesh

        def cast(path, a):
            if a.ndim < 2 or a.dtype != jnp.float32:
                return a
            c = a.astype(cdt)
            if mesh is not None:
                spec = sh.leaf_pspec(sh._path_str(path), a.shape, mesh)
                c = jax.lax.with_sharding_constraint(
                    c, NamedSharding(mesh, spec))
            return c

        return jax.tree_util.tree_map_with_path(cast, p)

    def grads_of(params, inputs, labels):
        def loss_fn(p):
            return lm.loss(_precast(p), inputs, labels)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, aux), grads = grads_of(params, batch["inputs"],
                                          batch["labels"])
        else:
            # Gradient accumulation: scan over microbatches keeps the
            # per-layer activation stash 1/n_micro as large.
            def split(t):
                return t.reshape(n_micro, t.shape[0] // n_micro,
                                 *t.shape[1:])
            mb = jax.tree.map(split, {"inputs": batch["inputs"],
                                      "labels": batch["labels"]})

            def acc_fn(carry, mbatch):
                g_acc, l_acc = carry
                (loss, _), grads = grads_of(params, mbatch["inputs"],
                                            mbatch["labels"])
                g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                     g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = lax_scan_named(acc_fn, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            aux = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return train_step


def lax_scan_named(f, init, xs):
    import jax.lax as lax
    return lax.scan(f, init, xs)


def make_prefill_step(cfg: ModelConfig, max_len: int):
    lm = LM(cfg)

    def prefill_step(params, inputs):
        return lm.prefill(params, inputs, max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    lm = LM(cfg)

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens)

    return serve_step


# ---------------------------------------------------------------------------
# Lowering helper used by the dry-run and the launchers
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               opt_cfg: Optional[AdamWConfig] = None, *,
               serve_sharding: str = "train",
               n_micro: Optional[int] = None,
               remat: Optional[str] = None,
               bf16_params: bool = False,
               moe_ffn_data: bool = False):
    """Lower the step function for one (arch x shape) cell on `mesh`.

    Perf-iteration knobs (Sec. Perf of EXPERIMENTS.md):
      serve_sharding="tp" : serve-time resharded weights (fold the data
        axes into TP; no per-step weight all-gathers) for prefill/decode.
      n_micro : override the config's gradient-accumulation count.
      remat   : override the config's remat policy ("none" | "full").

    Returns the jax `Lowered` object (call .compile() on it).
    """
    if remat is not None:
        cfg = cfg.scaled(remat=remat)
    opt_cfg = opt_cfg or AdamWConfig(
        moment_dtype="bfloat16" if cfg.name == "qwen3-moe-235b-a22b"
        else "float32")
    if bf16_params:
        import dataclasses as _dc
        opt_cfg = _dc.replace(opt_cfg, bf16_params=True)
    serve = (serve_sharding == "tp" and shape.kind != "train")
    specs = input_specs(cfg, shape)
    params_abs, opt_abs = abstract_state(
        cfg, opt_cfg if shape.kind == "train" else None)
    if bf16_params:
        # working params stored bf16; fp32 master lives in opt state
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 and len(s.shape) >= 2 else s,
            params_abs)
    p_sh = _named(sh.tree_pspecs(params_abs, mesh, serve=serve,
                                 moe_ffn_data=moe_ffn_data), mesh)
    b_sh = batch_shardings(cfg, shape, mesh)

    with mesh, sh.use_mesh(mesh):
        if shape.kind == "train":
            o_sh = _named(sh.tree_pspecs(opt_abs, mesh,
                                         moe_ffn_data=moe_ffn_data), mesh)
            if n_micro is not None:
                cfg = cfg.scaled(microbatch=n_micro)
            n_micro = effective_microbatches(cfg, shape.global_batch, mesh)
            step = make_train_step(cfg, opt_cfg, n_micro)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, {"inputs": b_sh["inputs"],
                                           "labels": b_sh["labels"]}),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1))
            return jitted.lower(params_abs, opt_abs, specs)
        if shape.kind == "prefill":
            step = make_prefill_step(cfg, shape.seq_len)
            c_abs = jax.eval_shape(
                functools.partial(LM(cfg).init_cache, shape.global_batch,
                                  shape.seq_len))
            c_sh = _named(cache_pspecs(c_abs, mesh), mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh["inputs"]),
                             out_shardings=(None, c_sh))
            return jitted.lower(params_abs, specs["inputs"])
        # decode
        step = make_decode_step(cfg)
        c_sh = _named(cache_pspecs(specs["cache"], mesh), mesh)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, c_sh, b_sh["tokens"]),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
        return jitted.lower(params_abs, specs["cache"], specs["tokens"])
