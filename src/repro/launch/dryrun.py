import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory / cost / collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k [--multi-pod] [--out benchmarks/results]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell

The per-cell JSON artifacts feed benchmarks/roofline.py and
EXPERIMENTS.md Sec. Dry-run / Sec. Roofline.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config, supported_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.models.config import SHAPES

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO,
    keyed by op kind; also record per-op replica-group sizes."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    group_sizes = []
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"^[%\w.\-]*\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        result_sig, opname = m.group(1), m.group(2)
        kind = None
        for k in COLLECTIVES:
            # match sync ops, versioned ops ("all-gather.1") and async
            # starts; skip "-done" halves so async pairs count once.
            if opname == k or opname.startswith(k + ".") or \
                    opname == k + "-start":
                kind = k
                break
        if opname.endswith("-done"):
            continue
        if kind is None:
            continue
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(result_sig)
        gm = re.search(r"replica_groups=\{\{([\d,]+)\}", ls)
        if gm:
            group_sizes.append(len(gm.group(1).split(",")))
    out["group_sizes"] = sorted(set(group_sizes))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, *, serve_sharding: str = "train",
             n_micro=None, remat=None, bf16_params: bool = False,
             moe_ffn_data: bool = False, kv_quant: bool = False,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    if kv_quant:
        cfg = cfg.scaled(kv_quant=True)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, serve_sharding=serve_sharding,
                         n_micro=n_micro, remat=remat,
                         bf16_params=bf16_params,
                         moe_ffn_data=moe_ffn_data)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "variant": {"serve_sharding": serve_sharding, "n_micro": n_micro,
                    "remat": remat},
    }
    if tag:
        shape_name = f"{shape_name}.{tag}"
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                rec[attr] = int(v)
    except Exception as e:  # pragma: no cover - backend specific
        rec["memory_analysis_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       ("flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = str(e)
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["hlo_bytes"] = len(hlo)
    os.makedirs(out_dir, exist_ok=True)
    if save_hlo:
        with open(os.path.join(out_dir, f"{arch}.{shape_name}."
                               f"{rec['mesh']}.hlo"), "w") as f:
            f.write(hlo)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}.{shape_name}.{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a.replace("_", "-") for a in ARCH_IDS]
                    + ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--serve-sharding", choices=("train", "tp"),
                    default="train",
                    help="'tp' = serve-time resharded weights (Sec. Perf)")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--remat", choices=("none", "full"), default=None)
    ap.add_argument("--bf16-params", action="store_true",
                    help="bf16 storage params + fp32 master in opt state")
    ap.add_argument("--moe-ffn-shard", action="store_true",
                    help="shard expert FFN dim (not D) over the data axis")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode cells (Perf A3)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output artifact filename")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for s in supported_shapes(get_config(arch)):
                cells.append((arch, s, False))
                cells.append((arch, s, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, s, mp in cells:
        tag = f"{arch} x {s} x {'2x16x16' if mp else '16x16'}"
        try:
            rec = run_cell(arch, s, mp, args.out, args.save_hlo,
                           serve_sharding=args.serve_sharding,
                           n_micro=args.n_micro, remat=args.remat,
                           bf16_params=args.bf16_params,
                           moe_ffn_data=args.moe_ffn_shard,
                           kv_quant=args.kv_quant, tag=args.tag)
            flops = rec.get("cost", {}).get("flops", -1)
            print(f"OK   {tag}: compile={rec['compile_s']}s "
                  f"flops={flops:.3e} "
                  f"temp={rec.get('temp_size_in_bytes', -1)/2**30:.2f}GiB")
        except Exception:
            failures += 1
            print(f"FAIL {tag}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
