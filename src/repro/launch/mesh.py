"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod : 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model"); the
"pod" axis carries only data parallelism + gradient all-reduce, so the
cross-pod (DCN-class) link never sees layer-granular collectives.

Defined as functions so importing the module never touches jax device
state (device count is locked on first jax init; the dry-run sets
XLA_FLAGS before any import).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)}; the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over however many devices exist (CPU tests)."""
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
