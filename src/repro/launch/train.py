"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt

On a real TPU deployment the same entry point runs per host under
`jax.distributed.initialize()` (multi-controller); on this CPU container
use --smoke (reduced config, 1-device debug mesh).  Production mesh
selection (16x16 / 2x16x16) and sharding live in mesh.py/steps.py; the
recommended XLA flags for collective overlap are below.
"""
from __future__ import annotations

import argparse
import os

# Latency-hiding collective flags for real TPU runs (harmless on CPU).
TPU_XLA_FLAGS = " ".join([
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_enable_async_all_gather=true",
])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + debug mesh (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if os.environ.get("TPU_WORKER_ID"):
        os.environ.setdefault("XLA_FLAGS", TPU_XLA_FLAGS)
        import jax
        jax.distributed.initialize()

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import TokenDataset
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.optim.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    mesh = make_debug_mesh() if args.smoke else \
        make_production_mesh(multi_pod=args.multi_pod)
    ds = TokenDataset(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=args.seed,
                      embed_dim=cfg.d_model if cfg.embed_input else None)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, seed=args.seed)
    trainer = Trainer(cfg, mesh, ds,
                      AdamWConfig(lr=args.lr, total_steps=args.steps),
                      tcfg)
    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}")


if __name__ == "__main__":
    main()
