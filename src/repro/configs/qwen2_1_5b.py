"""Qwen2-1.5B: 28L d1536, 12H GQA(kv=2) hd128, d_ff 8960, QKV bias,
vocab 151936.  [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, d_ff=8960, vocab=151936,
    n_heads=12, n_kv_heads=2, head_dim=128, qkv_bias=True,
    rope_theta=1e6, act="swiglu", tie_embeddings=True,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=128, vocab=512,
                      n_heads=4, n_kv_heads=2, head_dim=16,
                      attn_chunk=32, loss_chunk=32)
