"""Qwen3-0.6B: 28L d1024, 16H GQA(kv=8) hd128, d_ff 3072, vocab 151936,
qk_norm.  [hf:Qwen/Qwen3-0.6B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, d_ff=3072, vocab=151936,
    n_heads=16, n_kv_heads=8, head_dim=128, qk_norm=True,
    rope_theta=1e6, act="swiglu", tie_embeddings=True,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=128, vocab=512,
                      n_heads=4, n_kv_heads=2, head_dim=16,
                      attn_chunk=32, loss_chunk=32)
