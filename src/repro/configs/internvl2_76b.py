"""InternVL2-Llama3-76B language backbone: 80L d8192, 64H GQA(kv=8) hd128,
d_ff 28672, vocab 128256.  The InternViT frontend is a STUB for the
dry-run (`input_specs()` provides precomputed patch embeddings); the
patchify module itself (stride-14 conv with EcoFlow zero-free backward)
lives in repro.models.vision.  [arXiv:2404.16821; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, d_ff=28672, vocab=128256,
    n_heads=64, n_kv_heads=8, head_dim=128,
    rope_theta=5e5, act="swiglu", embed_input=True,
    tie_embeddings=False,
    microbatch=16,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=128, vocab=512,
                      n_heads=4, n_kv_heads=2, head_dim=16,
                      attn_chunk=32, loss_chunk=32)
