"""MusicGen-medium backbone: 48L d1536, 24H MHA(kv=24) hd64, d_ff 6144
(gelu), vocab 2048 (EnCodec codebook).  The EnCodec frontend is a STUB:
`input_specs()` provides precomputed frame embeddings (B,S,D).
[arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, d_ff=6144, vocab=2048,
    n_heads=24, n_kv_heads=24, head_dim=64,
    rope_theta=1e4, act="gelu", embed_input=True,
    tie_embeddings=False,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=128, vocab=256,
                      n_heads=4, n_kv_heads=4, head_dim=16,
                      attn_chunk=32, loss_chunk=32)
