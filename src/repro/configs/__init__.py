"""Architecture registry: `get_config(arch_id)` and `REGISTRY`.

One module per assigned architecture (exact public configs) plus the paper's
own CNN/GAN evaluation domain (`paper_cnn`, `paper_gan`).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = [
    "qwen3_moe_235b_a22b",
    "moonshot_v1_16b_a3b",
    "rwkv6_7b",
    "qwen3_0_6b",
    "qwen2_1_5b",
    "gemma_2b",
    "gemma_7b",
    "musicgen_medium",
    "internvl2_76b",
    "zamba2_2_7b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma-2b": "gemma_2b",
    "gemma-7b": "gemma_7b",
    "musicgen-medium": "musicgen_medium",
    "internvl2-76b": "internvl2_76b",
    "zamba2-2.7b": "zamba2_2_7b",
})


def get_config(arch: str) -> ModelConfig:
    arch_mod = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch_mod}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch_mod = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch_mod}")
    return mod.SMOKE


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Which assigned shape cells apply to this arch (long_500k only for
    sub-quadratic families, per the assignment)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return shapes
