"""Qwen3-MoE-235B-A22B: 94L d4096, 64H GQA(kv=4) hd128, MoE 128e top-8
d_ff_expert=1536, vocab 151936, qk_norm.  [hf:Qwen/Qwen3-235B-A22B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, d_ff=1536, vocab=151936,
    n_heads=64, n_kv_heads=4, head_dim=128, qk_norm=True,
    rope_theta=1e6, act="swiglu",
    n_experts=128, top_k=8, moe_dff=1536,
    tie_embeddings=False,
    microbatch=16,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=96, vocab=512,
                      n_heads=4, n_kv_heads=2, head_dim=16,
                      n_experts=8, top_k=2, moe_dff=96, capacity_factor=4.0,
                      attn_chunk=32, loss_chunk=32)
