"""Gemma-7B: 28L d3072, 16H MHA(kv=16) hd256, GeGLU d_ff 24576,
vocab 256000.  [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, d_ff=24576, vocab=256000,
    n_heads=16, n_kv_heads=16, head_dim=256,
    rope_theta=1e4, act="geglu", tie_embeddings=True,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=256, vocab=512,
                      n_heads=4, n_kv_heads=4, head_dim=16,
                      attn_chunk=32, loss_chunk=32)
