"""Moonshot/Moonlight-16B-A3B: 48L d2048, 16H MHA(kv=16) hd128, MoE 64e
top-6 d_ff_expert=1408 + 2 shared experts, vocab 163840.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, d_ff=1408, vocab=163840,
    n_heads=16, n_kv_heads=16, head_dim=128,
    rope_theta=5e4, act="swiglu",
    n_experts=64, top_k=6, moe_dff=1408, n_shared_experts=2,
    tie_embeddings=False,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=96, vocab=512,
                      n_heads=4, n_kv_heads=4, head_dim=16,
                      n_experts=8, top_k=2, moe_dff=96, n_shared_experts=1, capacity_factor=4.0,
                      attn_chunk=32, loss_chunk=32)
