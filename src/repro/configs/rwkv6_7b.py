"""RWKV6 (Finch) 7B: 32L d4096, attn-free, data-dependent per-channel
decay, head_size 64 (64 heads), channel-mix d_ff 14336, vocab 65536.
[arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, d_ff=14336, vocab=65536,
    ssm_head_dim=64, chunk_size=16,
    tie_embeddings=False,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=224, vocab=512,
                      ssm_head_dim=16, loss_chunk=32)
