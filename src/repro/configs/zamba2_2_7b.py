"""Zamba2-2.7B: 54 Mamba2 blocks d2560 (d_inner 5120, heads 80 x hd64,
ssm_state 64, conv k4) + one shared-weight attention block (32H MHA hd80,
d_ff 10240) applied every 6 Mamba blocks, vocab 32000.
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, d_ff=10240, vocab=32000,
    n_heads=32, n_kv_heads=32, head_dim=80,
    rope_theta=1e4, act="geglu",
    ssm_state=64, ssm_heads=80, ssm_head_dim=64, ssm_conv=4, ssm_expand=2,
    chunk_size=16, attn_every=6, tie_embeddings=True,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, d_ff=128, vocab=512,
                      n_heads=4, n_kv_heads=4, head_dim=16,
                      ssm_state=16, ssm_head_dim=16, attn_every=2,
                      attn_chunk=32, loss_chunk=32)
