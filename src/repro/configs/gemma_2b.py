"""Gemma-2B: 18L d2048, 8H MQA(kv=1) hd256, GeGLU d_ff 16384,
vocab 256000.  [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, d_ff=16384, vocab=256000,
    n_heads=8, n_kv_heads=1, head_dim=256,
    rope_theta=1e4, act="geglu", tie_embeddings=True,
    microbatch=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, d_ff=256, vocab=512,
                      n_heads=4, n_kv_heads=1, head_dim=16,
                      attn_chunk=32, loss_chunk=32)
