"""Naive (materialized-zero) baselines for transposed and dilated convs.

These reproduce what a CNN-inference accelerator does when handed a
transposed/dilated convolution (paper Sec. 3.1): insert `S-1` zero rows/cols
into the error map (inner padding), add `K-1` border zeros (outer padding),
then run a plain direct convolution.  The zero multiplications are real work
on the array (the paper's baselines clock-gate them for energy but still
spend the cycles).

They serve as (a) correctness oracles for the zero-free EcoFlow path and
(b) the MAC/cycle baselines for the dataflow simulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.ecoflow import DN, _pair
from repro.core.ecoflow import direct_conv as ecoflow_direct_conv


def dilate_insert_zeros(x: jax.Array, stride) -> jax.Array:
    """Insert (S-1) zeros between spatial elements of NHWC x."""
    sh, sw = _pair(stride)
    if sh == 1 and sw == 1:
        return x
    B, H, W, C = x.shape
    out = jnp.zeros((B, sh * (H - 1) + 1, sw * (W - 1) + 1, C), x.dtype)
    return out.at[:, ::sh, ::sw, :].set(x)


def dilate_filter_insert_zeros(w: jax.Array, dilation) -> jax.Array:
    """Materialize an HWIO filter at its effective receptive field: insert
    (D-1) zeros between taps, yielding (D*(K-1)+1, ...) spatial extent."""
    dh, dw = _pair(dilation)
    if dh == 1 and dw == 1:
        return w
    Kh, Kw, Ci, Co = w.shape
    out = jnp.zeros((dh * (Kh - 1) + 1, dw * (Kw - 1) + 1, Ci, Co), w.dtype)
    return out.at[::dh, ::dw].set(w)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "dilation"))
def dilated_forward_naive(x: jax.Array, w: jax.Array, *, stride=1, padding=0,
                          dilation=2) -> jax.Array:
    """Dilated (atrous) forward conv via an explicitly materialized dilated
    filter + plain direct conv -- what a CNN-inference accelerator does when
    handed an atrous layer: every inserted filter zero is a scheduled MAC."""
    w_dil = dilate_filter_insert_zeros(w, dilation)
    return ecoflow_direct_conv(x, w_dil, stride, padding)


def dilated_forward_zero_mac_fraction(k: int, dilation: int) -> float:
    """Fraction of MACs that touch an inserted filter zero in the naive
    dilated forward conv: every K_eff x K_eff window position spends
    K_eff^2 MACs of which only K^2 touch real taps (exact -- filter zeros
    are zeros at every window position)."""
    k_eff = dilation * (k - 1) + 1
    return 1.0 - (k * k) / (k_eff * k_eff)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out"))
def transposed_conv_naive(dy: jax.Array, w: jax.Array, *, stride, padding=0,
                          n_out=None) -> jax.Array:
    """Transposed conv via explicit zero insertion + border padding + direct
    conv with the 180deg-rotated filter.  (B,O,O,Cout) -> (B,N,N,Cin)."""
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    B, Oh, Ow, Cout = dy.shape
    Kh, Kw, Cin, _ = w.shape
    if n_out is None:
        n_out = (sh * (Oh - 1) + Kh - 2 * ph, sw * (Ow - 1) + Kw - 2 * pw)
    Nh, Nw = n_out
    dy_dil = dilate_insert_zeros(dy, (sh, sw))
    # 180deg-rotated filter, channels swapped to map Cout -> Cin.
    w_rot = jnp.swapaxes(jnp.flip(w, axis=(0, 1)), 2, 3)
    full = lax.conv_general_dilated(
        dy_dil, w_rot, window_strides=(1, 1),
        padding=[(Kh - 1, Kh - 1), (Kw - 1, Kw - 1)],
        dimension_numbers=DN, preferred_element_type=jnp.float32,
    ).astype(dy.dtype)
    eh = max(0, ph + Nh - full.shape[1])
    ew = max(0, pw + Nw - full.shape[2])
    if eh or ew:
        full = jnp.pad(full, ((0, 0), (0, eh), (0, ew), (0, 0)))
    return full[:, ph:ph + Nh, pw:pw + Nw, :]


@functools.partial(jax.jit, static_argnames=("stride", "padding", "k"))
def dilated_conv_filter_grad_naive(x: jax.Array, dy: jax.Array, *, stride,
                                   padding=0, k=None) -> jax.Array:
    """Filter gradient via explicit zero-dilation of dy used as the filter of
    a direct convolution over (padded) x."""
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    Kh, Kw = k
    B, Nh, Nw, Cin = x.shape
    dy_dil = dilate_insert_zeros(dy, (sh, sw))          # (B, Dh, Dw, Cout)
    # Treat x as a batch-of-channel images and dy_dil as filters:
    # dW[kx,ky,ci,co] = sum_b conv(x[..,ci], dy_dil[b,..,co]) at offset kx,ky.
    # Express with conv_general_dilated: lhs (Cin, Nh, Nw, B) "N"=Cin feature
    # maps, rhs (Dh, Dw, B, Cout) -- contraction over batch.
    lhs = jnp.transpose(x, (3, 1, 2, 0))                 # Cin,H,W,B
    rhs = jnp.transpose(dy_dil, (1, 2, 0, 3))            # Dh,Dw,B,Cout
    out = lax.conv_general_dilated(
        lhs, rhs, window_strides=(1, 1), padding=[(ph, ph), (pw, pw)],
        dimension_numbers=DN, preferred_element_type=jnp.float32,
    )                                                    # Cin,Kh,Kw,Cout
    out = jnp.transpose(out, (1, 2, 0, 3))[:Kh, :Kw]
    return out.astype(x.dtype)
