"""EcoFlow compile-time mapping (paper Sec. 4.1.1 / 4.2.1), faithful form.

The paper's compiler:
  1. forms the *symbolic outer product* of the (rotated) filter vector and the
     error vector -- every useful MAC, with no padding zeros;
  2. *labels* each product with the output element it accumulates into;
  3. assigns each error element's product column to a PE (one PE per error
     element), then *reorganizes* products (circular shifts / multicast
     groups) so that all products sharing a label sit in one PE column and can
     be reduced over the vertical point-to-point links;
  4. emits per-PE FSMs: an ordered MAC schedule + multicast subscriptions +
     "pass psum up" events.

This module builds that schedule explicitly (for the transposed and the
dilated convolution) and *functionally simulates* the PE array executing it:
local accumulation registers, vertical psum hops, per-cycle weight broadcast.
The simulation is used by tests to prove the dataflow computes the exact
convolution, and by the dataflow simulator to count cycles.

Notation follows Fig. 5/7: error e (O x O), forward filter w (K x K),
stride S, output gradient (N x N) with N = S*(O-1) + K (VALID, P=0).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

Label = Tuple[int, int]
Product = Tuple[int, int, int, int]  # (a, b, i, j): w[a,b] * e[i,j]


@dataclasses.dataclass
class PESchedule:
    """Per-PE FSM: ordered ops + multicast subscriptions + psum chain."""
    ops: List[Tuple[Product, Label]]
    multicast: set  # error elements (i, j) this PE must receive
    # labels whose final accumulation this PE owns (writes to memory):
    owned_labels: set


@dataclasses.dataclass
class TConvMapping:
    stride: int
    k: int
    err_n: int
    out_n: int
    pe_rows: int
    pe_cols: int
    pes: Dict[Tuple[int, int], PESchedule]
    # label -> ordered list of contributing PE coords (bottom-up chain)
    chains: Dict[Label, List[Tuple[int, int]]]

    @property
    def n_useful_macs(self) -> int:
        return sum(len(p.ops) for p in self.pes.values())

    def cycle_count(self) -> int:
        """Weights are broadcast sequentially (one w[a,b] per cycle, paper
        Sec. 4.1.2); a PE fires every cycle its subscribed error element
        pairs with the broadcast weight.  Vertical psum hops add one cycle
        per chain link after the last contributing MAC."""
        mac_cycles = self.k * self.k * max(
            1, max((len(p.multicast) for p in self.pes.values()), default=1))
        hop_cycles = max((len(c) - 1 for c in self.chains.values()), default=0)
        return mac_cycles + hop_cycles


def tconv_products(err_n: int, k: int, stride: int):
    """Symbolic outer product + labels for the transposed convolution.

    Product (a,b,i,j) contributes to output label (S*i + a, S*j + b).
    This is the zero-free MAC set: |filter| x |error| products, none zero.
    """
    for a in range(k):
        for b in range(k):
            for i in range(err_n):
                for j in range(err_n):
                    yield (a, b, i, j), (stride * i + a, stride * j + b)


def build_tconv_mapping(err_n: int, k: int, stride: int) -> TConvMapping:
    """EcoFlow mapping: PE array sized O x O (one PE per error element).

    All products with label L are assigned to the PE *column* of the
    largest-j contributor (the paper's circular shift serves the same
    purpose: aligning co-accumulating products vertically); within the
    column each product executes on the row of its error element, so the
    vertical point-to-point links reduce the label bottom-up.
    """
    out_n = stride * (err_n - 1) + k
    pes: Dict[Tuple[int, int], PESchedule] = {
        (r, c): PESchedule([], set(), set())
        for r in range(err_n) for c in range(err_n)}
    by_label: Dict[Label, List[Product]] = defaultdict(list)
    for prod, label in tconv_products(err_n, k, stride):
        by_label[label].append(prod)
    chains: Dict[Label, List[Tuple[int, int]]] = {}
    for label, prods in by_label.items():
        col = max(p[3] for p in prods)  # owner column (circular-shift target)
        rows = sorted({p[2] for p in prods}, reverse=True)  # bottom-up
        chains[label] = [(r, col) for r in rows]
        for (a, b, i, j) in prods:
            pe = pes[(i, col)]
            pe.ops.append(((a, b, i, j), label))
            pe.multicast.add((i, j))
        pes[(rows[-1], col)].owned_labels.add(label)
    # Order ops by weight broadcast sequence (w row-major), paper Sec. 4.1.2.
    for pe in pes.values():
        pe.ops.sort(key=lambda ol: (ol[0][0], ol[0][1]))
    return TConvMapping(stride, k, err_n, out_n, err_n, err_n, pes, chains)


def simulate_tconv(mapping: TConvMapping, err: np.ndarray, w: np.ndarray
                   ) -> np.ndarray:
    """Functionally execute the mapped dataflow on a PE array model.

    Each PE multiplies broadcast weights with multicast error elements per
    its FSM, accumulates per-label in a local register, and passes partial
    sums up the column; the chain head writes the output.  Proves the
    mapping computes the exact (zero-free) transposed convolution.
    """
    k, s = mapping.k, mapping.stride
    out = np.zeros((mapping.out_n, mapping.out_n), dtype=np.float64)
    # Local accumulation registers: (pe, label) -> value.
    acc: Dict[Tuple[Tuple[int, int], Label], float] = defaultdict(float)
    for (r, c), pe in mapping.pes.items():
        for (a, b, i, j), label in pe.ops:
            assert (i, j) in pe.multicast  # multicast subscription honored
            acc[((r, c), label)] += float(w[a, b]) * float(err[i, j])
    # Vertical psum reduction, bottom-up along each chain.
    for label, chain in mapping.chains.items():
        psum = 0.0
        for pe_coord in chain:  # chain is bottom-up
            psum += acc.pop((pe_coord, label), 0.0)
        head = chain[-1]
        assert label in mapping.pes[head].owned_labels
        out[label] = psum
    assert not acc, "all partial sums must be consumed by a chain"
    return out


# ---------------------------------------------------------------------------
# Dilated convolution (filter-gradient) mapping, paper Sec. 4.2
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DConvMapping:
    stride: int
    k: int          # filter-gradient spatial size (output of this conv)
    err_n: int      # error map size (the "filter" of the dilated conv)
    in_n: int       # ifmap size
    pes: Dict[Tuple[int, int], PESchedule]

    @property
    def n_useful_macs(self) -> int:
        return sum(len(p.ops) for p in self.pes.values())

    def cycle_count(self) -> int:
        # Errors broadcast sequentially; each PE fires once per broadcast
        # (every PE uses every error element exactly once per 2D slice).
        return max(len(p.ops) for p in self.pes.values())


def build_dconv_mapping(in_n: int, err_n: int, k: int, stride: int
                        ) -> DConvMapping:
    """One PE per filter-gradient element (paper Fig. 7): PE (kx,ky)
    accumulates  sum_{i,j} x[i*S+kx, j*S+ky] * e[i,j]  locally -- no inter-PE
    communication; the ifmap multicast groups are the strided gathers."""
    pes: Dict[Tuple[int, int], PESchedule] = {}
    for kx in range(k):
        for ky in range(k):
            pe = PESchedule([], set(), set())
            for i in range(err_n):
                for j in range(err_n):
                    xi, xj = i * stride + kx, j * stride + ky
                    if xi < in_n and xj < in_n:
                        pe.ops.append((((xi, xj, i, j)), (kx, ky)))
                        pe.multicast.add((xi, xj))
            pe.owned_labels.add((kx, ky))
            pes[(kx, ky)] = pe
    return DConvMapping(stride, k, err_n, in_n, pes)


def simulate_dconv(mapping: DConvMapping, x: np.ndarray, err: np.ndarray
                   ) -> np.ndarray:
    dw = np.zeros((mapping.k, mapping.k), dtype=np.float64)
    for (kx, ky), pe in mapping.pes.items():
        s = 0.0
        for (xi, xj, i, j), label in pe.ops:
            assert label == (kx, ky)
            s += float(x[xi, xj]) * float(err[i, j])
        dw[kx, ky] = s
    return dw


# ---------------------------------------------------------------------------
# Grouping and expansion (paper Sec. 4.1.1): fitting logical PE sets onto a
# fixed physical array.
# ---------------------------------------------------------------------------

def group_pe_sets(mapping: TConvMapping, pe_rows: int, pe_cols: int):
    """*Grouping*: pack several logical PE sets (channel/filter copies of
    the O x O set) side by side on a physical `pe_rows x pe_cols` array.

    Returns (sets_per_pass, occupancy): how many independent 2D
    convolutions run concurrently in one processing pass and the fraction
    of physical PEs they occupy.  This is the quantity the dataflow
    simulator's `_frag` models; exposed here so tests can pin it against
    the closed form.
    """
    r, c = mapping.pe_rows, mapping.pe_cols
    if r > pe_rows or c > pe_cols:
        return 0, 0.0
    fit = (pe_rows // r) * (pe_cols // c)
    occupancy = fit * r * c / (pe_rows * pe_cols)
    return fit, occupancy


def expand_tconv_mapping(mapping: TConvMapping, pe_rows: int, pe_cols: int
                         ) -> "TConvMapping":
    """*Expansion*: split a logical PE set larger than the physical array
    into column tiles executed as sequential passes.

    The paper expands along the error-matrix columns: each pass owns a
    contiguous slice of error columns; psum chains never cross column
    tiles (chains are vertical, see build_tconv_mapping), so the split is
    communication-free.  Returns a mapping whose schedules carry a
    `pass_id` ordering -- functionally identical MAC set, same chains.
    """
    if mapping.err_n <= pe_cols and mapping.err_n <= pe_rows:
        return mapping
    n_col_tiles = -(-mapping.err_n // pe_cols)
    n_row_tiles = -(-mapping.err_n // pe_rows)
    # Re-emit schedules with pass-major op ordering.  Physical PE (r, c)
    # executes logical PEs (r + i*pe_rows, c + j*pe_cols) over passes.
    pes: Dict[Tuple[int, int], PESchedule] = {}
    for (lr, lc), sched in mapping.pes.items():
        pr, pc = lr % pe_rows, lc % pe_cols
        pass_id = (lr // pe_rows) * n_col_tiles + (lc // pe_cols)
        dst = pes.setdefault((pr, pc), PESchedule([], set(), set()))
        for op in sched.ops:
            dst.ops.append(op)
        dst.multicast |= sched.multicast
        dst.owned_labels |= sched.owned_labels
        del pass_id  # ordering is by logical tile traversal above
    return TConvMapping(mapping.stride, mapping.k, mapping.err_n,
                        mapping.out_n, pe_rows, pe_cols, pes,
                        mapping.chains)


def simulate_tconv_expanded(mapping: TConvMapping, err, w):
    """Functional simulation of an expanded mapping (multi-pass): the MAC
    set and label chains are unchanged, so the plain simulator applies."""
    return simulate_tconv(mapping, err, w)
