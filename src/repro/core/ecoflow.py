"""EcoFlow zero-free dataflows for transposed and dilated convolutions.

This is the TPU-native adaptation of the paper's core contribution
(Orosa et al., "EcoFlow", 2022).  The paper eliminates the zero padding that
stride>1 introduces into (a) transposed convolutions (input-gradient
computation / GAN generators) and (b) dilated convolutions (filter-gradient
computation) by enumerating, at compile time, only the *useful* MACs and
mapping them onto the PE array.

On TPU the algebraic equivalent is *phase decomposition*:

  Transposed conv (stride S):
      dx[S*x+p, S*y+q] = sum_{a,b} dy[x-a, y-b] * W[a*S+p, b*S+q]
  i.e. the output interleaves S*S dense stride-1 convolutions of the un-padded
  error `dy` with 180deg-rotated *sub-filters* W_pq.  No zero is ever stored,
  moved, or multiplied -- exactly the MAC set the paper's symbolic outer
  product enumerates, regrouped into MXU-sized matmuls.

  Dilated conv (rate S, filter-gradient form):
      dW[kx,ky] = sum_{b,i,j} x[b, i*S+kx-P, j*S+ky-P] * dy[b,i,j]
  i.e. one strided gather of x per filter tap, contracted with dy as a
  (Cin x B*O*O) @ (B*O*O x Cout) matmul.  The dilated (zero-inserted) error
  tensor is never materialized.

  Dilated FORWARD conv (atrous rate D, segmentation workloads):
      y[i,j] = sum_{a,b} x[i*S + a*D - P, j*S + b*D - P] * W[a,b]
  i.e. one stride-strided gather of x per *useful* filter tap, contracted
  with the undilated tap as a (B*O*O x Cin) @ (Cin x Cout) matmul.  The
  D-dilated filter (K_eff = D*(K-1)+1 extent, mostly zeros) is never
  materialized; its adjoints (input/filter gradients) are the per-tap
  scatter/gather duals below.

Layouts: NHWC activations, HWIO filters (forward filter maps Cin->Cout).
All functions are jit-compatible with static stride/shape arguments.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.spec import ConvSpec, _pair  # geometry lives in spec.py

# Dimension numbers for NHWC/HWIO direct convolutions.
DN = ("NHWC", "HWIO", "NHWC")


def direct_conv(x: jax.Array, w: jax.Array, stride=1, padding=0,
                *, dilation=1, preferred_dtype=jnp.float32) -> jax.Array:
    """Plain direct (forward) convolution, NHWC x HWIO -> NHWC.

    `dilation` is the forward filter (rhs) dilation -- XLA's own dilated
    conv, the ground truth the zero-free dataflows are checked against.
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    return lax.conv_general_dilated(
        x, w, window_strides=(sh, sw), padding=[(ph, ph), (pw, pw)],
        rhs_dilation=(dh, dw),
        dimension_numbers=DN, preferred_element_type=preferred_dtype,
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Zero-free transposed convolution (input gradients / GAN generator layers)
# ---------------------------------------------------------------------------

def phase_subfilters(w: jax.Array, stride) -> list[list[jax.Array]]:
    """Split filter (K,K,Cin,Cout) into S*S rotated sub-filters.

    Sub-filter (p,q) has entries W[a*S+p, b*S+q] and is spatially flipped so
    that each phase becomes a stride-1 *correlation* (lax conv) of dy.
    Returned with channels transposed to map Cout->Cin (HWIO with I=Cout).
    """
    sh, sw = _pair(stride)
    out = []
    for p in range(sh):
        row = []
        for q in range(sw):
            sub = w[p::sh, q::sw]                      # (Kp, Kq, Cin, Cout)
            sub = jnp.flip(sub, axis=(0, 1))           # rotate 180deg
            sub = jnp.swapaxes(sub, 2, 3)              # (Kp, Kq, Cout, Cin)
            row.append(sub)
        out.append(row)
    return out


def transposed_conv_input_size(out_size: int, k: int, stride: int,
                               padding: int) -> int:
    """Forward-conv input length N given output length O (exact fit).
    Thin wrapper over `ConvSpec.input_size` (kept for callers that think
    in scalars)."""
    spec = ConvSpec.make(stride=stride, padding=padding, filter_shape=k)
    return spec.input_size((out_size, out_size))[0]


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out",
                                             "dilation"))
def transposed_conv_zero_free(dy: jax.Array, w: jax.Array, *, stride,
                              padding=0, n_out: tuple[int, int] | None = None,
                              dilation=1) -> jax.Array:
    """Zero-free transposed convolution (EcoFlow dataflow, dense form).

    Computes the gradient w.r.t. the input of `direct_conv(x, w, stride,
    padding, dilation)`, equivalently a transposed conv / deconvolution
    upsampling `dy`.

    Args:
      dy:  (B, Oh, Ow, Cout) error / generator input.
      w:   (Kh, Kw, Cin, Cout) forward filter.
      stride: forward stride S (upsampling factor).
      padding: forward padding P.
      n_out: (Nh, Nw) output (= forward input) spatial size.  Defaults to the
        exact-fit size S*(O-1)+K_eff-2P.
      dilation: forward filter dilation D.  At D == 1 the stride-phase
        decomposition below runs; at D > 1 the adjoint is computed by
        per-tap strided scatter-adds (`_dilated_transposed_zero_free`) --
        no dilation zero of either kind is ever materialized.
    Returns: (B, Nh, Nw, Cin).
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Oh, Ow, Cout = dy.shape
    Kh, Kw, Cin, _ = w.shape
    if n_out is None:
        spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                             filter_shape=(Kh, Kw), dilation=(dh, dw))
        n_out = spec.input_size((Oh, Ow))
    if (dh, dw) != (1, 1):
        return _dilated_transposed_zero_free(
            dy, w, stride=(sh, sw), padding=(ph, pw), dilation=(dh, dw),
            n_out=tuple(n_out))
    Nh, Nw = n_out
    # Full (pre-padding-slice) output size.
    Fh, Fw = sh * (Oh - 1) + Kh, sw * (Ow - 1) + Kw

    subs = phase_subfilters(w, (sh, sw))
    dx_full = jnp.zeros((B, Fh, Fw, Cin), dtype=dy.dtype)
    for p in range(sh):
        for q in range(sw):
            sub = subs[p][q]
            kp, kq = sub.shape[0], sub.shape[1]
            if kp == 0 or kq == 0:
                continue
            # Stride-1 "full" correlation of dy with the rotated sub-filter.
            part = lax.conv_general_dilated(
                dy, sub, window_strides=(1, 1),
                padding=[(kp - 1, kp - 1), (kq - 1, kq - 1)],
                dimension_numbers=DN,
                preferred_element_type=jnp.float32,
            ).astype(dy.dtype)
            # Number of output rows/cols congruent to p/q (mod S).
            xp = -(-(Fh - p) // sh)   # ceil((Fh-p)/S)
            xq = -(-(Fw - q) // sw)
            dx_full = dx_full.at[:, p::sh, q::sw, :].set(part[:, :xp, :xq, :])
    # Non-exact-fit inputs (forward ignored tail rows/cols): zero-pad tail.
    eh = max(0, ph + Nh - Fh)
    ew = max(0, pw + Nw - Fw)
    if eh or ew:
        dx_full = jnp.pad(dx_full, ((0, 0), (0, eh), (0, ew), (0, 0)))
    return dx_full[:, ph:ph + Nh, pw:pw + Nw, :]


# ---------------------------------------------------------------------------
# Zero-free dilated FORWARD convolution (atrous workloads) and its adjoint
# ---------------------------------------------------------------------------

def _tap_slice(xp: jax.Array, kx: int, ky: int, *, stride, dilation,
               out_size) -> jax.Array:
    """Host-side per-tap strided gather (the XLA dual of the in-kernel
    `kernels.tap_gather.gather_tap`): x[b, i*S + kx*D, j*S + ky*D, c] for
    i < Oh, j < Ow out of a padded NHWC input."""
    sh, sw = stride
    dh, dw = dilation
    oh, ow = out_size
    B, _, _, C = xp.shape
    return lax.slice(xp, (0, kx * dh, ky * dw, 0),
                     (B, kx * dh + (oh - 1) * sh + 1,
                      ky * dw + (ow - 1) * sw + 1, C), (1, sh, sw, 1))

@functools.partial(jax.jit, static_argnames=("stride", "padding", "dilation"))
def dilated_forward_zero_free(x: jax.Array, w: jax.Array, *, stride=1,
                              padding=0, dilation=2) -> jax.Array:
    """Zero-free dilated (atrous) forward convolution (EcoFlow dataflow).

        y[b, i, j] = sum_{a,b'} x[b, i*S + a*D - P, j*S + b'*D - P] * w[a, b']

    The naive lowering materializes the filter at its effective receptive
    field K_eff = D*(K-1)+1, with (K_eff^2 - K^2) inserted zeros scheduled
    as real MACs.  Here each of the K^2 *useful* taps instead gathers one
    stride-strided slice of the (once-padded) input and contracts it with
    the undilated filter tap as a (B*O*O x Cin) @ (Cin x Cout) matmul --
    the dilated filter is never materialized.

    Args:
      x:  (B, Nh, Nw, Cin) input.
      w:  (Kh, Kw, Cin, Cout) undilated filter.
      stride: output stride S.
      padding: input padding P.
      dilation: filter dilation D (tap spacing).
    Returns: (B, Oh, Ow, Cout) with O = floor((N + 2P - K_eff)/S) + 1.
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Nh, Nw, Cin = x.shape
    Kh, Kw, _, Cout = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                         filter_shape=(Kh, Kw), dilation=(dh, dw))
    Oh, Ow = spec.out_size((Nh, Nw))
    if Oh < 1 or Ow < 1:   # ValueError, not assert: survives `python -O`
        raise ValueError(
            f"input {(Nh, Nw)} too small for effective filter "
            f"{spec.dilated_filter_shape} at padding {(ph, pw)}")
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    w32 = w.astype(jnp.float32)
    acc = jnp.zeros((B, Oh, Ow, Cout), jnp.float32)
    for kx in range(Kh):
        for ky in range(Kw):
            # One zero-free strided gather per useful tap.
            xs = _tap_slice(xp, kx, ky, stride=(sh, sw),
                            dilation=(dh, dw), out_size=(Oh, Ow))
            acc += jnp.einsum("bijc,cd->bijd", xs.astype(jnp.float32),
                              w32[kx, ky],
                              preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def _dilated_transposed_zero_free(dy: jax.Array, w: jax.Array, *, stride,
                                  padding, dilation,
                                  n_out: tuple[int, int]) -> jax.Array:
    """Input gradient of the dilated forward conv: per-tap strided
    scatter-add (the adjoint of the per-tap gather above).

        dx[b, o*S + k*D - P] += dy[b, o] @ W[k]^T

    Each tap contributes one (B*O*O x Cout) @ (Cout x Cin) matmul written
    at offset k*D with stride S; neither the stride-upsampled error nor
    the dilated filter is materialized."""
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Oh, Ow, Cout = dy.shape
    Kh, Kw, Cin, _ = w.shape
    Nh, Nw = n_out
    Fh = sh * (Oh - 1) + dh * (Kh - 1) + 1   # full (pre-slice) extent
    Fw = sw * (Ow - 1) + dw * (Kw - 1) + 1
    dy32 = dy.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    dx_full = jnp.zeros((B, Fh, Fw, Cin), jnp.float32)
    for kx in range(Kh):
        for ky in range(Kw):
            contrib = jnp.einsum("bijo,co->bijc", dy32, w32[kx, ky],
                                 preferred_element_type=jnp.float32)
            dx_full = dx_full.at[
                :, kx * dh:kx * dh + (Oh - 1) * sh + 1:sh,
                ky * dw:ky * dw + (Ow - 1) * sw + 1:sw, :].add(contrib)
    # Non-exact-fit inputs (forward ignored tail rows/cols): zero-pad tail.
    eh = max(0, ph + Nh - Fh)
    ew = max(0, pw + Nw - Fw)
    if eh or ew:
        dx_full = jnp.pad(dx_full, ((0, 0), (0, eh), (0, ew), (0, 0)))
    return dx_full[:, ph:ph + Nh, pw:pw + Nw, :].astype(dy.dtype)


# ---------------------------------------------------------------------------
# Zero-free dilated convolution (filter gradients)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("stride", "padding", "k",
                                             "dilation"))
def dilated_conv_filter_grad_zero_free(x: jax.Array, dy: jax.Array, *,
                                       stride, padding=0,
                                       k: tuple[int, int] | None = None,
                                       dilation=1) -> jax.Array:
    """Zero-free dilated convolution computing dW (EcoFlow dataflow).

    Gradient w.r.t. the HWIO filter of `direct_conv(x, w, stride, padding,
    dilation)`: for each filter tap (kx, ky), a strided slice of x (at tap
    offset kx*D, ky*D) is contracted with dy.  Equals
    `conv(x, dy_dilated_by_S)` but never materializes the dilation zeros.

    Args:
      x:  (B, Nh, Nw, Cin) forward input.
      dy: (B, Oh, Ow, Cout) output error.
      stride: forward stride S (== dilation rate of the gradient conv).
      padding: forward padding P.
      k: (Kh, Kw) filter spatial size.
      dilation: forward filter dilation D (tap spacing of the gathers).
    Returns: (Kh, Kw, Cin, Cout).
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Nh, Nw, Cin = x.shape
    _, Oh, Ow, Cout = dy.shape
    if k is None:   # ValueError, not assert: survives `python -O`
        raise ValueError("filter size k=(Kh,Kw) is required")
    Kh, Kw = k
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    dy32 = dy.astype(jnp.float32)
    taps = []
    for kx in range(Kh):
        for ky in range(Kw):
            # One zero-free strided gather per useful tap.
            xs = _tap_slice(xp, kx, ky, stride=(sh, sw),
                            dilation=(dh, dw), out_size=(Oh, Ow))
            # (Cin, Cout) matmul with contraction over B*Oh*Ow.
            taps.append(jnp.einsum("bijc,bijd->cd", xs.astype(jnp.float32),
                                   dy32, preferred_element_type=jnp.float32))
    dw = jnp.stack(taps).reshape(Kh, Kw, Cin, Cout)
    return dw.astype(x.dtype)


# ---------------------------------------------------------------------------
# Padding bookkeeping (paper Sec. 3.1 closed forms) -- used by the dataflow
# simulator and by tests.
# ---------------------------------------------------------------------------

def tconv_inner_padding(n: int, stride: int) -> int:
    """# of internal zeros inserted into an N x N error map at stride S."""
    return (stride * (n - 1) + 1) ** 2 - n ** 2


def tconv_outer_padding(n: int, k: int, stride: int) -> int:
    """# of border zeros for an N x N error map, K x K filter, stride S."""
    return 4 * (k - 1) * (stride * (n - 1) + 1) + 4 * (k - 1) ** 2


def dconv_inner_padding(n: int, stride: int) -> int:
    """# of internal zeros inserted into an N x N error map (dilated conv)."""
    return (stride * (n - 1) + 1) ** 2 - n ** 2


def tconv_zero_mac_fraction(n: int, k: int, stride: int) -> float:
    """Fraction of MACs that touch an inserted zero in the naive transposed
    conv (sliding K x K window over the padded error map)."""
    padded = stride * (n - 1) + 1 + 2 * (k - 1)
    total_elems = padded * padded
    useful_elems = n * n
    # Each window position performs K*K MACs; expected fraction of zero MACs
    # equals the zero density of the padded map (windows tile it uniformly).
    return 1.0 - useful_elems / total_elems


def dconv_zero_mac_fraction(n: int, stride: int) -> float:
    """Fraction of zero MACs in the naive dilated conv (zero-dilated error
    used as the filter)."""
    dil = stride * (n - 1) + 1
    return 1.0 - (n * n) / (dil * dil)


def predicated_mac_fraction(spec, out_size) -> float:
    """Masked-lane fraction of the implicit-GEMM input-gradient lowering.

    The implicit-GEMM strategy computes the input gradient as ONE flat
    GEMM over all Fh x Fw output sites (the pre-padding-slice transposed
    extent `spec.full_size`), with an in-bound predicate per (site, tap)
    lane: tap (kx, ky) contributes to site (r, s) iff r - kx*Dh is a
    non-negative multiple of Sh below Oh*Sh (and likewise for columns).
    For EVERY tap exactly Oh sites per row axis satisfy the predicate
    (r = kx*Dh + i*Sh, i < Oh, and the largest such r is
    (Kh-1)*Dh + (Oh-1)*Sh = Fh - 1 -- always in range), so the masked
    fraction is tap-independent and exact, not an average:

        1 - (Oh * Ow) / (Fh * Fw)

    This is the strategy planner's predicated-lane waste term
    (`kernels/tiling.py`) and the per-layer lane-occupancy figure the
    dataflow simulator reports (`dataflow_sim.predicated_lane_fraction`),
    mirroring `tconv_zero_mac_fraction` for the materialized-zero path.
    Zero at S == D == 1 (the GEMM degenerates to the dense correlation).
    """
    oh, ow = out_size
    fh, fw = spec.full_size((oh, ow))
    return 1.0 - (oh * ow) / (fh * fw)
