"""SASiML-lite: analytical cycle + energy model for spatial-array dataflows.

The paper evaluates EcoFlow in SASiML, a cycle-accurate simulator of an
Eyeriss-class spatial array (13 x 15 PEs, 200 MHz, Table 3) with three
dataflow models: Row-Stationary (Eyeriss), TPU-style lowering (im2col +
output-stationary matmul), and EcoFlow.  We re-scope SASiML as an
*analytical* model: MAC schedules and memory-hierarchy access counts are
derived in closed form from the layer geometry and dataflow, energies from
Horowitz-45nm-class constants.  The functional correctness of the EcoFlow
schedule itself is proven separately (`repro.core.mapping` simulates the PE
array op-by-op).

The model reproduces the paper's *ratios*: Fig. 3 zero-MAC fractions,
Fig. 8/9 input/filter-gradient speedups (~4x @ stride 2, ~11x @ stride 4,
~52x @ stride 8 vs the TPU dataflow), Table 6/8 end-to-end gains, and the
Fig. 10/12 energy-breakdown shape (savings concentrated in SPAD + NoC,
DRAM roughly maintained).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Literal

from repro.core import ecoflow

Op = Literal["forward", "input_grad", "filter_grad", "dilated_forward"]
Dataflow = Literal["rs", "tpu", "ecoflow"]


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    """Paper Table 3 accelerator configuration."""
    pe_rows: int = 13
    pe_cols: int = 15
    clock_hz: float = 200e6
    word_bits: int = 16
    # Energy constants (pJ), Horowitz ISSCC'14 45nm class, 16-bit datapath.
    e_mac: float = 1.0          # 16b multiply + add
    e_spad: float = 1.0         # PE register-file access (per word)
    e_noc: float = 2.0          # on-chip network transfer (per word)
    e_gbuf: float = 20.0        # 108KB global buffer access (per word)
    e_dram: float = 320.0       # DRAM access (per 16-bit word), DDR4-class

    @property
    def n_pes(self) -> int:
        return self.pe_rows * self.pe_cols


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One convolutional layer (square spatial dims, as in the paper)."""
    name: str
    c_in: int       # input channels
    n_in: int       # ifmap spatial size
    n_out: int      # ofmap spatial size
    k: int          # filter spatial size
    m: int          # number of filters (output channels)
    stride: int
    batch: int = 4  # paper uses batch 4
    dilation: int = 1  # forward filter dilation (atrous rate)

    @property
    def k_eff(self) -> int:
        """Effective receptive field D*(K-1)+1 of the dilated filter."""
        return self.dilation * (self.k - 1) + 1

    @property
    def padding(self) -> int:
        # Padding consistent with n_out = (n_in + 2P - K_eff)/S + 1.
        return max(0, ((self.n_out - 1) * self.stride + self.k_eff
                       - self.n_in + 1) // 2)


# --------------------------------------------------------------------------
# MAC counting
# --------------------------------------------------------------------------

def useful_macs(layer: ConvLayer, op: Op) -> int:
    """Zero-free MAC count.  Every forward MAC has exactly one input-grad MAC
    and one filter-grad MAC, so all three ops share the same useful count."""
    return (layer.batch * layer.m * layer.c_in *
            layer.n_out ** 2 * layer.k ** 2)


def scheduled_macs(layer: ConvLayer, op: Op, dataflow: Dataflow) -> int:
    """MACs the dataflow actually schedules (incl. multiplications by
    padding zeros for the naive dataflows -- the PEs spend the cycles even if
    the multiplier is clock-gated, paper Sec. 3.1)."""
    if dataflow == "ecoflow":
        return useful_macs(layer, op)
    if op == "dilated_forward":
        # Naive dataflows sweep the filter at its materialized effective
        # extent: K_eff^2 MACs per output position, K^2 of them useful.
        return (layer.batch * layer.m * layer.c_in *
                layer.n_out ** 2 * layer.k_eff ** 2)
    if op == "forward" or layer.stride == 1:
        # Stride 1 inserts no dilation zeros, so EVERY dataflow schedules
        # exactly the useful MACs (zero_mac_fraction == 0) -- previously
        # the stride==1 case for tpu/rs gradient ops fell through to the
        # padded-MAC formulas below.
        return useful_macs(layer, op)
    s, k, n_err = layer.stride, layer.k, layer.n_out
    if op == "input_grad":
        # Direct conv over the zero-dilated + border-padded error map:
        # n_in^2 output positions, k^2 MACs each.
        return layer.batch * layer.m * layer.c_in * layer.n_in ** 2 * k ** 2
    elif op == "filter_grad":
        # Direct conv of the ifmap with the zero-dilated error as filter:
        # k^2 output positions, dil^2 MACs each.
        dil = s * (n_err - 1) + 1
        return layer.batch * layer.m * layer.c_in * k ** 2 * dil ** 2
    return useful_macs(layer, op)


def zero_mac_fraction(layer: ConvLayer, op: Op) -> float:
    tot = scheduled_macs(layer, op, "tpu")
    return 1.0 - useful_macs(layer, op) / tot


def predicated_lane_fraction(layer: ConvLayer) -> float:
    """Masked-lane fraction of the implicit-GEMM input-gradient lowering
    of this layer -- the flat `(B*Fh*Fw) x (K^2*M)` GEMM with an in-bound
    predicate per lane (kernels/implicit_gemm.py).  Delegates to the same
    `ecoflow.predicated_mac_fraction` closed form the strategy planner's
    waste term uses (`kernels/tiling.py`), so the simulator's lane
    accounting and the planner's race cannot drift apart.  Zero at
    stride 1 / dilation 1, where the GEMM degenerates to the dense
    correlation and every lane is useful."""
    from repro.core.spec import ConvSpec
    spec = ConvSpec.make(stride=layer.stride, padding=layer.padding,
                         filter_shape=layer.k, dilation=layer.dilation)
    return ecoflow.predicated_mac_fraction(
        spec, (layer.n_out, layer.n_out))


# --------------------------------------------------------------------------
# Cycle model
# --------------------------------------------------------------------------

def _frag(n: int, d: int) -> float:
    """Array-dimension fragmentation with tile packing: when a tile dim is
    smaller than the array dim, the compiler packs independent tiles side by
    side (paper: grouping); the final partial tile still wastes lanes."""
    if n >= d:
        return n / (math.ceil(n / d) * d)
    return (n * (d // n)) / d


def _mapping_utilization(layer: ConvLayer, op: Op, dataflow: Dataflow,
                         hw: ArrayConfig) -> float:
    """Fraction of PE-cycles doing scheduled work (edge/fragmentation
    effects of fitting the tiling onto the fixed array)."""
    R, C = hw.pe_rows, hw.pe_cols
    if dataflow == "tpu":
        # Lowered matmul, output-stationary systolic tiles of R x C outputs;
        # edge waste from partial tiles + pipeline fill of the contraction.
        if op == "forward":
            rows, cols = layer.batch * layer.n_out ** 2, layer.m
            depth = layer.k ** 2 * layer.c_in
        elif op == "dilated_forward":
            # im2col over the materialized K_eff-extent filter.
            rows, cols = layer.batch * layer.n_out ** 2, layer.m
            depth = layer.k_eff ** 2 * layer.c_in
        elif op == "input_grad":
            # (B*Nin^2, K^2*M) @ (K^2*M, Cin) over the padded error map.
            rows, cols = layer.batch * layer.n_in ** 2, layer.c_in
            depth = layer.k ** 2 * layer.m
        else:  # filter_grad: (K^2*Cin, B*Odil^2) @ (.., M)
            rows, cols = layer.k ** 2 * layer.c_in, layer.m
            depth = layer.batch * (layer.stride * (layer.n_out - 1) + 1) ** 2
        fill = depth / (depth + R)  # systolic fill/drain overhead
        return _frag(rows, R) * _frag(cols, C) * fill
    if dataflow == "rs":
        # Row-stationary: PE sets of (filter rows x output rows).
        if op == "input_grad":
            set_h, set_w = layer.k, min(layer.n_in, C)
        elif op == "filter_grad":
            set_h, set_w = min(layer.stride * (layer.n_out - 1) + 1, R), layer.k
        elif op == "dilated_forward":
            # Filter rows at the materialized K_eff extent.
            set_h, set_w = min(layer.k_eff, R), min(layer.n_out, C)
        else:
            set_h, set_w = layer.k, min(layer.n_out, C)
        used = min(hw.n_pes,
                   max(1, R // max(1, set_h)) * max(1, C // max(1, set_w)) *
                   set_h * set_w)
        return used / hw.n_pes
    # EcoFlow.  Input grads: PE sets sized by the error matrix (one PE per
    # error element, K^2 MACs each -- perfectly balanced by the circular
    # shift); expansion splits sets larger than the array, grouping packs
    # small ones (paper Sec. 4.1.1).  Residual waste: the final partial
    # expansion slice + the vertical psum-hop cycles at the end of each
    # label chain (ceil(K/S)-1 hops per K^2-MAC schedule).
    if op == "filter_grad":
        # One PE per filter-gradient element; channels/filters grouped, so
        # the array is saturated whenever K^2*Cin*M >= n_pes.
        sets = layer.k ** 2 * layer.c_in * layer.m
        occupancy = _frag(sets, hw.n_pes) if sets >= hw.n_pes else sets / hw.n_pes
        return occupancy
    # input_grad / forward / dilated_forward: one PE per output (error)
    # element, K^2 useful MACs each.  For the dilated forward the psum
    # chain spans the D-spaced tap extent instead of the stride-phase
    # extent -- the same ceil(extent/stride)-1 hop model with K_eff.
    err2 = layer.n_out ** 2
    occupancy = _frag(err2 * layer.batch * layer.m, hw.n_pes)
    extent = layer.k_eff if op == "dilated_forward" else layer.k
    hops = max(0, math.ceil(extent / layer.stride) - 1)
    hop_util = layer.k ** 2 / (layer.k ** 2 + hops)
    return occupancy * hop_util


def cycles(layer: ConvLayer, op: Op, dataflow: Dataflow,
           hw: ArrayConfig = ArrayConfig()) -> float:
    util = _mapping_utilization(layer, op, dataflow, hw)
    return scheduled_macs(layer, op, dataflow) / (hw.n_pes * util)


def exec_time_s(layer: ConvLayer, op: Op, dataflow: Dataflow,
                hw: ArrayConfig = ArrayConfig()) -> float:
    return cycles(layer, op, dataflow, hw) / hw.clock_hz


def speedup(layer: ConvLayer, op: Op, dataflow: Dataflow,
            baseline: Dataflow = "tpu", hw: ArrayConfig = ArrayConfig()
            ) -> float:
    return cycles(layer, op, baseline, hw) / cycles(layer, op, dataflow, hw)


# --------------------------------------------------------------------------
# Energy model
# --------------------------------------------------------------------------

def energy_breakdown_pj(layer: ConvLayer, op: Op, dataflow: Dataflow,
                        hw: ArrayConfig = ArrayConfig()) -> Dict[str, float]:
    """Energy per component (pJ).  Baselines clock-gate zero MACs (no ALU
    energy) but still move the zeros through SPAD/NoC -- which is exactly
    where the paper observes EcoFlow's savings (Fig. 10/12)."""
    sched = scheduled_macs(layer, op, dataflow)
    useful = useful_macs(layer, op)
    B, Cin, M, K, S = layer.batch, layer.c_in, layer.m, layer.k, layer.stride

    alu = useful * hw.e_mac
    # SPAD: each scheduled MAC reads an input word + a weight word and
    # read-modify-writes a psum word (zeros still occupy schedule slots).
    spad = sched * 4 * hw.e_spad
    # NoC: every scheduled input element delivery (multicast counted once
    # per receiving PE), plus psum hops.
    noc = sched * hw.e_noc
    if dataflow == "ecoflow":
        # Multicast groups deliver only useful elements; vertical psum hops.
        noc = useful * hw.e_noc * (1.0 + 1.0 / max(1, K))
    # Global buffer: inputs read once per processing pass with reuse across
    # the m filters; psums spilled once per pass.
    in_elems = B * Cin * layer.n_in ** 2
    err_elems = B * M * layer.n_out ** 2
    out_elems = {"forward": err_elems, "dilated_forward": err_elems,
                 "input_grad": in_elems,
                 "filter_grad": K * K * Cin * M}[op]
    reuse_passes = max(1, M // 16)
    gbuf = (in_elems * reuse_passes + err_elems * reuse_passes +
            2 * out_elems) * hw.e_gbuf
    if dataflow != "ecoflow" and sched > useful:
        # Naive dataflows stage the zero-padded tensors (stride-dilated
        # error maps / K_eff-extent filters) in the buffer.
        pad_ratio = sched / useful
        gbuf *= math.sqrt(pad_ratio)
    # DRAM: unique tensor traffic -- identical across dataflows (paper:
    # "the energy consumed by DRAM is maintained").
    dram = (in_elems + err_elems + out_elems + K * K * Cin * M) * hw.e_dram
    return {"ALU": alu, "SPAD": spad, "NoC": noc, "GBUFF": gbuf, "DRAM": dram}


def energy_pj(layer: ConvLayer, op: Op, dataflow: Dataflow,
              hw: ArrayConfig = ArrayConfig()) -> float:
    return sum(energy_breakdown_pj(layer, op, dataflow, hw).values())


# --------------------------------------------------------------------------
# Paper layer tables
# --------------------------------------------------------------------------

# Table 5: eight of the 72 evaluated CNN layers.
TABLE5_LAYERS = [
    ConvLayer("alexnet-CONV1",    3, 224, 55, 11, 64, 4),
    ConvLayer("alexnet-CONV2",   64, 31, 27, 5, 192, 1),
    ConvLayer("resnet50-CONV3", 128, 57, 28, 3, 128, 2),
    ConvLayer("shufflenet-CONV2", 58, 57, 28, 3, 58, 2),
    ConvLayer("shufflenet-CONV5", 232, 7, 7, 1, 232, 1),
    ConvLayer("inception-CONV3", 192, 17, 8, 3, 320, 2),
    ConvLayer("xception-CONV3",  728, 29, 14, 3, 1, 2),
    ConvLayer("mobilenet-CONV5", 512, 15, 7, 3, 1, 2),
]

# Optimized variants (Sec. 6.1.1): pooling replaced by larger stride.
OPT_LAYERS = [
    ConvLayer("alexnet-o-CONV1",  3, 224, 27, 11, 64, 8),
    ConvLayer("alexnet-o-CONV2", 64, 31, 13, 5, 192, 2),
]

# Table 7: GAN layers (CycleGAN / pix2pix).  Generator TCONV layers are
# encoded in their *equivalent direct-conv* orientation (a transposed conv
# IFM->OFM equals the input-gradient of a direct conv OFM->IFM), so the
# generator forward pass is the `input_grad` op of the layer below.
TABLE7_GAN_LAYERS = [
    ConvLayer("cyclegan-disc-CONV3", 64, 114, 56, 4, 128, 2),
    ConvLayer("cyclegan-gen-TCONV1", 128, 113, 56, 3, 256, 2),
    ConvLayer("pix2pix-disc-CONV6", 128, 130, 64, 4, 256, 2),
    ConvLayer("pix2pix-gen-TCONV4", 128, 130, 64, 4, 512, 2),
]

# Atrous (dilated-forward) segmentation layers -- the workload class the
# paper motivates in Sec. 1: DeepLab-style ASPP branches, stride 1 with
# the 3x3 filter applied at rate D in {2, 4}.
DILATED_LAYERS = [
    ConvLayer("deeplab-ASPP-d2", 256, 33, 33, 3, 256, 1, dilation=2),
    ConvLayer("deeplab-ASPP-d4", 256, 33, 33, 3, 256, 1, dilation=4),
]

# End-to-end model composition: fraction of training time spent in conv
# layers with stride>1 or stride-replaceable pooling (profiled breakdown,
# paper Sec. 6.1 methodology: Amdahl over per-layer GPU/CPU profiles).
END2END_FRACTIONS = {
    # name: (frac_bwd_strided, representative strided layer, frac stride-1)
    "alexnet":    (0.48, "alexnet-CONV1", 0.30),
    "resnet50":   (0.09, "resnet50-CONV3", 0.55),
    "shufflenet": (0.10, "shufflenet-CONV2", 0.55),
    "inception":  (0.10, "inception-CONV3", 0.55),
    "xception":   (0.13, "xception-CONV3", 0.55),
    "mobilenet":  (0.11, "mobilenet-CONV5", 0.55),
}

GAN_FRACTIONS = {
    # GANs use strides instead of pooling: most layers benefit; fraction is
    # the share of end-to-end training time in strided disc-bwd + gen-fwd
    # convs (profiled breakdown, Sec. 6.1 methodology).
    "pix2pix":  (0.37, "pix2pix-disc-CONV6"),
    "cyclegan": (0.40, "cyclegan-disc-CONV3"),
}


def layer_by_name(name: str) -> ConvLayer:
    for l in TABLE5_LAYERS + OPT_LAYERS + TABLE7_GAN_LAYERS + DILATED_LAYERS:
        if l.name == name:
            return l
    raise KeyError(name)


def end_to_end_speedup(network: str, dataflow: Dataflow,
                       hw: ArrayConfig = ArrayConfig()) -> float:
    """Amdahl combination over the profiled training-time breakdown:

      * `frac_strided` -- backward-pass convs with stride > 1 (or
        stride-replaceable pooling): accelerated by the dataflow at the
        representative layer's harmonic input/filter-grad speedup;
      * `frac_s1`      -- stride-1 backward convs: run at PARITY on every
        dataflow (stride 1 inserts no dilation zeros, so
        `scheduled_macs == useful_macs` and `zero_mac_fraction == 0` for
        all of tpu/rs/ecoflow -- the stride-1 fall-through fix);
      * the remainder (fwd convs, FC, optimizer): parity as well.

    The stride-1 term is carried explicitly (not folded silently into the
    remainder) so the profiled breakdown stays auditable against the
    fractions table.
    """
    frac_strided, rep, frac_s1 = END2END_FRACTIONS[network]
    if frac_strided < 0 or frac_s1 < 0 or frac_strided + frac_s1 > 1.0:
        raise ValueError(
            f"invalid training-time fractions for {network!r}: "
            f"strided={frac_strided}, stride-1={frac_s1}")
    layer = layer_by_name(rep)
    sp_ig = speedup(layer, "input_grad", dataflow, "tpu", hw)
    sp_fg = speedup(layer, "filter_grad", dataflow, "tpu", hw)
    sp = 2.0 / (1.0 / sp_ig + 1.0 / sp_fg)
    sp_s1 = 1.0   # stride-1 bwd: zero_mac_fraction == 0, all dataflows equal
    rest = 1.0 - frac_strided - frac_s1
    return 1.0 / (rest + frac_s1 / sp_s1 + frac_strided / sp)


def gan_end_to_end_speedup(network: str, dataflow: Dataflow,
                           hw: ArrayConfig = ArrayConfig()) -> float:
    frac, rep = GAN_FRACTIONS[network]
    layer = layer_by_name(rep)
    sp_ig = speedup(layer, "input_grad", dataflow, "tpu", hw)
    sp_fg = speedup(layer, "filter_grad", dataflow, "tpu", hw)
    sp = 2.0 / (1.0 / sp_ig + 1.0 / sp_fg)
    return 1.0 / ((1.0 - frac) + frac / sp)
