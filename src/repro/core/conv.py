"""EcoFlowConv: direct convolution whose backward pass uses the zero-free
EcoFlow dataflows.

`ecoflow_conv(x, w, stride, padding)` is a drop-in direct conv.  Its VJP
computes:
  * dL/dx with the zero-free *transposed* convolution (phase decomposition),
  * dL/dw with the zero-free *dilated* convolution (per-tap strided gathers),
exactly the two backward kernels the paper accelerates.  Forward/backward are
bit-compatible with `jax.grad` of a plain `lax.conv_general_dilated` (up to
fp accumulation order).

`use_pallas=True` routes the backward through the Pallas TPU kernels in
`repro.kernels` (interpret-mode on CPU).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ecoflow
from repro.core.ecoflow import _pair


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def ecoflow_conv(x: jax.Array, w: jax.Array, stride=1, padding=0,
                 use_pallas: bool = False) -> jax.Array:
    """Direct conv (NHWC x HWIO -> NHWC) with EcoFlow zero-free backward."""
    return ecoflow.direct_conv(x, w, stride, padding)


def _fwd(x, w, stride, padding, use_pallas):
    return ecoflow_conv(x, w, stride, padding, use_pallas), (x, w)


def _bwd(stride, padding, use_pallas, res, g):
    x, w = res
    kh, kw = w.shape[0], w.shape[1]
    if use_pallas:
        from repro.kernels import ops as kops
        dx = kops.tconv_phase(g, w, stride=_pair(stride),
                              padding=_pair(padding),
                              n_out=(x.shape[1], x.shape[2]))
        dw = kops.dconv_filter_grad(x, g, stride=_pair(stride),
                                    padding=_pair(padding), k=(kh, kw))
    else:
        dx = ecoflow.transposed_conv_zero_free(
            g, w, stride=_pair(stride), padding=_pair(padding),
            n_out=(x.shape[1], x.shape[2]))
        dw = ecoflow.dilated_conv_filter_grad_zero_free(
            x, g, stride=_pair(stride), padding=_pair(padding), k=(kh, kw))
    return dx.astype(x.dtype), dw.astype(w.dtype)


ecoflow_conv.defvjp(_fwd, _bwd)


def ecoflow_conv_transpose(dy: jax.Array, w: jax.Array, stride=1, padding=0,
                           n_out=None) -> jax.Array:
    """Standalone zero-free transposed conv (e.g. GAN generator layers)."""
    return ecoflow.transposed_conv_zero_free(
        dy, w, stride=_pair(stride), padding=_pair(padding),
        n_out=None if n_out is None else tuple(n_out))
