"""EcoFlowConv: direct convolution whose backward pass uses the zero-free
EcoFlow dataflows, dispatched through the conv backend registry.

`ecoflow_conv(x, w, stride, padding, backend)` is a drop-in direct conv.
Its VJP computes:
  * dL/dx with the zero-free *transposed* convolution (phase decomposition),
  * dL/dw with the zero-free *dilated* convolution (per-tap gathers),
exactly the two backward kernels the paper accelerates.  Forward/backward
are bit-compatible with `jax.grad` of a plain `lax.conv_general_dilated`
(up to fp accumulation order).

`ecoflow_dilated_conv(x, w, stride, padding, dilation, backend)` is the
dilated/atrous forward conv (the paper's third conv family): the filter
is applied at tap spacing D without materializing its effective extent,
and both adjoints are equally zero-free (per-tap scatter/gather).

`backend` selects the implementation from `repro.core.spec`:
  * "xla_zero_free" (default) -- dense XLA phase decomposition,
  * "pallas"                  -- fused single-launch Pallas TPU kernels
                                 (interpret mode off-TPU),
  * "reference"               -- jax's own conv gradients (ground truth).
Legacy `use_pallas` booleans are still accepted (True -> "pallas").
"""
from __future__ import annotations

import dataclasses
import functools

import jax

from repro.core.spec import ConvSpec, Epilogue, dispatch_backend


def _normalize_epilogue(epilogue, bias):
    """Fold the `bias=` / `epilogue=` kwargs into one descriptor (or None
    for the plain path).  A bias array with no descriptor means a pure
    bias-add epilogue; a descriptor with `bias=False` plus a bias array is
    promoted; identity descriptors with no bias collapse to None so the
    legacy jaxpr (and its structural pins) stay byte-identical."""
    if epilogue is None:
        return Epilogue(bias=True) if bias is not None else None
    if bias is not None and not epilogue.bias:
        epilogue = dataclasses.replace(epilogue, bias=True)
    if epilogue.bias and bias is None:
        raise ValueError("epilogue.bias=True but no bias array was given")
    return None if epilogue.is_identity else epilogue


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _conv_plain(x: jax.Array, w: jax.Array, stride=1, padding=0,
                backend=None, dilation=1) -> jax.Array:
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    return dispatch_backend(backend).forward(x, w, spec)


def ecoflow_conv(x: jax.Array, w: jax.Array, stride=1, padding=0,
                 backend=None, dilation=1, *, bias=None,
                 epilogue: Epilogue | None = None) -> jax.Array:
    """Direct conv (NHWC x HWIO -> NHWC) with EcoFlow zero-free backward.

    `dilation` > 1 makes the forward a dilated/atrous conv -- zero-free on
    the `xla_zero_free` and `pallas` backends (the dilated filter is never
    materialized); see `ecoflow_dilated_conv` for the keyword-friendly
    entry point.

    `bias` ((Cout,) array) and/or `epilogue` (an `Epilogue` descriptor)
    fuse the layer tail act(scale * conv + bias) into the conv launch on
    backends with an epilogue slot (DESIGN.md Sec. 2.8); other backends
    compose the identical math.  The VJP then masks the cotangent with
    act'(y) in-kernel and returns the bias gradient from the same fused
    backward launch."""
    ep = _normalize_epilogue(epilogue, bias)
    if ep is None:
        return _conv_plain(x, w, stride, padding, backend, dilation)
    return _conv_ep(x, w, bias if ep.bias else None, stride, padding,
                    backend, dilation, ep)


def _fwd(x, w, stride, padding, backend, dilation):
    return _conv_plain(x, w, stride, padding, backend, dilation), (x, w)


def _bwd(stride, padding, backend, dilation, res, g):
    """Both gradients through the backend's `backward` method: ONE fused
    dual-output launch on the `pallas` backend (dx and dW from a single
    dy fetch, kernels/dconv_backward.py), the two-launch input_grad +
    filter_grad composition elsewhere."""
    x, w = res
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    be = dispatch_backend(backend)
    dx, dw = be.backward(x, g, w, spec, (x.shape[1], x.shape[2]))
    return dx.astype(x.dtype), dw.astype(w.dtype)


_conv_plain.defvjp(_fwd, _bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _conv_ep(x, w, b, stride, padding, backend, dilation,
             epilogue: Epilogue):
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    return dispatch_backend(backend).forward_ep(x, w, b, spec, epilogue)


def _ep_fwd(x, w, b, stride, padding, backend, dilation, epilogue):
    y = _conv_ep(x, w, b, stride, padding, backend, dilation, epilogue)
    # The activation-gradient mask is a function of the OUTPUT y (relu:
    # y > 0; leaky: sign of y; tanh: 1 - y^2), so y is the only extra
    # residual -- no pre-activation tensor is ever materialized.
    return y, (x, w, y if epilogue.needs_y else None)


def _ep_bwd(stride, padding, backend, dilation, epilogue, res, g):
    x, w, y = res
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    be = dispatch_backend(backend)
    dx, dw, db = be.backward_ep(x, y, g, w, spec,
                                (x.shape[1], x.shape[2]), epilogue)
    db = None if db is None else db.astype(g.dtype)
    return dx.astype(x.dtype), dw.astype(w.dtype), db


_conv_ep.defvjp(_ep_fwd, _ep_bwd)


def ecoflow_dilated_conv(x: jax.Array, w: jax.Array, stride=1, padding=0,
                         dilation=2, backend=None, *, bias=None,
                         epilogue: Epilogue | None = None) -> jax.Array:
    """Zero-free dilated (atrous) forward convolution with zero-free VJP.

    The segmentation-style workload of the paper (Sec. 1, Table 5): the
    filter is applied at tap spacing `dilation` without materializing its
    D*(K-1)+1 effective extent.  Both gradients route through the same
    backend's zero-free adjoints (per-tap scatter for dx, per-tap gather
    for dW), so `jax.grad` through this op matches `jax.grad` of
    `lax.conv_general_dilated(..., rhs_dilation=D)`."""
    return ecoflow_conv(x, w, stride, padding, backend, dilation,
                        bias=bias, epilogue=epilogue)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _conv_transpose(dy, w, stride, padding, n_out, backend, dilation):
    # ConvSpec.make, NOT the raw dataclass: every other entry point gets
    # int -> pair normalization + geometry validation here, and a direct
    # call with a scalar stride otherwise produces an unusable spec deep
    # inside the backend (`stride[i]` on an int).
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    return dispatch_backend(backend).input_grad(dy, w, spec, n_out)


def _ct_fwd(dy, w, stride, padding, n_out, backend, dilation):
    return _conv_transpose(dy, w, stride, padding, n_out, backend,
                           dilation), (dy, w)


def _ct_bwd(stride, padding, n_out, backend, dilation, res, g):
    """VJP of the transposed conv, itself zero-free.

    The transposed conv is the adjoint of the direct conv's linear map, so
    the pullback of a cotangent g w.r.t. `dy` is the *direct* conv of g,
    and w.r.t. `w` it is the same zero-free dilated filter gradient with g
    in the input role -- the cotangent sits in the INPUT role of both, so
    the backend's `ct_backward` computes the pair from one g fetch (ONE
    fused launch on `pallas`; forward + filter_grad elsewhere).  This
    keeps the GAN generator differentiable through every backend (the
    Pallas kernels have no autodiff rule of their own) and routes its
    backward through the paper's dataflows."""
    dy, w = res
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    be = dispatch_backend(backend)
    ddy, dw = be.ct_backward(g, dy, w, spec)
    return ddy.astype(dy.dtype), dw.astype(w.dtype)


_conv_transpose.defvjp(_ct_fwd, _ct_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _conv_transpose_ep(dy, w, b, stride, padding, n_out, backend, dilation,
                       epilogue: Epilogue):
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    return dispatch_backend(backend).input_grad_ep(dy, w, b, spec, n_out,
                                                  epilogue)


def _ct_ep_fwd(dy, w, b, stride, padding, n_out, backend, dilation,
               epilogue):
    z = _conv_transpose_ep(dy, w, b, stride, padding, n_out, backend,
                           dilation, epilogue)
    return z, (dy, w, z if epilogue.needs_y else None)


def _ct_ep_bwd(stride, padding, n_out, backend, dilation, epilogue, res, g):
    dy, w, z = res
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    be = dispatch_backend(backend)
    ddy, dw, db = be.ct_backward_ep(g, z, dy, w, spec, epilogue)
    db = None if db is None else db.astype(g.dtype)
    return ddy.astype(dy.dtype), dw.astype(w.dtype), db


_conv_transpose_ep.defvjp(_ct_ep_fwd, _ct_ep_bwd)


def ecoflow_conv_transpose(dy: jax.Array, w: jax.Array, stride=1, padding=0,
                           n_out=None, backend=None, dilation=1, *,
                           bias=None,
                           epilogue: Epilogue | None = None) -> jax.Array:
    """Standalone zero-free transposed conv (e.g. GAN generator layers),
    dispatched through the backend registry.

    `dilation` > 1 makes this the adjoint of a *dilated* forward conv
    (atrous decoder layers): on the `pallas` backend the unified
    (phase, tap) kernel runs any (stride, dilation) pair in one launch."""
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=w.shape[:2], dilation=dilation)
    if n_out is None:
        n_out = spec.input_size((dy.shape[1], dy.shape[2]))
    n_out = tuple(int(n) for n in n_out)
    # The geometry contract: dy must be the forward-conv output of an
    # n_out-sized input.  Reject inconsistent sizes here with a clear
    # error -- otherwise the custom VJP's adjoint conv would produce a
    # cotangent shape mismatch deep inside autodiff.
    if spec.out_size(n_out) != (dy.shape[1], dy.shape[2]):
        raise ValueError(
            f"n_out={n_out} is inconsistent with dy spatial size "
            f"{dy.shape[1:3]} for stride={spec.stride}, "
            f"padding={spec.padding}, filter={spec.filter_shape}, "
            f"dilation={spec.dilation}: a forward conv over n_out yields "
            f"{spec.out_size(n_out)}")
    ep = _normalize_epilogue(epilogue, bias)
    if ep is None:
        return _conv_transpose(dy, w, spec.stride, spec.padding,
                               n_out, backend, spec.dilation)
    return _conv_transpose_ep(dy, w, bias if ep.bias else None, spec.stride,
                              spec.padding, n_out, backend, spec.dilation,
                              ep)
