"""ConvSpec: normalized convolution geometry + the conv backend registry.

Every convolution in the repo (forward -- plain or dilated/atrous,
zero-free input-gradient / transposed, zero-free filter-gradient /
dilated) is described by one `ConvSpec` -- stride/padding/filter/dilation
pairs plus the derived phase bookkeeping the EcoFlow decomposition needs
(sub-filter shapes, effective receptive field, full/output sizes).  This absorbs the `_pair` / `transposed_conv_input_size` helpers
previously duplicated across `core/ecoflow.py` and `kernels/ops.py`.

Backends implement the three ops behind a uniform interface and register
under a name:

  * ``reference``      -- `jax.vjp` of `lax.conv_general_dilated`
                          (ground truth; materializes dilation zeros).
  * ``xla_zero_free``  -- the EcoFlow phase decomposition expressed as
                          dense XLA ops (S*S stride-1 convs + scatters,
                          per-tap strided gathers).  This is the
                          multi-launch path the fused kernels replace; it
                          is kept as a backend both as a fallback and as
                          the baseline the benchmarks compare against.
  * ``pallas``         -- the fused single-launch Pallas TPU kernels
                          (`kernels/tconv_phase.py`,
                          `kernels/dconv_filtergrad.py`, and the
                          predicated `kernels/implicit_gemm.py` the
                          strategy planner races against the phase
                          decomposition per geometry); interpret mode
                          off-TPU.  Tile extents are NOT pinned here:
                          every kernel resolves its tiling per geometry
                          through `kernels/tiling.py` (the old
                          `tile: int = 128` defaults are gone).

`resolve_backend` also accepts the legacy `use_pallas` booleans
(False -> xla_zero_free, True -> pallas) so old call sites keep working.

See DESIGN.md Sec. 2 for the EcoFlow -> MXU mapping the backends realize.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Sequence, Tuple, Union

# A backend designator: None (default), legacy use_pallas bool, a name, a
# ConvBackend, or a SEQUENCE of designators -- the last resolves through
# `fallback_backend` into a graceful-degradation ladder that tries each
# entry in order (DESIGN.md Sec. 2.11).
BackendLike = Union[None, bool, str, "ConvBackend",
                    Sequence[Union[None, bool, str, "ConvBackend"]]]

DEFAULT_BACKEND = "xla_zero_free"

_ACTIVATIONS = ("none", "relu", "leaky_relu", "tanh")


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Elementwise tail fused into a conv launch (DESIGN.md Sec. 2.8).

    Describes y = act(scale * conv + bias): an optional scalar scale, an
    optional per-output-channel bias add, then one of the supported
    activations.  The descriptor is frozen/hashable so it can ride through
    `jax.jit` static arguments and `jax.custom_vjp` nondiff argnums; the
    bias VECTOR itself stays a traced operand (an extra kernel input).

    The backward contract exploits that every supported activation's
    derivative is recoverable from the activation OUTPUT y (no
    pre-activation residual needed): relu' = (y > 0), leaky_relu' =
    where(y > 0, 1, slope) for slope > 0, tanh' = 1 - y^2.  `grad_factor`
    is that derivative; the fused backward kernels apply it in-VMEM to the
    resident cotangent block before the dx/dW matmuls and accumulate the
    bias gradient (sum of the masked cotangent) as a third kernel output.
    """
    activation: str = "none"
    bias: bool = False
    slope: float = 0.01           # leaky_relu negative slope (> 0)
    scale: Optional[float] = None  # scalar multiplier on the conv output

    def __post_init__(self):
        if self.activation not in _ACTIVATIONS:
            raise ValueError(f"unknown epilogue activation "
                             f"{self.activation!r}; expected one of "
                             f"{_ACTIVATIONS}")
        if self.activation == "leaky_relu" and not self.slope > 0:
            # slope 0 would be plain relu; slope < 0 breaks the
            # y-recoverable-derivative contract (sign(y) != sign(pre)).
            raise ValueError(f"leaky_relu slope must be > 0, "
                             f"got {self.slope}")

    @property
    def is_identity(self) -> bool:
        return (self.activation == "none" and not self.bias
                and self.scale is None)

    @property
    def needs_y(self) -> bool:
        """True when the backward needs the forward output residual (the
        activation-gradient mask is a function of y)."""
        return self.activation != "none"

    @property
    def tag(self) -> str:
        """Compact stable string for cache keys / bench rows."""
        if self.is_identity:
            return "none"
        act = self.activation
        if act == "leaky_relu":
            act += f"{self.slope:g}"
        parts = (["b"] if self.bias else []) \
            + ([act] if act != "none" else [])
        if self.scale is not None:
            parts.append(f"s{self.scale:g}")
        return "+".join(parts)

    def apply(self, vals, bias=None):
        """Forward tail: act(scale * vals + bias).  Pure jnp elementwise,
        usable both host-side (reference/xla backends) and on a
        VMEM-resident block inside a Pallas kernel."""
        import jax.numpy as jnp
        if self.bias and bias is None:
            raise ValueError("epilogue requests a bias but none was given")
        if self.scale is not None:
            vals = vals * self.scale
        if bias is not None:
            vals = vals + bias.astype(vals.dtype)
        if self.activation == "relu":
            vals = jnp.maximum(vals, 0.0)
        elif self.activation == "leaky_relu":
            vals = jnp.where(vals > 0, vals, self.slope * vals)
        elif self.activation == "tanh":
            vals = jnp.tanh(vals)
        return vals

    def grad_factor(self, y):
        """Activation derivative act'(pre), computed from the OUTPUT y."""
        import jax.numpy as jnp
        if self.activation == "relu":
            return (y > 0).astype(y.dtype)
        if self.activation == "leaky_relu":
            return jnp.where(y > 0, 1.0, self.slope).astype(y.dtype)
        if self.activation == "tanh":
            return 1.0 - jnp.square(y)
        return None

    def mask_cotangent(self, y, g):
        """g * act'(y): the masked (UNSCALED) cotangent.  The bias
        gradient is its channel-wise sum; dx/dW additionally carry the
        scalar `scale` factor."""
        f = self.grad_factor(y)
        return g if f is None else g * f.astype(g.dtype)


def _pair(v) -> tuple[int, int]:
    """Normalize an int-or-2-sequence to an (int, int) tuple."""
    if isinstance(v, (tuple, list)):
        if len(v) != 2:
            raise ValueError(f"expected 2 elements, got {v!r}")
        return (int(v[0]), int(v[1]))
    return (int(v), int(v))


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static geometry of one convolution (NHWC x HWIO).

    All fields are per-axis (h, w) pairs; construct with `ConvSpec.make`
    to get int -> pair normalization.  The spec is hashable, so it can be
    a static argument of jit'd functions.
    """
    stride: tuple[int, int] = (1, 1)
    padding: tuple[int, int] = (0, 0)
    filter_shape: tuple[int, int] = (1, 1)   # (Kh, Kw)
    dilation: tuple[int, int] = (1, 1)       # forward filter dilation

    @classmethod
    def make(cls, *, stride=1, padding=0, filter_shape=1,
             dilation=1) -> "ConvSpec":
        """Validated constructor.  Rejects degenerate geometry with
        `ValueError` (NOT `assert`, which `python -O` strips): a stride of
        0 otherwise surfaces as a `ZeroDivisionError` deep inside the
        phase bookkeeping, and negative padding as silent wrong shapes."""
        stride = _pair(stride)
        padding = _pair(padding)
        filter_shape = _pair(filter_shape)
        dilation = _pair(dilation)
        if min(stride) < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        if min(padding) < 0:
            raise ValueError(f"padding must be >= 0, got {padding}")
        if min(filter_shape) < 1:
            raise ValueError(f"filter_shape must be >= 1, got {filter_shape}")
        if min(dilation) < 1:
            raise ValueError(f"dilation must be >= 1, got {dilation}")
        return cls(stride, padding, filter_shape, dilation)

    # -- forward geometry ---------------------------------------------------

    @property
    def dilated_filter_shape(self) -> tuple[int, int]:
        """Effective receptive field K_eff = D*(K-1) + 1 per axis: the
        spatial extent of the filter once its taps are spread D apart.
        Equals `filter_shape` at dilation 1."""
        return tuple(self.dilation[i] * (self.filter_shape[i] - 1) + 1
                     for i in range(2))

    def out_size(self, in_size: Sequence[int]) -> tuple[int, int]:
        """Forward output spatial size O = floor((N + 2P - K_eff)/S) + 1."""
        n = _pair(in_size)
        ke = self.dilated_filter_shape
        return tuple((n[i] + 2 * self.padding[i] - ke[i])
                     // self.stride[i] + 1 for i in range(2))

    def input_size(self, out_size: Sequence[int]) -> tuple[int, int]:
        """Exact-fit forward input size N = S*(O-1) + K_eff - 2P (the
        default `n_out` of the transposed conv)."""
        o = _pair(out_size)
        ke = self.dilated_filter_shape
        return tuple(self.stride[i] * (o[i] - 1) + ke[i]
                     - 2 * self.padding[i] for i in range(2))

    def full_size(self, out_size: Sequence[int]) -> tuple[int, int]:
        """Pre-padding-slice transposed-conv output size F = S*(O-1) +
        K_eff."""
        o = _pair(out_size)
        ke = self.dilated_filter_shape
        return tuple(self.stride[i] * (o[i] - 1) + ke[i]
                     for i in range(2))

    # -- phase (EcoFlow) bookkeeping ----------------------------------------
    # The stride-phase properties below (n_phases .. useful_taps) describe
    # the transposed conv of an UNDILATED forward conv (dilation 1).  The
    # stride x dilation GENERAL decomposition -- tap (kx, ky) lands in
    # output residue class ((kx*D) mod S, (ky*D) mod S), taps group by
    # kx mod (S/gcd(S, D)), and within a residue class successive taps sit
    # D/gcd(S, D) phase rows apart -- is the tap_* family at the end of
    # this block (see DESIGN.md Sec. 2.5).  At dilation 1 the two views
    # coincide (period == stride, step == 1).

    @property
    def n_phases(self) -> int:
        """Number of stride phases S_h * S_w of the transposed conv."""
        return self.stride[0] * self.stride[1]

    def phase_index(self, p: int, q: int) -> int:
        """Linear index of phase (p, q) in the packed phase-major layout."""
        return p * self.stride[1] + q

    def phase_filter_shape(self, p: int, q: int) -> tuple[int, int]:
        """Sub-filter taps of phase (p, q): ceil((K - p)/S) per axis.
        Zero for phases beyond the filter extent (stride > K)."""
        return (max(0, -(-(self.filter_shape[0] - p) // self.stride[0])),
                max(0, -(-(self.filter_shape[1] - q) // self.stride[1])))

    @property
    def packed_phase_shape(self) -> tuple[int, int]:
        """Uniform (zero-padded) sub-filter shape ceil(K/S) per axis --
        the tap extent of the packed all-phase filter tensor."""
        return (-(-self.filter_shape[0] // self.stride[0]),
                -(-self.filter_shape[1] // self.stride[1]))

    def useful_taps(self) -> int:
        """Total taps over all phases == Kh*Kw (every tap in exactly one
        phase; the zero-free property)."""
        return sum(kp * kq
                   for p in range(self.stride[0])
                   for q in range(self.stride[1])
                   for kp, kq in [self.phase_filter_shape(p, q)])

    # -- stride x dilation general (tap-phase) bookkeeping -------------------
    # Transposed conv of a forward conv with stride S and filter dilation D:
    # tap kx contributes to full-output rows r = i*S + kx*D, i.e. residue
    # class (kx*D) mod S.  Residues repeat with period S/gcd(S, D) in kx, so
    # taps group by kx mod period, and taps kx = a + u*period of class `a`
    # land on phase rows m = i + (a*D)//S + u*(D/gcd(S, D)) -- an arithmetic
    # tap lattice: each residue class is a stride-1 correlation of dy with a
    # (D/gcd)-dilated sub-filter.  At D == 1 this reduces exactly to the
    # stride-phase properties above.

    @property
    def tap_phase_period(self) -> tuple[int, int]:
        """Tap-grouping period S/gcd(S, D) per axis: taps kx and
        kx + period share the output residue class (kx*D) mod S."""
        return tuple(self.stride[i] // math.gcd(self.stride[i],
                                                self.dilation[i])
                     for i in range(2))

    @property
    def tap_phase_step(self) -> tuple[int, int]:
        """Phase-row spacing D/gcd(S, D) between successive taps of one
        residue class (the sub-filter's own dilation rate)."""
        return tuple(self.dilation[i] // math.gcd(self.stride[i],
                                                  self.dilation[i])
                     for i in range(2))

    @property
    def n_tap_phases(self) -> tuple[int, int]:
        """Non-empty residue classes min(K, period) per axis; the remaining
        stride residues receive no tap (structural zeros of the
        upsampling)."""
        per = self.tap_phase_period
        return tuple(min(self.filter_shape[i], per[i]) for i in range(2))

    @property
    def taps_per_phase(self) -> tuple[int, int]:
        """Uniform (zero-padded) within-phase tap count ceil(K/period) per
        axis -- the packed tap extent of the general decomposition."""
        per = self.tap_phase_period
        return tuple(-(-self.filter_shape[i] // per[i]) for i in range(2))

    def tap_phase_residue(self, a: int, axis: int) -> int:
        """Output residue class (a*D) mod S of tap-phase `a` on `axis`."""
        return (a * self.dilation[axis]) % self.stride[axis]

    def tap_phase_base(self, a: int, axis: int) -> int:
        """Leading phase-row offset (a*D) // S of tap-phase `a`: the row
        where that class's first tap (u = 0) lands for output i = 0."""
        return (a * self.dilation[axis]) // self.stride[axis]


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvBackend:
    """One implementation of the conv ops.

    forward(x, w, spec)                -> y     (B,N,N,Cin)x(K,K,Cin,Cout)
    input_grad(dy, w, spec, n_out)     -> dx    zero-free transposed conv
    filter_grad(x, dy, spec)           -> dw    zero-free dilated conv

    All three honor `spec.dilation` (forward filter dilation): the forward
    op is then a dilated/atrous conv and the gradients are its adjoints.

    A backend may additionally provide FUSED backward implementations
    (`fused_backward` / `fused_ct_backward`): both gradients of a conv's
    VJP from a single kernel launch sharing one fetch of the common
    operand (see kernels/dconv_backward.py, DESIGN.md Sec. 2.7).  The
    `backward` / `ct_backward` methods below are what `core/conv.py`
    dispatches through: they use the fused path when the backend has one
    and otherwise fall back to the equivalent two-launch composition of
    the primitive ops -- so `reference` and `xla_zero_free` (and any
    externally registered three-op backend) keep working unchanged.
    """
    name: str
    forward: Callable
    input_grad: Callable
    filter_grad: Callable
    # (x, dy, w, spec, n_out) -> (dx, dw): direct-conv VJP, shared dy.
    fused_backward: Union[Callable, None] = None
    # (g, dy, w, spec) -> (ddy, dw): transposed-conv VJP, shared g.
    fused_ct_backward: Union[Callable, None] = None
    # Epilogue-fused variants (DESIGN.md Sec. 2.8).  When absent, the
    # generic *_ep methods compose the plain ops with Epilogue.apply /
    # Epilogue.mask_cotangent -- mathematically identical, so the parity
    # grids hold across backends with or without fused implementations.
    # (x, w, bias, spec, ep) -> y
    fused_forward_ep: Union[Callable, None] = None
    # (dy, w, bias, spec, n_out, ep) -> x
    fused_input_grad_ep: Union[Callable, None] = None
    # (x, y, dy, w, spec, n_out, ep) -> (dx, dw, db|None)
    fused_backward_ep: Union[Callable, None] = None
    # (g, z, dy, w, spec, ep) -> (ddy, dw, db|None)
    fused_ct_backward_ep: Union[Callable, None] = None

    def backward(self, x, dy, w, spec: "ConvSpec", n_out):
        """Both gradients of direct_conv(x, w, spec) w.r.t. cotangent dy:
        (dx, dw).  One launch on backends with a fused kernel; the
        two-launch input_grad + filter_grad composition otherwise."""
        if self.fused_backward is not None:
            return self.fused_backward(x, dy, w, spec, n_out)
        dx = self.input_grad(dy, w, spec, n_out)
        dw = self.filter_grad(x, dy, spec)
        return dx, dw

    def ct_backward(self, g, dy, w, spec: "ConvSpec"):
        """Both gradients of the transposed conv tconv(dy, w, spec)
        w.r.t. cotangent g: (ddy, dw).  The adjoint pair is (direct conv
        of g, filter grad with g in the input role) -- the shared operand
        is g, so the fused kernel shares its fetch (and tap gathers)."""
        if self.fused_ct_backward is not None:
            return self.fused_ct_backward(g, dy, w, spec)
        ddy = self.forward(g, w, spec)
        dw = self.filter_grad(g, dy, spec)
        return ddy, dw

    # -- epilogue-fused entry points (DESIGN.md Sec. 2.8) ------------------

    def forward_ep(self, x, w, bias, spec: "ConvSpec", ep: Epilogue):
        """y = ep.apply(forward(x, w), bias), fused in-kernel when the
        backend has an epilogue slot."""
        if self.fused_forward_ep is not None:
            return self.fused_forward_ep(x, w, bias, spec, ep)
        return ep.apply(self.forward(x, w, spec), bias)

    def input_grad_ep(self, dy, w, bias, spec: "ConvSpec", n_out,
                      ep: Epilogue):
        """Transposed conv with a fused tail: the generator-style
        tconv-as-a-layer use, NOT the conv adjoint."""
        if self.fused_input_grad_ep is not None:
            return self.fused_input_grad_ep(dy, w, bias, spec, n_out, ep)
        return ep.apply(self.input_grad(dy, w, spec, n_out), bias)

    def backward_ep(self, x, y, dy, w, spec: "ConvSpec", n_out,
                    ep: Epilogue):
        """VJP of forward_ep: masks the cotangent with act'(y), then the
        shared dx/dW launch; db (sum of the masked cotangent) rides along
        as a third output when ep.bias.  Returns (dx, dw, db|None)."""
        if self.fused_backward_ep is not None:
            return self.fused_backward_ep(x, y, dy, w, spec, n_out, ep)
        m = ep.mask_cotangent(y, dy)
        db = m.sum(axis=(0, 1, 2)) if ep.bias else None
        if ep.scale is not None:
            m = m * ep.scale
        dx, dw = self.backward(x, m, w, spec, n_out)
        return dx, dw, db

    def ct_backward_ep(self, g, z, dy, w, spec: "ConvSpec", ep: Epilogue):
        """VJP of input_grad_ep (z is its forward output).  Returns
        (ddy, dw, db|None)."""
        if self.fused_ct_backward_ep is not None:
            return self.fused_ct_backward_ep(g, z, dy, w, spec, ep)
        m = ep.mask_cotangent(z, g)
        db = m.sum(axis=(0, 1, 2)) if ep.bias else None
        if ep.scale is not None:
            m = m * ep.scale
        ddy, dw = self.ct_backward(m, dy, w, spec)
        return ddy, dw, db


_BACKENDS: Dict[str, ConvBackend] = {}


def register_backend(backend: ConvBackend) -> ConvBackend:
    _BACKENDS[backend.name] = backend
    return backend


def available_backends() -> tuple[str, ...]:
    _ensure_default_backends()
    return tuple(sorted(_BACKENDS))


def resolve_backend(backend: BackendLike) -> ConvBackend:
    """Name / bool / None / ConvBackend / sequence-of-those -> ConvBackend.

    A tuple or list resolves through `fallback_backend`: a degradation
    ladder trying each entry in order.  Tuples of names stay hashable, so
    a ladder can ride through `jax.jit` static arguments and
    `jax.custom_vjp` nondiff argnums exactly like a plain name."""
    _ensure_default_backends()
    if isinstance(backend, ConvBackend):
        return backend
    if isinstance(backend, (tuple, list)):
        return fallback_backend(tuple(backend))
    if backend is None:
        name = DEFAULT_BACKEND
    elif isinstance(backend, bool):  # legacy use_pallas flag
        name = "pallas" if backend else "xla_zero_free"
    else:
        name = str(backend)
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown conv backend {name!r}; available: "
            f"{', '.join(available_backends())}") from None


# ---------------------------------------------------------------------------
# Graceful degradation: a fallback ladder over backends (DESIGN.md
# Sec. 2.11).  `ConvServeEngine` drives its per-bucket ladder explicitly
# (it needs circuit-breaker state around each rung); this seam is the
# same semantics for every OTHER call site -- pass a tuple of backend
# names anywhere a backend goes and a failing fused launch degrades to
# the next rung instead of killing the computation.
# ---------------------------------------------------------------------------

_FALLBACK_CACHE: Dict[tuple, ConvBackend] = {}


def fallback_backend(chain: Sequence[BackendLike], *,
                     on_fallback: Optional[Callable] = None) -> ConvBackend:
    """A `ConvBackend` that tries each backend in `chain` in order.

    Every op (plain, fused, and epilogue-fused) attempts the rungs left
    to right; an exception from rung i invokes
    ``on_fallback(backend_name, op_name, exc)`` (when given) and falls
    through to rung i+1.  When every rung fails the LAST exception
    propagates -- the ladder never silently swallows a total failure.

    Exceptions are caught EAGERLY, per call: under `jax.jit` a rung that
    raises at trace time degrades, but a rung whose failure only
    manifests at run time on device does not (trace-time dispatch cannot
    see it).  The serving engine therefore keeps per-attempt jitted
    functions and walks the ladder itself; this seam covers eager and
    trace-time failures for everyone else.

    Ladders without an `on_fallback` observer are memoized per chain, so
    repeated `resolve_backend(("pallas", "reference"))` calls return the
    SAME object -- `dispatch_backend`'s `_SHARDED_CACHE` (keyed on
    `id(base)`) and jit static-argument caching both stay effective."""
    entries: Tuple[BackendLike, ...] = tuple(chain)
    if not entries:
        raise ValueError("fallback chain must name at least one backend")

    cache_key = None
    if on_fallback is None:
        try:
            cache_key = tuple(
                e if isinstance(e, (str, bool, type(None))) else id(e)
                for e in entries)
        except TypeError:  # pragma: no cover - entries above always hashable
            cache_key = None
        hit = _FALLBACK_CACHE.get(cache_key) if cache_key else None
        if hit is not None:
            return hit

    backends = tuple(resolve_backend(b) for b in entries)

    def _run(op_name, call):
        last_exc = None
        for be in backends:
            try:
                return call(be)
            except Exception as exc:  # noqa: BLE001 - ladder catches all
                last_exc = exc
                if on_fallback is not None:
                    on_fallback(be.name, op_name, exc)
        raise last_exc

    ladder = ConvBackend(
        name=">".join(be.name for be in backends),
        forward=lambda x, w, spec: _run(
            "forward", lambda be: be.forward(x, w, spec)),
        input_grad=lambda dy, w, spec, n_out: _run(
            "input_grad", lambda be: be.input_grad(dy, w, spec, n_out)),
        filter_grad=lambda x, dy, spec: _run(
            "filter_grad", lambda be: be.filter_grad(x, dy, spec)),
        # Fused slots route through each rung's own METHOD (not the raw
        # fused callable): a rung without a fused kernel contributes its
        # two-launch composition instead of being skipped.
        fused_backward=lambda x, dy, w, spec, n_out: _run(
            "backward", lambda be: be.backward(x, dy, w, spec, n_out)),
        fused_ct_backward=lambda g, dy, w, spec: _run(
            "ct_backward", lambda be: be.ct_backward(g, dy, w, spec)),
        fused_forward_ep=lambda x, w, bias, spec, ep: _run(
            "forward_ep", lambda be: be.forward_ep(x, w, bias, spec, ep)),
        fused_input_grad_ep=lambda dy, w, bias, spec, n_out, ep: _run(
            "input_grad_ep",
            lambda be: be.input_grad_ep(dy, w, bias, spec, n_out, ep)),
        fused_backward_ep=lambda x, y, dy, w, spec, n_out, ep: _run(
            "backward_ep",
            lambda be: be.backward_ep(x, y, dy, w, spec, n_out, ep)),
        fused_ct_backward_ep=lambda g, z, dy, w, spec, ep: _run(
            "ct_backward_ep",
            lambda be: be.ct_backward_ep(g, z, dy, w, spec, ep)))
    if cache_key is not None:
        _FALLBACK_CACHE[cache_key] = ladder
    return ladder


# ---------------------------------------------------------------------------
# Default backends.  Registered lazily to avoid import cycles
# (core.ecoflow / kernels.ops import this module for ConvSpec).
# ---------------------------------------------------------------------------

_DEFAULTS_REGISTERED = False


def _ensure_default_backends() -> None:
    global _DEFAULTS_REGISTERED
    if _DEFAULTS_REGISTERED:
        return

    import jax

    from repro.core import ecoflow

    # -- reference: jax's own conv gradients (materializes zeros) ----------
    def _ref_forward(x, w, spec: ConvSpec):
        return ecoflow.direct_conv(x, w, spec.stride, spec.padding,
                                   dilation=spec.dilation)

    def _ref_input_grad(dy, w, spec: ConvSpec, n_out):
        nh, nw = _pair(n_out)
        x_shape = (dy.shape[0], nh, nw, w.shape[2])
        f = lambda x_: ecoflow.direct_conv(x_, w, spec.stride, spec.padding,
                                           dilation=spec.dilation)
        import jax.numpy as jnp
        _, vjp = jax.vjp(f, jnp.zeros(x_shape, dy.dtype))
        return vjp(dy)[0]

    def _ref_filter_grad(x, dy, spec: ConvSpec):
        kh, kw = spec.filter_shape
        w_shape = (kh, kw, x.shape[3], dy.shape[3])
        f = lambda w_: ecoflow.direct_conv(x, w_, spec.stride, spec.padding,
                                           dilation=spec.dilation)
        import jax.numpy as jnp
        _, vjp = jax.vjp(f, jnp.zeros(w_shape, x.dtype))
        return vjp(dy)[0]

    register_backend(ConvBackend("reference", _ref_forward,
                                 _ref_input_grad, _ref_filter_grad))

    # -- xla_zero_free: EcoFlow phase/tap decomposition in dense XLA -------
    def _xla_forward(x, w, spec: ConvSpec):
        if spec.dilation == (1, 1):
            return _ref_forward(x, w, spec)
        return ecoflow.dilated_forward_zero_free(
            x, w, stride=spec.stride, padding=spec.padding,
            dilation=spec.dilation)

    def _xla_input_grad(dy, w, spec: ConvSpec, n_out):
        return ecoflow.transposed_conv_zero_free(
            dy, w, stride=spec.stride, padding=spec.padding,
            n_out=_pair(n_out), dilation=spec.dilation)

    def _xla_filter_grad(x, dy, spec: ConvSpec):
        return ecoflow.dilated_conv_filter_grad_zero_free(
            x, dy, stride=spec.stride, padding=spec.padding,
            k=spec.filter_shape, dilation=spec.dilation)

    register_backend(ConvBackend("xla_zero_free", _xla_forward,
                                 _xla_input_grad, _xla_filter_grad))

    # -- pallas: fused single-launch kernels -------------------------------
    def _pl_forward(x, w, spec: ConvSpec):
        if spec.dilation == (1, 1):
            return _ref_forward(x, w, spec)
        from repro.kernels import ops as kops
        return kops.dconv_forward(x, w, stride=spec.stride,
                                  padding=spec.padding,
                                  dilation=spec.dilation)

    def _pl_input_grad(dy, w, spec: ConvSpec, n_out):
        # ONE launch for ANY (stride, dilation) pair, through the
        # per-geometry STRATEGY planner: `tiling.plan_strategy` races the
        # unified (phase, tap) decomposition against the predicated
        # implicit-GEMM kernel and the wrapper launches the winner --
        # both single-launch, so the jaxpr pins hold either way (see
        # DESIGN.md Sec. 2.5 / 2.10).  Ops implicit-GEMM does not cover
        # (forward, filter grad, the fused dual-gradient backwards below)
        # fall back to phase decomposition inside the planner.
        from repro.kernels import ops as kops
        return kops.tconv_phase(dy, w, stride=spec.stride,
                                padding=spec.padding, n_out=_pair(n_out),
                                dilation=spec.dilation)

    def _pl_filter_grad(x, dy, spec: ConvSpec):
        from repro.kernels import ops as kops
        return kops.dconv_filter_grad(x, dy, stride=spec.stride,
                                      padding=spec.padding,
                                      k=spec.filter_shape,
                                      dilation=spec.dilation)

    def _pl_backward(x, dy, w, spec: ConvSpec, n_out):
        from repro.kernels import ops as kops
        return kops.conv_backward(x, dy, w, stride=spec.stride,
                                  padding=spec.padding,
                                  n_out=_pair(n_out),
                                  dilation=spec.dilation)

    def _pl_ct_backward(g, dy, w, spec: ConvSpec):
        from repro.kernels import ops as kops
        return kops.tconv_backward(g, dy, w, stride=spec.stride,
                                   padding=spec.padding,
                                   dilation=spec.dilation)

    # Epilogue-fused launches.  Note the forward: the plain pallas forward
    # defers dilation (1, 1) to XLA, but with an epilogue requested the
    # (dilation-general) Pallas kernel is always used so the tail is fused
    # into the single conv launch.
    def _pl_forward_ep(x, w, bias, spec: ConvSpec, ep: Epilogue):
        from repro.kernels import ops as kops
        return kops.dconv_forward(x, w, stride=spec.stride,
                                  padding=spec.padding,
                                  dilation=spec.dilation,
                                  bias=bias, epilogue=ep)

    def _pl_input_grad_ep(dy, w, bias, spec: ConvSpec, n_out,
                          ep: Epilogue):
        from repro.kernels import ops as kops
        return kops.tconv_phase(dy, w, stride=spec.stride,
                                padding=spec.padding, n_out=_pair(n_out),
                                dilation=spec.dilation,
                                bias=bias, epilogue=ep)

    def _pl_backward_ep(x, y, dy, w, spec: ConvSpec, n_out, ep: Epilogue):
        from repro.kernels import ops as kops
        return kops.conv_backward(x, dy, w, stride=spec.stride,
                                  padding=spec.padding, n_out=_pair(n_out),
                                  dilation=spec.dilation,
                                  y=y, epilogue=ep)

    def _pl_ct_backward_ep(g, z, dy, w, spec: ConvSpec, ep: Epilogue):
        from repro.kernels import ops as kops
        return kops.tconv_backward(g, dy, w, stride=spec.stride,
                                   padding=spec.padding,
                                   dilation=spec.dilation,
                                   z=z, epilogue=ep)

    register_backend(ConvBackend("pallas", _pl_forward,
                                 _pl_input_grad, _pl_filter_grad,
                                 fused_backward=_pl_backward,
                                 fused_ct_backward=_pl_ct_backward,
                                 fused_forward_ep=_pl_forward_ep,
                                 fused_input_grad_ep=_pl_input_grad_ep,
                                 fused_backward_ep=_pl_backward_ep,
                                 fused_ct_backward_ep=_pl_ct_backward_ep))

    # Only mark done once every default registered -- a failure above
    # surfaces on the next call instead of poisoning the registry.
    _DEFAULTS_REGISTERED = True


# ---------------------------------------------------------------------------
# Sharding-aware dispatch: shard_map'd launches on a multi-device mesh
# (DESIGN.md Sec. 2.9).
# ---------------------------------------------------------------------------

def dispatch_backend(backend: BackendLike) -> ConvBackend:
    """Mesh-aware `resolve_backend`.

    Outside a `repro.parallel.sharding.use_mesh` context (or on a 1-chip
    mesh) this IS `resolve_backend` -- the single-device jaxpr is
    byte-identical to before.  Under an active multi-device mesh it wraps
    the resolved backend so every conv op launches through `shard_map`
    with locally-shaped blocks: batch sharded over the logical "dp" axes,
    channels over "tp", explicit psums for the reduced gradients.  The
    mesh is read at TRACE time, so jitted steps must trace under
    `use_mesh` (the model step helpers do)."""
    be = resolve_backend(backend)
    try:
        from repro.parallel import sharding as _sh
    except Exception:  # pragma: no cover - parallel pkg always present
        return be
    mesh = _sh.current_mesh()
    if mesh is None or mesh.size <= 1:
        return be
    return sharded_backend(be, mesh)


_SHARDED_CACHE: Dict[tuple, ConvBackend] = {}


def sharded_backend(base: ConvBackend, mesh) -> ConvBackend:
    """shard_map wrapper around `base` for `mesh` (memoized per pair).

    Per-op sharding scheme -- chosen so NO forward-path psum is ever
    needed, which keeps nonlinear epilogues correct (they must see exact
    sums, so only NON-contracted dims may shard):

      forward / forward_ep       x:(B@dp,..)  w:(..,Cin,Cout@tp) -> y@(dp,tp)
      input_grad / _ep (tconv)   dy:(B@dp,..) w:(..,Cin@tp,Cout) -> dx@(dp,tp)
      backward / backward_ep     per-shard fused launch, then
                                 psum(dx, tp) + psum(dW/db, dp)
      ct_backward / _ep          per-shard fused launch, then
                                 psum(ddy, tp) + psum(dW/db, dp)
      filter_grad                psum(dW, dp)

    Each axis is applied only when it divides the corresponding global
    dim (same guard policy as `parallel.sharding._guard`); when neither
    axis applies the base backend runs replicated with no shard_map.
    `check_rep=False` because pallas_call has no replication rule.  The
    base backend's methods run INSIDE the shard_map body, so its
    fused-vs-two-launch fallback and `tiling.plan_tiles` both see LOCAL
    shapes -- one forward and one backward pallas_call per shard."""
    key = (id(base), mesh)
    hit = _SHARDED_CACHE.get(key)
    if hit is not None:
        return hit

    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel import sharding as _sh

    la = _sh.logical_axes(mesh)
    dp_axes, tp_axes = la["dp"], la["tp"]

    def _ax(axes, dim):
        """`axes` if it is real (>1 devices) and divides `dim`."""
        if axes is None:
            return None
        n = _sh._axis_size(mesh, axes)
        return axes if n > 1 and dim % n == 0 else None

    def _launch(body, in_specs, out_specs, *args):
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)(*args)

    def _psum(v, axes):
        return jax.lax.psum(v, axes) if axes is not None else v

    # -- forward family: shard the produced dims, contract full ones ------

    def forward(x, w, spec):
        bd, cd = _ax(dp_axes, x.shape[0]), _ax(tp_axes, w.shape[3])
        if bd is None and cd is None:
            return base.forward(x, w, spec)
        return _launch(lambda x_, w_: base.forward(x_, w_, spec),
                       (P(bd, None, None, None), P(None, None, None, cd)),
                       P(bd, None, None, cd), x, w)

    def forward_ep(x, w, bias, spec, ep):
        bd, cd = _ax(dp_axes, x.shape[0]), _ax(tp_axes, w.shape[3])
        if bd is None and cd is None:
            return base.forward_ep(x, w, bias, spec, ep)
        if bias is None:
            return _launch(
                lambda x_, w_: base.forward_ep(x_, w_, None, spec, ep),
                (P(bd, None, None, None), P(None, None, None, cd)),
                P(bd, None, None, cd), x, w)
        return _launch(
            lambda x_, w_, b_: base.forward_ep(x_, w_, b_, spec, ep),
            (P(bd, None, None, None), P(None, None, None, cd), P(cd)),
            P(bd, None, None, cd), x, w, bias)

    # tconv-as-a-layer: the produced channel dim is Cin (w.shape[2]); the
    # contracted Cout stays full per shard, so the epilogue bias (a
    # per-Cin vector here) applies to exact sums.

    def input_grad(dy, w, spec, n_out):
        bd, cd = _ax(dp_axes, dy.shape[0]), _ax(tp_axes, w.shape[2])
        if bd is None and cd is None:
            return base.input_grad(dy, w, spec, n_out)
        return _launch(
            lambda dy_, w_: base.input_grad(dy_, w_, spec, n_out),
            (P(bd, None, None, None), P(None, None, cd, None)),
            P(bd, None, None, cd), dy, w)

    def input_grad_ep(dy, w, bias, spec, n_out, ep):
        bd, cd = _ax(dp_axes, dy.shape[0]), _ax(tp_axes, w.shape[2])
        if bd is None and cd is None:
            return base.input_grad_ep(dy, w, bias, spec, n_out, ep)
        if bias is None:
            return _launch(
                lambda dy_, w_: base.input_grad_ep(dy_, w_, None, spec,
                                                   n_out, ep),
                (P(bd, None, None, None), P(None, None, cd, None)),
                P(bd, None, None, cd), dy, w)
        return _launch(
            lambda dy_, w_, b_: base.input_grad_ep(dy_, w_, b_, spec,
                                                   n_out, ep),
            (P(bd, None, None, None), P(None, None, cd, None), P(cd)),
            P(bd, None, None, cd), dy, w, bias)

    # -- backward family: per-shard fused launch + explicit psums ---------
    # dx/ddy are partial over the sharded channel dim (tp); dW/db are
    # partial over the batch shards (dp).  The psums sit OUTSIDE the
    # pallas_call but inside the shard_map body, so each conv layer still
    # lowers to exactly one backward launch per shard.

    def filter_grad(x, dy, spec):
        bd, cd = _ax(dp_axes, x.shape[0]), _ax(tp_axes, dy.shape[3])
        if bd is None and cd is None:
            return base.filter_grad(x, dy, spec)
        return _launch(
            lambda x_, dy_: _psum(base.filter_grad(x_, dy_, spec), bd),
            (P(bd, None, None, None), P(bd, None, None, cd)),
            P(None, None, None, cd), x, dy)

    def backward(x, dy, w, spec, n_out):
        bd, cd = _ax(dp_axes, x.shape[0]), _ax(tp_axes, w.shape[3])
        if bd is None and cd is None:
            return base.backward(x, dy, w, spec, n_out)

        def body(x_, dy_, w_):
            dx, dw = base.backward(x_, dy_, w_, spec, n_out)
            return _psum(dx, cd), _psum(dw, bd)

        return _launch(body,
                       (P(bd, None, None, None), P(bd, None, None, cd),
                        P(None, None, None, cd)),
                       (P(bd, None, None, None), P(None, None, None, cd)),
                       x, dy, w)

    def backward_ep(x, y, dy, w, spec, n_out, ep):
        bd, cd = _ax(dp_axes, x.shape[0]), _ax(tp_axes, w.shape[3])
        if bd is None and cd is None:
            return base.backward_ep(x, y, dy, w, spec, n_out, ep)

        def body(x_, dy_, w_, *rest):
            y_ = rest[0] if ep.needs_y else None
            dx, dw, db = base.backward_ep(x_, y_, dy_, w_, spec, n_out, ep)
            dx, dw = _psum(dx, cd), _psum(dw, bd)
            if db is None:
                return dx, dw
            return dx, dw, _psum(db, bd)

        in_specs = [P(bd, None, None, None), P(bd, None, None, cd),
                    P(None, None, None, cd)]
        args = [x, dy, w]
        if ep.needs_y:
            in_specs.append(P(bd, None, None, cd))
            args.append(y)
        out_specs = (P(bd, None, None, None), P(None, None, None, cd))
        if ep.bias:
            out_specs = out_specs + (P(cd),)
        out = _launch(body, tuple(in_specs), out_specs, *args)
        return out if ep.bias else (out[0], out[1], None)

    def ct_backward(g, dy, w, spec):
        bd, cd = _ax(dp_axes, g.shape[0]), _ax(tp_axes, w.shape[2])
        if bd is None and cd is None:
            return base.ct_backward(g, dy, w, spec)

        def body(g_, dy_, w_):
            ddy, dw = base.ct_backward(g_, dy_, w_, spec)
            return _psum(ddy, cd), _psum(dw, bd)

        return _launch(body,
                       (P(bd, None, None, cd), P(bd, None, None, None),
                        P(None, None, cd, None)),
                       (P(bd, None, None, None), P(None, None, cd, None)),
                       g, dy, w)

    def ct_backward_ep(g, z, dy, w, spec, ep):
        bd, cd = _ax(dp_axes, g.shape[0]), _ax(tp_axes, w.shape[2])
        if bd is None and cd is None:
            return base.ct_backward_ep(g, z, dy, w, spec, ep)

        def body(g_, dy_, w_, *rest):
            z_ = rest[0] if ep.needs_y else None
            ddy, dw, db = base.ct_backward_ep(g_, z_, dy_, w_, spec, ep)
            ddy, dw = _psum(ddy, cd), _psum(dw, bd)
            if db is None:
                return ddy, dw
            return ddy, dw, _psum(db, bd)

        in_specs = [P(bd, None, None, cd), P(bd, None, None, None),
                    P(None, None, cd, None)]
        args = [g, dy, w]
        if ep.needs_y:
            in_specs.append(P(bd, None, None, cd))
            args.append(z)
        out_specs = (P(bd, None, None, None), P(None, None, cd, None))
        if ep.bias:
            out_specs = out_specs + (P(cd),)
        out = _launch(body, tuple(in_specs), out_specs, *args)
        return out if ep.bias else (out[0], out[1], None)

    wrapped = ConvBackend(
        name=f"{base.name}@shard",
        forward=forward,
        input_grad=input_grad,
        filter_grad=filter_grad,
        # All fused slots filled so the ConvBackend methods always route
        # to the shard_map wrappers; the base backend's own
        # fused-vs-two-launch choice happens inside the body.
        fused_backward=backward,
        fused_ct_backward=ct_backward,
        fused_forward_ep=forward_ep,
        fused_input_grad_ep=input_grad_ep,
        fused_backward_ep=backward_ep,
        fused_ct_backward_ep=ct_backward_ep)
    _SHARDED_CACHE[key] = wrapped
    return wrapped
