"""DCGAN-style generator/discriminator (the paper's GAN evaluation domain).

The generator upsamples with `ecoflow_conv_transpose` (the paper's
zero-free transposed-conv dataflow is its *forward* pass); the
discriminator downsamples with strided `ecoflow_conv` (zero-free backward).
Together they exercise every dataflow the paper evaluates in Sec. 6.3.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.conv import ecoflow_conv, ecoflow_conv_transpose
from repro.core.spec import ConvSpec, Epilogue

_RELU = Epilogue(activation="relu")
_TANH = Epilogue(activation="tanh")
_LEAKY = Epilogue(activation="leaky_relu", slope=0.2)

# The generator's upsampling ladder: (param name, tconv-input spatial
# size, output spatial size, fused epilogue).  `generator_apply` and
# `generator_plan_requests` both read this, so the serving warmup plans
# exactly the launches the forward pass makes.
GENERATOR_LAYERS = (("t1", (4, 4), (8, 8), _RELU),
                    ("t2", (8, 8), (16, 16), _RELU),
                    ("t3", (16, 16), (32, 32), _TANH))


def _w(rng, k, cin, cout):
    return (1.0 / math.sqrt(k * k * cin)) * jax.random.truncated_normal(
        rng, -2., 2., (k, k, cin, cout), jnp.float32)


def generator_init(rng, *, z_dim=64, base=64, out_ch=3):
    ks = jax.random.split(rng, 4)
    return {
        "proj": (1.0 / math.sqrt(z_dim)) * jax.random.truncated_normal(
            ks[0], -2., 2., (z_dim, 4 * 4 * base * 2), jnp.float32),
        # conv filters are stored in *direct-conv* orientation (K,K,Cin,Cout)
        # where Cin is the upsampled (output) side, matching the
        # transposed-conv-as-input-gradient formulation.
        "t1": _w(ks[1], 4, base, base * 2),     # 4x4 -> 8x8
        "t2": _w(ks[2], 4, base // 2, base),    # 8x8 -> 16x16
        "t3": _w(ks[3], 4, out_ch, base // 2),  # 16x16 -> 32x32
    }


def generator_apply(params, z, *, backend=None, fuse_epilogue=True):
    """`backend` selects the conv dispatch backend (see repro.core.spec);
    the zero-free transposed conv is the generator's *forward* pass.
    `fuse_epilogue` requests each layer's relu/tanh tail through the
    transposed conv's epilogue slot (DESIGN.md Sec. 2.8); False keeps
    the separate activation ops for A/B comparison."""
    B = z.shape[0]
    x = (z @ params["proj"]).reshape(B, 4, 4, -1)
    x = jax.nn.relu(x)
    if fuse_epilogue:
        x = ecoflow_conv_transpose(x, params["t1"], 2, 1, n_out=(8, 8),
                                   backend=backend, epilogue=_RELU)
        x = ecoflow_conv_transpose(x, params["t2"], 2, 1, n_out=(16, 16),
                                   backend=backend, epilogue=_RELU)
        x = ecoflow_conv_transpose(x, params["t3"], 2, 1, n_out=(32, 32),
                                   backend=backend, epilogue=_TANH)
        return x
    x = jax.nn.relu(ecoflow_conv_transpose(x, params["t1"], 2, 1,
                                           n_out=(8, 8), backend=backend))
    x = jax.nn.relu(ecoflow_conv_transpose(x, params["t2"], 2, 1,
                                           n_out=(16, 16), backend=backend))
    x = jnp.tanh(ecoflow_conv_transpose(x, params["t3"], 2, 1,
                                        n_out=(32, 32), backend=backend))
    return x


def generator_plan_requests(params, batch, *, fuse_epilogue=True):
    """Tile-planning warmup entries for one serving bucket of the
    generator: one `"input_grad"` entry per transposed-conv layer (the
    zero-free transposed conv IS the generator's forward pass), in the
    `(op, spec, x_shape, dy_shape, epilogue)` form
    `kernels.tiling.warmup_plans` consumes.  `x_shape` is the upsampled
    OUTPUT side and `dy_shape` the tconv input, matching the
    input-gradient formulation the filters are stored in."""
    entries = []
    for name, in_hw, out_hw, ep in GENERATOR_LAYERS:
        w = params[name]
        spec = ConvSpec.make(stride=2, padding=1,
                             filter_shape=tuple(w.shape[:2]))
        entries.append(("input_grad", spec,
                        (batch, out_hw[0], out_hw[1], int(w.shape[2])),
                        (batch, in_hw[0], in_hw[1], int(w.shape[3])),
                        ep if fuse_epilogue else None))
    return entries


def discriminator_init(rng, *, in_ch=3, base=64):
    ks = jax.random.split(rng, 4)
    return {
        "c1": _w(ks[0], 4, in_ch, base // 2),
        "c2": _w(ks[1], 4, base // 2, base),
        "c3": _w(ks[2], 4, base, base * 2),
        "head": (1.0 / math.sqrt(4 * 4 * base * 2)) *
        jax.random.truncated_normal(ks[3], -2., 2.,
                                    (4 * 4 * base * 2, 1), jnp.float32),
    }


def discriminator_apply(params, x, *, backend=None, fuse_epilogue=True):
    if fuse_epilogue:   # leaky_relu(0.2) fused into each conv launch
        x = ecoflow_conv(x, params["c1"], 2, 1, backend,
                         epilogue=_LEAKY)                 # 32 -> 16
        x = ecoflow_conv(x, params["c2"], 2, 1, backend,
                         epilogue=_LEAKY)                 # 16 -> 8
        x = ecoflow_conv(x, params["c3"], 2, 1, backend,
                         epilogue=_LEAKY)                 # 8 -> 4
        return x.reshape(x.shape[0], -1) @ params["head"]
    a = lambda t: jax.nn.leaky_relu(t, 0.2)
    x = a(ecoflow_conv(x, params["c1"], 2, 1, backend))   # 32 -> 16
    x = a(ecoflow_conv(x, params["c2"], 2, 1, backend))   # 16 -> 8
    x = a(ecoflow_conv(x, params["c3"], 2, 1, backend))   # 8 -> 4
    return x.reshape(x.shape[0], -1) @ params["head"]


def gan_losses(g_params, d_params, z, real, *, backend=None,
               fuse_epilogue=True):
    """Non-saturating GAN losses (g_loss, d_loss)."""
    fake = generator_apply(g_params, z, backend=backend,
                           fuse_epilogue=fuse_epilogue)
    d_fake = discriminator_apply(d_params, fake, backend=backend,
                                 fuse_epilogue=fuse_epilogue)
    d_real = discriminator_apply(d_params, real, backend=backend,
                                 fuse_epilogue=fuse_epilogue)
    sp = jax.nn.softplus
    d_loss = sp(-d_real).mean() + sp(d_fake).mean()
    g_loss = sp(-d_fake).mean()
    return g_loss, d_loss


def gen_sgd_step(g_params, d_params, z, *, lr=0.05, backend=None,
                 fuse_epilogue=True):
    """One generator SGD step against a frozen discriminator:
    (new_g_params, g_loss) for the non-saturating loss.

    Mesh-aware like `cnn.sgd_step`: under `sharding.use_mesh` the
    transposed convs (generator forward) and direct convs (discriminator)
    dispatch to shard_map'd launches and the latent batch stays sharded
    on "dp"; outside a mesh this is the plain single-device step."""
    from repro.parallel import sharding

    z = sharding.shard(z, "dp", None)

    def g_loss(gp):
        fake = generator_apply(gp, z, backend=backend,
                               fuse_epilogue=fuse_epilogue)
        d_fake = discriminator_apply(d_params, fake, backend=backend,
                                     fuse_epilogue=fuse_epilogue)
        return jax.nn.softplus(-d_fake).mean()

    loss, grads = jax.value_and_grad(g_loss)(g_params)
    new = jax.tree_util.tree_map(lambda p, g: p - lr * g, g_params, grads)
    return new, loss


def gan_init(rng, *, z_dim=64, base=64, ch=3):
    """The full GAN training state pytree: {"g": ..., "d": ...}.
    One checkpointable unit for ConvTrainer (DESIGN.md Sec. 2.12)."""
    kg, kd = jax.random.split(rng)
    return {"g": generator_init(kg, z_dim=z_dim, base=base, out_ch=ch),
            "d": discriminator_init(kd, in_ch=ch, base=base)}


def gan_sgd_step(state, z, real, *, lr=0.05, backend=None,
                 fuse_epilogue=True):
    """One simultaneous GAN step on the {"g", "d"} state pytree:
    (new_state, g_loss, d_loss).  Both gradients evaluate against the
    PRE-step opposite network (simultaneous gradient descent), so the
    update is a pure function of (state, z, real) -- the determinism
    the elastic resume drills rely on.  Mesh-aware like `cnn.sgd_step`:
    under `sharding.use_mesh` every conv dispatches to shard_map'd
    launches with the batch pinned to "dp"."""
    from repro.parallel import sharding

    z = sharding.shard(z, "dp", None)
    real = sharding.shard(real, "dp", None, None, None)
    g_params, d_params = state["g"], state["d"]

    def g_loss_fn(gp):
        fake = generator_apply(gp, z, backend=backend,
                               fuse_epilogue=fuse_epilogue)
        d_fake = discriminator_apply(d_params, fake, backend=backend,
                                     fuse_epilogue=fuse_epilogue)
        return jax.nn.softplus(-d_fake).mean()

    def d_loss_fn(dp):
        fake = generator_apply(g_params, z, backend=backend,
                               fuse_epilogue=fuse_epilogue)
        d_fake = discriminator_apply(dp, fake, backend=backend,
                                     fuse_epilogue=fuse_epilogue)
        d_real = discriminator_apply(dp, real, backend=backend,
                                     fuse_epilogue=fuse_epilogue)
        sp = jax.nn.softplus
        return sp(-d_real).mean() + sp(d_fake).mean()

    g_loss, g_grads = jax.value_and_grad(g_loss_fn)(g_params)
    d_loss, d_grads = jax.value_and_grad(d_loss_fn)(d_params)
    upd = lambda p, g: jax.tree_util.tree_map(
        lambda a, b: a - lr * b, p, g)
    return ({"g": upd(g_params, g_grads), "d": upd(d_params, d_grads)},
            g_loss, d_loss)


def guarded_gen_sgd_step(g_params, d_params, z, *, lr=0.05, backend=None,
                         fuse_epilogue=True):
    """`gen_sgd_step` + in-graph all-finite flag:
    (new_g_params, g_loss, all_finite).  Same jit, same launch count
    (DESIGN.md Sec. 2.12); `lr` may be a traced scalar."""
    from repro.models.layers import tree_all_finite

    new, loss = gen_sgd_step(g_params, d_params, z, lr=lr,
                             backend=backend, fuse_epilogue=fuse_epilogue)
    return new, loss, tree_all_finite(new, loss)


def guarded_gan_sgd_step(state, z, real, *, lr=0.05, backend=None,
                         fuse_epilogue=True):
    """`gan_sgd_step` + in-graph all-finite flag:
    (new_state, g_loss, d_loss, all_finite)."""
    from repro.models.layers import tree_all_finite

    new, g_loss, d_loss = gan_sgd_step(state, z, real, lr=lr,
                                       backend=backend,
                                       fuse_epilogue=fuse_epilogue)
    return new, g_loss, d_loss, tree_all_finite(new, g_loss, d_loss)
