"""Mixture-of-Experts layer with capacity-based top-k dispatch (GShard
style) and expert parallelism over the "model" mesh axis.

Each batch row is a dispatch group (G=B, n=S tokens): one-hot dispatch /
combine tensors of shape (B, S, E, C) with per-group capacity
C = ceil(S * top_k / E * capacity_factor).  Dropped tokens pass through the
residual (standard Switch behaviour).  Expert weights are stacked (E, ...)
and sharded over "model" (EP); tokens therefore cross an all-to-all that
GSPMD derives from the dispatch einsum.

Optional shared experts (DeepSeek/Moonlight style) run densely on every
token.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


def _init(rng, shape, scale):
    return scale * jax.random.truncated_normal(rng, -2., 2., shape,
                                               dtype=jnp.float32)


def moe_init(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 6)
    d, f, e = cfg.d_model, cfg.moe_dff, cfg.n_experts
    s_in, s_out = 1 / math.sqrt(d), 1 / math.sqrt(f)
    p = {
        "router": _init(k[0], (d, e), s_in),
        "experts_wi": _init(k[1], (e, d, f), s_in),
        "experts_wg": _init(k[2], (e, d, f), s_in),
        "experts_wo": _init(k[3], (e, f, d), s_out),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_dff * cfg.n_shared_experts
        p["shared_wi"] = _init(k[4], (d, fs), s_in)
        p["shared_wg"] = _init(jax.random.fold_in(k[4], 1), (d, fs), s_in)
        p["shared_wo"] = _init(k[5], (fs, d), s_out)
    return p


def capacity(cfg: ModelConfig, seq: int) -> int:
    c = int(math.ceil(seq * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(4, -(-c // 4) * 4)  # pad to a multiple of 4 lanes


def moe_block(params, x, cfg: ModelConfig):
    """x (B,S,D) -> (B,S,D).  Returns (out, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    dt = x.dtype

    logits = (x @ params["router"].astype(dt)).astype(jnp.float32)  # B,S,E
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                   # B,S,K
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch): mean prob * mean assignment.
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(gate_idx, E).sum(2).mean(axis=(0, 1)) / K
    aux = E * jnp.sum(me * ce)

    # Position of each (token, k) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)           # B,S,K,E
    flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                            # B,SK,E
    pos = pos.reshape(B, S, K, E)
    in_cap = (pos < C) & (onehot > 0)
    # dispatch[b,s,e,c] = 1 if token s goes to slot c of expert e.
    disp = (jax.nn.one_hot(jnp.where(in_cap, pos, C), C, dtype=dt) *
            in_cap[..., None].astype(dt)).sum(2)                     # B,S,E,C
    comb = (jax.nn.one_hot(jnp.where(in_cap, pos, C), C,
                           dtype=jnp.float32) *
            (gate_vals[..., None] * in_cap.astype(jnp.float32)
             )[..., None]).sum(2)                                    # B,S,E,C
    disp = shard(disp, "dp", None, "tp", None)

    xe = jnp.einsum("bsd,bsec->becd", x, disp)                       # B,E,C,D
    xe = shard(xe, "dp", "tp", None, None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe,
                               params["experts_wg"].astype(dt)))
    h = h * jnp.einsum("becd,edf->becf", xe,
                       params["experts_wi"].astype(dt))
    h = shard(h, "dp", "tp", None, None)
    ye = jnp.einsum("becf,efd->becd", h, params["experts_wo"].astype(dt))
    out = jnp.einsum("becd,bsec->bsd", ye.astype(jnp.float32), comb)
    out = out.astype(dt)

    if cfg.n_shared_experts:
        hs = jax.nn.silu(x @ params["shared_wg"].astype(dt)) * \
            (x @ params["shared_wi"].astype(dt))
        out = out + hs @ params["shared_wo"].astype(dt)
    return out, aux
