"""Vision modules built on the EcoFlow conv dispatch.

* Patchify frontend (InternVL's InternViT entry point): a stride-14
  convolution -- during training its backward pass is *exactly* the
  paper's worst case (stride >> 1); with the naive dataflow ~99.5 % of
  input-gradient MACs multiply inserted zeros and `ecoflow_conv`
  eliminates all of them.  The dry-run `input_specs()` for internvl2-76b
  provides the *output* of this module (precomputed patch embeddings, per
  the assignment's stub rule); the module itself is implemented and
  tested here.

* Atrous segmentation head (ASPP-lite): the dilated-forward workload the
  paper motivates in Sec. 1 -- parallel 3x3 convs at rates {1, 2, 4} with
  same-padding, fused by a 1x1 conv into per-pixel class logits.  Every
  branch routes through `ecoflow_dilated_conv`, so neither the forward
  nor either gradient ever materializes the D-dilated filter.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.conv import ecoflow_conv, ecoflow_dilated_conv
from repro.core.spec import ConvSpec, Epilogue

_RELU = Epilogue(activation="relu")


def patchify_init(rng, *, patch=14, in_ch=3, d_model=1024):
    scale = 1.0 / math.sqrt(patch * patch * in_ch)
    return {
        "proj": scale * jax.random.truncated_normal(
            rng, -2., 2., (patch, patch, in_ch, d_model), jnp.float32),
        "pos": 0.02 * jax.random.normal(
            jax.random.fold_in(rng, 1), (1, 1, d_model), jnp.float32),
    }


def patchify_apply(params, images, *, patch=14, backend=None):
    """images (B,H,W,C) -> patch embeddings (B, H/p * W/p, D).

    `backend` selects the conv dispatch backend (see repro.core.spec)."""
    x = ecoflow_conv(images, params["proj"], patch, 0, backend)
    B, hp, wp, D = x.shape
    return x.reshape(B, hp * wp, D) + params["pos"]


# ---------------------------------------------------------------------------
# Atrous segmentation head (dilated-forward workload)
# ---------------------------------------------------------------------------

def atrous_head_init(rng, *, in_ch=3, width=16, n_classes=4,
                     rates=(1, 2, 4)):
    """ASPP-lite: one 3x3 branch per atrous rate + a 1x1 fuse conv."""
    params = {}
    scale = 1.0 / math.sqrt(9 * in_ch)
    for i, r in enumerate(rates):
        params[f"rate{r}"] = scale * jax.random.normal(
            jax.random.fold_in(rng, i), (3, 3, in_ch, width), jnp.float32)
    fuse_in = width * len(rates)
    params["fuse"] = (1.0 / math.sqrt(fuse_in)) * jax.random.normal(
        jax.random.fold_in(rng, 97), (1, 1, fuse_in, n_classes),
        jnp.float32)
    return params


def atrous_head_apply(params, images, *, rates=(1, 2, 4), backend=None,
                      fuse_epilogue=True):
    """images (B,H,W,C) -> per-pixel class logits (B,H,W,n_classes).

    Each 3x3 branch runs at stride 1 with padding == rate (same-padding
    for the D*(K-1)+1 = 2r+1 effective receptive field), so all branches
    stay at full resolution and concatenate channel-wise before the 1x1
    fuse.  `backend` selects the conv dispatch backend; `fuse_epilogue`
    requests each branch's relu through the dilated conv's epilogue slot
    (DESIGN.md Sec. 2.8)."""
    if fuse_epilogue:
        feats = [ecoflow_dilated_conv(images, params[f"rate{r}"], 1, r, r,
                                      backend, epilogue=_RELU)
                 for r in rates]
    else:
        feats = [jax.nn.relu(ecoflow_dilated_conv(
            images, params[f"rate{r}"], 1, r, r, backend)) for r in rates]
    h = jnp.concatenate(feats, axis=-1)
    return ecoflow_conv(h, params["fuse"], 1, 0, backend)


def atrous_plan_requests(params, image_shape, *, rates=(1, 2, 4),
                         fuse_epilogue=True):
    """Tile-planning warmup entries for one serving bucket of the atrous
    head: one `"forward"` entry per dilated 3x3 branch plus the 1x1 fuse
    conv, in the `(op, spec, x_shape, dy_shape, epilogue)` form
    `kernels.tiling.warmup_plans` consumes.  `image_shape` is the
    bucket's padded batch shape (B, H, W, C); every branch is
    same-padded, so all output shapes stay (B, H, W, .)."""
    b, h, w, c = (int(s) for s in image_shape)
    entries = []
    for r in rates:
        wt = params[f"rate{r}"]
        spec = ConvSpec.make(stride=1, padding=r,
                             filter_shape=tuple(wt.shape[:2]), dilation=r)
        entries.append(("forward", spec, (b, h, w, c),
                        (b, h, w, int(wt.shape[3])),
                        _RELU if fuse_epilogue else None))
    fuse = params["fuse"]
    spec = ConvSpec.make(stride=1, padding=0, filter_shape=1)
    entries.append(("forward", spec, (b, h, w, int(fuse.shape[2])),
                    (b, h, w, int(fuse.shape[3])), None))
    return entries


def atrous_seg_loss(params, images, labels, *, rates=(1, 2, 4),
                    backend=None, fuse_epilogue=True):
    """Mean per-pixel cross entropy of the atrous head."""
    logits = atrous_head_apply(params, images, rates=rates, backend=backend,
                               fuse_epilogue=fuse_epilogue)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()
