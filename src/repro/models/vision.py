"""ViT patchify frontend (InternVL's InternViT entry point).

A stride-14 convolution: during training its backward pass is *exactly*
the paper's worst case (stride >> 1) -- with the naive dataflow ~99.5 % of
input-gradient MACs multiply inserted zeros; `ecoflow_conv` eliminates all
of them.  The dry-run `input_specs()` for internvl2-76b provides the
*output* of this module (precomputed patch embeddings, per the
assignment's stub rule); the module itself is implemented and tested here.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.conv import ecoflow_conv


def patchify_init(rng, *, patch=14, in_ch=3, d_model=1024):
    scale = 1.0 / math.sqrt(patch * patch * in_ch)
    return {
        "proj": scale * jax.random.truncated_normal(
            rng, -2., 2., (patch, patch, in_ch, d_model), jnp.float32),
        "pos": 0.02 * jax.random.normal(
            jax.random.fold_in(rng, 1), (1, 1, d_model), jnp.float32),
    }


def patchify_apply(params, images, *, patch=14, backend=None):
    """images (B,H,W,C) -> patch embeddings (B, H/p * W/p, D).

    `backend` selects the conv dispatch backend (see repro.core.spec)."""
    x = ecoflow_conv(images, params["proj"], patch, 0, backend)
    B, hp, wp, D = x.shape
    return x.reshape(B, hp * wp, D) + params["pos"]
