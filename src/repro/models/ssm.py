"""Linear-attention / SSM substrate: chunked training scan + recurrent
decode, shared by Mamba2 (SSD, per-head scalar decay) and RWKV6 (Finch,
data-dependent per-channel decay).

The recurrence (per head, state S in R^{dk x dv}):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = q_t^T S_{t'}  (+ u-bonus diagonal term for RWKV)
with t' = t (Mamba2 reads the post-update state) or t-1 (RWKV reads the
pre-update state, the current token entering through the u bonus).

Training uses the chunk-parallel form (GLA/SSD style): within a chunk of T
tokens the strictly-lower-triangular part is a dense attention matmul with
relative decay exp(A_i - A_j); across chunks a lax.scan carries the state.
All exponentials are bounded by clamping per-step log-decay to
LOG_DECAY_MIN = -80/T (industry practice in chunked linear-attention
kernels; see DESIGN.md numerics note).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig


def log_decay_min(chunk: int) -> float:
    return -80.0 / chunk


def chunked_linear_attention(q, k, v, log_w, *, chunk: int,
                             u: Optional[jax.Array] = None,
                             state0: Optional[jax.Array] = None,
                             pre_update_read: bool = False):
    """q,k,log_w (B,S,H,dk); v (B,S,H,dv); u (H,dk) or None.

    Returns (y (B,S,H,dv), final_state (B,H,dk,dv)).
    pre_update_read=True gives the RWKV semantics (y reads S_{t-1}; the
    diagonal term is weighted by u), False the Mamba2/SSD semantics
    (y reads S_t; diagonal weight 1).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    T = min(chunk, S)
    pad = (-S) % T
    if pad:
        # Zero k/v and log_w=0 (w=1) leave the carried state untouched.
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) *
                                 (t.ndim - 2))
        q, k, v, log_w = zpad(q), zpad(k), zpad(v), zpad(log_w)
    S_pad = S + pad
    nc = S_pad // T
    log_w = jnp.clip(log_w.astype(jnp.float32), log_decay_min(T), 0.0)

    def rs(x):  # (B,S_pad,...) -> (nc,B,T,...)
        return jnp.moveaxis(x.reshape(B, nc, T, *x.shape[2:]), 1, 0)

    qc, kc, vc, wc = rs(q.astype(jnp.float32)), rs(k.astype(jnp.float32)), \
        rs(v.astype(jnp.float32)), rs(log_w)

    if u is None:
        dcoef = jnp.ones((H, dk), jnp.float32)
    else:
        dcoef = u.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((T, T), jnp.float32), k=-1)

    def body(state, inp):
        qb, kb, vb, wb = inp                      # (B,T,H,*)
        A = jnp.cumsum(wb, axis=1)                # inclusive cumlog decay
        A_q = A - wb if pre_update_read else A
        q_s = qb * jnp.exp(A_q)                   # exp <= 1
        k_s = kb * jnp.exp(-A)                    # exp <= e^{80}
        att = jnp.einsum("bihd,bjhd->bhij", q_s, k_s) * tri
        y = jnp.einsum("bhij,bjhe->bihe", att, vb)
        diag = jnp.einsum("bihd,bihd,hd->bih", qb, kb, dcoef)
        y = y + diag[..., None] * vb
        y = y + jnp.einsum("bihd,bhde->bihe", q_s, state)
        A_last = A[:, -1:]                        # (B,1,H,dk)
        k_T = kb * jnp.exp(A_last - A)            # exp <= 1
        state = state * jnp.exp(A_last[:, 0])[..., None] + \
            jnp.einsum("bjhd,bjhe->bhde", k_T, vb)
        return state, y

    s0 = state0.astype(jnp.float32) if state0 is not None else \
        jnp.zeros((B, H, dk, dv), jnp.float32)
    state, ys = lax.scan(body, s0, (qc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S_pad, H, dv)[:, :S]
    return y.astype(q.dtype), state


def linear_attention_decode(q, k, v, log_w, state, *, u=None,
                            pre_update_read: bool = False):
    """One-token recurrent step.  q,k,log_w (B,H,dk), v (B,H,dv),
    state (B,H,dk,dv) -> (y (B,H,dv), new_state)."""
    log_w = jnp.clip(log_w.astype(jnp.float32), -80.0, 0.0)
    w = jnp.exp(log_w)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    new_state = state * w[..., None] + kf[..., None] * vf[..., None, :]
    read = state if pre_update_read else new_state
    y = jnp.einsum("bhd,bhde->bhe", qf, read)
    dcoef = jnp.ones_like(kf) if u is None else u.astype(jnp.float32)
    if pre_update_read:
        y = y + jnp.einsum("bhd,bhd->bh", qf * dcoef, kf)[..., None] * vf
    return y.astype(q.dtype), new_state


def recurrent_reference(q, k, v, log_w, *, u=None, pre_update_read=False,
                        state0=None):
    """Step-by-step oracle for the chunked scan (tests)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    s = state0 if state0 is not None else jnp.zeros((B, H, dk, dv),
                                                    jnp.float32)
    ys = []
    for t in range(S):
        y, s = linear_attention_decode(q[:, t], k[:, t], v[:, t],
                                       log_w[:, t], s, u=u,
                                       pre_update_read=pre_update_read)
        ys.append(y)
    return jnp.stack(ys, axis=1), s


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (Mamba's short conv).  Stride-1 => the EcoFlow
# dataflow degenerates to the direct dataflow (no padding zeros exist); the
# tap-sum below *is* the zero-free schedule.
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B,S,C), w (K,C) depthwise causal: y[t] = sum_k w[k] x[t-K+1+k]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    S = x.shape[1]
    for kk in range(K):
        y = y + xp[:, kk:kk + S, :].astype(jnp.float32) * w[kk]
    return y.astype(x.dtype)


def causal_conv1d_step(x_t: jax.Array, conv_state: jax.Array,
                       w: jax.Array):
    """x_t (B,C), conv_state (B,K-1,C) of previous inputs."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32))
    return y.astype(x_t.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 block (SSD)
# ---------------------------------------------------------------------------

def _init(rng, shape, scale):
    return scale * jax.random.truncated_normal(rng, -2., 2., shape,
                                               dtype=jnp.float32)


def mamba2_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    H = di // cfg.ssm_head_dim
    ks = jax.random.split(rng, 4)
    s = 1 / math.sqrt(d)
    return {
        # z, x, B, C, dt fused input projection
        "in_proj": _init(ks[0], (d, 2 * di + 2 * n + H), s),
        "conv_w": _init(ks[1], (cfg.ssm_conv, di + 2 * n), 0.5),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": _init(ks[2], (di, d), 1 / math.sqrt(di)),
    }


def _mamba_parts(params, x, cfg: ModelConfig):
    di, n = cfg.d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    dt_ = x.dtype
    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt_raw, di, n, H


def _mamba_ssm_inputs(params, xbc, dt_raw, cfg, di, n, H):
    xs, B_in, C_in = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = jnp.exp(params["A_log"])                   # (H,) positive
    log_w = (-dt * A)[..., None]                   # (..., H, 1)
    lead = xs.shape[:-1]
    xs = xs.reshape(*lead, H, cfg.ssm_head_dim)
    v = xs * dt[..., None].astype(xs.dtype)
    k = jnp.broadcast_to(B_in[..., None, :], (*lead, H, n)).astype(xs.dtype)
    q = jnp.broadcast_to(C_in[..., None, :], (*lead, H, n)).astype(xs.dtype)
    log_w = jnp.broadcast_to(log_w, (*lead, H, n))
    return xs, q, k, v, log_w


def mamba2_block(params, x, cfg: ModelConfig):
    """x (B,S,D) -> (B,S,D) (training / prefill)."""
    from repro.models.layers import rmsnorm
    B, S, D = x.shape
    z, xbc, dt_raw, di, n, H = _mamba_parts(params, x, cfg)
    xbc = causal_conv1d(xbc, params["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs, q, k, v, log_w = _mamba_ssm_inputs(params, xbc, dt_raw, cfg, di, n, H)
    y, _ = chunked_linear_attention(q, k, v, log_w, chunk=cfg.chunk_size)
    y = y + params["D"].astype(x.dtype)[:, None] * xs
    y = y.reshape(B, S, di)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"].astype(x.dtype)


def mamba2_decode(params, x, cfg: ModelConfig, conv_state, ssm_state):
    """x (B,1,D); conv_state (B,K-1,C); ssm_state (B,H,n,dh)."""
    from repro.models.layers import rmsnorm
    B, S, D = x.shape
    z, xbc, dt_raw, di, n, H = _mamba_parts(params, x[:, 0], cfg)
    xbc, conv_state = causal_conv1d_step(xbc, conv_state, params["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs, q, k, v, log_w = _mamba_ssm_inputs(params, xbc, dt_raw, cfg, di, n, H)
    y, ssm_state = linear_attention_decode(q, k, v, log_w, ssm_state)
    y = y + params["D"].astype(x.dtype)[:, None] * xs
    y = y.reshape(B, di)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return (y @ params["out_proj"].astype(x.dtype))[:, None, :], \
        conv_state, ssm_state


# ---------------------------------------------------------------------------
# RWKV6 block (Finch): data-dependent per-channel decay
# ---------------------------------------------------------------------------

def rwkv6_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    dk = cfg.ssm_head_dim
    H = d // dk
    low = 64  # decay LoRA rank
    ks = jax.random.split(rng, 10)
    s = 1 / math.sqrt(d)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),   # r,k,v,w,g token-shift
        "wr": _init(ks[0], (d, d), s), "wk": _init(ks[1], (d, d), s),
        "wv": _init(ks[2], (d, d), s), "wg": _init(ks[3], (d, d), s),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),
        "w1": _init(ks[4], (d, low), s),
        "w2": _init(ks[5], (low, d), 1 / math.sqrt(low)),
        "u": _init(ks[6], (H, dk), 1.0),
        "ln_scale": jnp.zeros((d,), jnp.float32),
        "wo": _init(ks[7], (d, d), s),
        # channel mix
        "mu_c": 0.5 * jnp.ones((2, d), jnp.float32),
        "ck": _init(ks[8], (d, cfg.d_ff), s),
        "cr": _init(jax.random.fold_in(ks[8], 1), (d, d), s),
        "cv": _init(ks[9], (cfg.d_ff, d), 1 / math.sqrt(cfg.d_ff)),
    }


def _token_shift(x, x_prev):
    """x (B,S,D); x_prev (B,1,D) last token of the previous segment."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _rwkv_mix(params, x, xs):
    mu = params["mu"]
    mix = lambda i: (x + mu[i] * (xs - x)).astype(x.dtype)
    return mix(0), mix(1), mix(2), mix(3), mix(4)


def _rwkv_qkvwg(params, x, xs, cfg):
    dt = x.dtype
    d = x.shape[-1]
    dk = cfg.ssm_head_dim
    H = d // dk
    xr, xk, xv, xw, xg = _rwkv_mix(params, x, xs)
    lead = x.shape[:-1]
    r = (xr @ params["wr"].astype(dt)).reshape(*lead, H, dk)
    k = (xk @ params["wk"].astype(dt)).reshape(*lead, H, dk)
    v = (xv @ params["wv"].astype(dt)).reshape(*lead, H, dk)
    g = xg @ params["wg"].astype(dt)
    # Data-dependent decay (the Finch contribution):
    ww = params["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["w1"]) @ params["w2"]
    log_w = -jnp.exp(ww).reshape(*lead, H, dk)
    return r, k, v, g, log_w


def rwkv6_time_mix(params, x, cfg: ModelConfig, x_prev=None):
    """Returns (out, x_last (B,1,D), state (B,H,dk,dk)) for caching."""
    from repro.models.layers import rmsnorm
    B, S, D = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, D), x.dtype)
    xs = _token_shift(x, x_prev)
    r, k, v, g, log_w = _rwkv_qkvwg(params, x, xs, cfg)
    y, state = chunked_linear_attention(
        r, k, v, log_w, chunk=cfg.chunk_size, u=params["u"],
        pre_update_read=True)
    y = y.reshape(B, S, D)
    y = rmsnorm({"scale": params["ln_scale"]}, y, cfg.norm_eps)
    out = (y * jax.nn.silu(g)) @ params["wo"].astype(x.dtype)
    return out, x[:, -1:], state


def rwkv6_time_mix_decode(params, x, cfg: ModelConfig, x_prev, state):
    """x (B,1,D); x_prev (B,1,D); state (B,H,dk,dk)."""
    from repro.models.layers import rmsnorm
    B, S, D = x.shape
    xs = x_prev
    r, k, v, g, log_w = _rwkv_qkvwg(params, x[:, 0], xs[:, 0], cfg)
    y, state = linear_attention_decode(r, k, v, log_w, state,
                                       u=params["u"], pre_update_read=True)
    y = y.reshape(B, D)
    y = rmsnorm({"scale": params["ln_scale"]}, y, cfg.norm_eps)
    out = (y * jax.nn.silu(g)) @ params["wo"].astype(x.dtype)
    return out[:, None, :], x, state


def rwkv6_channel_mix(params, x, cfg: ModelConfig, x_prev=None):
    """Returns (out, x_last (B,1,D))."""
    B, S, D = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, D), x.dtype)
    xs = _token_shift(x, x_prev)
    mu = params["mu_c"]
    xk = (x + mu[0] * (xs - x)).astype(x.dtype)
    xr = (x + mu[1] * (xs - x)).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ params["ck"].astype(x.dtype)))
    rr = jax.nn.sigmoid(xr @ params["cr"].astype(x.dtype))
    return rr * (kk @ params["cv"].astype(x.dtype)), x[:, -1:]
