"""Model configuration for every supported architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    # attention (ignored for pure-SSM archs)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    act: str = "swiglu"          # swiglu | geglu | gelu (non-gated)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM / linear attention
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    chunk_size: int = 64         # linear-attention chunk length
    # hybrid (zamba2): one shared attention block applied every attn_every
    # mamba blocks, with shared weights (Zamba's parameter-sharing trick)
    attn_every: int = 0
    # io
    embed_input: bool = False    # audio/vlm stub: inputs are embeddings
    # int8 KV cache (serving): halves the decode memory stream -- the
    # dominant roofline term after the Perf A1 cache fixes.  Per
    # (position, head) max-abs scales; transformer families only.
    kv_quant: bool = False
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # numerics / compile
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"          # none | full
    # Target number of gradient-accumulation microbatches for train_4k
    # (effective count is clamped so the per-microbatch batch still divides
    # the data axes; see launch/steps.py).
    microbatch: int = 1
    attn_chunk: int = 1024       # flash-attention kv/q chunk
    loss_chunk: int = 512        # vocab-logit sequence chunking
    # True when attention is sub-quadratic / absent => long_500k supported
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}
