"""Shared transformer layers: norms, rope, attention, MLPs, embeddings.

All functions are pure (params passed explicitly as dict pytrees), bf16
compute / fp32 params, and compile-friendly for 94-layer scans at 512
SPMD partitions: attention is chunked (flash-style online softmax) so the
S x S score matrix never materializes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.parallel.sharding import shard  # activation-sharding helper


def _init(rng, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else (1.0 / math.sqrt(shape[0]))
    return scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape,
                                               dtype=dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    # f32 accumulation via einsum, but x itself stays bf16: a wholesale
    # x.astype(f32) here becomes, under the layer scan's backward pass, a
    # hoisted f32 copy of the entire (L,B,S,D) activation stash (XLA moves
    # `convert` above the per-layer dynamic-slice), tripling activation
    # memory.  Measured: internvl2-76b train cell 19.5 -> 9.5 GiB/device.
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    var = ss[..., None] / x.shape[-1]
    scale = lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return x * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B,S,H,D), positions (B,S) -> rotated x."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, flash-style chunked causal; decode path over a KV cache)
# ---------------------------------------------------------------------------

def attention_init(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 5)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": _init(k[0], (d, qd)),
        "wk": _init(k[1], (d, kvd)),
        "wv": _init(k[2], (d, kvd)),
        "wo": _init(k[3], (qd, d), scale=1.0 / math.sqrt(qd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), jnp.float32)
        p["bk"] = jnp.zeros((kvd,), jnp.float32)
        p["bv"] = jnp.zeros((kvd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim)
        p["k_norm"] = rmsnorm_init(cfg.head_dim)
    return p


def _qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention(q, k, v, *, causal: bool = True, chunk: int = 1024,
                    q_offset: int = 0):
    """Chunked online-softmax attention; never materializes S x S scores.

    q (B,Sq,Hq,D), k/v (B,Sk,Hk,D) with Hq % Hk == 0.  `q_offset` is the
    absolute position of q[0] relative to k[0] (for decode: Sk - Sq).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    g = Hq // Hk
    scale = D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hk, g, D)
    nkc = -(-Sk // chunk)
    pad = nkc * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nkc, chunk, Hk, D)
    vc = v.reshape(B, nkc, chunk, Hk, D)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        acc, m, l = carry
        kb, vb, ci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb.astype(jnp.float32))
        mask = k_pos[None, :] <= q_pos[:, None] if causal else \
            (k_pos[None, :] < Sk) | jnp.zeros((Sq, 1), bool)
        mask = mask & (k_pos[None, :] < Sk)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, Hk, g, D), jnp.float32)
    m0 = jnp.full((B, Sq, Hk, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hk, g), jnp.float32)
    (acc, m, l), _ = lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nkc)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attention_block(params, x, cfg: ModelConfig, positions):
    """Training / prefill attention.  Returns (out, (k, v)) for caching."""
    q, k, v = _qkv(params, x, cfg, positions)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    out = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    B, S, _, _ = out.shape
    out = out.reshape(B, S, cfg.q_dim) @ params["wo"].astype(x.dtype)
    return out, (k, v)


def attention_decode(params, x, cfg: ModelConfig, cache_k, cache_v,
                     cache_len):
    """Single-token decode against a KV cache.

    x (B,1,D); cache_k/v (B,Smax,Hk,D); cache_len scalar int32 (tokens
    already in the cache).  Returns (out, new_k, new_v).
    """
    B, S, _ = x.shape
    positions = (cache_len + jnp.arange(S))[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (B, S))
    q, k, v = _qkv(params, x, cfg, positions)
    ck = lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                  (0, cache_len, 0, 0))
    cv = lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                  (0, cache_len, 0, 0))
    Smax = ck.shape[1]
    g = cfg.n_heads // cfg.n_kv_heads
    # Keep the cache in its storage dtype and accumulate in f32 via
    # preferred_element_type: materializing ck.astype(f32) doubles the
    # dominant HBM stream of the decode step AND forces GSPMD to gather
    # the converted copy (measured: 2 x 50 GB f32 all-gathers per step on
    # qwen3-moe-235b decode_32k -- see EXPERIMENTS.md Sec. Perf, change 1).
    qf = (q.astype(jnp.float32) * cfg.head_dim ** -0.5).astype(ck.dtype)
    qf = qf.reshape(B, S, cfg.n_kv_heads, g, cfg.head_dim)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, ck,
                   preferred_element_type=jnp.float32)
    k_pos = jnp.arange(Smax)[None, :]
    q_pos = (cache_len + jnp.arange(S))[:, None]
    mask = k_pos <= q_pos
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, S, cfg.q_dim).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), ck, cv


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (serving)
# ---------------------------------------------------------------------------

def kv_quantize(k: jax.Array):
    """(.., S, H, D) bf16 -> (int8 values, f32 scales (.., S, H)).
    Per (position, head) max-abs scaling."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode_quant(params, x, cfg: ModelConfig, cache_k, cache_v,
                           k_scale, v_scale, cache_len):
    """attention_decode against an int8-quantized KV cache.

    cache_k/v (B,Smax,Hk,D) int8; k_scale/v_scale (B,Smax,Hk) f32.
    Returns (out, ck, cv, ks, vs).
    """
    B, S, _ = x.shape
    positions = (cache_len + jnp.arange(S))[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (B, S))
    q, k, v = _qkv(params, x, cfg, positions)
    kq, ks_new = kv_quantize(k)
    vq, vs_new = kv_quantize(v)
    ck = lax.dynamic_update_slice(cache_k, kq, (0, cache_len, 0, 0))
    cv = lax.dynamic_update_slice(cache_v, vq, (0, cache_len, 0, 0))
    ks = lax.dynamic_update_slice(k_scale, ks_new, (0, cache_len, 0))
    vs = lax.dynamic_update_slice(v_scale, vs_new, (0, cache_len, 0))
    Smax = ck.shape[1]
    g = cfg.n_heads // cfg.n_kv_heads
    qf = (q.astype(jnp.float32) * cfg.head_dim ** -0.5
          ).reshape(B, S, cfg.n_kv_heads, g, cfg.head_dim)
    # int8 contraction with late scale application: the D-contraction runs
    # on the int8 stream (s8 x f32 accumulate); the per-(pos,head) scale
    # multiplies the (B,q,h,g,k) scores -- no dequantized cache copy.
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, ck.astype(jnp.float32))
    s = s * jnp.moveaxis(ks, 1, -1)[:, None, :, None, :]   # (B,1,h,1,Smax)
    k_pos = jnp.arange(Smax)[None, :]
    q_pos = (cache_len + jnp.arange(S))[:, None]
    mask = k_pos <= q_pos
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * jnp.moveaxis(vs, 1, -1)[:, None, :, None, :]
    out = jnp.einsum("bqhgk,bkhd->bqhgd", pv, cv.astype(jnp.float32))
    out = out.reshape(B, S, cfg.q_dim).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), ck, cv, ks, vs


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    if cfg.act == "gelu":
        return {"wi": _init(k[0], (d, f)), "wo": _init(k[1], (f, d))}
    return {"wi": _init(k[0], (d, f)), "wg": _init(k[1], (d, f)),
            "wo": _init(k[2], (f, d))}


def mlp_block(params, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.act == "gelu":
        h = jax.nn.gelu(x @ params["wi"].astype(dt))
    else:
        gate_fn = jax.nn.silu if cfg.act == "swiglu" else \
            (lambda t: jax.nn.gelu(t, approximate=True))
        h = gate_fn(x @ params["wg"].astype(dt)) * (x @ params["wi"].astype(dt))
    h = shard(h, "dp", None, "tp")
    return h @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy head
# ---------------------------------------------------------------------------

def embedding_init(rng, cfg: ModelConfig):
    p = {"tok": _init(rng, (cfg.vocab, cfg.d_model), scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = _init(jax.random.fold_in(rng, 1),
                          (cfg.d_model, cfg.vocab))
    return p


def embed(params, tokens, cfg: ModelConfig):
    return params["tok"].astype(cfg.compute_dtype)[tokens]


def logits_head(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return (x @ params["tok"].T.astype(x.dtype)).astype(jnp.float32)
    return (x @ params["head"].astype(x.dtype)).astype(jnp.float32)


def chunked_xent(params, x, labels, cfg: ModelConfig):
    """Cross-entropy without materializing (B,S,V) logits: scan over
    sequence chunks, rematerializing logits in the backward pass."""
    B, S, D = x.shape
    c = min(cfg.loss_chunk, S)
    nc = S // c if S % c == 0 else -(-S // c)
    pad = nc * c - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = jnp.moveaxis(x.reshape(B, nc, c, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        xb, lb = inp
        logits = logits_head(params, xb, cfg)          # (B,c,V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        valid = (lb >= 0).astype(jnp.float32)
        nll = (logz - gold) * valid
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = lax.scan(chunk_loss, (0.0, 0.0), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def tree_all_finite(*trees) -> jax.Array:
    """Scalar bool: every inexact leaf of every given pytree is finite.

    The in-graph numerics guard (DESIGN.md Sec. 2.12): a handful of
    `isfinite(...).all()` reductions folded into the SAME jitted step --
    cheap XLA element-wise + reduce ops, no extra kernel launch per conv
    layer -- so a guarded step costs one fused tail, not a second pass
    over the model.  Integer leaves (labels, counters) are skipped."""
    flags = []
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            leaf = jnp.asarray(leaf)
            if jnp.issubdtype(leaf.dtype, jnp.inexact):
                flags.append(jnp.isfinite(leaf).all())
    out = jnp.asarray(True)
    for f in flags:
        out = jnp.logical_and(out, f)
    return out
