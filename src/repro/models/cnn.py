"""CNN models for the paper's training evaluation domain.

Every convolution routes through `ecoflow_conv`, so the backward pass uses
the paper's zero-free transposed (input-grad) and dilated (filter-grad)
dataflows.  The `strided` variant replaces pooling with larger-stride convs
(paper Sec. 6.1.1 optimization).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.conv import ecoflow_conv
from repro.core.spec import Epilogue

_RELU = Epilogue(activation="relu")


def _conv_init(rng, k, cin, cout):
    scale = 1.0 / math.sqrt(k * k * cin)
    return scale * jax.random.truncated_normal(rng, -2., 2.,
                                               (k, k, cin, cout), jnp.float32)


def simple_cnn_init(rng, *, in_ch=3, widths=(32, 64, 128), n_classes=10,
                    k=3):
    """AllConvNet-style CNN: stride-2 convs instead of pooling."""
    keys = jax.random.split(rng, len(widths) + 1)
    params = {"convs": []}
    c = in_ch
    for i, w in enumerate(widths):
        params["convs"].append(_conv_init(keys[i], k, c, w))
        c = w
    params["head"] = (1.0 / math.sqrt(c)) * jax.random.truncated_normal(
        keys[-1], -2., 2., (c, n_classes), jnp.float32)
    return params


def simple_cnn_apply(params, x, *, stride=2, backend=None,
                     fuse_epilogue=True):
    """x (B,H,W,Cin) -> logits (B,n_classes).

    `backend` selects the conv dispatch backend
    (reference | xla_zero_free | pallas, see repro.core.spec).
    `fuse_epilogue` requests each layer's relu declaratively through the
    conv's epilogue slot (one fused launch per layer, forward AND
    backward -- DESIGN.md Sec. 2.8); False keeps the separate
    `jax.nn.relu` tail for A/B comparison."""
    for w in params["convs"]:
        if fuse_epilogue:
            x = ecoflow_conv(x, w, stride, 1, backend, epilogue=_RELU)
        else:
            x = jax.nn.relu(ecoflow_conv(x, w, stride, 1, backend))
    x = x.mean(axis=(1, 2))
    return x @ params["head"]


def cnn_loss(params, x, labels, *, stride=2, backend=None,
             fuse_epilogue=True):
    logits = simple_cnn_apply(params, x, stride=stride,
                              backend=backend,
                              fuse_epilogue=fuse_epilogue)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


def sgd_step(params, x, labels, *, lr=0.05, stride=2, backend=None,
             fuse_epilogue=True):
    """One SGD step: (new_params, loss).

    Mesh-aware: traced under a `repro.parallel.sharding.use_mesh` context
    the convs dispatch to shard_map'd launches (batch on "dp", channels
    on "tp" -- DESIGN.md Sec. 2.9) and the constraint below keeps the
    batch dim of the input sharded; outside a mesh both are no-ops and
    the step is the plain single-device jaxpr."""
    from repro.parallel import sharding

    x = sharding.shard(x, "dp", None, None, None)
    loss, grads = jax.value_and_grad(cnn_loss)(
        params, x, labels, stride=stride, backend=backend,
        fuse_epilogue=fuse_epilogue)
    new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def guarded_sgd_step(params, x, labels, *, lr=0.05, stride=2, backend=None,
                     fuse_epilogue=True):
    """`sgd_step` + the in-graph numerics guard: (new_params, loss,
    all_finite), where `all_finite` is a scalar bool over the UPDATED
    params and the loss, computed inside the same jit (cheap XLA
    reductions -- the guarded step is jaxpr-pinned to the same
    `pallas_call` count as the unguarded one, DESIGN.md Sec. 2.12).
    `lr` may be a traced scalar, so shrink-lr retries reuse the
    compiled step."""
    from repro.models.layers import tree_all_finite

    new, loss = sgd_step(params, x, labels, lr=lr, stride=stride,
                         backend=backend, fuse_epilogue=fuse_epilogue)
    return new, loss, tree_all_finite(new, loss)
