"""GenericLM: one model class covering all 10 assigned architectures.

Families:
  dense / moe / audio / vlm : pre-norm transformer decoder (GQA attention,
      gated MLP or MoE).  audio/vlm take precomputed frontend embeddings
      (`cfg.embed_input`) per the assignment (frontend is a stub).
  ssm    : RWKV6 (time-mix + channel-mix blocks).
  hybrid : Zamba2-style -- Mamba2 blocks with one *shared-weight* attention
      block applied every `attn_every` Mamba blocks.

Layers are stacked and executed with `lax.scan` (per-layer remat), so the
94-layer MoE compiles as a single block body.  Decode carries an explicit
cache pytree (KV for attention, conv+SSM state for ssm/hybrid).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


def _stack_init(block_init, rng, n):
    rngs = jax.random.split(rng, n)
    return jax.vmap(block_init)(rngs)


@jax.custom_vjp
def _diff_barrier(x):
    """`lax.optimization_barrier` with a differentiation rule (identity;
    the cotangent is barriered too, preserving the hoisting fence in the
    backward pass).  jax 0.4.x has no built-in rule for the primitive."""
    return lax.optimization_barrier(x)


def _diff_barrier_fwd(x):
    return _diff_barrier(x), None


def _diff_barrier_bwd(_, g):
    return (lax.optimization_barrier(g),)


_diff_barrier.defvjp(_diff_barrier_fwd, _diff_barrier_bwd)


# ---------------------------------------------------------------------------
# Per-family block definitions
# ---------------------------------------------------------------------------

def _tf_block_init(cfg: ModelConfig):
    def init(rng):
        k = jax.random.split(rng, 2)
        p = {"ln1": L.rmsnorm_init(cfg.d_model),
             "attn": L.attention_init(k[0], cfg),
             "ln2": L.rmsnorm_init(cfg.d_model)}
        if cfg.n_experts:
            p["moe"] = M.moe_init(k[1], cfg)
        else:
            p["mlp"] = L.mlp_init(k[1], cfg)
        return p
    return init


def _tf_block_apply(p, x, cfg: ModelConfig, positions):
    h, _ = L.attention_block(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                             cfg, positions)
    x = x + h
    x = shard(x, "dp", None, None)
    hin = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        if hin.shape[1] == 1:  # decode: group over batch instead of seq
            h2, aux = M.moe_block(p["moe"], hin.transpose(1, 0, 2), cfg)
            h2 = h2.transpose(1, 0, 2)
        else:
            h2, aux = M.moe_block(p["moe"], hin, cfg)
    else:
        h2, aux = L.mlp_block(p["mlp"], hin, cfg), 0.0
    return x + h2, aux


def _rwkv_block_init(cfg: ModelConfig):
    def init(rng):
        return {"ln1": L.rmsnorm_init(cfg.d_model),
                "ln2": L.rmsnorm_init(cfg.d_model),
                "mix": S.rwkv6_init(rng, cfg)}
    return init


def _mamba_block_init(cfg: ModelConfig):
    def init(rng):
        return {"ln": L.rmsnorm_init(cfg.d_model),
                "mamba": S.mamba2_init(rng, cfg)}
    return init


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # -- init ---------------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        ke, kb, ks = jax.random.split(rng, 3)
        params = {"embed": L.embedding_init(ke, cfg),
                  "final_norm": L.rmsnorm_init(cfg.d_model)}
        if cfg.family == "ssm":
            params["blocks"] = _stack_init(_rwkv_block_init(cfg), kb,
                                           cfg.n_layers)
        elif cfg.family == "hybrid":
            params["blocks"] = _stack_init(_mamba_block_init(cfg), kb,
                                           cfg.n_layers)
            params["shared_attn"] = _tf_block_init(cfg)(ks)
        else:
            params["blocks"] = _stack_init(_tf_block_init(cfg), kb,
                                           cfg.n_layers)
        return params

    # -- shared -------------------------------------------------------------
    def _embed_in(self, params, inputs):
        cfg = self.cfg
        if cfg.embed_input:
            return inputs.astype(cfg.compute_dtype)
        return L.embed(params["embed"], inputs, cfg)

    def _groups(self):
        cfg = self.cfg
        assert cfg.attn_every and cfg.n_layers % cfg.attn_every == 0
        return cfg.n_layers // cfg.attn_every, cfg.attn_every

    # -- forward (training) --------------------------------------------------
    def forward(self, params, inputs, positions=None):
        """inputs: tokens (B,S) int32 or embeddings (B,S,D).  Returns
        (hidden (B,S,D), aux_loss)."""
        cfg = self.cfg
        x = self._embed_in(params, inputs)
        B, Ssz, D = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(Ssz)[None], (B, Ssz))
        x = shard(x, "dp", None, None)

        if cfg.family == "ssm":
            def body(x, p):
                h, _, _ = S.rwkv6_time_mix(
                    p["mix"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg)
                x = x + h
                h2, _ = S.rwkv6_channel_mix(
                    p["mix"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
                return x + h2, 0.0
        elif cfg.family == "hybrid":
            def mamba_body(x, p):
                return x + S.mamba2_block(
                    p["mamba"], L.rmsnorm(p["ln"], x, cfg.norm_eps), cfg), 0.0

            def body(x, pg):  # one group: shared attn + attn_every mambas
                x, aux = _tf_block_apply(params["shared_attn"], x, cfg,
                                         positions)
                mb = mamba_body
                if cfg.remat == "full":
                    mb = jax.checkpoint(mamba_body)
                x, _ = lax.scan(lambda c, p: (mb(c, p)[0], None), x, pg)
                return x, aux
        else:
            def body(x, p):
                return _tf_block_apply(p, x, cfg, positions)

        if cfg.remat == "full":
            inner = body

            def body(x, p, _inner=inner):
                # Barrier INSIDE the remat region: during the backward
                # recompute the first op on the stashed bf16 activations
                # becomes barrier->convert, which XLA cannot hoist above
                # the per-layer dynamic-slice.  Without it the whole
                # (L,B,S,D) stash is converted to f32 wholesale, tripling
                # resident activation memory.
                return _inner(_diff_barrier(x), p)

            body = jax.checkpoint(body)

        blocks = params["blocks"]
        if cfg.family == "hybrid":
            G, per = self._groups()
            blocks = jax.tree.map(
                lambda a: a.reshape(G, per, *a.shape[1:]), blocks)

        def scan_fn(x, p):
            x, a = body(x, p)
            return x, a

        x, aux_stack = lax.scan(scan_fn, x, blocks)
        aux = jnp.sum(aux_stack) if cfg.n_experts else 0.0
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux

    def loss(self, params, inputs, labels):
        x, aux = self.forward(params, inputs)
        nll = L.chunked_xent(params["embed"], x, labels, self.cfg)
        return nll + 0.01 * aux, {"nll": nll, "aux": aux}

    # -- cache --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None):
        """Abstract-safe cache construction (jnp.zeros only)."""
        cfg = self.cfg
        dt = dtype or cfg.compute_dtype
        if cfg.family == "ssm":
            H = cfg.d_model // cfg.ssm_head_dim
            return {
                "x_prev_t": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dt),
                "x_prev_c": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dt),
                "state": jnp.zeros((cfg.n_layers, batch, H,
                                    cfg.ssm_head_dim, cfg.ssm_head_dim),
                                   jnp.float32),
                "len": jnp.zeros((), jnp.int32),
            }
        if cfg.family == "hybrid":
            G, per = self._groups()
            H = cfg.d_inner // cfg.ssm_head_dim
            C = cfg.d_inner + 2 * cfg.ssm_state
            return {
                "conv": jnp.zeros((G, per, batch, cfg.ssm_conv - 1, C), dt),
                "state": jnp.zeros((G, per, batch, H, cfg.ssm_state,
                                    cfg.ssm_head_dim), jnp.float32),
                "k": jnp.zeros((G, batch, max_len, cfg.n_kv_heads,
                                cfg.head_dim), dt),
                "v": jnp.zeros((G, batch, max_len, cfg.n_kv_heads,
                                cfg.head_dim), dt),
                "len": jnp.zeros((), jnp.int32),
            }
        if cfg.kv_quant:
            return {
                "k": jnp.zeros((cfg.n_layers, batch, max_len,
                                cfg.n_kv_heads, cfg.head_dim), jnp.int8),
                "v": jnp.zeros((cfg.n_layers, batch, max_len,
                                cfg.n_kv_heads, cfg.head_dim), jnp.int8),
                "k_scale": jnp.zeros((cfg.n_layers, batch, max_len,
                                      cfg.n_kv_heads), jnp.float32),
                "v_scale": jnp.zeros((cfg.n_layers, batch, max_len,
                                      cfg.n_kv_heads), jnp.float32),
                "len": jnp.zeros((), jnp.int32),
            }
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "len": jnp.zeros((), jnp.int32),
        }

    # -- prefill ------------------------------------------------------------
    def prefill(self, params, inputs, max_len: int):
        """Process a prompt, return (last-token logits, cache)."""
        cfg = self.cfg
        x = self._embed_in(params, inputs)
        B, Ssz, D = x.shape
        positions = jnp.broadcast_to(jnp.arange(Ssz)[None], (B, Ssz))
        x = shard(x, "dp", None, None)
        cache = self.init_cache(B, max_len)

        if cfg.family == "ssm":
            def body(x, p):
                xin = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
                h, xt, st = S.rwkv6_time_mix(p["mix"], xin, cfg)
                x = x + h
                xc = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
                h2, xcl = S.rwkv6_channel_mix(p["mix"], xc, cfg)
                return x + h2, (xt, xcl, st)

            x, per_layer = lax.scan(body, x, params["blocks"])
            cache["x_prev_t"], cache["x_prev_c"], cache["state"] = per_layer
        elif cfg.family == "hybrid":
            G, per = self._groups()
            blocks = jax.tree.map(
                lambda a: a.reshape(G, per, *a.shape[1:]), params["blocks"])

            def mamba_prefill(x, p):
                xin = L.rmsnorm(p["ln"], x, cfg.norm_eps)
                z, xbc, dt_raw, di, n, H = S._mamba_parts(p["mamba"], xin, cfg)
                xbc_c = S.causal_conv1d(xbc, p["mamba"]["conv_w"])
                xbc_a = jax.nn.silu(xbc_c)
                xs, q, k, v, lw = S._mamba_ssm_inputs(
                    p["mamba"], xbc_a, dt_raw, cfg, di, n, H)
                y, st = S.chunked_linear_attention(q, k, v, lw,
                                                   chunk=cfg.chunk_size)
                y = y + p["mamba"]["D"].astype(x.dtype)[:, None] * xs
                y = y.reshape(*xin.shape[:-1], di)
                y = L.rmsnorm({"scale": p["mamba"]["norm_scale"]}, y,
                              cfg.norm_eps)
                y = y * jax.nn.silu(z)
                out = x + y @ p["mamba"]["out_proj"].astype(x.dtype)
                conv_tail = xbc[:, -(cfg.ssm_conv - 1):]
                return out, (conv_tail, st)

            def group(x, pg):
                xin = L.rmsnorm(params["shared_attn"]["ln1"], x, cfg.norm_eps)
                h, (kk, vv) = L.attention_block(
                    params["shared_attn"]["attn"], xin, cfg, positions)
                x = x + h
                x = x + L.mlp_block(
                    params["shared_attn"]["mlp"],
                    L.rmsnorm(params["shared_attn"]["ln2"], x, cfg.norm_eps),
                    cfg)
                x, (conv, st) = lax.scan(mamba_prefill, x, pg)
                kk = _pad_cache(kk, max_len)
                vv = _pad_cache(vv, max_len)
                return x, (conv, st, kk, vv)

            x, (conv, st, kk, vv) = lax.scan(group, x, blocks)
            cache["conv"], cache["state"] = conv, st
            cache["k"], cache["v"] = kk, vv
        else:
            def body(x, p):
                h, (kk, vv) = L.attention_block(
                    p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                    positions)
                x = x + h
                hin = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
                if cfg.n_experts:
                    h2, _ = M.moe_block(p["moe"], hin, cfg)
                else:
                    h2 = L.mlp_block(p["mlp"], hin, cfg)
                if cfg.kv_quant:
                    kq, ks = L.kv_quantize(kk)
                    vq, vs = L.kv_quantize(vv)
                    return x + h2, (_pad_cache(kq, max_len),
                                    _pad_cache(vq, max_len),
                                    _pad_scale(ks, max_len),
                                    _pad_scale(vs, max_len))
                return x + h2, (_pad_cache(kk, max_len),
                                _pad_cache(vv, max_len))

            x, kvs = lax.scan(body, x, params["blocks"])
            if cfg.kv_quant:
                (cache["k"], cache["v"],
                 cache["k_scale"], cache["v_scale"]) = kvs
            else:
                cache["k"], cache["v"] = kvs

        cache["len"] = jnp.asarray(Ssz, jnp.int32)
        x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
        logits = L.logits_head(params["embed"], x, cfg)
        return logits, cache

    # -- decode -------------------------------------------------------------
    def decode_step(self, params, cache, tokens):
        """tokens (B,1) int32 -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)
        B = x.shape[0]
        clen = cache["len"]

        if cfg.family == "ssm":
            def body(x, slc):
                p, xt, xc, st = slc
                xin = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
                h, xt2, st2 = S.rwkv6_time_mix_decode(p["mix"], xin, cfg,
                                                      xt, st)
                x = x + h
                xcin = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
                h2, xc2 = S.rwkv6_channel_mix(p["mix"], xcin, cfg, xc)
                return x + h2, (xt2, xc2, st2)

            x, (xt, xc, st) = lax.scan(
                body, x, (params["blocks"], cache["x_prev_t"],
                          cache["x_prev_c"], cache["state"]))
            cache = dict(cache, x_prev_t=xt, x_prev_c=xc, state=st,
                         len=clen + 1)
        elif cfg.family == "hybrid":
            G, per = self._groups()
            blocks = jax.tree.map(
                lambda a: a.reshape(G, per, *a.shape[1:]), params["blocks"])

            def mamba_step(x, slc):
                p, conv, st = slc
                xin = L.rmsnorm(p["ln"], x, cfg.norm_eps)
                y, conv2, st2 = S.mamba2_decode(p["mamba"], xin, cfg, conv,
                                                st)
                return x + y, (conv2, st2)

            def group(x, slc):
                pg, conv, st, kk, vv = slc
                sa = params["shared_attn"]
                h, kk2, vv2 = L.attention_decode(
                    sa["attn"], L.rmsnorm(sa["ln1"], x, cfg.norm_eps), cfg,
                    kk, vv, clen)
                x = x + h
                x = x + L.mlp_block(
                    sa["mlp"], L.rmsnorm(sa["ln2"], x, cfg.norm_eps), cfg)
                x, (conv2, st2) = lax.scan(mamba_step, x, (pg, conv, st))
                return x, (conv2, st2, kk2, vv2)

            x, (conv, st, kk, vv) = lax.scan(
                group, x, (blocks, cache["conv"], cache["state"],
                           cache["k"], cache["v"]))
            cache = dict(cache, conv=conv, state=st, k=kk, v=vv,
                         len=clen + 1)
        else:
            def _ffn(x, p):
                hin = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
                if cfg.n_experts:
                    h2, _ = M.moe_block(p["moe"], hin.transpose(1, 0, 2),
                                        cfg)
                    return h2.transpose(1, 0, 2)
                return L.mlp_block(p["mlp"], hin, cfg)

            if cfg.kv_quant:
                def body(x, slc):
                    p, kk, vv, ks, vs = slc
                    h, kk2, vv2, ks2, vs2 = L.attention_decode_quant(
                        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                        cfg, kk, vv, ks, vs, clen)
                    x = x + h
                    return x + _ffn(x, p), (kk2, vv2, ks2, vs2)

                x, (kk, vv, ks, vs) = lax.scan(
                    body, x, (params["blocks"], cache["k"], cache["v"],
                              cache["k_scale"], cache["v_scale"]))
                cache = dict(cache, k=kk, v=vv, k_scale=ks, v_scale=vs,
                             len=clen + 1)
            else:
                def body(x, slc):
                    p, kk, vv = slc
                    h, kk2, vv2 = L.attention_decode(
                        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                        cfg, kk, vv, clen)
                    x = x + h
                    return x + _ffn(x, p), (kk2, vv2)

                x, (kk, vv) = lax.scan(body, x,
                                       (params["blocks"], cache["k"],
                                        cache["v"]))
                cache = dict(cache, k=kk, v=vv, len=clen + 1)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.logits_head(params["embed"], x, cfg)
        return logits, cache


def _pad_cache(k, max_len):
    """(B,S,H,D) -> (B,max_len,H,D) zero-padded KV cache buffer."""
    B, Ssz, H, D = k.shape
    if Ssz == max_len:
        return k
    return jnp.pad(k, ((0, 0), (0, max_len - Ssz), (0, 0), (0, 0)))


def _pad_scale(s, max_len):
    """(B,S,H) -> (B,max_len,H) zero-padded scale buffer."""
    B, Ssz, H = s.shape
    if Ssz == max_len:
        return s
    return jnp.pad(s, ((0, 0), (0, max_len - Ssz), (0, 0)))


