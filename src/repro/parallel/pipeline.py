"""Pipeline parallelism: GPipe-schedule microbatch pipeline built from
shard_map + lax.ppermute over a "stage" mesh axis.

The production meshes for this paper's workloads are (data, model) --
EcoFlow's own technique has no pipeline dimension -- but at >=1000-node
scale a stage axis is how the 94-layer MoE would hide inter-pod latency,
so the substrate ships one, tested on CPU with a small stage count.

Usage:
    stages = [stage_fn] * n_stages       # same fn, stage-sliced params
    y = gpipe(mesh, "stage", stage_fn, params_stacked, x, n_microbatches)

`params_stacked` leaves have a leading stage dim, sharded over the stage
axis; `x` is (n_micro * micro_batch, ...) sharded over the stage axis on
dim 0 only virtually (each stage works on a rotating microbatch window).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(mesh: Mesh, axis: str, stage_fn: Callable, stage_params, x,
          n_micro: int):
    """Run a GPipe pipeline of size mesh.shape[axis].

    stage_fn(params_slice, x_micro) -> x_micro; applied in sequence over
    stages with microbatches flowing via ppermute.  x: (n_micro, mb, ...).
    Returns y with the same shape.
    """
    n_stages = mesh.shape[axis]
    assert x.shape[0] == n_micro

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1); xs: all microbatches
        # (n_micro, mb, ...) -- only stage 0's copy is "real" input.
        params = jax.tree.map(lambda a: a[0], params)
        stage = lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])

        def tick(carry, t):
            buf, ys = carry
            # Stage 0 injects microbatch t (if any); others use the buffer
            # handed over from the previous stage on the previous tick.
            inject = xs[jnp.minimum(t, n_micro - 1)]
            cur = jnp.where(stage == 0, inject, buf)
            out = stage_fn(params, cur)
            # Hand off to the next stage.
            nxt = lax.ppermute(out, axis,
                               [(i, (i + 1) % n_stages)
                                for i in range(n_stages)])
            # The last stage emits microbatch (t - (n_stages-1)) at tick t.
            emit_idx = t - (n_stages - 1)
            ys = jnp.where(
                (stage == n_stages - 1) & (emit_idx >= 0) &
                (emit_idx < n_micro),
                ys.at[jnp.clip(emit_idx, 0, n_micro - 1)].set(out), ys)
            return (nxt, ys), None

        ys0 = jnp.zeros_like(xs)
        # carries become stage-varying after the first ppermute; mark the
        # initial values as varying over the stage axis.  `lax.pcast` only
        # exists once shard_map has varying-manual-axes tracking (jax>=0.8);
        # on older jax the scan carry needs no annotation.
        pcast = getattr(lax, "pcast", None)
        if pcast is not None:
            buf = pcast(buf, (axis,), to="varying")
            ys0 = pcast(ys0, (axis,), to="varying")
        (_, ys), _ = lax.scan(tick, (buf, ys0), jnp.arange(n_ticks))
        # Broadcast the last stage's outputs to everyone.
        ys = lax.psum(jnp.where(stage == n_stages - 1, ys, 0.0), axis)
        return ys

    pspec_params = jax.tree.map(lambda a: P(axis, *([None] * (a.ndim - 1))),
                                stage_params)
    f = shard_map(per_stage, mesh=mesh,
                  in_specs=(pspec_params, P(*([None] * x.ndim))),
                  out_specs=P(*([None] * x.ndim)))
    return f(stage_params, x)
