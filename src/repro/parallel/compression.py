"""int8 error-feedback gradient compression over an explicit shard_map
all-reduce -- the optional cross-pod bandwidth saver (DESIGN.md Sec. 6).

With FSDP, gradients are reduce-scattered automatically by GSPMD.  For the
*pod* axis (DCN-class links between pods), `compressed_psum` offers an
explicit 4x-smaller all-reduce: per-tensor max-abs int8 quantization with a
persistent error-feedback accumulator so quantization noise is unbiased
over steps (1-bit-Adam-style residual correction).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str, error: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """All-reduce mean of x over `axis_name` with int8 compression and
    error feedback.  Must run inside shard_map/pmap.  Returns
    (reduced, new_error)."""
    xf = x.astype(jnp.float32) + error
    q, scale = quantize_int8(xf)
    deq = dequantize_int8(q, scale)
    new_error = xf - deq
    # int8 payload all-reduce: sum int32-accumulated quantized values and
    # the scales separately (scale differs per shard -> reduce scaled).
    summed = lax.psum(deq, axis_name)
    n = lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (summed / n).astype(x.dtype), new_error


def make_compressed_grad_allreduce(mesh, axis_name: str = "pod"):
    """Tree-level wrapper: returns f(grads, errors) -> (grads, errors)
    running one compressed all-reduce per leaf over `axis_name`."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def per_leaf(g, e):
        return compressed_psum(g, axis_name, e)

    def f(grads, errors):
        outs = jax.tree.map(
            lambda g, e: shard_map(
                functools.partial(per_leaf),
                mesh=mesh,
                in_specs=(P(*([None] * g.ndim)), P(*([None] * g.ndim))),
                out_specs=(P(*([None] * g.ndim)), P(*([None] * g.ndim))),
            )(g, e), grads, errors)
        new_g = jax.tree.map(lambda t: t[0], outs,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_e = jax.tree.map(lambda t: t[1], outs,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_g, new_e

    return f
