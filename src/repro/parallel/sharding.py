"""Sharding rules: FSDP + TP + EP + SP PartitionSpec inference.

Mesh axes:
  single-pod : ("data", "model")                   -- 16 x 16 = 256 chips
  multi-pod  : ("pod", "data", "model")            -- 2 x 16 x 16 = 512

Logical axes used throughout the model code:
  "fsdp"  -> ("pod", "data")   parameter / optimizer-state sharding (ZeRO-3:
             params, grads and Adam moments all carry the same specs, so the
             optimizer is fully sharded)
  "tp"    -> "model"           tensor parallelism: attention heads, ffn
             hidden, vocab; also EP: the MoE expert dimension
  "dp"    -> ("pod", "data")   batch dimension of activations
  "sp"    -> "model"           sequence parallelism for long-context /
             small-head archs

Every axis assignment is guarded by divisibility: a dimension that does not
divide by the mesh-axis size is left unsharded (e.g. gemma-2b's single KV
head under 16-way TP), letting GSPMD pick the collectives instead of
failing to lower.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def _state():
    if not hasattr(_ctx, "mesh"):
        _ctx.mesh = None
    return _ctx


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate a mesh for `shard()` activation constraints."""
    st = _state()
    prev = st.mesh
    st.mesh = mesh
    try:
        yield
    finally:
        st.mesh = prev


def current_mesh() -> Optional[Mesh]:
    """Mesh activated by the innermost `use_mesh` context (None outside).

    Read at trace time by the conv dispatch layer
    (`repro.core.spec.dispatch_backend`) to choose between replicated and
    shard_map'd launches, so callers that jit under a mesh must also
    trace under `use_mesh` (the model step helpers do this)."""
    return _state().mesh


def logical_axes(mesh: Mesh, *, serve: bool = False) -> dict:
    """Logical -> mesh axis mapping.

    serve=False (training layout): weights 2D-sharded over (fsdp, tp);
    every pass all-gathers the data-axis weight shards -- fine when the
    per-microbatch compute amortizes it.

    serve=True (inference layout -- the Sec. Perf "serve-tp resharding"
    optimization): the data axes are FOLDED INTO TP, so weights are fully
    sharded over all chips and stay resident -- no per-step gathers.  The
    batch is left unsharded on the weight side ("dp" still maps to the
    data axes for activations/caches).
    """
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    fsdp_ax = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    tp = "model" if "model" in names else None
    if serve and tp is not None and fsdp:
        tp_serve = ("model",) + fsdp
        return {"fsdp": None, "dp": fsdp_ax, "tp": tp_serve,
                "sp": tp_serve}
    return {
        "fsdp": fsdp_ax,
        "dp": fsdp_ax,
        "tp": tp,
        "sp": tp,
    }


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _guard(mesh: Mesh, spec_entries, shape) -> P:
    """Drop axes whose size does not divide the corresponding dim."""
    out = []
    for dim, ax in zip(shape, spec_entries):
        if ax is None or dim % _axis_size(mesh, ax) != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def shard(x: jax.Array, *logical) -> jax.Array:
    """Activation sharding constraint by logical axis names ("dp","tp",
    "sp", None).  No-op outside a `use_mesh` context (CPU smoke tests)."""
    mesh = _state().mesh
    if mesh is None:
        return x
    la = logical_axes(mesh)
    entries = [la.get(ax) if isinstance(ax, str) else ax for ax in logical]
    spec = _guard(mesh, entries, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter PartitionSpec inference
# ---------------------------------------------------------------------------

# (leaf-name regex, spec for the *trailing* dims).  Leading dims (layer
# stacking for scan, expert dim handled explicitly) default to None.
_NAME_RULES = [
    (r"^experts_w[ig]$", ("tp", "fsdp", None)),     # (E, D, F): EP + FSDP
    (r"^experts_wo$",    ("tp", None, "fsdp")),     # (E, F, D)
    (r"^tok$",           ("tp", "fsdp")),           # (V, D) vocab-sharded
    (r"^head$",          ("fsdp", "tp")),           # (D, V)
    (r"^(wq|wk|wv|wi|wg|w_in|in_proj|router)$", ("fsdp", "tp")),
    (r"^(wo|w_out|out_proj)$", ("tp", "fsdp")),
    (r"^conv_w$",        (None, "tp")),             # (K, C) depthwise conv
    (r".*",              (None,)),                  # norms, biases, scalars
]

# 4-D conv filters (KH, KW, Cin, Cout) cannot be claimed by name rules:
# CNN conv stacks live in python lists, so the leaf name is a bare list
# index ("convs/1" -> "1"), and the GAN layers use per-layer names ("t2",
# "c3").  Every one of them used to fall through to the replicate-
# everything catch-all and was silently fully replicated under FSDP.  The
# structural rank-4 rule below (applied in `leaf_pspec` when no name rule
# claims the leaf) shards Cout over "tp" -- the non-contracted output dim
# each shard_map'd forward launch produces locally -- and Cin over "fsdp"
# for ZeRO-3 storage (the dispatch layer's shard_map in_specs re-gather
# it per use), with the usual divisibility guard (e.g. the Cin=3 stem
# stays unsharded).
_CONV_FILTER_SPEC = (None, None, "fsdp", "tp")
_SERVE_CONV_FILTER_SPEC = (None, None, None, "tp")  # serve: stay resident

# Serve-time layout (Sec. Perf "serve-tp resharding"): weights fully
# sharded over ALL chips ("tp" = model + data axes; experts keep E over
# model ("ep") and shard the ffn dim over the data axes ("dax")) so they
# stay resident -- no per-step data-axis all-gathers.
_SERVE_RULES = [
    (r"^experts_w[ig]$", ("ep", None, "dax")),      # (E, D, F)
    (r"^experts_wo$",    ("ep", "dax", None)),      # (E, F, D)
    (r"^tok$",           ("ep", "dax")),            # (V, D)
    (r"^head$",          ("dax", "ep")),            # (D, V)
    (r"^(wq|wk|wv|wi|wg|w_in|in_proj|router)$", (None, "tp")),
    (r"^(wo|w_out|out_proj)$", ("tp", None)),
    (r"^conv_w$",        (None, "tp")),
    (r".*",              (None,)),
]


# Beyond-paper MoE-train variant (EXPERIMENTS.md Perf change B5): shard
# the experts' FFN dim over the data axis instead of D.  The expert
# matmuls then contract an UNSHARDED dim -- no per-pass weight
# all-gathers; the cost moves to activation reductions, which scale with
# tokens*top_k instead of with total expert bytes.
_MOE_FFN_RULES = [
    (r"^experts_w[ig]$", ("tp", None, "fsdp")),     # (E, D, F@data)
    (r"^experts_wo$",    ("tp", "fsdp", None)),     # (E, F@data, D)
]


def leaf_pspec(path: str, shape, mesh: Mesh, *, serve: bool = False,
               moe_ffn_data: bool = False) -> P:
    la = logical_axes(mesh, serve=serve)
    if serve:
        names = mesh.axis_names
        dax = tuple(a for a in ("pod", "data") if a in names)
        la = dict(la, ep="model" if "model" in names else None,
                  dax=dax if len(dax) > 1 else (dax[0] if dax else None))
    rules = _SERVE_RULES if serve else _NAME_RULES
    if moe_ffn_data and not serve:
        rules = _MOE_FFN_RULES + rules
    name = path.split("/")[-1]
    for pat, spec in rules:
        if re.match(pat, name):
            if pat == r".*" and len(shape) == 4:
                # structural conv-filter rule -- see _CONV_FILTER_SPEC
                spec = (_SERVE_CONV_FILTER_SPEC if serve
                        else _CONV_FILTER_SPEC)
            entries = [la.get(s) if isinstance(s, str) else s for s in spec]
            if len(entries) < len(shape):   # leading scan/stack dims
                entries = [None] * (len(shape) - len(entries)) + entries
            elif len(entries) > len(shape):
                entries = entries[-len(shape):] if len(shape) else []
            return _guard(mesh, entries, shape)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_pspecs(tree, mesh: Mesh, *, serve: bool = False,
                moe_ffn_data: bool = False):
    """PartitionSpec pytree for a (shape-)pytree of parameters."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaf_pspec(_path_str(path), leaf.shape, mesh,
                                      serve=serve,
                                      moe_ffn_data=moe_ffn_data),
        tree)


def tree_shardings(tree, mesh: Mesh, *, serve: bool = False,
                   moe_ffn_data: bool = False):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        tree_pspecs(tree, mesh, serve=serve,
                                    moe_ffn_data=moe_ffn_data))


def batch_pspec(mesh: Mesh, rank: int, batch_dim: int = 0,
                batch_size: Optional[int] = None) -> P:
    """Shard the batch dim over ("pod","data"), guarded by divisibility.

    The guard needs the concrete size: with ``batch_size=None`` the batch
    dim is left UNSHARDED rather than (as before) sharded unconditionally
    -- an unguarded spec applied to a ragged last batch
    (B % |dp| != 0) fails to lower.  Pass the batch size to opt in."""
    la = logical_axes(mesh)
    dp = la["dp"]
    entries = [None] * rank
    if (dp is not None and batch_size is not None
            and batch_size % _axis_size(mesh, dp) == 0):
        entries[batch_dim] = dp
    return P(*entries)
