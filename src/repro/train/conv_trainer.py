"""ConvTrainer: the training-side counterpart of the serving engine's
fault-tolerance layer (DESIGN.md Sec. 2.12).

Runs the paper's CNN-classification and GAN workloads on ANY mesh
through the mesh-aware model steps (Sec. 2.9), with:

  * checkpoint/resume on the atomic `train/checkpoint.py` format and
    deterministic data skip-ahead (`data/pipeline.py::ConvDataset` --
    batches are pure functions of (seed, step), so an interrupted run
    resumes bit-identically and an elastic restart replays the exact
    same stream on a different mesh);
  * an IN-GRAPH numerics guard: each jitted step additionally returns a
    scalar all-finite flag over the updated params + loss
    (`models/layers.py::tree_all_finite` -- cheap XLA reductions folded
    into the same launch plan; the guarded step is jaxpr-pinned to the
    same `pallas_call` count as the unguarded one);
  * a non-finite policy owned by the shared `StepGuard`: rollback to
    the last good in-memory state (steps never donate, so rollback is
    keeping the previous pytree), per-layer blame localization run
    EAGERLY on the reference backend only on the failure path, then
    bounded retry / skip / shrink-lr before giving up;
  * seeded fault consultation: one `serve.faults.FaultInjector` site
    (`train.<workload>`) is stepped once per step ATTEMPT --
    launch-class events raise / delay, output-class events poison the
    host batch so the REAL guard trips (no test-only seam).

The run-level recovery loop (host loss -> survivors -> `elastic_mesh`
-> re-sharded restore -> continue) lives in `train/supervisor.py`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.data.pipeline import ConvDataset
from repro.models import cnn, gan
from repro.parallel import sharding as sh
from repro.serve import faults
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import StepGuard

WORKLOADS = ("cnn", "gan", "gan_gen")


class NonFiniteStepError(RuntimeError):
    """The bounded non-finite retry policy gave up: the step produced
    non-finite updates `max_retries`+ times in a row with clean data,
    which means the loss surface (or a kernel) is broken -- retrying
    further would hide a real bug.  Carries the per-layer blame."""

    def __init__(self, step: int, blame: Sequence[str]):
        super().__init__(
            f"step {step} non-finite after bounded retries; "
            f"non-finite grads in: {list(blame)}")
        self.step = step
        self.blame = tuple(blame)


@dataclasses.dataclass
class ConvTrainerConfig:
    workload: str = "cnn"            # cnn | gan | gan_gen
    total_steps: int = 8
    lr: float = 0.05
    backend: Optional[str] = None    # reference | xla_zero_free | pallas
    fuse_epilogue: bool = True
    stride: int = 2                  # CNN downsampling stride
    # model geometry (JSON-stable scalars/lists so bench configs can
    # carry a ConvTrainerConfig verbatim)
    widths: Sequence[int] = (8, 16)
    image: int = 12
    channels: int = 3
    n_classes: int = 10
    z_dim: int = 16
    base: int = 8
    batch: int = 8
    seed: int = 0
    # checkpointing
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 4
    keep_last: int = 3
    async_checkpoint: bool = False
    # guard / fault policy
    guard: bool = True
    step_timeout_s: Optional[float] = None
    max_retries: int = 2
    nonfinite_policy: str = "skip"   # skip | shrink_lr
    lr_shrink: float = 0.5
    blame: bool = True               # eager per-layer localization

    def __post_init__(self):
        if self.workload not in WORKLOADS:
            raise ValueError(f"workload must be one of {WORKLOADS}, "
                             f"got {self.workload!r}")


_BATCH_KEYS = {"cnn": ("x", "labels"), "gan": ("z", "real"),
               "gan_gen": ("z",)}


class ConvTrainer:
    """One conv training run on one (fixed) mesh.  Mesh changes are a
    supervisor concern: the supervisor builds a fresh ConvTrainer per
    elastic mesh and the checkpoint format re-shards on restore."""

    def __init__(self, tcfg: ConvTrainerConfig, *,
                 mesh: Optional[Mesh] = None,
                 injector: Optional["faults.FaultInjector"] = None):
        self.tcfg = tcfg
        self.mesh = mesh
        self.injector = injector
        self.data = ConvDataset(
            kind=tcfg.workload, batch=tcfg.batch, image=tcfg.image,
            channels=tcfg.channels, n_classes=tcfg.n_classes,
            z_dim=tcfg.z_dim, seed=tcfg.seed)
        self.guard = StepGuard(
            step_timeout_s=tcfg.step_timeout_s,
            max_retries=tcfg.max_retries,
            nonfinite_policy=tcfg.nonfinite_policy,
            lr_shrink=tcfg.lr_shrink)
        self._ckptr = (ckpt.AsyncCheckpointer(tcfg.ckpt_dir,
                                              tcfg.keep_last)
                       if tcfg.ckpt_dir and tcfg.async_checkpoint
                       else None)
        self._site = faults.train_site(tcfg.workload)
        # NO donation: rollback after a non-finite step is simply
        # keeping the previous state pytree alive.
        self._jit = jax.jit(self.build_step(guarded=tcfg.guard))
        self.blames: List[Dict[str, Any]] = []
        # Monotonic time of this trainer's first COMPLETED step (jit +
        # restore included); the supervisor reads it for recovery-cost
        # accounting even when the run later dies mid-segment.
        self.first_step_wall: Optional[float] = None

    # -- step construction ---------------------------------------------------
    def build_step(self, *, guarded: bool) -> Callable:
        """`(state, data_tuple, lr) -> (new_state, metrics, finite)` for
        this workload.  `lr` is a traced scalar, so shrink-lr retries
        reuse the compiled step.  With `guarded=False` the finite flag
        is a constant True and the body is exactly today's unguarded
        model step (the benchmark's overhead baseline)."""
        t = self.tcfg
        be, fe = t.backend, t.fuse_epilogue
        if t.workload == "cnn":
            def fn(state, data, lr):
                x, labels = data
                if guarded:
                    new, loss, fin = cnn.guarded_sgd_step(
                        state, x, labels, lr=lr, stride=t.stride,
                        backend=be, fuse_epilogue=fe)
                else:
                    new, loss = cnn.sgd_step(
                        state, x, labels, lr=lr, stride=t.stride,
                        backend=be, fuse_epilogue=fe)
                    fin = jnp.asarray(True)
                return new, {"loss": loss}, fin
        elif t.workload == "gan_gen":
            def fn(state, data, lr):
                (z,) = data
                if guarded:
                    new_g, loss, fin = gan.guarded_gen_sgd_step(
                        state["g"], state["d"], z, lr=lr, backend=be,
                        fuse_epilogue=fe)
                else:
                    new_g, loss = gan.gen_sgd_step(
                        state["g"], state["d"], z, lr=lr, backend=be,
                        fuse_epilogue=fe)
                    fin = jnp.asarray(True)
                return ({"g": new_g, "d": state["d"]}, {"loss": loss},
                        fin)
        else:   # gan: simultaneous G+D step on the {"g","d"} pytree
            def fn(state, data, lr):
                z, real = data
                if guarded:
                    new, g_loss, d_loss, fin = gan.guarded_gan_sgd_step(
                        state, z, real, lr=lr, backend=be,
                        fuse_epilogue=fe)
                else:
                    new, g_loss, d_loss = gan.gan_sgd_step(
                        state, z, real, lr=lr, backend=be,
                        fuse_epilogue=fe)
                    fin = jnp.asarray(True)
                return new, {"loss": g_loss, "d_loss": d_loss}, fin
        return fn

    # -- state ---------------------------------------------------------------
    def init_state(self):
        t = self.tcfg
        key = jax.random.PRNGKey(t.seed)
        if t.workload == "cnn":
            state = cnn.simple_cnn_init(
                key, in_ch=t.channels, widths=tuple(t.widths),
                n_classes=t.n_classes)
        else:
            state = gan.gan_init(key, z_dim=t.z_dim, base=t.base,
                                 ch=t.channels)
        if self.mesh is not None:
            with self.mesh, sh.use_mesh(self.mesh):
                state = jax.device_put(
                    state, sh.tree_shardings(state, self.mesh))
        return state

    def maybe_restore(self) -> Tuple[Any, int]:
        """(state, start_step): the latest INTACT checkpoint re-sharded
        onto THIS trainer's mesh (torn steps fall back with a
        RuntimeWarning inside `checkpoint.latest_step`/`restore`), or
        the seeded init at step 0."""
        state = self.init_state()
        d = self.tcfg.ckpt_dir
        if not d:
            return state, 0
        step = ckpt.latest_step(d)
        if step is None:
            return state, 0
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        shardings = None
        if self.mesh is not None:
            with self.mesh, sh.use_mesh(self.mesh):
                shardings = sh.tree_shardings(like, self.mesh)
        return ckpt.restore(d, step, like, shardings), step

    def save(self, step: int, state, *, blocking: bool = False):
        if not self.tcfg.ckpt_dir:
            return
        if self._ckptr is not None and not blocking:
            self._ckptr.save_async(step, state)
        else:
            if self._ckptr is not None:
                self._ckptr.wait()
            ckpt.save(self.tcfg.ckpt_dir, step, state,
                      keep_last=self.tcfg.keep_last)

    # -- data placement ------------------------------------------------------
    def _put_batch(self, batch: Dict[str, np.ndarray]) -> tuple:
        arrs = [np.asarray(batch[k])
                for k in _BATCH_KEYS[self.tcfg.workload]]
        if self.mesh is None:
            return tuple(jnp.asarray(a) for a in arrs)
        with self.mesh, sh.use_mesh(self.mesh):
            return tuple(
                jax.device_put(a, NamedSharding(
                    self.mesh,
                    sh.batch_pspec(self.mesh, a.ndim, 0, a.shape[0])))
                for a in arrs)

    # -- blame localization (failure path only) ------------------------------
    def localize_nonfinite(self, state, batch) -> List[str]:
        """Which layer's grad went non-finite: recompute the gradients
        EAGERLY (no jit) on the reference backend from host copies and
        name the offending leaves.  This runs only after the in-graph
        guard already tripped, so its cost is off the hot path."""
        t = self.tcfg
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                            state)

        if t.workload == "cnn":
            x = jnp.asarray(batch["x"])
            labels = jnp.asarray(batch["labels"])
            grads = jax.grad(lambda p: cnn.cnn_loss(
                p, x, labels, stride=t.stride, backend="reference",
                fuse_epilogue=False))(host)
        elif t.workload == "gan_gen":
            z = jnp.asarray(batch["z"])

            def g_loss(gp):
                fake = gan.generator_apply(gp, z, backend="reference",
                                           fuse_epilogue=False)
                d_fake = gan.discriminator_apply(
                    host["d"], fake, backend="reference",
                    fuse_epilogue=False)
                return jax.nn.softplus(-d_fake).mean()

            grads = {"g": jax.grad(g_loss)(host["g"])}
        else:
            z = jnp.asarray(batch["z"])
            real = jnp.asarray(batch["real"])

            def both(st):
                g_loss, d_loss = gan.gan_losses(
                    st["g"], st["d"], z, real, backend="reference",
                    fuse_epilogue=False)
                return g_loss + d_loss

            grads = jax.grad(both)(host)

        bad = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(grads)[0]:
            if not np.all(np.isfinite(np.asarray(leaf))):
                bad.append(jax.tree_util.keystr(path))
        return sorted(bad)

    # -- loop ----------------------------------------------------------------
    def _run_step(self, state, data, lr):
        if self.mesh is None:
            return self._jit(state, data, lr)
        with self.mesh, sh.use_mesh(self.mesh):
            return self._jit(state, data, lr)

    def run(self, *, fail_hook: Optional[Callable[[int], None]] = None
            ) -> Dict[str, Any]:
        """Train to total_steps, resuming from the latest intact
        checkpoint.  `fail_hook(step)` is the supervisor's seam: called
        once per step BEFORE the attempt, it raises `HostFailure` (or
        any injected fault) to simulate losing part of the mesh.

        Returns state/history plus the guard stats and
        `first_step_wall` -- the monotonic time at which the first step
        of THIS trainer completed (jit + restore included), which the
        supervisor uses to account recovery wallclock."""
        t = self.tcfg
        state, start = self.maybe_restore()
        history: List[Dict[str, Any]] = []
        lr_scale = 1.0
        first_step_wall: Optional[float] = None
        step = start
        while step < t.total_steps:
            if fail_hook is not None:
                fail_hook(step)
            batch = self.data.batch_at(step)   # deterministic skip-ahead
            ev = None
            if self.injector is not None:
                # Launch-class events raise/delay here; output-class
                # events poison the HOST batch so the real in-graph
                # guard trips on device.
                try:
                    ev = self.injector.raise_or_delay(self._site)
                except faults.InjectedFault as e:
                    e.train_step = step   # the supervisor accounts
                    raise                 # steps lost by TRAIN step
                batch = faults.poison_batch(self.injector, ev, batch)
            data = self._put_batch(batch)
            self.guard.start_step()
            new_state, metrics, finite = self._run_step(
                state, data, jnp.float32(t.lr * lr_scale))
            straggled = False
            if bool(np.asarray(finite)):
                state = new_state           # commit
                self.guard.good_step()
                lr_scale = 1.0
                straggled = self.guard.straggled()
                if first_step_wall is None:
                    first_step_wall = time.monotonic()
                    self.first_step_wall = first_step_wall
                history.append({"step": step + 1,
                                "loss": float(np.asarray(
                                    metrics["loss"]))})
                if straggled:
                    # Straggler watchdog: checkpoint now so a slow host
                    # can be evicted without losing work.
                    self.save(step + 1, state, blocking=True)
                elif t.ckpt_dir and (step + 1) % t.ckpt_every == 0:
                    self.save(step + 1, state)
                step += 1
                continue
            # Non-finite: new_state is DISCARDED (rollback = the old
            # pytree), blame is localized eagerly, and the shared guard
            # decides between retry / skip / shrink-lr / give-up.
            blame = (self.localize_nonfinite(state, batch)
                     if t.blame else [])
            self.blames.append({"step": step, "grads": blame,
                                "injected": ev is not None})
            decision = self.guard.nonfinite()
            if decision.action == "give_up":
                raise NonFiniteStepError(step, blame)
            if decision.action == "skip":
                step += 1
                continue
            lr_scale = decision.lr_scale    # retry the SAME step
        if t.ckpt_dir:
            self.save(t.total_steps, state, blocking=True)
        if self._ckptr is not None:
            self._ckptr.wait()
        return {"state": state, "history": history,
                "start_step": start, "guard_stats": dict(self.guard.stats),
                "blames": list(self.blames),
                "first_step_wall": first_step_wall}
