"""RunSupervisor: drives a whole conv training run under the seeded
fault registry, surviving host loss by elastic re-meshing (DESIGN.md
Sec. 2.12).

The recovery protocol, per caught failure:

  1. classify -- a `HostFailure` (from the host-loss schedule hook) or
     an `InjectedDeviceLoss` (from the per-step injector site) names
     which hosts died; an `InjectedKernelFault` keeps the mesh;
  2. shrink   -- `fault_tolerance.survivors` drops the dead hosts'
     devices and `elastic_mesh` builds the largest valid (data, model)
     mesh from what remains (model axis halves until it divides);
  3. restore  -- a FRESH `ConvTrainer` on the new mesh restores the
     latest intact checkpoint, re-sharded leaf-by-leaf onto the shrunk
     mesh (torn checkpoints fall back with a RuntimeWarning); the data
     pipeline skips ahead for free (batches are pure in (seed, step));
  4. account  -- steps lost (failure step minus restored step), one
     recompile (the fresh trainer's jit), and recovery wallclock (from
     catching the failure to the new trainer's first completed step).

Non-finite steps never reach the supervisor: the trainer's in-graph
guard + `StepGuard` policy handle rollback/retry inside the run.  The
supervisor only restarts on faults that invalidate the mesh or the
process, bounded by `max_recoveries`.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.serve.faults import (InjectedDeviceLoss, InjectedFault)
from repro.train import checkpoint as ckpt
from repro.train.conv_trainer import ConvTrainer, ConvTrainerConfig
from repro.train.fault_tolerance import (HostFailure, elastic_mesh,
                                         survivors)


class RunSupervisor:
    """Owns the device universe for one run: builds meshes, trainers,
    and the recovery report.

    `host_schedule` is `{step: [host_id, ...]}` (the shape
    `fault_tolerance.host_failure_schedule` returns); each entry fires
    once, at the first trainer step >= its key that a live trainer
    reaches.  `injector` is threaded into every trainer, so per-step
    faults (NaN poison, kernel exceptions, latency spikes, device
    losses) replay from the same seeded registry across recoveries --
    counters advance monotonically over the whole run."""

    def __init__(self, tcfg: ConvTrainerConfig, *,
                 devices: Optional[Sequence] = None,
                 devices_per_host: int = 1, model_parallel: int = 2,
                 host_schedule: Optional[Dict[int, List[int]]] = None,
                 injector=None, max_recoveries: int = 8):
        if not tcfg.ckpt_dir:
            raise ValueError("RunSupervisor needs tcfg.ckpt_dir: "
                             "recovery restores from checkpoints")
        self.tcfg = tcfg
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.devices_per_host = devices_per_host
        self.model_parallel = model_parallel
        self.host_schedule = dict(host_schedule or {})
        self.injector = injector
        self.max_recoveries = max_recoveries
        self.report: Dict[str, Any] = {
            "recoveries": [], "steps_lost": 0, "recompiles": 0,
            "recovery_wallclock_s": 0.0, "meshes": [],
            "host_losses": 0, "device_losses": 0, "kernel_faults": 0,
            # StepGuard stats summed over every trainer segment (each
            # elastic mesh gets a fresh trainer + guard)
            "guard": {"stragglers": 0, "nonfinite_steps": 0,
                      "retries": 0, "skips": 0, "lr_shrinks": 0,
                      "give_ups": 0}}

    def _live_hosts(self) -> List[int]:
        return sorted({d.id // self.devices_per_host
                       for d in self.devices})

    def _hook(self):
        """Per-step hook for the trainer: fire every pending scheduled
        host loss whose step has arrived (>=, not ==: a step skipped by
        the guard or lost to an earlier recovery must not defuse the
        failure)."""
        pending = self.host_schedule

        def hook(step: int):
            due = [s for s in pending if s <= step]
            if not due:
                return
            hosts: List[int] = []
            for s in due:
                hosts.extend(pending.pop(s))
            live = set(self._live_hosts())
            hosts = sorted(set(h for h in hosts if h in live))
            if hosts and len(hosts) < len(live):
                raise HostFailure(step, hosts)
            # Losing every host (or only already-dead ones) is not an
            # elastic event -- nothing to do.
        return hook

    def _shrink(self, mesh: Mesh, dead_hosts: Sequence[int]):
        self.devices = survivors(mesh, list(dead_hosts),
                                 self.devices_per_host)

    def run(self) -> Dict[str, Any]:
        """Drive the run to total_steps across as many elastic meshes
        as the storm requires; returns the final trainer output plus
        the recovery report."""
        t_recover_from: Optional[float] = None
        failed_step: Optional[int] = None
        while True:
            mesh = elastic_mesh(self.devices,
                                model_parallel=self.model_parallel)
            self.report["meshes"].append(
                {ax: int(mesh.shape[ax]) for ax in mesh.axis_names})
            trainer = ConvTrainer(self.tcfg, mesh=mesh,
                                  injector=self.injector)
            if t_recover_from is not None:
                # Recovery accounting: the fresh trainer's jit is the
                # recompile; steps lost = failure step minus the step
                # the intact checkpoint put us back to.
                restored = ckpt.latest_step(self.tcfg.ckpt_dir) or 0
                self.report["recompiles"] += 1
                self.report["steps_lost"] += max(
                    0, failed_step - restored)
            try:
                out = trainer.run(fail_hook=self._hook())
            except HostFailure as e:
                self._account_segment(trainer, t_recover_from)
                self._on_failure("host_losses", e.step, mesh, e.hosts)
                t_recover_from, failed_step = time.monotonic(), e.step
                continue
            except InjectedDeviceLoss as e:
                # The injector names an invocation, not a host: map the
                # loss to the highest-id live host (deterministic).
                step = getattr(e, "train_step", e.index)
                if len(self._live_hosts()) <= 1:
                    raise   # nothing left to shrink to
                dead = [self._live_hosts()[-1]]
                self._account_segment(trainer, t_recover_from)
                self._on_failure("device_losses", step, mesh, dead)
                t_recover_from, failed_step = time.monotonic(), step
                continue
            except InjectedFault as e:
                # Kernel fault: the mesh is fine -- restart the loop
                # from the latest checkpoint on the same devices.
                step = getattr(e, "train_step", e.index)
                self._account_segment(trainer, t_recover_from)
                self._on_failure("kernel_faults", step, mesh, [])
                t_recover_from, failed_step = time.monotonic(), step
                continue
            self._account_segment(trainer, t_recover_from)
            out["report"] = self.report
            return out

    def _account_segment(self, trainer: ConvTrainer,
                         t_recover_from: Optional[float]):
        """Close out one trainer segment: fold its guard stats into the
        run-wide totals, and (when the segment was itself a recovery)
        account the recovery wallclock -- failure catch -> the fresh
        trainer's first completed step (restore + recompile + step
        included) -- even when that trainer later dies too."""
        for k, v in trainer.guard.stats.items():
            self.report["guard"][k] += v
        if t_recover_from is not None and \
                trainer.first_step_wall is not None:
            self.report["recovery_wallclock_s"] += (
                trainer.first_step_wall - t_recover_from)

    def _on_failure(self, kind: str, step: int, mesh: Mesh,
                    dead_hosts: Sequence[int]):
        if len(self.report["recoveries"]) >= self.max_recoveries:
            raise RuntimeError(
                f"supervisor exceeded max_recoveries="
                f"{self.max_recoveries}")
        self.report[kind] += 1
        self.report["recoveries"].append(
            {"kind": kind, "step": int(step),
             "dead_hosts": sorted(int(h) for h in dead_hosts)})
        if dead_hosts:
            self._shrink(mesh, dead_hosts)
