"""Fault-tolerance utilities: elastic re-meshing and restart orchestration.

The policies (DESIGN.md Sec. 6):
  * node failure   -> restart from the latest atomic checkpoint; data
    pipeline skip-ahead is free because batches are pure functions of step.
  * shrink/grow    -> `elastic_mesh` builds the largest valid (data, model)
    mesh from surviving devices; checkpoint restore re-shards every leaf
    onto the new mesh (leaves are stored unsharded).
  * stragglers     -> Trainer's step-timeout watchdog forces an early
    checkpoint so a slow host can be evicted without losing work.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh


def elastic_mesh(devices: Optional[Sequence] = None, *,
                 model_parallel: int = 16) -> Mesh:
    """Largest (data, model) mesh from the surviving device set.

    Keeps the model axis fixed (TP degree is a property of the sharded
    weight layout) and shrinks the data axis, matching how elastic FSDP
    deployments drain failed hosts.
    """
    devices = list(devices if devices is not None else jax.devices())
    mp = model_parallel
    while mp > 1 and len(devices) % mp:
        mp //= 2
    dp = len(devices) // mp
    use = devices[:dp * mp]
    return Mesh(np.asarray(use).reshape(dp, mp), ("data", "model"))


def survivors(mesh: Mesh, failed_host_ids: Sequence[int],
              devices_per_host: int = 8):
    """Device list minus those on failed hosts (simulation helper)."""
    out = []
    for d in mesh.devices.flatten():
        host = d.id // devices_per_host
        if host not in failed_host_ids:
            out.append(d)
    return out
