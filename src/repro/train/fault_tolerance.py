"""Fault-tolerance utilities: elastic re-meshing and restart orchestration.

The policies (DESIGN.md Sec. 6):
  * node failure   -> restart from the latest atomic checkpoint; data
    pipeline skip-ahead is free because batches are pure functions of step.
  * shrink/grow    -> `elastic_mesh` builds the largest valid (data, model)
    mesh from surviving devices; checkpoint restore re-shards every leaf
    onto the new mesh (leaves are stored unsharded).
  * stragglers     -> Trainer's step-timeout watchdog forces an early
    checkpoint so a slow host can be evicted without losing work.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh


def elastic_mesh(devices: Optional[Sequence] = None, *,
                 model_parallel: int = 16) -> Mesh:
    """Largest (data, model) mesh from the surviving device set.

    Keeps the model axis fixed (TP degree is a property of the sharded
    weight layout) and shrinks the data axis, matching how elastic FSDP
    deployments drain failed hosts.
    """
    devices = list(devices if devices is not None else jax.devices())
    if not devices:
        # Every host failed: surface the condition explicitly -- a
        # zero-device Mesh would only blow up later, deep inside jit.
        raise ValueError("elastic_mesh: no surviving devices")
    if model_parallel < 1:
        raise ValueError(f"model_parallel must be >= 1, "
                         f"got {model_parallel}")
    mp = model_parallel
    while mp > 1 and len(devices) % mp:
        mp //= 2
    dp = len(devices) // mp
    use = devices[:dp * mp]
    return Mesh(np.asarray(use).reshape(dp, mp), ("data", "model"))


def survivors(mesh: Mesh, failed_host_ids: Sequence[int],
              devices_per_host: int = 8):
    """Device list minus those on failed hosts (simulation helper)."""
    out = []
    for d in mesh.devices.flatten():
        host = d.id // devices_per_host
        if host not in failed_host_ids:
            out.append(d)
    return out


def host_failure_schedule(seed: int, *, n_hosts: int, n_steps: int,
                          rate: float = 0.02) -> dict:
    """Deterministic host-loss schedule for elastic-training drills,
    built on the SAME seeded registry the serving engine injects from
    (`serve.faults.FaultSchedule`): one seed replays identical failure
    timing across a serving test and a training drill.

    Returns ``{step: [host_id, ...]}`` -- feed each step's losses to
    `survivors` + `elastic_mesh` to rebuild the mesh mid-run."""
    from repro.serve.faults import FaultSchedule

    sched = FaultSchedule.seeded(
        seed, sites=[f"host:{h}" for h in range(n_hosts)], rate=rate,
        horizon=n_steps, kinds=("device_loss",))
    out: dict = {}
    for ev in sched.events:
        out.setdefault(ev.index, []).append(int(ev.site.split(":")[1]))
    return {step: sorted(hosts) for step, hosts in sorted(out.items())}
