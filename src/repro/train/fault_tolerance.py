"""Fault-tolerance utilities: elastic re-meshing, the shared per-step
guard, and restart orchestration.

The policies (DESIGN.md Sec. 6 and Sec. 2.12):
  * node failure   -> restart from the latest atomic checkpoint; data
    pipeline skip-ahead is free because batches are pure functions of step.
  * shrink/grow    -> `elastic_mesh` builds the largest valid (data, model)
    mesh from surviving devices; checkpoint restore re-shards every leaf
    onto the new mesh (leaves are stored unsharded).
  * stragglers     -> the `StepGuard` step-timeout watchdog forces an
    early checkpoint so a slow host can be evicted without losing work.
  * bad numerics   -> `StepGuard` also owns the bounded non-finite retry
    policy (rollback + retry, then skip or shrink-lr, then give up) the
    LM `Trainer` and the conv `ConvTrainer` share instead of diverging
    copies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh


class HostFailure(RuntimeError):
    """Raised (by a schedule hook / the injector mapping) when hosts are
    lost at a step; the run supervisor catches it, rebuilds the mesh
    from survivors, and resumes from the latest intact checkpoint."""

    def __init__(self, step: int, hosts: Sequence[int]):
        super().__init__(f"lost host(s) {sorted(hosts)} at step {step}")
        self.step = int(step)
        self.hosts = tuple(sorted(int(h) for h in hosts))


def elastic_mesh(devices: Optional[Sequence] = None, *,
                 model_parallel: int = 16) -> Mesh:
    """Largest (data, model) mesh from the surviving device set.

    Keeps the model axis fixed (TP degree is a property of the sharded
    weight layout) and shrinks the data axis, matching how elastic FSDP
    deployments drain failed hosts.
    """
    devices = list(devices if devices is not None else jax.devices())
    if not devices:
        # Every host failed: surface the condition explicitly -- a
        # zero-device Mesh would only blow up later, deep inside jit.
        raise ValueError("elastic_mesh: no surviving devices")
    if model_parallel < 1:
        raise ValueError(f"model_parallel must be >= 1, "
                         f"got {model_parallel}")
    mp = model_parallel
    while mp > 1 and len(devices) % mp:
        mp //= 2
    dp = len(devices) // mp
    use = devices[:dp * mp]
    return Mesh(np.asarray(use).reshape(dp, mp), ("data", "model"))


def survivors(mesh: Mesh, failed_host_ids: Sequence[int],
              devices_per_host: int = 8):
    """Device list minus those on failed hosts (simulation helper)."""
    out = []
    for d in mesh.devices.flatten():
        host = d.id // devices_per_host
        if host not in failed_host_ids:
            out.append(d)
    return out


@dataclasses.dataclass(frozen=True)
class GuardDecision:
    """What to do after a non-finite step: `action` in
    retry | skip | give_up; `lr_scale` applies to retries only."""
    action: str
    lr_scale: float = 1.0


class StepGuard:
    """The per-step guard the LM `Trainer` and `ConvTrainer` share: one
    straggler watchdog plus one bounded non-finite retry state machine
    (DESIGN.md Sec. 2.12).

    Straggler side: `start_step()` before the step, `straggled()` after
    -- True when the step exceeded `step_timeout_s` (the caller forces a
    blocking checkpoint so the slow host can be evicted without losing
    work).

    Numerics side: on a non-finite step the caller rolls back to its
    last good in-memory state (steps are non-donating, so "rollback" is
    keeping the old pytree) and asks `nonfinite()` what to do next:

      failure 1              -> retry the SAME step at full lr (the
                                dominant transient case: a poisoned
                                batch, a one-off kernel glitch);
      failure 2..max_retries -> policy: "skip" abandons the step and
                                moves on; "shrink_lr" retries at
                                lr * lr_shrink**(failures-1);
      failure > max_retries  -> give_up (the caller raises -- the loss
                                surface itself is producing non-finite
                                updates and retrying is hiding a bug).

    `good_step()` resets the per-step attempt counter; `stats` counts
    every decision for tests/benchmarks."""

    def __init__(self, *, step_timeout_s: Optional[float] = None,
                 max_retries: int = 2, nonfinite_policy: str = "skip",
                 lr_shrink: float = 0.5):
        if nonfinite_policy not in ("skip", "shrink_lr"):
            raise ValueError(
                f"nonfinite_policy must be 'skip' or 'shrink_lr', "
                f"got {nonfinite_policy!r}")
        if max_retries < 1:
            raise ValueError(f"max_retries must be >= 1, got {max_retries}")
        self.step_timeout_s = step_timeout_s
        self.max_retries = max_retries
        self.nonfinite_policy = nonfinite_policy
        self.lr_shrink = lr_shrink
        self._t0: Optional[float] = None
        self._failures = 0
        self.stats = {"stragglers": 0, "nonfinite_steps": 0,
                      "retries": 0, "skips": 0, "lr_shrinks": 0,
                      "give_ups": 0}

    # -- straggler watchdog --------------------------------------------------
    def start_step(self):
        self._t0 = time.monotonic()

    def straggled(self) -> bool:
        if self.step_timeout_s is None or self._t0 is None:
            return False
        if time.monotonic() - self._t0 > self.step_timeout_s:
            self.stats["stragglers"] += 1
            return True
        return False

    # -- non-finite policy ---------------------------------------------------
    def nonfinite(self) -> GuardDecision:
        self._failures += 1
        n = self._failures
        if n == 1:
            self.stats["nonfinite_steps"] += 1
        if n > self.max_retries:
            self.stats["give_ups"] += 1
            self._failures = 0
            return GuardDecision("give_up")
        if n == 1:
            self.stats["retries"] += 1
            return GuardDecision("retry", 1.0)
        if self.nonfinite_policy == "skip":
            self.stats["skips"] += 1
            self._failures = 0
            return GuardDecision("skip")
        self.stats["retries"] += 1
        self.stats["lr_shrinks"] += 1
        return GuardDecision("retry", self.lr_shrink ** (n - 1))

    def good_step(self):
        self._failures = 0


def host_failure_schedule(seed: int, *, n_hosts: int, n_steps: int,
                          rate: float = 0.02) -> dict:
    """Deterministic host-loss schedule for elastic-training drills,
    built on the SAME seeded registry the serving engine injects from
    (`serve.faults.FaultSchedule`): one seed replays identical failure
    timing across a serving test and a training drill.

    Returns ``{step: [host_id, ...]}`` -- feed each step's losses to
    `survivors` + `elastic_mesh` to rebuild the mesh mid-run."""
    from repro.serve.faults import FaultSchedule

    sched = FaultSchedule.seeded(
        seed, sites=[f"host:{h}" for h in range(n_hosts)], rate=rate,
        horizon=n_steps, kinds=("device_loss",))
    out: dict = {}
    for ev in sched.events:
        out.setdefault(ev.index, []).append(int(ev.site.split(":")[1]))
    return {step: sorted(hosts) for step, hosts in sorted(out.items())}
