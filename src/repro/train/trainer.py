"""Trainer: sharded train loop with checkpoint/restart, async saves,
deterministic data skip-ahead, and failure injection hooks for tests.

The loop is mesh-agnostic: pass any Mesh (the 16x16/2x16x16 production
meshes from launch/mesh.py, or a 1-device debug mesh on CPU).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.data.pipeline import TokenDataset
from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.optim.optimizer import AdamWConfig, adamw_init
from repro.launch.steps import make_train_step
from repro.parallel import sharding as sh
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import StepGuard


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 10
    seed: int = 0
    # fault tolerance
    step_timeout_s: Optional[float] = None   # straggler watchdog
    async_checkpoint: bool = True


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh: Mesh, dataset: TokenDataset,
                 opt_cfg: Optional[AdamWConfig] = None,
                 tcfg: Optional[TrainerConfig] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.dataset = dataset
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.tcfg = tcfg or TrainerConfig()
        self.lm = LM(cfg)
        self._ckptr = (ckpt.AsyncCheckpointer(self.tcfg.ckpt_dir,
                                              self.tcfg.keep_last)
                       if self.tcfg.ckpt_dir else None)
        # Shared straggler watchdog (fault_tolerance.StepGuard): the
        # ConvTrainer runs the same implementation with the non-finite
        # retry side enabled as well.
        self.guard = StepGuard(step_timeout_s=self.tcfg.step_timeout_s)

        with mesh, sh.use_mesh(mesh):
            params_abs = jax.eval_shape(self.lm.init,
                                        jax.random.PRNGKey(self.tcfg.seed))
            self.p_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                sh.tree_pspecs(params_abs, mesh),
                is_leaf=lambda s: not isinstance(s, dict))
            opt_abs = jax.eval_shape(
                lambda p: adamw_init(p, self.opt_cfg), params_abs)
            self.o_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                sh.tree_pspecs(opt_abs, mesh),
                is_leaf=lambda s: not isinstance(s, dict))
            self.step_fn = jax.jit(
                make_train_step(cfg, self.opt_cfg),
                in_shardings=(self.p_sh, self.o_sh, None),
                out_shardings=(self.p_sh, self.o_sh, None),
                donate_argnums=(0, 1))

    # -- state ---------------------------------------------------------------
    def init_state(self):
        with self.mesh, sh.use_mesh(self.mesh):
            params = jax.jit(self.lm.init, out_shardings=self.p_sh)(
                jax.random.PRNGKey(self.tcfg.seed))
            opt = jax.jit(lambda p: adamw_init(p, self.opt_cfg),
                          out_shardings=self.o_sh)(params)
        return params, opt, 0

    def maybe_restore(self):
        """Restore the latest checkpoint if one exists (elastic: works on a
        different mesh than the one that saved it)."""
        d = self.tcfg.ckpt_dir
        if not d:
            return self.init_state()
        step = ckpt.latest_step(d)
        if step is None:
            return self.init_state()
        params_abs = jax.eval_shape(self.lm.init,
                                    jax.random.PRNGKey(self.tcfg.seed))
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, self.opt_cfg),
                                 params_abs)
        state = ckpt.restore(d, step,
                             {"params": params_abs, "opt": opt_abs},
                             {"params": self.p_sh, "opt": self.o_sh})
        return state["params"], state["opt"], step

    def save(self, step, params, opt, blocking=False):
        if not self._ckptr:
            return
        tree = {"params": params, "opt": opt}
        if self.tcfg.async_checkpoint and not blocking:
            self._ckptr.save_async(step, tree)
        else:
            ckpt.save(self.tcfg.ckpt_dir, step, tree,
                      keep_last=self.tcfg.keep_last)

    # -- loop ----------------------------------------------------------------
    def run(self, *, fail_at_step: Optional[int] = None) -> Dict[str, Any]:
        """Train to total_steps (resuming from the latest checkpoint).
        `fail_at_step` raises after that step completes -- used by the
        fault-tolerance tests to simulate a node failure."""
        params, opt, start = self.maybe_restore()
        history = []
        for step in range(start, self.tcfg.total_steps):
            batch = self.dataset.batch(step)  # deterministic skip-ahead
            self.guard.start_step()
            with self.mesh, sh.use_mesh(self.mesh):
                params, opt, metrics = self.step_fn(params, opt, batch)
            if self.guard.straggled():
                # Straggler watchdog: surface, checkpoint, continue.
                self.save(step + 1, params, opt, blocking=True)
            if (step + 1) % self.tcfg.log_every == 0 or \
                    step + 1 == self.tcfg.total_steps:
                history.append({"step": step + 1,
                                "loss": float(metrics["loss"])})
            if self.tcfg.ckpt_dir and (step + 1) % self.tcfg.ckpt_every == 0:
                self.save(step + 1, params, opt)
            if fail_at_step is not None and step + 1 >= fail_at_step:
                if self._ckptr:
                    self._ckptr.wait()
                raise RuntimeError(f"injected failure at step {step + 1}")
        if self._ckptr:
            self.save(self.tcfg.total_steps, params, opt, blocking=True)
            self._ckptr.wait()
        return {"params": params, "opt": opt, "history": history}
