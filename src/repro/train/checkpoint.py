"""Checkpointing: atomic, manifest-driven, async, mesh-reshardable.

Layout:  <dir>/step_<N>/
           manifest.json          {step, tree structure, leaf metadata}
           leaf_<i>.npy           one array per pytree leaf (host-gathered)
         <dir>/LATEST             atomic pointer file

Properties required at scale (DESIGN.md Sec. 6):
  * atomic:   writes go to step_<N>.tmp then os.replace -- a crash mid-save
    never corrupts the latest checkpoint.
  * async:    `save_async` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping the next train steps.
  * elastic:  restore() takes the *current* shardings and device_puts each
    leaf accordingly, so a checkpoint saved on one mesh restores onto any
    other mesh (ZeRO-style resharding is implicit: leaves are stored
    unsharded).
  * bounded:  keep_last prunes old steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3):
    """Synchronous atomic save of a pytree of (sharded) arrays."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    meta = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        meta["leaves"].append({"i": i, "shape": list(arr.shape),
                               "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _prune(ckpt_dir, keep_last)


def _prune(ckpt_dir: str, keep_last: int):
    """Drop old steps, counting `keep_last` over INTACT steps only: torn
    newer directories (a crashed async write, a truncated copy) must not
    push the newest restorable checkpoint out of the retention window."""
    if not keep_last:
        return
    steps = sorted(available_steps(ckpt_dir))
    intact = [s for s in steps if step_intact(ckpt_dir, s)]
    keep = set(intact[-keep_last:])
    for s in steps:
        if s in keep or s > min(keep, default=-1):
            # Torn steps newer than the oldest kept intact step stay too:
            # they may still be mid-write by a concurrent saver.
            continue
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def available_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return out


def step_intact(ckpt_dir: str, step: int) -> bool:
    """True when step_<N> is fully readable: the manifest parses with
    its expected keys and every leaf file loads with the recorded shape.
    A checkpoint written through `save` always passes (the directory is
    published atomically); a torn copy, a partially-deleted step, or a
    leaf truncated by a disk-full crash fails."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    try:
        with open(os.path.join(final, "manifest.json")) as f:
            meta = json.load(f)
        leaves = meta["leaves"]
        for i, rec in enumerate(leaves):
            arr = np.load(os.path.join(final, f"leaf_{i}.npy"),
                          allow_pickle=False)
            if tuple(arr.shape) != tuple(rec["shape"]):
                return False
    except Exception:   # noqa: BLE001 - any unreadability means corrupt
        return False
    return True


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest INTACT step.  The LATEST pointer is consulted first, but a
    corrupt (or stale) candidate is skipped with a `RuntimeWarning` and
    the next-newest intact step is returned instead -- the same
    warn-and-fall-back policy as the tile cache (kernels/tiling.py):
    restart resumes from the best usable state, never crashes on a torn
    file, and never silently trains from scratch."""
    candidates = sorted(available_steps(ckpt_dir), reverse=True)
    path = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(path):
        try:
            with open(path) as f:
                pointed = int(f.read().strip())
            candidates = [pointed] + [s for s in candidates if s != pointed]
        except (OSError, ValueError):
            warnings.warn(
                f"unreadable LATEST pointer in {ckpt_dir}; falling back "
                f"to the newest intact step directory",
                RuntimeWarning, stacklevel=2)
    for s in candidates:
        if step_intact(ckpt_dir, s):
            return s
        warnings.warn(
            f"checkpoint step_{s} in {ckpt_dir} is truncated or "
            f"partially written; skipping it for the newest intact step",
            RuntimeWarning, stacklevel=2)
    return None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None, *,
            fallback: bool = True):
    """Restore into the structure of `like`, placing each leaf with the
    given shardings (mesh-resharding restore).

    A truncated or partially-written step_<N> is skipped with a
    `RuntimeWarning` and the newest intact EARLIER step restores instead
    (`fallback=False` raises `RuntimeError` for callers that need the
    exact step).  With no intact step at all, `FileNotFoundError`."""
    if not step_intact(ckpt_dir, step):
        if not fallback:
            raise RuntimeError(
                f"checkpoint step_{step} in {ckpt_dir} is truncated or "
                f"partially written and fallback is disabled")
        intact = [s for s in sorted(available_steps(ckpt_dir))
                  if s != step and step_intact(ckpt_dir, s)]
        if not intact:
            raise FileNotFoundError(
                f"checkpoint step_{step} in {ckpt_dir} is corrupt and no "
                f"intact step exists to fall back to")
        warnings.warn(
            f"checkpoint step_{step} in {ckpt_dir} is truncated or "
            f"partially written; restoring newest intact step_{intact[-1]} "
            f"instead", RuntimeWarning, stacklevel=2)
        step = intact[-1]
    final = os.path.join(ckpt_dir, f"step_{step}")
    like_leaves, treedef = _flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(like_leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(like_leaves, shard_leaves)):
        arr = np.load(os.path.join(final, f"leaf_{i}.npy"),
                      allow_pickle=False)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: ckpt {arr.shape} vs expected {ref.shape}")
        arr = arr.astype(ref.dtype)   # both branches: a resharding
        # restore must not silently keep the checkpoint dtype either
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread.

    A failure in the background write (disk full, permission flip, torn
    filesystem) is NOT swallowed: it is captured and re-raised on the
    next `wait()` / `save_async()`, so the trainer finds out a
    checkpoint it believes exists was never published, while the step
    that overlapped the write still completes."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint write to {self.ckpt_dir} failed"
            ) from err

    def save_async(self, step: int, tree: Any):
        self.wait()
        # Synchronous device->host snapshot (consistent state) ...
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        # ... asynchronous disk write; exceptions are parked for the
        # next wait()/save_async() instead of dying with the thread.
        def _write():
            try:
                save(self.ckpt_dir, step, host_tree,
                     keep_last=self.keep_last)
            except BaseException as e:   # noqa: BLE001 - must propagate
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
