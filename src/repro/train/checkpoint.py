"""Checkpointing: atomic, manifest-driven, async, mesh-reshardable.

Layout:  <dir>/step_<N>/
           manifest.json          {step, tree structure, leaf metadata}
           leaf_<i>.npy           one array per pytree leaf (host-gathered)
         <dir>/LATEST             atomic pointer file

Properties required at scale (DESIGN.md Sec. 6):
  * atomic:   writes go to step_<N>.tmp then os.replace -- a crash mid-save
    never corrupts the latest checkpoint.
  * async:    `save_async` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping the next train steps.
  * elastic:  restore() takes the *current* shardings and device_puts each
    leaf accordingly, so a checkpoint saved on one mesh restores onto any
    other mesh (ZeRO-style resharding is implicit: leaves are stored
    unsharded).
  * bounded:  keep_last prunes old steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3):
    """Synchronous atomic save of a pytree of (sharded) arrays."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    meta = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        meta["leaves"].append({"i": i, "shape": list(arr.shape),
                               "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _prune(ckpt_dir, keep_last)


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(available_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def available_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        steps = available_steps(ckpt_dir)
        return max(steps) if steps else None
    with open(path) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None):
    """Restore into the structure of `like`, placing each leaf with the
    given shardings (mesh-resharding restore)."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    like_leaves, treedef = _flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(like_leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(like_leaves, shard_leaves)):
        arr = np.load(os.path.join(final, f"leaf_{i}.npy"))
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"leaf {i}: ckpt {arr.shape} vs expected {ref.shape}"
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr.astype(ref.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        # Synchronous device->host snapshot (consistent state) ...
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)
        # ... asynchronous disk write.
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree),
            kwargs={"keep_last": self.keep_last}, daemon=True)
        self._thread.start()
