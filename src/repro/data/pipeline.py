"""Sharded data pipeline: deterministic synthetic + memory-mapped file
token streams, background prefetch, and skip-ahead for restart/straggler
recovery.

Determinism contract: batch contents are a pure function of (seed, step),
independent of worker count or restart position -- the property elastic
restarts and straggler-skipping rely on (DESIGN.md Sec. 6).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class TokenDataset:
    """Deterministic token stream.  Synthetic (hash-based) by default, or
    backed by a memory-mapped uint16/uint32 token file."""

    def __init__(self, *, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, token_file: Optional[str] = None,
                 embed_dim: Optional[int] = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.embed_dim = embed_dim
        self._tokens = None
        if token_file is not None:
            self._tokens = np.memmap(token_file, dtype=np.uint32, mode="r")

    def batch(self, step: int) -> dict:
        """Batch for a global step -- pure function of (seed, step)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S = self.global_batch, self.seq_len
        if self._tokens is not None:
            n = len(self._tokens) - (S + 1)
            starts = rng.integers(0, n, size=B)
            toks = np.stack([self._tokens[s:s + S + 1] for s in starts])
            toks = toks.astype(np.int32)
        else:
            toks = rng.integers(0, self.vocab, size=(B, S + 1),
                                dtype=np.int32)
        out = {"labels": toks[:, 1:]}
        if self.embed_dim is not None:  # audio/vlm stub frontends
            out["inputs"] = rng.standard_normal(
                (B, S, self.embed_dim)).astype(np.float32)
        else:
            out["inputs"] = toks[:, :-1]
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class ConvDataset:
    """Deterministic synthetic batches for the conv training workloads
    (ConvTrainer, DESIGN.md Sec. 2.12) under the same contract as
    `TokenDataset`: batch contents are a pure function of (seed, step),
    so elastic restarts skip ahead for free and an interrupted-then-
    resumed run replays bit-identical data.

    kind "cnn"     -> {"x": (B,H,W,C) f32, "labels": (B,) i32}
    kind "gan_gen" -> {"z": (B,z_dim) f32}
    kind "gan"     -> {"z": (B,z_dim) f32, "real": (B,32,32,C) f32}
    (the GAN "real" side is 32x32 -- the generator ladder's fixed
    output geometry, models/gan.py GENERATOR_LAYERS)."""

    def __init__(self, *, kind: str, batch: int, image: int = 12,
                 channels: int = 3, n_classes: int = 10, z_dim: int = 16,
                 seed: int = 0):
        if kind not in ("cnn", "gan", "gan_gen"):
            raise ValueError(f"unknown conv workload kind {kind!r}")
        self.kind = kind
        self.batch = batch
        self.image = image
        self.channels = channels
        self.n_classes = n_classes
        self.z_dim = z_dim
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        """Batch for a global step -- pure function of (seed, step)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B = self.batch
        if self.kind == "cnn":
            return {"x": rng.standard_normal(
                        (B, self.image, self.image, self.channels)
                    ).astype(np.float32),
                    "labels": rng.integers(0, self.n_classes, size=B,
                                           dtype=np.int32)}
        out = {"z": rng.standard_normal((B, self.z_dim)).astype(np.float32)}
        if self.kind == "gan":
            out["real"] = rng.standard_normal(
                (B, 32, 32, self.channels)).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded) with device put hook."""

    def __init__(self, dataset: TokenDataset, start_step: int = 0,
                 depth: int = 2, put=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._put = put or (lambda x: x)

        def worker():
            for batch in dataset.iterate(start_step):
                if self._stop.is_set():
                    return
                self._q.put(self._put(batch))

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
