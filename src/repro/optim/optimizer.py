"""Optimizers from scratch: AdamW (sharded moments), global-norm clipping,
cosine schedule with linear warmup.

Moment tensors inherit the parameter PartitionSpecs, so with FSDP parameter
sharding this is ZeRO-3: parameters, gradients and optimizer state are all
fully sharded.  `moment_dtype=bfloat16` halves optimizer HBM for >=100B
models (the qwen3-moe-235b config uses it; see DESIGN.md Sec. 6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: str = "float32"
    # Store the working params in bf16 and keep the fp32 master copy in
    # the optimizer state (MaxText-style).  The FSDP all-gathers inside
    # the train step then move bf16 BY CONSTRUCTION -- XLA's partitioner
    # otherwise gathers the fp32 master before the compute-dtype convert
    # no matter where the cast is placed (measured; EXPERIMENTS.md Perf
    # change T2).  Same total optimizer HBM (4+2 vs 4 B/param), half the
    # dominant collective stream.
    bf16_params: bool = False


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "count": jnp.zeros((), jnp.int32)}
    if cfg.bf16_params:
        # fp32 master lives in the optimizer state; `params` are bf16.
        state["master"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def cast_params_for_storage(params, cfg: AdamWConfig):
    """bf16 storage copy of fp32 init params (matrices only)."""
    if not cfg.bf16_params:
        return params
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if p.ndim >= 2 and p.dtype == jnp.float32 else p, params)


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics).

    With cfg.bf16_params the update reads/writes the fp32 master in
    opt_state["master"] (bootstrapped from the bf16 params on the first
    step) and emits bf16 working params.
    """
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    lr = cosine_schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c
    mdt = jnp.dtype(cfg.moment_dtype)

    if cfg.bf16_params:
        first = opt_state["count"] == 0
        base = jax.tree.map(
            lambda mst, p: jnp.where(first, p.astype(jnp.float32), mst),
            opt_state["master"], params)
    else:
        base = params

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp, m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, base, grads, opt_state["m"], opt_state["v"])
    is_triple = lambda t: isinstance(t, tuple) and len(t) == 3
    new_master = jax.tree.map(lambda t: t[0], out, is_leaf=is_triple)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_triple)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_triple)
    new_params = jax.tree.map(lambda np_, p: np_.astype(p.dtype),
                              new_master, params)
    new_state = {"m": new_m, "v": new_v, "count": count}
    if cfg.bf16_params:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gn, "lr": lr}


# ---------------------------------------------------------------------------
# Lion (evoLved sign momentum) -- the low-memory alternative: one moment,
# sign updates.  Same sharded-state properties as AdamW.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LionConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.99
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: str = "float32"

    # schedule-compat shim so cosine_schedule works unchanged
    @property
    def eps(self):  # pragma: no cover - unused by Lion
        return 0.0


def lion_init(params, cfg: LionConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "count": jnp.zeros((), jnp.int32)}


def lion_update(grads, opt_state, params, cfg: LionConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    lr = cosine_schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32)
        update = jnp.sign(b1 * m32 + (1 - b1) * g32)
        if p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        new_m = b2 * m32 + (1 - b2) * g32
        return newp.astype(p.dtype), new_m.astype(mdt)

    out = jax.tree.map(upd, params, grads, opt_state["m"])
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return new_params, {"m": new_m, "count": count}, \
        {"grad_norm": gn, "lr": lr}
