"""Pallas TPU kernels: fused dual-gradient conv backward -- BOTH
gradients of a convolution from ONE `pallas_call`.

A training step runs, per conv layer, the two backward dataflows the
paper accelerates -- the transposed conv (input gradient) and the
dilated conv (filter gradient) -- over the SAME error map.  Launching
them as two independent `pallas_call`s (PR 1-4) re-fetches `dy` from HBM
twice and pays two kernel dispatches; per the bench-host note, the
launch/step count dominates interpret-mode Pallas wall clock, so the
pair is the highest-leverage fusion target (HUGE^2 makes the same
observation for GAN training: efficiency comes from restructuring the
backward *pair*, not either kernel alone).

Two fusions live here, one per VJP in `core/conv.py`:

`conv_backward_pallas(x, dy, w)` -> (dx, dW)   [direct-conv VJP]
    The shared operand is `dy`.  One launch with TWO output refs:
      * dx via the unified (phase, tap) decomposition of
        `kernels/tconv_phase.py` -- each step windows the VMEM-resident
        padded dy block at its tap offset;
      * dW via the per-tap gather of `kernels/dconv_filtergrad.py` --
        the *unpadded* dy window is a STATIC slice of the SAME resident
        padded dy block, so the error map is fetched once and feeds both
        accumulations.
    Every packed (phase, slot) pair of the input-grad decomposition maps
    bijectively onto a filter tap kx = a + (KP-1-uf)*period (padding
    slots map past the filter extent and are skipped/masked), so the
    single (phase, tap) enumeration drives both gradients.

    grid = (Cin_t, B, T/pu, Cout_t, TK/u)      T = phases, TK = taps
      dy block  (1, hp, wp, Co_t)   index (b, co): the ONE dy fetch,
                                    resident across the tap axis
      w block   (pu, u, Co_t, Ci_t) packed rotated sub-filters
      x block   (1, Hp, Wp, Ci_t)   index (b, ci): resident across
                                    (phase, cout, tap)
      dx block  (1, pu, ho, wo, Ci_t) fp32, accumulates over (co, tap)
                                    -- a single CONSECUTIVE visit streak
                                    per (ci, b, phase), as in tconv
      dW block  (T_w, Ci_t, Cout_pad) fp32, index (ci): stationary
                                    across (b, phase, co, tap) -- spans
                                    full (padded) Cout so its streak is
                                    never interrupted by the co axis
    The phase axis sits OUTSIDE the Cout axis (unlike tconv) because the
    dx accumulator's visits must stay consecutive while the dW block
    stays stationary; with the common n_co == 1 plan the dy block is
    fetched once per (ci, b) and resident across everything else.

`tconv_backward_pallas(g, dy, w)` -> (ddy, dW)   [transposed-conv VJP]
    The generator-layer backward: z = tconv(dy, w), cotangent g.  Its
    pair is (conv(g, w), filter_grad(g, dy)) -- the shared operand is
    `g`, which sits in the INPUT role of both.  Each step's tap gather
    of the resident g block feeds TWO matmuls: against the tap's weights
    (-> ddy) and against the dy window (-> dW) -- the fusion shares the
    gather itself, not just the block fetch.

    grid = (B, Cin_t, Cout_t, T/u)
      g block   (1, Hp, Wp, Ci_t)   index (b, ci): the ONE g fetch
      w block   (u, Ci_t, Co_t)     this step's taps' weights
      dy block  (1, Oh, Ow, Co_t)   index (b, co)
      ddy block (1, Oh, Ow, Cout_pad) fp32, index (b): spans full Cout
                                    (per-co column writes via pl.ds) so
                                    its streak covers the whole b slice
      dW block  (T_w, Cin_pad, Cout_pad) fp32, constant index: a single
                                    streak over the entire grid; each
                                    (tap, ci, co) cell is visited once
                                    per batch step (init at b == 0)

Tile extents come from `kernels/tiling.py` ("backward"/"ct_backward"
ops) whose working-set model accounts for the JOINT residency: shared
operand block + both fp32 accumulators.  See DESIGN.md Sec. 2.7.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spec import ConvSpec, _pair
from repro.kernels import tiling
from repro.kernels.tap_gather import gather_tap, pad_to_tap_windows
from repro.kernels.tconv_phase import (assemble_phase_major,
                                       pack_phase_filters)


# ---------------------------------------------------------------------------
# direct-conv VJP: (dx, dW) from one dy residency
# ---------------------------------------------------------------------------

def _bwd_kernel(dy_ref, w_ref, x_ref, *refs, tpw: int, kp: int,
                kq: int, kh: int, kwf: int, per_h: int, per_w: int, sh: int,
                sw: int, dil_h: int, dil_w: int, step_h: int, step_w: int,
                pad_h: int, pad_w: int, ho: int, wo: int, oh: int, ow: int,
                pu: int, n_t: int, u: int, n_k: int, n_b: int, n_ci: int,
                n_co: int, co_t: int, ep=None, has_y: bool = False,
                has_db: bool = False):
    # refs = ([y_ref,] dx_ref, dw_ref [, db_ref]): the forward-output
    # residual input and the bias-gradient output exist only when the
    # epilogue needs them, so the epilogue-free launch keeps the exact
    # legacy spec lists (and jaxpr pins).
    y_ref = refs[0] if has_y else None
    dx_ref, dw_ref = refs[1 if has_y else 0], refs[2 if has_y else 1]
    db_ref = refs[-1] if has_db else None
    b = pl.program_id(1)
    t0 = pl.program_id(2) * pu if n_t > 1 else 0
    co = pl.program_id(3)
    k0 = pl.program_id(4) * u if n_k > 1 else 0
    # Activation-gradient masking IN-VMEM on the resident cotangent block
    # (DESIGN.md Sec. 2.8): dym = dy * act'(y) is the masked (unscaled)
    # cotangent feeding the bias gradient; dx/dW additionally carry the
    # epilogue's scalar scale.  Padded positions stay zero (dy pad is 0).
    dyv = dy_ref[0]
    dym = dyv if y_ref is None else (
        dyv * ep.grad_factor(y_ref[0]).astype(dyv.dtype))
    dyv = dym if ep is None or ep.scale is None else dym * ep.scale
    xv = x_ref[0]
    # The shared residency: the filter-grad side's UNPADDED error window
    # is a static slice of the same VMEM-resident padded dy block the
    # input-grad windows come from -- dy is fetched exactly once.
    rhs_fg = dyv[pad_h:pad_h + oh, pad_w:pad_w + ow].reshape(
        oh * ow, dyv.shape[-1]).astype(jnp.float32)
    if db_ref is not None:
        # Bias gradient: channel-sum of the masked cotangent, accumulated
        # in-kernel as the launch's third output.  One contribution per
        # (batch, cout-tile) -- taken at the first (ci, phase, tap) step.
        dbc = dym[pad_h:pad_h + oh, pad_w:pad_w + ow].astype(
            jnp.float32).sum(axis=(0, 1))                # (co_t,)
        db_cols = slice(None) if n_co == 1 else pl.ds(co * co_t, co_t)
        take = []
        if n_ci > 1:
            take.append(pl.program_id(0) == 0)
        if n_t > 1:
            take.append(pl.program_id(2) == 0)
        if n_k > 1:
            take.append(pl.program_id(4) == 0)
        if n_b == 1:
            if take:
                @pl.when(functools.reduce(jnp.logical_and, take))
                def _db_set():
                    db_ref[0, db_cols] = dbc
            else:
                db_ref[0, db_cols] = dbc
        else:
            @pl.when(functools.reduce(jnp.logical_and, take + [b == 0]))
            def _db_init():
                db_ref[0, db_cols] = dbc

            @pl.when(functools.reduce(jnp.logical_and, take + [b > 0]))
            def _db_acc():
                db_ref[0, db_cols] += dbc
    dx_first = None if (n_co == 1 and n_k == 1) else (
        (co == 0) if n_k == 1 else ((co == 0) & (pl.program_id(4) == 0)))
    # Traced (phase, slot) indices (multiple phase/tap grid steps) cannot
    # skip padding slots at trace time: zero the stationary dW block at
    # the first step of its streak and always accumulate masked products.
    traced = n_t > 1 or n_k > 1
    if traced:
        conds = []
        if n_b > 1:
            conds.append(b == 0)
        if n_co > 1:
            conds.append(co == 0)
        if n_t > 1:
            conds.append(pl.program_id(2) == 0)
        if n_k > 1:
            conds.append(pl.program_id(4) == 0)
        zero = functools.reduce(jnp.logical_and, conds)

        @pl.when(zero)
        def _zero_dw():
            dw_ref[...] = jnp.zeros(dw_ref.shape, dw_ref.dtype)

    cols = slice(None) if n_co == 1 else pl.ds(co * co_t, co_t)
    for p in range(pu):
        t = t0 + p
        a, bb = t // tpw, t % tpw
        acc = None
        for j in range(u):
            k = k0 + j
            uf, vf = k // kq, k % kq
            # The shared (phase, slot) -> filter-tap enumeration.
            # Flipped-slot mapping (see pack_phase_filters): slot uf of
            # phase a holds tap kx = a + (KP-1-uf)*period; padding slots
            # of ragged phases land past the filter extent and carry
            # all-zero packed weights.
            kx = a + (kp - 1 - uf) * per_h
            ky = bb + (kq - 1 - vf) * per_w
            if not traced and (kx >= kh or ky >= kwf):
                # Padding slot, statically known: its dx matmul is a
                # multiply-by-zero and its dW product must not land --
                # skip BOTH.  (The standalone tconv kernel spends a zero
                # matmul here; the fused kernel's dW-side validity test
                # makes the deadness explicit for free.)  Safe because
                # `not traced` implies full (phase, tap) unroll, so every
                # phase sees its >= 1 valid slot within this step.
                continue
            # -- dx: this (phase, tap)'s window of the padded dy block --
            start_h = pad_h - (a * dil_h) // sh - (kp - 1 - uf) * step_h
            start_w = pad_w - (bb * dil_w) // sw - (kq - 1 - vf) * step_w
            if isinstance(start_h, int) and isinstance(start_w, int):
                win = dyv[start_h:start_h + ho, start_w:start_w + wo]
            else:
                win = jax.lax.dynamic_slice(
                    dyv, (start_h, start_w, 0), (ho, wo, dyv.shape[-1]))
            lhs = win.reshape(ho * wo, win.shape[-1]).astype(jnp.float32)
            rhs = w_ref[p, j].astype(jnp.float32)        # (co_t, ci_t)
            prod = jax.lax.dot(lhs, rhs,
                               preferred_element_type=jnp.float32)
            acc = prod if acc is None else acc + prod
            # -- dW: the same slot's filter tap, gathered from x --
            tap = gather_tap(xv, kx, ky, sh=sh, sw=sw, dh=dil_h,
                             dw=dil_w, oh=oh, ow=ow)
            lhs_w = tap.reshape(oh * ow,
                                xv.shape[-1]).astype(jnp.float32)
            pw = jax.lax.dot_general(
                lhs_w, rhs_fg, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)      # (ci_t, co_t)
            if not traced:
                tf = kx * kwf + ky
                if n_b == 1:
                    dw_ref[tf, :, cols] = pw
                else:
                    @pl.when(b == 0)
                    def _init(tf=tf, pw=pw):
                        dw_ref[tf, :, cols] = pw

                    @pl.when(b > 0)
                    def _acc(tf=tf, pw=pw):
                        dw_ref[tf, :, cols] += pw
            else:
                valid = (kx < kh) & (ky < kwf)
                pw = jnp.where(valid, pw, 0.0)
                tf = jnp.where(valid, kx * kwf + ky, 0)
                dw_ref[pl.ds(tf, 1), :, cols] += pw[None]
        acc = acc.reshape(ho, wo, dx_ref.shape[-1])
        if dx_first is None:
            dx_ref[0, p] = acc
        else:
            @pl.when(dx_first)
            def _dx_init(p=p, acc=acc):
                dx_ref[0, p] = acc

            @pl.when(jnp.logical_not(dx_first))
            def _dx_acc(p=p, acc=acc):
                dx_ref[0, p] += acc


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out",
                                             "dilation", "cin_tile",
                                             "cout_tile", "tap_unroll",
                                             "phase_unroll", "interpret",
                                             "epilogue"))
def conv_backward_pallas(x: jax.Array, dy: jax.Array, w: jax.Array, *,
                         stride, padding=(0, 0), n_out=None,
                         dilation=(1, 1), y: jax.Array | None = None,
                         epilogue=None,
                         cin_tile: int | None = None,
                         cout_tile: int | None = None,
                         tap_unroll: int | None = None,
                         phase_unroll: int | None = None,
                         interpret: bool = True):
    """(dx, dW) of direct_conv(x, w, stride, padding, dilation) w.r.t.
    cotangent dy, in a SINGLE `pallas_call` with two output refs.

    x:  (B, Nh, Nw, Cin) forward input (residual).
    dy: (B, Oh, Ow, Cout) error map -- fetched ONCE, shared by both
        gradient accumulations.
    w:  (Kh, Kw, Cin, Cout) forward filter.
    Returns (dx (B, Nh, Nw, Cin) as dy.dtype upcast-safe,
             dW (Kh, Kw, Cin, Cout) as x.dtype).
    Bit-identical (up to fp accumulation order) to
    (tconv_fused_pallas(dy, w), dconv_filter_grad_pallas(x, dy)).

    With `epilogue` (static `Epilogue`) this is the VJP of the
    epilogue-fused forward: `y` is the forward OUTPUT residual, the
    activation-gradient mask act'(y) is applied in-VMEM to the resident
    dy block before both matmuls, and when the epilogue carries a bias
    the bias gradient is accumulated in-kernel as a THIRD output --
    the return becomes (dx, dW, db|None).
    """
    sh, sw = _pair(stride)
    ph, pw_ = _pair(padding)
    dil_h, dil_w = _pair(dilation)
    B, Nh_x, Nw_x, Cin = x.shape
    _, Oh, Ow, Cout = dy.shape
    Kh, Kw, _, _ = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw_),
                         filter_shape=(Kh, Kw), dilation=(dil_h, dil_w))
    if n_out is None:
        n_out = (Nh_x, Nw_x)
    Nh, Nw = _pair(n_out)
    if spec.out_size((Nh_x, Nw_x)) != (Oh, Ow):
        raise ValueError(
            f"dy spatial {dy.shape[1:3]} inconsistent with x spatial "
            f"{x.shape[1:3]} for stride={spec.stride}, "
            f"padding={spec.padding}, filter={spec.filter_shape}, "
            f"dilation={spec.dilation}: forward yields "
            f"{spec.out_size((Nh_x, Nw_x))}")
    Fh, Fw = spec.full_size((Oh, Ow))
    step_h, step_w = spec.tap_phase_step
    TPh, TPw = spec.n_tap_phases
    KP, KQ = spec.taps_per_phase
    T, TK = TPh * TPw, KP * KQ
    T_w = Kh * Kw

    w_packed = pack_phase_filters(w, (sh, sw), (dil_h, dil_w))
    w_flat = w_packed.reshape(T, TK, Cout, Cin)

    pad_h = spec.tap_phase_base(TPh - 1, 0) + (KP - 1) * step_h
    pad_w = spec.tap_phase_base(TPw - 1, 1) + (KQ - 1) * step_w
    ho, wo = -(-Fh // sh), -(-Fw // sw)
    dy_pad = jnp.pad(dy, ((0, 0), (pad_h, ho - Oh), (pad_w, wo - Ow),
                          (0, 0)))
    hp, wp = dy_pad.shape[1], dy_pad.shape[2]

    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw_, pw_), (0, 0)))
    xp = pad_to_tap_windows(xp, stride=(sh, sw), dilation=(dil_h, dil_w),
                            k=(Kh, Kw), out_size=(Oh, Ow))
    xh, xw = xp.shape[1], xp.shape[2]

    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    has_y = epilogue is not None and epilogue.needs_y
    has_db = epilogue is not None and epilogue.bias
    if has_y and y is None:
        raise ValueError("epilogue has an activation but no forward "
                         "output residual y was given")
    if None in (cin_tile, cout_tile, tap_unroll, phase_unroll):
        plan = tiling.plan_tiles("backward", spec, x_shape=x.shape,
                                 dy_shape=dy.shape,
                                 itemsize=dy.dtype.itemsize,
                                 interpret=interpret, epilogue=epilogue)
        cin_tile = plan.cin_tile if cin_tile is None else cin_tile
        cout_tile = plan.cout_tile if cout_tile is None else cout_tile
        tap_unroll = plan.tap_unroll if tap_unroll is None else tap_unroll
        phase_unroll = plan.phase_unroll if phase_unroll is None \
            else phase_unroll
    ci_t = min(cin_tile, Cin)
    co_t = min(cout_tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    if Cout % co_t:
        dy_pad = jnp.pad(dy_pad, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
        w_flat = jnp.pad(w_flat, ((0, 0),) * 2 +
                         ((0, n_co * co_t - Cout), (0, 0)))
    if Cin % ci_t:
        w_flat = jnp.pad(w_flat, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))
        xp = jnp.pad(xp, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))
    co_pad = n_co * co_t

    u = tiling.largest_divisor_leq(TK, tap_unroll)
    pu = tiling.largest_divisor_leq(T, phase_unroll)
    n_k, n_t = TK // u, T // pu
    per_h, per_w = spec.tap_phase_period
    kern = functools.partial(
        _bwd_kernel, tpw=TPw, kp=KP, kq=KQ, kh=Kh, kwf=Kw, per_h=per_h,
        per_w=per_w, sh=sh, sw=sw, dil_h=dil_h, dil_w=dil_w, step_h=step_h,
        step_w=step_w, pad_h=pad_h, pad_w=pad_w, ho=ho, wo=wo, oh=Oh,
        ow=Ow, pu=pu, n_t=n_t, u=u, n_k=n_k, n_b=B, n_ci=n_ci, n_co=n_co,
        co_t=co_t, ep=epilogue, has_y=has_y, has_db=has_db)
    in_specs = [
        pl.BlockSpec((1, hp, wp, co_t),
                     lambda ci, b, t, co, k: (b, 0, 0, co)),
        pl.BlockSpec((pu, u, co_t, ci_t),
                     lambda ci, b, t, co, k: (t, k, co, ci)),
        pl.BlockSpec((1, xh, xw, ci_t),
                     lambda ci, b, t, co, k: (b, 0, 0, ci)),
    ]
    ins = [dy_pad, w_flat, xp]
    if has_y:
        # y rides next to dy with the identical padding/blocking so the
        # mask multiply is pure resident-block elementwise work.
        yp = jnp.pad(y, ((0, 0), (pad_h, ho - Oh), (pad_w, wo - Ow),
                         (0, 0)))
        if Cout % co_t:
            yp = jnp.pad(yp, ((0, 0),) * 3 + ((0, co_pad - Cout),))
        in_specs.append(pl.BlockSpec((1, hp, wp, co_t),
                                     lambda ci, b, t, co, k: (b, 0, 0, co)))
        ins.append(yp)
    out_specs = [
        pl.BlockSpec((1, pu, ho, wo, ci_t),
                     lambda ci, b, t, co, k: (b, t, 0, 0, ci)),
        pl.BlockSpec((T_w, ci_t, co_pad),
                     lambda ci, b, t, co, k: (0, ci, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, T, ho, wo, n_ci * ci_t), jnp.float32),
        jax.ShapeDtypeStruct((T_w, n_ci * ci_t, co_pad), jnp.float32),
    ]
    if has_db:
        out_specs.append(pl.BlockSpec((1, co_pad),
                                      lambda ci, b, t, co, k: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((1, co_pad), jnp.float32))
    outs = pl.pallas_call(
        kern,
        grid=(n_ci, B, n_t, n_co, n_k),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*ins)
    dx_pm, dw_flat = outs[0], outs[1]

    # dW: slice the channel pads, restore the (Kh, Kw) tap layout.
    if Cin % ci_t or Cout % co_t:
        dw_flat = dw_flat[:, :Cin, :Cout]
    dw = dw_flat.reshape(Kh, Kw, Cin, Cout).astype(x.dtype)

    # dx: phase-major -> strided interleave, shared with tconv.
    out = dx_pm
    if Cin % ci_t:
        out = out[..., :Cin]
    dx = assemble_phase_major(out, spec, n_out=(Nh, Nw),
                              full_size=(Fh, Fw)).astype(dy.dtype)
    if epilogue is None:
        return dx, dw
    db = outs[2][0, :Cout].astype(dy.dtype) if has_db else None
    return dx, dw, db


# ---------------------------------------------------------------------------
# transposed-conv VJP: (ddy, dW) from one g residency
# ---------------------------------------------------------------------------

def _ct_bwd_kernel(g_ref, w_ref, dy_ref, *refs, sh: int,
                   sw: int, dil_h: int, dil_w: int, oh: int, ow: int,
                   kwf: int, u: int, n_t: int, n_b: int, n_ci: int,
                   n_co: int, ci_t: int, co_t: int, ep=None,
                   has_z: bool = False, has_db: bool = False):
    # refs = ([z_ref,] ddy_ref, dw_ref [, db_ref]); z is the fused
    # transposed conv's own forward output, masking its cotangent g.
    z_ref = refs[0] if has_z else None
    ddy_ref, dw_ref = refs[1 if has_z else 0], refs[2 if has_z else 1]
    db_ref = refs[-1] if has_db else None
    b, ci, co = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    t0 = pl.program_id(3) * u if n_t > 1 else 0
    # In-VMEM activation-gradient mask on the resident cotangent block:
    # every tap gather below reads the masked g, so both matmuls (ddy
    # and dW) see the epilogue's pullback without an extra HBM pass.
    gv = g_ref[0]
    gm = gv if z_ref is None else (
        gv * ep.grad_factor(z_ref[0]).astype(gv.dtype))
    gv = gm if ep is None or ep.scale is None else gm * ep.scale
    rhs_fg = dy_ref[0].reshape(oh * ow, co_t).astype(jnp.float32)
    ci_cols = slice(None) if n_ci == 1 else pl.ds(ci * ci_t, ci_t)
    co_cols = slice(None) if n_co == 1 else pl.ds(co * co_t, co_t)
    if db_ref is not None:
        # Bias gradient over the tconv's OUTPUT channels (Cin): sum of
        # the masked (unscaled) cotangent, one contribution per
        # (batch, cin-tile) at the first (cout, tap) step.
        dbc = gm.astype(jnp.float32).sum(axis=(0, 1))       # (ci_t,)
        take = []
        if n_co > 1:
            take.append(co == 0)
        if n_t > 1:
            take.append(pl.program_id(3) == 0)
        if n_b == 1:
            if take:
                @pl.when(functools.reduce(jnp.logical_and, take))
                def _db_set():
                    db_ref[0, ci_cols] = dbc
            else:
                db_ref[0, ci_cols] = dbc
        else:
            @pl.when(functools.reduce(jnp.logical_and, take + [b == 0]))
            def _db_init():
                db_ref[0, ci_cols] = dbc

            @pl.when(functools.reduce(jnp.logical_and, take + [b > 0]))
            def _db_acc():
                db_ref[0, ci_cols] += dbc
    acc_f = None
    for j in range(u):
        t = t0 + j
        kx, ky = t // kwf, t % kwf
        # ONE tap gather of the resident g block feeds BOTH matmuls.
        tap = gather_tap(gv, kx, ky, sh=sh, sw=sw, dh=dil_h, dw=dil_w,
                         oh=oh, ow=ow)                   # (oh, ow, ci_t)
        lhs = tap.reshape(oh * ow, ci_t).astype(jnp.float32)
        wt = w_ref[j].astype(jnp.float32)                # (ci_t, co_t)
        prod_f = jax.lax.dot(lhs, wt, preferred_element_type=jnp.float32)
        acc_f = prod_f if acc_f is None else acc_f + prod_f
        pw = jax.lax.dot_general(lhs, rhs_fg, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        # dW[t, ci tile, co tile]: visited once per batch step.
        ti = t if isinstance(t, int) else pl.ds(t, 1)
        pv = pw if isinstance(t, int) else pw[None]
        if n_b == 1:
            dw_ref[ti, ci_cols, co_cols] = pv
        else:
            @pl.when(b == 0)
            def _dw_init(ti=ti, pv=pv):
                dw_ref[ti, ci_cols, co_cols] = pv

            @pl.when(b > 0)
            def _dw_acc(ti=ti, pv=pv):
                dw_ref[ti, ci_cols, co_cols] += pv
    acc_f = acc_f.reshape(oh, ow, co_t)
    if n_ci == 1 and n_t == 1:
        ddy_ref[0, :, :, co_cols] = acc_f
    else:
        first = (ci == 0) if n_t == 1 else ((ci == 0)
                                            & (pl.program_id(3) == 0))

        @pl.when(first)
        def _ddy_init():
            ddy_ref[0, :, :, co_cols] = acc_f

        @pl.when(jnp.logical_not(first))
        def _ddy_acc():
            ddy_ref[0, :, :, co_cols] += acc_f


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "dilation", "cin_tile",
                                             "cout_tile", "tap_unroll",
                                             "interpret", "epilogue"))
def tconv_backward_pallas(g: jax.Array, dy: jax.Array, w: jax.Array, *,
                          stride, padding=(0, 0), dilation=(1, 1),
                          z: jax.Array | None = None, epilogue=None,
                          cin_tile: int | None = None,
                          cout_tile: int | None = None,
                          tap_unroll: int | None = None,
                          interpret: bool = True):
    """(ddy, dW) of the transposed conv z = tconv(dy, w) w.r.t. cotangent
    g, in a SINGLE `pallas_call` with two output refs.

    g:  (B, Nh, Nw, Cin) cotangent of z (the x-side shape) -- fetched
        ONCE; each tap gather feeds both the conv(g, w) matmul (ddy) and
        the filter-gradient matmul against dy (dW).
    dy: (B, Oh, Ow, Cout) the transposed conv's own input (residual).
    w:  (Kh, Kw, Cin, Cout) forward-orientation filter.
    Returns (ddy (B, Oh, Ow, Cout), dW (Kh, Kw, Cin, Cout)).

    With `epilogue` (static `Epilogue`) this is the VJP of the
    epilogue-fused transposed conv: `z` is its forward output residual,
    act'(z) masks the resident g block in-VMEM before the shared tap
    gathers, and when the epilogue carries a bias its gradient (over the
    tconv OUTPUT channels, Cin) is the launch's third output -- the
    return becomes (ddy, dW, db|None).
    """
    sh, sw = _pair(stride)
    ph, pw_ = _pair(padding)
    dil_h, dil_w = _pair(dilation)
    B, Nh, Nw, Cin = g.shape
    _, Oh, Ow, Cout = dy.shape
    Kh, Kw, _, _ = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw_),
                         filter_shape=(Kh, Kw), dilation=(dil_h, dil_w))
    if spec.out_size((Nh, Nw)) != (Oh, Ow):
        raise ValueError(
            f"dy spatial {dy.shape[1:3]} inconsistent with cotangent "
            f"spatial {g.shape[1:3]} for stride={spec.stride}, "
            f"padding={spec.padding}, filter={spec.filter_shape}, "
            f"dilation={spec.dilation}: forward yields "
            f"{spec.out_size((Nh, Nw))}")
    T = Kh * Kw

    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    has_z = epilogue is not None and epilogue.needs_y
    has_db = epilogue is not None and epilogue.bias
    if has_z and z is None:
        raise ValueError("epilogue has an activation but no forward "
                         "output residual z was given")
    if None in (cin_tile, cout_tile, tap_unroll):
        plan = tiling.plan_tiles("ct_backward", spec, x_shape=g.shape,
                                 dy_shape=dy.shape,
                                 itemsize=g.dtype.itemsize,
                                 interpret=interpret, epilogue=epilogue)
        cin_tile = plan.cin_tile if cin_tile is None else cin_tile
        cout_tile = plan.cout_tile if cout_tile is None else cout_tile
        tap_unroll = plan.tap_unroll if tap_unroll is None else tap_unroll
    ci_t = min(cin_tile, Cin)
    co_t = min(cout_tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)

    gp = jnp.pad(g, ((0, 0), (ph, ph), (pw_, pw_), (0, 0)))
    gp = pad_to_tap_windows(gp, stride=(sh, sw), dilation=(dil_h, dil_w),
                            k=(Kh, Kw), out_size=(Oh, Ow))
    hp, wp = gp.shape[1], gp.shape[2]
    w_taps = w.reshape(T, Cin, Cout)
    dy_p = dy
    if Cin % ci_t:
        gp = jnp.pad(gp, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))
        w_taps = jnp.pad(w_taps, ((0, 0), (0, n_ci * ci_t - Cin), (0, 0)))
    if Cout % co_t:
        w_taps = jnp.pad(w_taps,
                         ((0, 0), (0, 0), (0, n_co * co_t - Cout)))
        dy_p = jnp.pad(dy_p, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
    ci_pad, co_pad = n_ci * ci_t, n_co * co_t

    u = tiling.largest_divisor_leq(T, tap_unroll)
    n_t = T // u
    kern = functools.partial(_ct_bwd_kernel, sh=sh, sw=sw, dil_h=dil_h,
                             dil_w=dil_w, oh=Oh, ow=Ow, kwf=Kw, u=u,
                             n_t=n_t, n_b=B, n_ci=n_ci, n_co=n_co,
                             ci_t=ci_t, co_t=co_t, ep=epilogue,
                             has_z=has_z, has_db=has_db)
    in_specs = [
        pl.BlockSpec((1, hp, wp, ci_t),
                     lambda b, ci, co, t: (b, 0, 0, ci)),
        pl.BlockSpec((u, ci_t, co_t),
                     lambda b, ci, co, t: (t, ci, co)),
        pl.BlockSpec((1, Oh, Ow, co_t),
                     lambda b, ci, co, t: (b, 0, 0, co)),
    ]
    ins = [gp, w_taps, dy_p]
    if has_z:
        # z rides next to g with the identical padding/blocking so the
        # mask multiply is pure resident-block elementwise work.
        zp = jnp.pad(z, ((0, 0), (ph, ph), (pw_, pw_), (0, 0)))
        zp = pad_to_tap_windows(zp, stride=(sh, sw),
                                dilation=(dil_h, dil_w), k=(Kh, Kw),
                                out_size=(Oh, Ow))
        if Cin % ci_t:
            zp = jnp.pad(zp, ((0, 0),) * 3 + ((0, ci_pad - Cin),))
        in_specs.append(pl.BlockSpec((1, hp, wp, ci_t),
                                     lambda b, ci, co, t: (b, 0, 0, ci)))
        ins.append(zp)
    out_specs = [
        pl.BlockSpec((1, Oh, Ow, co_pad),
                     lambda b, ci, co, t: (b, 0, 0, 0)),
        pl.BlockSpec((T, ci_pad, co_pad),
                     lambda b, ci, co, t: (0, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, Oh, Ow, co_pad), jnp.float32),
        jax.ShapeDtypeStruct((T, ci_pad, co_pad), jnp.float32),
    ]
    if has_db:
        out_specs.append(pl.BlockSpec((1, ci_pad),
                                      lambda b, ci, co, t: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((1, ci_pad), jnp.float32))
    outs = pl.pallas_call(
        kern,
        grid=(B, n_ci, n_co, n_t),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*ins)
    ddy, dw_flat = outs[0], outs[1]
    if Cout % co_t:
        ddy = ddy[..., :Cout]
    if Cin % ci_t or Cout % co_t:
        dw_flat = dw_flat[:, :Cin, :Cout]
    dw = dw_flat.reshape(Kh, Kw, Cin, Cout).astype(g.dtype)
    if epilogue is None:
        return ddy.astype(dy.dtype), dw
    db = outs[2][0, :Cin].astype(g.dtype) if has_db else None
    return ddy.astype(dy.dtype), dw, db


# ---------------------------------------------------------------------------
# autotune runners
# ---------------------------------------------------------------------------

def _backward_runner(spec: ConvSpec, x_shape, dy_shape, epilogue=None):
    """Autotune hook: execute the fused dual-gradient kernel at one
    candidate plan."""
    x = jnp.zeros(x_shape, jnp.float32)
    dy = jnp.zeros(dy_shape, jnp.float32)
    w = jnp.zeros(spec.filter_shape + (x_shape[-1], dy_shape[-1]),
                  jnp.float32)
    y = (jnp.zeros(dy_shape, jnp.float32)
         if epilogue is not None and epilogue.needs_y else None)
    interp = jax.default_backend() != "tpu"

    def run(plan: tiling.TilePlan):
        return jax.block_until_ready(conv_backward_pallas(
            x, dy, w, stride=spec.stride, padding=spec.padding,
            n_out=(x_shape[1], x_shape[2]), dilation=spec.dilation,
            y=y, epilogue=epilogue,
            cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
            tap_unroll=plan.tap_unroll, phase_unroll=plan.phase_unroll,
            interpret=interp))

    return run


def _ct_backward_runner(spec: ConvSpec, x_shape, dy_shape, epilogue=None):
    """Autotune hook for the transposed-conv fused backward."""
    g = jnp.zeros(x_shape, jnp.float32)
    dy = jnp.zeros(dy_shape, jnp.float32)
    w = jnp.zeros(spec.filter_shape + (x_shape[-1], dy_shape[-1]),
                  jnp.float32)
    z = (jnp.zeros(x_shape, jnp.float32)
         if epilogue is not None and epilogue.needs_y else None)
    interp = jax.default_backend() != "tpu"

    def run(plan: tiling.TilePlan):
        return jax.block_until_ready(tconv_backward_pallas(
            g, dy, w, stride=spec.stride, padding=spec.padding,
            dilation=spec.dilation, z=z, epilogue=epilogue,
            cin_tile=plan.cin_tile,
            cout_tile=plan.cout_tile, tap_unroll=plan.tap_unroll,
            interpret=interp))

    return run


tiling.register_autotune_runner("backward", _backward_runner)
tiling.register_autotune_runner("ct_backward", _ct_backward_runner)
