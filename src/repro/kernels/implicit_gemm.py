"""Pallas TPU kernel: implicit-GEMM transposed / input-gradient conv.

The phase decomposition (kernels/tconv_phase.py) is EcoFlow's zero-free
answer to the strided transposed conv; this module is the strongest
in-repo baseline it races against -- the predicated implicit-GEMM
formulation of microsoft/AttentionEngine's `conv_transpose_example.py`
(SNIPPETS.md Snippet 1): ONE flat GEMM over

    (M = B * Fh * Fw) x (K = Kh * Kw * Cout)

where every (output site, tap) lane carries an in-bound predicate

    h = r - kx*Dh        in_bound = (h % Sh == 0) and 0 <= h // Sh < Oh

and out-of-bound lanes contribute zero.  No phase bookkeeping, no
per-phase sub-filter packing, no host-side residue interleave -- at the
cost of predicated (wasted) MXU lanes: the masked fraction is exactly
`ecoflow.predicated_mac_fraction(spec, (Oh, Ow))` = 1 - Oh*Ow/(Fh*Fw).

TPU realization of the predicate: Mosaic has no per-element gather, so
the `h % S == 0` mask is realized STRUCTURALLY -- the VMEM-resident dy
block is zero-interleaved in-register (a concat + reshape upsample; the
zeros exist only in VMEM, never in HBM) and padded by the tap reach
Dh*(Kh-1) per side, after which every tap's contribution is a STATIC
window of that frame feeding a plain MXU matmul:

    dx_full[r, s] += up[r + (Kh-1-kx)*Dh, s + (Kw-1-ky)*Dw] . W[kx,ky]^T

with `up` the padded upsampled frame (extent Fh + Dh*(Kh-1) per axis).
This is lane-for-lane the predicated flat GEMM: the zero lanes ARE the
failed predicates, multiplied instead of branched -- the exact trade the
strategy planner's waste term prices (DESIGN.md Sec. 2.10).  There is no
scatter and no `lhs/rhs_dilation` conv anywhere in this path (structural
pins in tests/test_implicit_gemm.py).

BlockSpec tiling: grid (B, Cin_t, Cout_t, T/u); per grid step the kernel
holds
  dy block  (1, Oh, Ow, Co_t)    -- the UNPADDED error tile (index map
                                    (b, co) only: resident across taps)
  w block   (u, Co_t, Ci_t)      -- this step's flat-tap weights
  out block (1, Fh, Fw, Ci_t)    -- fp32 accumulator across (co, tap)
in VMEM, plus the transient upsampled frame.  The epilogue slot is wired
like every other family: act(scale * . + bias) applied to the resident
accumulator on the LAST visit, so positions no tap reaches take
epilogue(0) = act(bias) with no host-side fill gather.  Host side does
only the padding crop (+ non-exact-fit tail fill), then casts back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spec import ConvSpec, _pair
from repro.kernels import tiling


def _upsample_pad(dyv: jax.Array, sh: int, sw: int, gh: int, gw: int
                  ) -> jax.Array:
    """Zero-interleave a (Oh, Ow, C) block by (sh, sw) and pad both sides
    by the tap reach (gh, gw).  This materializes the failed predicate
    lanes as VMEM zeros: row r of the result is dy[r' // sh] when
    r' = r - gh satisfies r' % sh == 0 and r' // sh < Oh, else zero --
    the `h_idx % S == 0` in-bound mask of the flat-GEMM formulation."""
    oh, ow, c = dyv.shape
    if sw > 1:
        z = jnp.zeros((oh, ow, sw - 1, c), dyv.dtype)
        dyv = jnp.concatenate([dyv[:, :, None, :], z], axis=2)
        dyv = dyv.reshape(oh, ow * sw, c)[:, :(ow - 1) * sw + 1]
    if sh > 1:
        w_up = dyv.shape[1]
        z = jnp.zeros((oh, sh - 1, w_up, c), dyv.dtype)
        dyv = jnp.concatenate([dyv[:, None], z], axis=1)
        dyv = dyv.reshape(oh * sh, w_up, c)[:(oh - 1) * sh + 1]
    return jnp.pad(dyv, ((gh, gh), (gw, gw), (0, 0)))


def _ig_kernel(dy_ref, w_ref, *refs, sh: int, sw: int, dh: int, dw: int,
               kh: int, kwf: int, fh: int, fw: int, u: int, n_k: int,
               seq1: bool, ep=None):
    """`u` flat taps per sequential grid step: upsample the resident dy
    tile in VMEM, slice each tap's (Fh, Fw) window (static offsets when a
    single tap step remains), one MXU matmul per tap against its
    (Co_t, Ci_t) weights, accumulate the fp32 out tile across the
    sequential (Cout-tile, tap-step) grid axes.

    refs = ([bias_ref,] out_ref); `ep` fuses act(scale * . + bias) onto
    the finished full-extent tile before its HBM store."""
    bias_ref = refs[0] if len(refs) == 2 else None
    out_ref = refs[-1]
    co = pl.program_id(2)
    k0 = pl.program_id(3) * u if n_k > 1 else 0
    gh, gw = dh * (kh - 1), dw * (kwf - 1)
    up = _upsample_pad(dy_ref[0], sh, sw, gh, gw)
    # seq1: single sequential (Cout-tile, tap) step -> unconditional
    # init, inline epilogue.
    first = None if seq1 else (
        (co == 0) if n_k == 1 else ((co == 0) & (pl.program_id(3) == 0)))
    last = None
    if ep is not None and not seq1:
        last = (co == pl.num_programs(2) - 1)
        if n_k > 1:
            last &= pl.program_id(3) == n_k - 1

    def _tail(vals):
        return ep.apply(vals, None if bias_ref is None else bias_ref[0])

    acc = None
    for j in range(u):
        k = k0 + j
        kx, ky = k // kwf, k % kwf
        start_h = (kh - 1 - kx) * dh
        start_w = (kwf - 1 - ky) * dw
        if isinstance(start_h, int) and isinstance(start_w, int):
            win = up[start_h:start_h + fh, start_w:start_w + fw]
        else:
            win = jax.lax.dynamic_slice(
                up, (start_h, start_w, 0), (fh, fw, up.shape[-1]))
        lhs = win.reshape(fh * fw, win.shape[-1]).astype(jnp.float32)
        rhs = w_ref[j].astype(jnp.float32)           # (co_t, ci_t)
        prod = jax.lax.dot(lhs, rhs, preferred_element_type=jnp.float32)
        acc = prod if acc is None else acc + prod
    acc = acc.reshape(fh, fw, out_ref.shape[-1])
    if first is None:
        out_ref[0] = _tail(acc) if ep is not None else acc
    else:
        @pl.when(first)
        def _init():
            out_ref[0] = acc

        @pl.when(jnp.logical_not(first))
        def _acc():
            out_ref[0] += acc

        if ep is not None:
            @pl.when(last)
            def _epilogue():
                out_ref[0] = _tail(out_ref[0])


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out",
                                             "dilation", "cin_tile",
                                             "cout_tile", "tap_unroll",
                                             "interpret", "epilogue"))
def tconv_implicit_gemm_pallas(dy: jax.Array, w: jax.Array, *, stride,
                               padding=(0, 0), n_out=None, dilation=(1, 1),
                               bias: jax.Array | None = None,
                               epilogue=None,
                               cin_tile: int | None = None,
                               cout_tile: int | None = None,
                               tap_unroll: int | None = None,
                               interpret: bool = True) -> jax.Array:
    """Predicated implicit-GEMM transposed conv in a SINGLE `pallas_call`,
    any (S, D).

    dy: (B, Oh, Ow, Cout) error / generator input.
    w:  (Kh, Kw, Cin, Cout) forward filter.
    Returns (B, Nh, Nw, Cin) where (Nh, Nw) = n_out (default exact fit).
    Same contract as `tconv_fused_pallas` -- the two are interchangeable
    behind `plan_strategy` -- but no phase machinery: the stride predicate
    lives in the VMEM zero-interleave, every tap is a static window.

    `epilogue` (static `Epilogue`) fuses act(scale * . + bias) in-kernel;
    `bias` is the (Cin,) vector (the tconv OUTPUT channels).
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Oh, Ow, Cout = dy.shape
    Kh, Kw, Cin, _ = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                         filter_shape=(Kh, Kw), dilation=(dh, dw))
    if n_out is None:
        n_out = spec.input_size((Oh, Ow))
    Nh, Nw = _pair(n_out)
    Fh, Fw = spec.full_size((Oh, Ow))    # S(O-1) + D(K-1) + 1 pre-slice
    T = Kh * Kw

    # Flat tap-major weights: slot kx*Kw + ky holds W[kx, ky]^T.  No flip
    # and no per-phase packing -- the tap's window offset (Kh-1-kx)*Dh
    # realizes the transposed orientation.
    w_flat = w.transpose(0, 1, 3, 2).reshape(T, Cout, Cin)

    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    if epilogue is not None and epilogue.bias and bias is None:
        raise ValueError("epilogue.bias=True but no bias array was given")
    if None in (cin_tile, cout_tile, tap_unroll):
        plan = tiling.plan_tiles("input_grad", spec,
                                 x_shape=(B, Nh, Nw, Cin),
                                 dy_shape=dy.shape,
                                 itemsize=dy.dtype.itemsize,
                                 interpret=interpret, epilogue=epilogue)
        cin_tile = plan.cin_tile if cin_tile is None else cin_tile
        cout_tile = plan.cout_tile if cout_tile is None else cout_tile
        tap_unroll = plan.tap_unroll if tap_unroll is None else tap_unroll
    ci_t = min(cin_tile, Cin)
    co_t = min(cout_tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    dy_in = dy
    if Cout % co_t:
        dy_in = jnp.pad(dy, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
        w_flat = jnp.pad(w_flat, ((0, 0),
                                  (0, n_co * co_t - Cout), (0, 0)))
    if Cin % ci_t:
        w_flat = jnp.pad(w_flat, ((0, 0),) * 2 +
                         ((0, n_ci * ci_t - Cin),))

    u = tiling.largest_divisor_leq(T, tap_unroll)
    n_k = T // u
    kern = functools.partial(_ig_kernel, sh=sh, sw=sw, dh=dh, dw=dw,
                             kh=Kh, kwf=Kw, fh=Fh, fw=Fw, u=u, n_k=n_k,
                             seq1=(n_co == 1 and n_k == 1), ep=epilogue)
    in_specs = [
        pl.BlockSpec((1, Oh, Ow, co_t), lambda b, ci, co, k: (b, 0, 0, co)),
        pl.BlockSpec((u, co_t, ci_t), lambda b, ci, co, k: (k, co, ci)),
    ]
    ins = [dy_in, w_flat]
    if epilogue is not None and epilogue.bias:
        bp = bias.astype(jnp.float32).reshape(1, Cin)
        if Cin % ci_t:
            bp = jnp.pad(bp, ((0, 0), (0, n_ci * ci_t - Cin)))
        in_specs.append(pl.BlockSpec((1, ci_t),
                                     lambda b, ci, co, k: (0, ci)))
        ins.append(bp)
    out = pl.pallas_call(
        kern,
        grid=(B, n_ci, n_co, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Fh, Fw, ci_t),
                               lambda b, ci, co, k: (b, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((B, Fh, Fw, n_ci * ci_t),
                                       jnp.float32),
        interpret=interpret,
    )(*ins)

    if Cin % ci_t:   # slice only when channel padding occurred
        out = out[..., :Cin]
    # Non-exact-fit tails (forward ignored input rows/cols) lie beyond
    # the Fh x Fw extent: no tap reaches them, so under an epilogue they
    # take epilogue(0) = act(bias) -- the same fill the phase path's
    # assembly supplies (nonzero only when a bias rides along).
    eh, ew = max(0, ph + Nh - Fh), max(0, pw + Nw - Fw)
    if eh or ew:
        if epilogue is not None and epilogue.bias:
            fv = epilogue.apply(jnp.zeros((Cin,), jnp.float32), bias)
            fv = fv.astype(out.dtype)
            if eh:
                out = jnp.concatenate(
                    [out, jnp.broadcast_to(fv, (B, eh, out.shape[2], Cin))],
                    axis=1)
            if ew:
                out = jnp.concatenate(
                    [out, jnp.broadcast_to(fv, (B, out.shape[1], ew, Cin))],
                    axis=2)
        else:
            out = jnp.pad(out, ((0, 0), (0, eh), (0, ew), (0, 0)))
    return out[:, ph:ph + Nh, pw:pw + Nw, :].astype(dy.dtype)


def _autotune_runner(spec: ConvSpec, x_shape, dy_shape, epilogue=None):
    """Autotune hook: execute the real kernel at one candidate plan."""
    dy = jnp.zeros(dy_shape, jnp.float32)
    w = jnp.zeros(spec.filter_shape + (x_shape[-1], dy_shape[-1]),
                  jnp.float32)
    bias = (jnp.zeros((x_shape[-1],), jnp.float32)
            if epilogue is not None and epilogue.bias else None)
    n_out = (x_shape[1], x_shape[2])
    interp = jax.default_backend() != "tpu"

    def run(plan: tiling.TilePlan):
        return jax.block_until_ready(tconv_implicit_gemm_pallas(
            dy, w, stride=spec.stride, padding=spec.padding, n_out=n_out,
            dilation=spec.dilation, bias=bias, epilogue=epilogue,
            cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
            tap_unroll=plan.tap_unroll, interpret=interp))

    return run


tiling.register_autotune_runner("input_grad", _autotune_runner,
                                strategy="implicit_gemm")
