"""jit'd public wrappers around the Pallas kernels.

`tconv_phase` assembles the full zero-free transposed convolution from S*S
phase kernels (interleaving is a pure layout operation); `dconv_filter_grad`
is the zero-free filter gradient.  Both run the kernels in interpret mode on
CPU (the container target) and compiled mode on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention import flash_attention_pallas
from repro.kernels.dconv_filtergrad import dconv_filter_grad_pallas
from repro.kernels.tconv_phase import tconv_phase_pallas

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def flash_attention(q, k, v, *, causal=True, blk_q=128, blk_k=128):
    """Blockwise causal GQA attention via the Pallas flash kernel."""
    return flash_attention_pallas(q, k, v, causal=causal, blk_q=blk_q,
                                  blk_k=blk_k, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out"))
def tconv_phase(dy: jax.Array, w: jax.Array, *, stride, padding,
                n_out) -> jax.Array:
    """Zero-free transposed conv via S*S Pallas phase kernels.

    dy (B,Oh,Ow,Cout), w (Kh,Kw,Cin,Cout) -> dx (B,Nh,Nw,Cin).
    """
    sh, sw = stride
    ph, pw = padding
    B, Oh, Ow, Cout = dy.shape
    Kh, Kw, Cin, _ = w.shape
    Nh, Nw = n_out
    Fh, Fw = sh * (Oh - 1) + Kh, sw * (Ow - 1) + Kw
    dx_full = jnp.zeros((B, Fh, Fw, Cin), dtype=dy.dtype)
    for p in range(sh):
        for q in range(sw):
            sub = w[p::sh, q::sw]
            kp, kq = sub.shape[0], sub.shape[1]
            if kp == 0 or kq == 0:
                continue
            sub = jnp.swapaxes(jnp.flip(sub, axis=(0, 1)), 2, 3)
            part = tconv_phase_pallas(dy, sub, interpret=_INTERPRET)
            xp = -(-(Fh - p) // sh)
            xq = -(-(Fw - q) // sw)
            dx_full = dx_full.at[:, p::sh, q::sw, :].set(part[:, :xp, :xq, :])
    eh, ew = max(0, ph + Nh - Fh), max(0, pw + Nw - Fw)
    if eh or ew:
        dx_full = jnp.pad(dx_full, ((0, 0), (0, eh), (0, ew), (0, 0)))
    return dx_full[:, ph:ph + Nh, pw:pw + Nw, :]


@functools.partial(jax.jit, static_argnames=("stride", "padding", "k"))
def dconv_filter_grad(x: jax.Array, dy: jax.Array, *, stride, padding,
                      k) -> jax.Array:
    """Zero-free filter gradient via the Pallas tap-matmul kernel."""
    return dconv_filter_grad_pallas(x, dy, stride=tuple(stride),
                                    padding=tuple(padding), k=tuple(k),
                                    interpret=_INTERPRET)
