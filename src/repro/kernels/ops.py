"""Public wrappers around the Pallas kernels.

`tconv_phase` is the fused zero-free transposed convolution -- ONE
`pallas_call` computes the input gradient of any (stride, dilation)
forward conv via the unified (phase, tap) grid; `dconv_filter_grad` is
the zero-free filter gradient with in-kernel tap gathering (no K^2 input
replication, dilation-aware tap offsets); `dconv_forward` is the fused
zero-free dilated (atrous) forward conv with the dilation taps on the
grid; `conv_backward` / `tconv_backward` are the fused DUAL-GRADIENT
backwards -- both gradients of a conv VJP from one launch sharing a
single fetch of the common operand (dy for the direct conv, the
cotangent for the transposed conv).  All run the kernels in interpret
mode on CPU (the container target) and compiled mode on real TPUs.
These are the `pallas` conv backend
(`repro.core.spec.resolve_backend("pallas")`).

The interpret/compiled decision is resolved PER CALL, not at import: an
import-time `jax.default_backend()` both forces backend initialization as
a side effect of importing this module and goes stale if the device set
changes afterwards (e.g. a TPU runtime initialized late, or tests that
swap platforms).  The kernel entry points are themselves jit'd with
`interpret` static, so each resolved value gets its own compiled cache
entry and nothing re-traces per call.

Tiling is geometry-aware: these wrappers resolve the TilePlan
(cin/cout/spatial tiles, tap/phase unroll) through
`repro.kernels.tiling.plan_tiles` ON EVERY CALL -- from the ConvSpec,
operand shapes, dtype, and the VMEM budget -- and pass it to the kernels
as explicit static arguments.  Resolving OUTSIDE the jit'd kernels
matters: a plan change (flipping `ECOFLOW_TILING=autotune`, a refreshed
tile cache, a new `ECOFLOW_VMEM_BUDGET`) re-keys the kernel's compile
cache and takes effect on the next call, instead of being frozen into
the first trace the way a kernel-internal default would be (kernels
called directly with tile arguments left as None plan at trace time and
carry that caveat).  Analytical model by default; see DESIGN.md
Sec. 2.6.
"""
from __future__ import annotations

import jax

from repro.core.spec import ConvSpec, _pair
from repro.kernels import tiling
from repro.kernels.attention import flash_attention_pallas
from repro.kernels.dconv_backward import (conv_backward_pallas,
                                          tconv_backward_pallas)
from repro.kernels.dconv_filtergrad import dconv_filter_grad_pallas
from repro.kernels.dconv_forward import dconv_forward_pallas
from repro.kernels.implicit_gemm import tconv_implicit_gemm_pallas
from repro.kernels.tconv_phase import tconv_fused_pallas


def _interpret() -> bool:
    """True off-TPU (run the kernels in interpret mode), resolved lazily
    at call time -- see the module docstring."""
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, blk_q=128, blk_k=128):
    """Blockwise causal GQA attention via the Pallas flash kernel."""
    return flash_attention_pallas(q, k, v, causal=causal, blk_q=blk_q,
                                  blk_k=blk_k, interpret=_interpret())


def tconv_phase(dy: jax.Array, w: jax.Array, *, stride, padding,
                n_out, dilation=(1, 1), bias=None,
                epilogue=None, strategy=None) -> jax.Array:
    """Fused zero-free transposed conv / input gradient: ONE Pallas
    launch for any (stride, dilation) geometry, through the strategy
    planner -- `tiling.plan_strategy` races the phase decomposition
    against the predicated implicit-GEMM kernel per geometry and this
    wrapper launches whichever family the plan names (both preserve the
    one-launch invariant and the epilogue contract).

    dy (B,Oh,Ow,Cout), w (Kh,Kw,Cin,Cout) -> dx (B,Nh,Nw,Cin).
    `epilogue` / `bias` fuse act(scale * . + bias) onto the output
    in-kernel (bias over the OUTPUT channels Cin).
    `strategy` pins "phase" | "implicit_gemm" | "auto" for this call
    (None reads ECOFLOW_STRATEGY; benchmarks use the pin to time one
    strategy without env juggling).
    """
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=(w.shape[0], w.shape[1]),
                         dilation=dilation)
    nh, nw = _pair(n_out)
    strategy, plan = tiling.plan_strategy(
        "input_grad", spec, x_shape=(dy.shape[0], nh, nw, w.shape[2]),
        dy_shape=dy.shape, itemsize=dy.dtype.itemsize,
        interpret=_interpret(), epilogue=epilogue, strategy=strategy)
    if strategy == "implicit_gemm":
        return tconv_implicit_gemm_pallas(
            dy, w, stride=tuple(stride), padding=tuple(padding),
            n_out=(nh, nw), dilation=tuple(dilation),
            bias=bias, epilogue=epilogue,
            cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
            tap_unroll=plan.tap_unroll, interpret=_interpret())
    return tconv_fused_pallas(dy, w, stride=tuple(stride),
                              padding=tuple(padding), n_out=(nh, nw),
                              dilation=tuple(dilation),
                              bias=bias, epilogue=epilogue,
                              cin_tile=plan.cin_tile,
                              cout_tile=plan.cout_tile,
                              tap_unroll=plan.tap_unroll,
                              phase_unroll=plan.phase_unroll,
                              interpret=_interpret())


def dconv_filter_grad(x: jax.Array, dy: jax.Array, *, stride, padding,
                      k, dilation=(1, 1)) -> jax.Array:
    """Zero-free filter gradient via the in-kernel tap-gather matmul."""
    spec = ConvSpec.make(stride=stride, padding=padding, filter_shape=k,
                         dilation=dilation)
    plan = tiling.plan_tiles("filter_grad", spec, x_shape=x.shape,
                             dy_shape=dy.shape, itemsize=x.dtype.itemsize,
                             interpret=_interpret())
    return dconv_filter_grad_pallas(x, dy, stride=tuple(stride),
                                    padding=tuple(padding), k=tuple(k),
                                    dilation=tuple(dilation),
                                    cin_tile=plan.cin_tile,
                                    cout_tile=plan.cout_tile,
                                    spatial_tile=plan.spatial_tile,
                                    tap_unroll=plan.tap_unroll,
                                    interpret=_interpret())


def conv_backward(x: jax.Array, dy: jax.Array, w: jax.Array, *, stride,
                  padding, n_out, dilation=(1, 1), y=None, epilogue=None):
    """Fused dual-gradient conv backward: (dx, dW) from ONE Pallas
    launch sharing a single dy fetch (kernels/dconv_backward.py).

    x (B,Nh,Nw,Cin), dy (B,Oh,Ow,Cout), w (Kh,Kw,Cin,Cout)
    -> (dx (B,Nh,Nw,Cin), dW (Kh,Kw,Cin,Cout)).
    With `epilogue` this is the VJP of the epilogue-fused forward (`y` is
    its output residual): the act'(y) mask is applied in-VMEM and the
    return gains the in-kernel bias gradient, (dx, dW, db|None).
    """
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=(w.shape[0], w.shape[1]),
                         dilation=dilation)
    nh, nw = _pair(n_out)
    plan = tiling.plan_tiles("backward", spec, x_shape=x.shape,
                             dy_shape=dy.shape,
                             itemsize=dy.dtype.itemsize,
                             interpret=_interpret(), epilogue=epilogue)
    return conv_backward_pallas(x, dy, w, stride=spec.stride,
                                padding=spec.padding, n_out=(nh, nw),
                                dilation=spec.dilation,
                                y=y, epilogue=epilogue,
                                cin_tile=plan.cin_tile,
                                cout_tile=plan.cout_tile,
                                tap_unroll=plan.tap_unroll,
                                phase_unroll=plan.phase_unroll,
                                interpret=_interpret())


def tconv_backward(g: jax.Array, dy: jax.Array, w: jax.Array, *, stride,
                   padding, dilation=(1, 1), z=None, epilogue=None):
    """Fused transposed-conv backward: (ddy, dW) from ONE Pallas launch
    sharing a single cotangent fetch (every tap gather feeds both the
    conv matmul and the filter-grad matmul).

    g (B,Nh,Nw,Cin) cotangent, dy (B,Oh,Ow,Cout), w (Kh,Kw,Cin,Cout)
    -> (ddy (B,Oh,Ow,Cout), dW (Kh,Kw,Cin,Cout)).
    With `epilogue` this is the VJP of the epilogue-fused transposed conv
    (`z` is its output residual) and returns (ddy, dW, db|None).
    """
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=(w.shape[0], w.shape[1]),
                         dilation=dilation)
    plan = tiling.plan_tiles("ct_backward", spec, x_shape=g.shape,
                             dy_shape=dy.shape,
                             itemsize=g.dtype.itemsize,
                             interpret=_interpret(), epilogue=epilogue)
    return tconv_backward_pallas(g, dy, w, stride=spec.stride,
                                 padding=spec.padding,
                                 dilation=spec.dilation,
                                 z=z, epilogue=epilogue,
                                 cin_tile=plan.cin_tile,
                                 cout_tile=plan.cout_tile,
                                 tap_unroll=plan.tap_unroll,
                                 interpret=_interpret())


def dconv_forward(x: jax.Array, w: jax.Array, *, stride, padding,
                  dilation, bias=None, epilogue=None) -> jax.Array:
    """Fused zero-free dilated (atrous) forward conv: one Pallas launch
    with the dilation taps on the grid.

    x (B,Nh,Nw,Cin), w (Kh,Kw,Cin,Cout) -> y (B,Oh,Ow,Cout).
    `epilogue` / `bias` fuse act(scale * conv + bias) onto the resident
    output block before its HBM store.
    """
    spec = ConvSpec.make(stride=stride, padding=padding,
                         filter_shape=(w.shape[0], w.shape[1]),
                         dilation=dilation)
    oh, ow = spec.out_size((x.shape[1], x.shape[2]))
    if oh < 1 or ow < 1:
        # Degenerate geometry: skip planning, let the kernel raise its
        # too-small-input ValueError with the full context.
        return dconv_forward_pallas(x, w, stride=tuple(stride),
                                    padding=tuple(padding),
                                    dilation=tuple(dilation),
                                    interpret=_interpret())
    plan = tiling.plan_tiles("forward", spec, x_shape=x.shape,
                             dy_shape=(x.shape[0], oh, ow, w.shape[3]),
                             itemsize=x.dtype.itemsize,
                             interpret=_interpret(), epilogue=epilogue)
    return dconv_forward_pallas(x, w, stride=tuple(stride),
                                padding=tuple(padding),
                                dilation=tuple(dilation),
                                bias=bias, epilogue=epilogue,
                                cin_tile=plan.cin_tile,
                                cout_tile=plan.cout_tile,
                                tap_unroll=plan.tap_unroll,
                                interpret=_interpret())
