"""jit'd public wrappers around the Pallas kernels.

`tconv_phase` is the fused zero-free transposed convolution -- ONE
`pallas_call` computes all S*S stride phases (phase interleaving is a pure
reshape/transpose); `dconv_filter_grad` is the zero-free filter gradient
with in-kernel tap gathering (no K^2 input replication, dilation-aware
tap offsets); `dconv_forward` is the fused zero-free dilated (atrous)
forward conv with the dilation taps on the grid.  All run the kernels in
interpret mode on CPU (the container target) and compiled mode on real
TPUs.  These are the `pallas` conv backend
(`repro.core.spec.resolve_backend("pallas")`).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.attention import flash_attention_pallas
from repro.kernels.dconv_filtergrad import dconv_filter_grad_pallas
from repro.kernels.dconv_forward import dconv_forward_pallas
from repro.kernels.tconv_phase import tconv_fused_pallas

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def flash_attention(q, k, v, *, causal=True, blk_q=128, blk_k=128):
    """Blockwise causal GQA attention via the Pallas flash kernel."""
    return flash_attention_pallas(q, k, v, causal=causal, blk_q=blk_q,
                                  blk_k=blk_k, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out"))
def tconv_phase(dy: jax.Array, w: jax.Array, *, stride, padding,
                n_out) -> jax.Array:
    """Fused zero-free transposed conv: one Pallas launch for all phases.

    dy (B,Oh,Ow,Cout), w (Kh,Kw,Cin,Cout) -> dx (B,Nh,Nw,Cin).
    """
    return tconv_fused_pallas(dy, w, stride=tuple(stride),
                              padding=tuple(padding), n_out=tuple(n_out),
                              interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "k",
                                             "dilation"))
def dconv_filter_grad(x: jax.Array, dy: jax.Array, *, stride, padding,
                      k, dilation=(1, 1)) -> jax.Array:
    """Zero-free filter gradient via the in-kernel tap-gather matmul."""
    return dconv_filter_grad_pallas(x, dy, stride=tuple(stride),
                                    padding=tuple(padding), k=tuple(k),
                                    dilation=tuple(dilation),
                                    interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "dilation"))
def dconv_forward(x: jax.Array, w: jax.Array, *, stride, padding,
                  dilation) -> jax.Array:
    """Fused zero-free dilated (atrous) forward conv: one Pallas launch
    with the dilation taps on the grid.

    x (B,Nh,Nw,Cin), w (Kh,Kw,Cin,Cout) -> y (B,Oh,Ow,Cout).
    """
    return dconv_forward_pallas(x, w, stride=tuple(stride),
                                padding=tuple(padding),
                                dilation=tuple(dilation),
                                interpret=_INTERPRET)
