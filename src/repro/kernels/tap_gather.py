"""Shared per-tap gather machinery for the dilated-tap Pallas kernels.

`kernels/dconv_forward.py` (dilated forward) and
`kernels/dconv_filtergrad.py` (filter gradient) realize the same EcoFlow
primitive -- the per-tap multicast group: a window of the once-padded
input at tap offset (kx*D_h, ky*D_w), subsampled by the output stride.
Both the host-side window-fit guard and the in-kernel gather live here so
a fix to the window math reaches every kernel (the B>1 re-fetch lesson:
one-sided fixes to duplicated scaffolding go stale silently).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tap_window_extent(o: int, s: int, d: int, k: int) -> int:
    """Padded-input extent needed so the tap window fits for every tap:
    (O-1)*S + D*(K-1) + 1 per axis."""
    return (o - 1) * s + d * (k - 1) + 1


def pad_to_tap_windows(xp: jax.Array, *, stride, dilation, k,
                       out_size) -> jax.Array:
    """Tail-pad an NHWC padded input so every (kx*D, ky*D) tap window
    fits.  The out_size floor already guarantees the fit for exact and
    non-exact geometries; this guard makes the kernels robust to any
    caller-supplied padding."""
    sh, sw = stride
    dh, dw = dilation
    kh, kw = k
    oh, ow = out_size
    need_h = tap_window_extent(oh, sh, dh, kh)
    need_w = tap_window_extent(ow, sw, dw, kw)
    if xp.shape[1] < need_h or xp.shape[2] < need_w:
        xp = jnp.pad(xp, ((0, 0), (0, max(0, need_h - xp.shape[1])),
                          (0, max(0, need_w - xp.shape[2])), (0, 0)))
    return xp


def gather_tap(x_hwc: jax.Array, kx, ky, *, sh: int, sw: int, dh: int,
               dw: int, oh: int, ow: int) -> jax.Array:
    """In-kernel per-tap multicast group: tap offset (kx*D, ky*D) into a
    VMEM-resident (H, W, C) block, then static-stride subsample --
    x[i*S + kx*D, j*S + ky*D, :] for i < oh, j < ow.

    (kx, ky) may be traced (derived from a grid index) or python ints
    (an unrolled tap with a single tap grid step): static taps lower to
    ONE fused strided slice instead of a dynamic_slice + subsample pair,
    which is both cheaper in interpret mode and friendlier to the Mosaic
    lowering."""
    if isinstance(kx, int) and isinstance(ky, int):
        return x_hwc[kx * dh:kx * dh + (oh - 1) * sh + 1:sh,
                     ky * dw:ky * dw + (ow - 1) * sw + 1:sw]
    win = jax.lax.dynamic_slice(
        x_hwc, (kx * dh, ky * dw, 0),
        ((oh - 1) * sh + 1, (ow - 1) * sw + 1, x_hwc.shape[-1]))
    return win[::sh, ::sw]                           # (oh, ow, C)
