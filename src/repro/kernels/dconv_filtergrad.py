"""Pallas TPU kernel: zero-free dilated-convolution filter gradient.

EcoFlow's filter-gradient dataflow (paper Sec. 4.2): one PE per filter
gradient element, each accumulating
sum_{b,i,j} x[b, iS+kx*D, jS+ky*D] * dy[b,i,j] locally, with the ifmap
delivered via per-tap multicast groups (D is the forward filter dilation,
1 for plain convs).

TPU mapping: the per-tap multicast group is realized INSIDE the kernel --
the padded input block is VMEM-resident and each grid step dynamic-slices
its tap window (kx*D, ky*D) out of it and subsamples by the stride, so the
K_h*K_w-replicated `x_taps` gather of the old formulation is never
materialized.  Each PE-column accumulation becomes one
(Cin x B*O*O) @ (B*O*O x Cout) MXU matmul -- and with tap unrolling, `u`
such matmuls run per grid step against the SAME resident blocks, with
static (compile-time) tap offsets.

BlockSpec tiling (geometry-aware, chosen by `kernels/tiling.py`):

    grid = (Cin_t, Cout_t, B, SP, T/u)     batch/spatial/tap SEQUENTIAL
    x block   (1, 1, rows_x, Wp, ci_t)     one spatial slab of the padded
                                           input; index map (b, sp, ci)
                                           -- resident across the tap axis
    dy block  (1, 1, sp, Ow, co_t)         this slab's error rows
    out block (T, ci_t, co_t)              fp32 accumulator: ALL taps of
                                           this channel tile, stationary
                                           across every (B, SP, tap) step

Batch and the spatial slabs are in-kernel fp32 accumulation axes: the
first (b=0, sp=0) step initializes each tap row of the out block, every
later step accumulates into it, and the block is flushed to HBM exactly
once per (ci, co) tile.  The (B, T, Cin, Cout) HBM partial slabs and the
host-side `out.sum(axis=0)` of the previous revision are gone.  The
PR 2 re-fetch lesson still holds: the padded-input block's index map
depends only on axes OUTSIDE the tap axis, so it is never re-fetched
while the taps of one slab stream; the out block's index map ignores all
three sequential axes, so its grid visits stay consecutive.

Spatial tiling: when the planner splits Oh into slabs, the wrapper
builds overlapping input slabs host-side (rows_x = (sp-1)*S + D*(K-1)+1
rows each -- the halo costs O(n_sp * K_eff) extra rows, not a full
Hp x Wp residency), so the x block never holds the full padded frame.
Tap unrolling: `u` taps per grid step as separate matmuls against the
resident blocks -- each tap slice is consumed before the next is
gathered, so unrolling never materializes a K^2-replicated tap stack.

See DESIGN.md Sec. 2.6 for the tiling policy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spec import ConvSpec, _pair
from repro.kernels import tiling
from repro.kernels.tap_gather import gather_tap, pad_to_tap_windows


def _fg_kernel(x_ref, dy_ref, out_ref, *, sh: int, sw: int, dh: int,
               dw: int, sp: int, ow: int, kw: int, u: int, n_t: int,
               seq1: bool):
    # With a single tap step, t0 is a python int and every tap gather
    # below lowers to STATIC strided slices of the resident block.
    t0 = pl.program_id(4) * u if n_t > 1 else 0
    ci_t = x_ref.shape[-1]
    co_t = dy_ref.shape[-1]
    rhs = dy_ref[0, 0].reshape(sp * ow, co_t).astype(jnp.float32)
    xv = x_ref[0, 0]
    # seq1: B == n_sp == 1, so every visit to an out row is its first --
    # the init/accumulate predication compiles away entirely.
    first = None if seq1 else ((pl.program_id(2) == 0)
                               & (pl.program_id(3) == 0))

    def _store(t, prod, accumulate: bool):
        if isinstance(t, int):
            out_ref[t] = (out_ref[t] + prod) if accumulate else prod
        elif accumulate:
            out_ref[pl.ds(t, 1)] += prod[None]
        else:
            out_ref[pl.ds(t, 1)] = prod[None]

    for j in range(u):
        t = t0 + j
        kx, ky = t // kw, t % kw
        tap = gather_tap(xv, kx, ky, sh=sh, sw=sw, dh=dh, dw=dw,
                         oh=sp, ow=ow)                 # (sp, ow, ci_t)
        lhs = tap.reshape(sp * ow, ci_t).astype(jnp.float32)
        # One PE-column block per tap: (ci_t x sp*ow) @ (sp*ow x co_t).
        # Kept as per-tap matmuls (NOT one concatenated wide matmul): the
        # concat materializes a u-replicated tap stack and costs more
        # than it saves on both the interpret and Mosaic paths.
        prod = jax.lax.dot_general(
            lhs, rhs, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (ci_t, co_t)
        if first is None:
            _store(t, prod, accumulate=False)
        else:
            @pl.when(first)
            def _init(t=t, prod=prod):
                _store(t, prod, accumulate=False)

            @pl.when(jnp.logical_not(first))
            def _acc(t=t, prod=prod):
                _store(t, prod, accumulate=True)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "k",
                                             "dilation", "cin_tile",
                                             "cout_tile", "spatial_tile",
                                             "tap_unroll", "interpret"))
def dconv_filter_grad_pallas(x: jax.Array, dy: jax.Array, *, stride,
                             padding, k, dilation=(1, 1),
                             cin_tile: int | None = None,
                             cout_tile: int | None = None,
                             spatial_tile: int | None = None,
                             tap_unroll: int | None = None,
                             interpret: bool = True) -> jax.Array:
    """dW (Kh,Kw,Cin,Cout) for direct_conv(x, w, stride, padding, dilation).

    SINGLE `pallas_call`; the input is padded once and tap windows are
    sliced inside the kernel (no K^2 input replication on the host side).
    Batch and spatial slabs accumulate IN KERNEL into a stationary fp32
    out block -- no per-batch HBM partials, no host-side reduction.  Tile
    extents default to the geometry-aware planner in `kernels/tiling.py`;
    pass them explicitly to pin a tiling (tests do).
    """
    sh, sw = stride
    ph, pw = padding
    dh, dw = _pair(dilation)
    Kh, Kw = k
    B, Nh, Nw, Cin = x.shape
    _, Oh, Ow, Cout = dy.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                         filter_shape=(Kh, Kw), dilation=(dh, dw))
    T = Kh * Kw

    if None in (cin_tile, cout_tile, spatial_tile, tap_unroll):
        plan = tiling.plan_tiles("filter_grad", spec, x_shape=x.shape,
                                 dy_shape=dy.shape,
                                 itemsize=x.dtype.itemsize,
                                 interpret=interpret)
        cin_tile = plan.cin_tile if cin_tile is None else cin_tile
        cout_tile = plan.cout_tile if cout_tile is None else cout_tile
        spatial_tile = plan.spatial_tile if spatial_tile is None \
            else spatial_tile
        tap_unroll = plan.tap_unroll if tap_unroll is None else tap_unroll
    ci_t, co_t = min(cin_tile, Cin), min(cout_tile, Cout)
    sp = max(1, min(spatial_tile, Oh))
    u = tiling.largest_divisor_leq(T, tap_unroll)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    n_sp, n_t = -(-Oh // sp), T // u
    oh_pad = n_sp * sp

    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    xp = pad_to_tap_windows(xp, stride=(sh, sw), dilation=(dh, dw),
                            k=(Kh, Kw), out_size=(oh_pad, Ow))
    rows_x = (sp - 1) * sh + dh * (Kh - 1) + 1
    if n_sp > 1:
        # Overlapping spatial slabs (halo = D*(K-1) + S-1 rows each): the
        # kernel's x block holds ONE slab, never the full padded frame.
        x_sl = jnp.stack([jax.lax.slice_in_dim(xp, s * sp * sh,
                                               s * sp * sh + rows_x, axis=1)
                          for s in range(n_sp)], axis=1)
    else:
        x_sl = xp[:, None]                 # (B, 1, Hp, Wp, Cin)
    wp = x_sl.shape[3]
    # Channel pad only when the tile does not divide the channel count
    # (the planner prefers exact tiles, making this a no-op on most nets).
    if Cin % ci_t:
        x_sl = jnp.pad(x_sl, ((0, 0),) * 4 + ((0, n_ci * ci_t - Cin),))
    dy_p = dy
    if Cout % co_t:
        dy_p = jnp.pad(dy_p, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
    if oh_pad != Oh:    # zero error rows contribute nothing to dW
        dy_p = jnp.pad(dy_p, ((0, 0), (0, oh_pad - Oh), (0, 0), (0, 0)))
    dy_sl = dy_p.reshape(B, n_sp, sp, Ow, n_co * co_t)

    kern = functools.partial(_fg_kernel, sh=sh, sw=sw, dh=dh, dw=dw,
                             sp=sp, ow=Ow, kw=Kw, u=u, n_t=n_t,
                             seq1=(B == 1 and n_sp == 1))
    out = pl.pallas_call(
        kern,
        grid=(n_ci, n_co, B, n_sp, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, rows_x, wp, ci_t),
                         lambda ci, co, b, s, t: (b, s, 0, 0, ci)),
            pl.BlockSpec((1, 1, sp, Ow, co_t),
                         lambda ci, co, b, s, t: (b, s, 0, 0, co)),
        ],
        out_specs=pl.BlockSpec((T, ci_t, co_t),
                               lambda ci, co, b, s, t: (0, ci, co)),
        out_shape=jax.ShapeDtypeStruct((T, n_ci * ci_t, n_co * co_t),
                                       jnp.float32),
        interpret=interpret,
    )(x_sl, dy_sl)
    if Cin % ci_t or Cout % co_t:   # slice only when padding occurred
        out = out[:, :Cin, :Cout]
    return out.reshape(Kh, Kw, Cin, Cout).astype(x.dtype)


def _autotune_runner(spec: ConvSpec, x_shape, dy_shape):
    """Autotune hook: execute the real kernel at one candidate plan (fp32
    proxy operands; geometry, not values, determines the timing)."""
    x = jnp.zeros(x_shape, jnp.float32)
    dy = jnp.zeros(dy_shape, jnp.float32)
    interp = jax.default_backend() != "tpu"

    def run(plan: tiling.TilePlan):
        return jax.block_until_ready(dconv_filter_grad_pallas(
            x, dy, stride=spec.stride, padding=spec.padding,
            k=spec.filter_shape, dilation=spec.dilation,
            cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
            spatial_tile=plan.spatial_tile, tap_unroll=plan.tap_unroll,
            interpret=interp))

    return run


tiling.register_autotune_runner("filter_grad", _autotune_runner)
