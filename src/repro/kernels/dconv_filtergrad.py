"""Pallas TPU kernel: zero-free dilated-convolution filter gradient.

EcoFlow's filter-gradient dataflow (paper Sec. 4.2): one PE per filter
gradient element, each accumulating
sum_{b,i,j} x[b, iS+kx*D, jS+ky*D] * dy[b,i,j] locally, with the ifmap
delivered via per-tap multicast groups (D is the forward filter dilation,
1 for plain convs).

TPU mapping: the per-tap multicast group is realized INSIDE the kernel --
the padded input block is VMEM-resident and each grid step dynamic-slices
its tap window (kx*D, ky*D) out of it and subsamples by the stride, so the
K_h*K_w-replicated `x_taps` gather of the old formulation is never
materialized (peak memory: one padded input, not K^2 copies).  Each
PE-column accumulation becomes one (Cin x B*O*O) @ (B*O*O x Cout) MXU
matmul.

BlockSpec tiling: grid (B, Cin_tiles, T, Cout_tiles) with batch the
OUTERMOST axis; per step the kernel holds x_pad (1,Hp,Wp,Ci_t),
dy (1,Oh,Ow,Co_t) and out (1,1,Ci_t,Co_t) in VMEM.  The x block's index
map depends only on (b, ci) -- both outer axes -- so it is NOT re-fetched
across the tap/Cout grid axes (an earlier revision iterated batch
*innermost* to accumulate in-kernel, which re-fetched the padded input
every grid step for B > 1).  Each step instead writes its (B, T, Ci, Co)
partial and the wrapper reduces over B host-side -- one cheap fp32 sum of
K^2*Cin*Cout-sized slabs.  Ci_t = Co_t = 128 aligns the matmul to the
MXU.  See DESIGN.md Sec. 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spec import _pair
from repro.kernels.tap_gather import gather_tap, pad_to_tap_windows


def _fg_kernel(x_ref, dy_ref, out_ref, *, sh: int, sw: int, dh: int,
               dw: int, oh: int, ow: int, kw: int):
    t = pl.program_id(2)
    kx, ky = t // kw, t % kw
    ci_t = x_ref.shape[-1]
    tap = gather_tap(x_ref[0], kx, ky, sh=sh, sw=sw, dh=dh, dw=dw,
                     oh=oh, ow=ow)                   # (oh, ow, ci_t)
    lhs = tap.reshape(oh * ow, ci_t).astype(jnp.float32)
    rhs = dy_ref[0].reshape(oh * ow, dy_ref.shape[-1]).astype(jnp.float32)
    out_ref[0, 0] = jax.lax.dot_general(
        lhs, rhs, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "k",
                                             "dilation", "tile",
                                             "interpret"))
def dconv_filter_grad_pallas(x: jax.Array, dy: jax.Array, *, stride,
                             padding, k, dilation=(1, 1), tile: int = 128,
                             interpret: bool = True) -> jax.Array:
    """dW (Kh,Kw,Cin,Cout) for direct_conv(x, w, stride, padding, dilation).

    SINGLE `pallas_call`; the input is padded once and tap windows are
    sliced inside the kernel (no K^2 input replication on the host side).
    Per-batch partials are reduced host-side so the padded-input block
    stays VMEM-resident across the tap/Cout grid axes.
    """
    sh, sw = stride
    ph, pw = padding
    dh, dw = _pair(dilation)
    Kh, Kw = k
    B, Nh, Nw, Cin = x.shape
    _, Oh, Ow, Cout = dy.shape
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    xp = pad_to_tap_windows(xp, stride=(sh, sw), dilation=(dh, dw),
                            k=(Kh, Kw), out_size=(Oh, Ow))
    hp, wp = xp.shape[1], xp.shape[2]
    T = Kh * Kw
    ci_t, co_t = min(tile, Cin), min(tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    if Cin % ci_t:
        xp = jnp.pad(xp, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))
    if Cout % co_t:
        dy = jnp.pad(dy, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
    kern = functools.partial(_fg_kernel, sh=sh, sw=sw, dh=dh, dw=dw,
                             oh=Oh, ow=Ow, kw=Kw)
    out = pl.pallas_call(
        kern,
        grid=(B, n_ci, T, n_co),
        in_specs=[
            pl.BlockSpec((1, hp, wp, ci_t),
                         lambda b, ci, t, co: (b, 0, 0, ci)),
            pl.BlockSpec((1, Oh, Ow, co_t),
                         lambda b, ci, t, co: (b, 0, 0, co)),
        ],
        out_specs=pl.BlockSpec((1, 1, ci_t, co_t),
                               lambda b, ci, t, co: (b, t, ci, co)),
        out_shape=jax.ShapeDtypeStruct((B, T, n_ci * ci_t, n_co * co_t),
                                       jnp.float32),
        interpret=interpret,
    )(xp, dy)
    dw_ = out.sum(axis=0)[:, :Cin, :Cout].reshape(Kh, Kw, Cin, Cout)
    return dw_.astype(x.dtype)
