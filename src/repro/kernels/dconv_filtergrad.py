"""Pallas TPU kernel: zero-free dilated-convolution filter gradient.

EcoFlow's filter-gradient dataflow (paper Sec. 4.2): one PE per filter
gradient element, each accumulating  sum_{b,i,j} x[b,iS+kx,jS+ky] * dy[b,i,j]
locally, with the ifmap delivered via per-tap multicast groups.

TPU mapping: the per-tap multicast group is a strided gather of x (built
once in the wrapper -- `x_taps[t] = x[:, kx::S, ky::S]`), and each PE-column
accumulation becomes one (Cin x B*O*O) @ (B*O*O x Cout) MXU matmul.  The
batch dimension is the innermost (sequential) grid axis so partial products
accumulate into the fp32 output tile across grid steps -- the Pallas
equivalent of the paper's local psum register.

BlockSpec tiling: grid (T, Cin_tiles, Cout_tiles, B); per step the kernel
holds x_tap (1,1,Oh,Ow,Ci_t), dy (1,Oh,Ow,Co_t) and out (1,Ci_t,Co_t) in
VMEM.  Ci_t = Co_t = 128 aligns the matmul to the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fg_kernel(x_ref, dy_ref, out_ref):
    b = pl.program_id(3)
    oh, ow = x_ref.shape[2], x_ref.shape[3]
    lhs = x_ref[0, 0].reshape(oh * ow, x_ref.shape[-1]).astype(jnp.float32)
    rhs = dy_ref[0].reshape(oh * ow, dy_ref.shape[-1]).astype(jnp.float32)
    prod = jax.lax.dot_general(lhs, rhs, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(b == 0)
    def _init():
        out_ref[0] = prod.astype(out_ref.dtype)

    @pl.when(b > 0)
    def _acc():
        out_ref[0] += prod.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "k",
                                             "tile", "interpret"))
def dconv_filter_grad_pallas(x: jax.Array, dy: jax.Array, *, stride,
                             padding, k, tile: int = 128,
                             interpret: bool = True) -> jax.Array:
    """dW (Kh,Kw,Cin,Cout) for direct_conv(x, w, stride, padding)."""
    sh, sw = stride
    ph, pw = padding
    Kh, Kw = k
    B, Nh, Nw, Cin = x.shape
    _, Oh, Ow, Cout = dy.shape
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    # Per-tap strided gathers = the paper's ifmap multicast groups.
    taps = []
    for kx in range(Kh):
        for ky in range(Kw):
            taps.append(jax.lax.slice(
                xp, (0, kx, ky, 0),
                (B, kx + (Oh - 1) * sh + 1, ky + (Ow - 1) * sw + 1, Cin),
                (1, sh, sw, 1)))
    x_taps = jnp.stack(taps)                      # (T, B, Oh, Ow, Cin)
    T = Kh * Kw
    ci_t, co_t = min(tile, Cin), min(tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    if Cin % ci_t:
        x_taps = jnp.pad(x_taps, ((0, 0),) * 4 + ((0, n_ci * ci_t - Cin),))
    if Cout % co_t:
        dy = jnp.pad(dy, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
    out = pl.pallas_call(
        _fg_kernel,
        grid=(T, n_ci, n_co, B),
        in_specs=[
            pl.BlockSpec((1, 1, Oh, Ow, ci_t),
                         lambda t, ci, co, b: (t, b, 0, 0, ci)),
            pl.BlockSpec((1, Oh, Ow, co_t),
                         lambda t, ci, co, b: (b, 0, 0, co)),
        ],
        out_specs=pl.BlockSpec((1, ci_t, co_t),
                               lambda t, ci, co, b: (t, ci, co)),
        out_shape=jax.ShapeDtypeStruct((T, n_ci * ci_t, n_co * co_t),
                                       jnp.float32),
        interpret=interpret,
    )(x_taps, dy)
    dw = out[:, :Cin, :Cout].reshape(Kh, Kw, Cin, Cout)
    return dw.astype(x.dtype)
