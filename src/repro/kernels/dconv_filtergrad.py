"""Pallas TPU kernel: zero-free dilated-convolution filter gradient.

EcoFlow's filter-gradient dataflow (paper Sec. 4.2): one PE per filter
gradient element, each accumulating  sum_{b,i,j} x[b,iS+kx,jS+ky] * dy[b,i,j]
locally, with the ifmap delivered via per-tap multicast groups.

TPU mapping: the per-tap multicast group is realized INSIDE the kernel --
the padded input block is VMEM-resident and each grid step dynamic-slices
its tap window (kx, ky) out of it and subsamples by the stride, so the
K_h*K_w-replicated `x_taps` gather of the old formulation is never
materialized (peak memory: one padded input, not K^2 copies).  Each
PE-column accumulation becomes one (Cin x B*O*O) @ (B*O*O x Cout) MXU
matmul.  The batch dimension is the innermost (sequential) grid axis so
partial products accumulate into the fp32 output tile across grid steps --
the Pallas equivalent of the paper's local psum register.

BlockSpec tiling: grid (T, Cin_tiles, Cout_tiles, B); per step the kernel
holds x_pad (1,Hp,Wp,Ci_t), dy (1,Oh,Ow,Co_t) and out (1,Ci_t,Co_t) in
VMEM.  The x block's index map depends only on (b, ci), so it is NOT
re-fetched across the tap/Cout grid axes.  Ci_t = Co_t = 128 aligns the
matmul to the MXU.  See DESIGN.md Sec. 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fg_kernel(x_ref, dy_ref, out_ref, *, sh: int, sw: int,
               oh: int, ow: int, kw: int):
    t = pl.program_id(0)
    b = pl.program_id(3)
    kx, ky = t // kw, t % kw
    ci_t = x_ref.shape[-1]
    # In-kernel tap gather: dynamic tap offset, then static-stride
    # subsample -- x[b, kx + i*S_h, ky + j*S_w, :] for i < Oh, j < Ow.
    win = jax.lax.dynamic_slice(
        x_ref[0], (kx, ky, 0),
        ((oh - 1) * sh + 1, (ow - 1) * sw + 1, ci_t))
    tap = win[::sh, ::sw]                            # (oh, ow, ci_t)
    lhs = tap.reshape(oh * ow, ci_t).astype(jnp.float32)
    rhs = dy_ref[0].reshape(oh * ow, dy_ref.shape[-1]).astype(jnp.float32)
    prod = jax.lax.dot_general(lhs, rhs, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(b == 0)
    def _init():
        out_ref[0] = prod.astype(out_ref.dtype)

    @pl.when(b > 0)
    def _acc():
        out_ref[0] += prod.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "k",
                                             "tile", "interpret"))
def dconv_filter_grad_pallas(x: jax.Array, dy: jax.Array, *, stride,
                             padding, k, tile: int = 128,
                             interpret: bool = True) -> jax.Array:
    """dW (Kh,Kw,Cin,Cout) for direct_conv(x, w, stride, padding).

    SINGLE `pallas_call`; the input is padded once and tap windows are
    sliced inside the kernel (no K^2 input replication on the host side).
    """
    sh, sw = stride
    ph, pw = padding
    Kh, Kw = k
    B, Nh, Nw, Cin = x.shape
    _, Oh, Ow, Cout = dy.shape
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    # Tap windows must fit for every (kx, ky); non-exact-fit inputs already
    # satisfy Hp >= (Oh-1)*S_h + Kh, but guard with an explicit tail pad.
    need_h = (Oh - 1) * sh + Kh
    need_w = (Ow - 1) * sw + Kw
    if xp.shape[1] < need_h or xp.shape[2] < need_w:
        xp = jnp.pad(xp, ((0, 0), (0, max(0, need_h - xp.shape[1])),
                          (0, max(0, need_w - xp.shape[2])), (0, 0)))
    hp, wp = xp.shape[1], xp.shape[2]
    T = Kh * Kw
    ci_t, co_t = min(tile, Cin), min(tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    if Cin % ci_t:
        xp = jnp.pad(xp, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))
    if Cout % co_t:
        dy = jnp.pad(dy, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
    kern = functools.partial(_fg_kernel, sh=sh, sw=sw, oh=Oh, ow=Ow, kw=Kw)
    out = pl.pallas_call(
        kern,
        grid=(T, n_ci, n_co, B),
        in_specs=[
            pl.BlockSpec((1, hp, wp, ci_t),
                         lambda t, ci, co, b: (b, 0, 0, ci)),
            pl.BlockSpec((1, Oh, Ow, co_t),
                         lambda t, ci, co, b: (b, 0, 0, co)),
        ],
        out_specs=pl.BlockSpec((1, ci_t, co_t),
                               lambda t, ci, co, b: (t, ci, co)),
        out_shape=jax.ShapeDtypeStruct((T, n_ci * ci_t, n_co * co_t),
                                       jnp.float32),
        interpret=interpret,
    )(xp, dy)
    dw = out[:, :Cin, :Cout].reshape(Kh, Kw, Cin, Cout)
    return dw.astype(x.dtype)
