"""Pure-jnp oracles for the Pallas kernels.

Each function is the mathematical specification of one kernel, written with
plain jnp/lax ops (these are themselves validated against `jax.vjp` of a
plain convolution in tests/test_core_conv.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ecoflow


def tconv_phase_ref(dy, w, *, stride, padding, n_out, dilation=(1, 1)):
    """Oracle for the unified (phase, tap) transposed-convolution kernel
    (any stride x dilation pair)."""
    return ecoflow.transposed_conv_zero_free(
        dy, w, stride=stride, padding=padding, n_out=tuple(n_out),
        dilation=tuple(dilation))


def dconv_filter_grad_ref(x, dy, *, stride, padding, k, dilation=(1, 1)):
    """Oracle for the zero-free filter-gradient kernel."""
    return ecoflow.dilated_conv_filter_grad_zero_free(
        x, dy, stride=stride, padding=padding, k=tuple(k),
        dilation=tuple(dilation))


def dconv_forward_ref(x, w, *, stride, padding, dilation):
    """Oracle for the fused dilated-forward kernel: XLA's own rhs-dilated
    conv (materializes nothing either, but is the independent ground
    truth)."""
    return ecoflow.direct_conv(x, w, stride, padding, dilation=dilation)


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """Oracle for the flash-attention kernel: (B,S,H,D) GQA attention."""
    Bq, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    rep = Hq // Hk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        ki = jnp.arange(Sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
