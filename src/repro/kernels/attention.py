"""Pallas TPU kernel: blockwise (flash) causal GQA attention.

The serving/prefill hot-spot of every attention arch in the pool.  One
`pallas_call` runs the full online-softmax recurrence:

  grid (B, Hq, Sq/blk_q, Sk/blk_k), kv innermost (sequential on TPU), with
  the running max `m`, normalizer `l` and the fp32 output accumulator kept
  in VMEM scratch across kv steps -- the Pallas equivalent of the flash
  attention SRAM state.

BlockSpec tiling: per grid step the kernel holds
  q block   (1, blk_q, 1, D)
  k/v block (1, blk_k, 1, D)     -- GQA: Hq head h reads Hk head h//g
  out block (1, blk_q, 1, D)     -- written once, on the last kv step
so VMEM holds O(blk_q*D + blk_k*D) per step regardless of Sk; blk_q =
blk_k = 128 aligns both matmuls ((blk_q x D) @ (D x blk_k) and
(blk_q x blk_k) @ (blk_k x D)) to the MXU.

Causal masking uses absolute positions (q_offset = Sk - Sq supports
decode-style suffix queries).  Fully-masked kv blocks are skipped via
pl.when on the block index -- the flash-attention "causal block skip",
which halves the schedule for the prefill cells.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, blk_q: int, blk_k: int, causal: bool,
                  sq: int, sk: int, q_offset: int):
    qi, ki = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + qi * blk_q + jax.lax.iota(jnp.int32, blk_q)
    k_pos = ki * blk_k + jax.lax.iota(jnp.int32, blk_k)
    # Causal block skip: this kv block contributes iff its first key is
    # <= the last query position (and inside the real sequence).
    live = (k_pos[0] <= q_pos[-1]) if causal else True

    @pl.when(live)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos[None, :] < sk
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "interpret", "q_offset"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, blk_q: int = 128,
                           blk_k: int = 128, q_offset: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q (B,Sq,Hq,D), k/v (B,Sk,Hk,D), Hq % Hk == 0 -> (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    g = Hq // Hk
    scale = D ** -0.5
    off = (Sk - Sq) if q_offset is None else q_offset
    bq, bk = min(blk_q, Sq), min(blk_k, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    if Sq % bq:
        q = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0), (0, 0)))
    if Sk % bk:
        k = jnp.pad(k, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0)))
    kern = functools.partial(_flash_kernel, scale=scale, blk_q=bq,
                             blk_k=bk, causal=causal, sq=Sq, sk=Sk,
                             q_offset=off)
    out = pl.pallas_call(
        kern,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, i, j, g=g: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, i, j, g=g: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * bq, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # normalizer l
            pltpu.VMEM((bq, D), jnp.float32),     # fp32 out accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
