"""Pallas TPU kernel: fused zero-free dilated (atrous) forward convolution.

EcoFlow's dilated-forward dataflow: the atrous filter is applied at tap
spacing D without ever materializing its K_eff = D*(K-1)+1 effective
extent -- the (K_eff^2 - K^2) inserted filter zeros that a naive lowering
schedules as real MACs simply never exist.

TPU mapping (the EcoFlow -> MXU translation, see DESIGN.md Sec. 2.4): the
**dilation taps are the grid** -- ONE `pallas_call` with the useful-tap
index t = kx*Kw + ky as its innermost (sequential) axis.  Each grid step
realizes one per-tap multicast group inside the kernel: the once-padded
input block is VMEM-resident, the step `dynamic_slice`s its tap window at
offset (kx*D_h, ky*D_w), subsamples by the output stride, and contracts
the gathered (Oh*Ow, Cin_t) slab with that tap's (Cin_t, Cout_t) weights
on the MXU.  Partial products accumulate into the fp32 output tile across
the sequential (Cin-tile, tap) steps -- the Pallas equivalent of the
paper's local psum register.

BlockSpec tiling: grid (B, Cout_t, Cin_t, T) with T = Kh*Kw innermost;
per step the kernel holds
  x block   (1, Hp, Wp, Ci_t)    -- padded once; index map depends only on
                                    (b, ci), so it is NOT re-fetched
                                    across the tap axis
  w block   (1, Ci_t, Co_t)      -- this tap's weights for this Cin tile
  out block (1, Oh, Ow, Co_t)    -- fp32 accumulator across (ci, tap)
in VMEM.  The Cin axis is a second sequential-accumulation axis, so the
padded-input working set no longer spans full channel depth (the old
layout held (1, Hp, Wp, Cin) whole).  Ci_t = Co_t = 128 aligns the
matmul to the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spec import ConvSpec, _pair
from repro.kernels.tap_gather import gather_tap, pad_to_tap_windows


def _df_kernel(x_ref, w_ref, out_ref, *, sh: int, sw: int, dh: int, dw: int,
               oh: int, ow: int, kw: int):
    ci = pl.program_id(2)
    t = pl.program_id(3)
    kx, ky = t // kw, t % kw
    ci_t = x_ref.shape[-1]
    tap = gather_tap(x_ref[0], kx, ky, sh=sh, sw=sw, dh=dh, dw=dw,
                     oh=oh, ow=ow)                     # (oh, ow, ci_t)
    lhs = tap.reshape(oh * ow, ci_t).astype(jnp.float32)
    rhs = w_ref[0].astype(jnp.float32)                 # (ci_t, co_t)
    prod = jax.lax.dot(lhs, rhs, preferred_element_type=jnp.float32)
    prod = prod.reshape(oh, ow, out_ref.shape[-1])

    @pl.when((t == 0) & (ci == 0))
    def _init():
        out_ref[0] = prod

    @pl.when((t > 0) | (ci > 0))
    def _acc():
        out_ref[0] += prod


@functools.partial(jax.jit, static_argnames=("stride", "padding", "dilation",
                                             "cin_tile", "cout_tile",
                                             "interpret"))
def dconv_forward_pallas(x: jax.Array, w: jax.Array, *, stride=(1, 1),
                         padding=(0, 0), dilation=(2, 2),
                         cin_tile: int = 128, cout_tile: int = 128,
                         interpret: bool = True) -> jax.Array:
    """Zero-free dilated forward conv in a SINGLE `pallas_call`.

    x: (B, Nh, Nw, Cin) input.
    w: (Kh, Kw, Cin, Cout) undilated filter, applied at tap spacing D.
    Returns (B, Oh, Ow, Cout) with O = floor((N + 2P - K_eff)/S) + 1.
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Nh, Nw, Cin = x.shape
    Kh, Kw, _, Cout = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                         filter_shape=(Kh, Kw), dilation=(dh, dw))
    Oh, Ow = spec.out_size((Nh, Nw))
    if Oh < 1 or Ow < 1:   # ValueError, not assert: survives `python -O`
        raise ValueError(
            f"input {(Nh, Nw)} too small for effective filter "
            f"{spec.dilated_filter_shape} at padding {(ph, pw)}")
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    xp = pad_to_tap_windows(xp, stride=(sh, sw), dilation=(dh, dw),
                            k=(Kh, Kw), out_size=(Oh, Ow))
    hp, wp = xp.shape[1], xp.shape[2]
    T = Kh * Kw
    ci_t = min(cin_tile, Cin)
    co_t = min(cout_tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    w_taps = w.reshape(T, Cin, Cout)
    if Cin % ci_t:
        xp = jnp.pad(xp, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))
        w_taps = jnp.pad(w_taps,
                         ((0, 0), (0, n_ci * ci_t - Cin), (0, 0)))
    if Cout % co_t:
        w_taps = jnp.pad(w_taps,
                         ((0, 0), (0, 0), (0, n_co * co_t - Cout)))
    kern = functools.partial(_df_kernel, sh=sh, sw=sw, dh=dh, dw=dw,
                             oh=Oh, ow=Ow, kw=Kw)
    out = pl.pallas_call(
        kern,
        grid=(B, n_co, n_ci, T),
        in_specs=[
            pl.BlockSpec((1, hp, wp, ci_t),
                         lambda b, co, ci, t: (b, 0, 0, ci)),
            pl.BlockSpec((1, ci_t, co_t),
                         lambda b, co, ci, t: (t, ci, co)),
        ],
        out_specs=pl.BlockSpec((1, Oh, Ow, co_t),
                               lambda b, co, ci, t: (b, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((B, Oh, Ow, n_co * co_t),
                                       jnp.float32),
        interpret=interpret,
    )(xp, w_taps)
    return out[..., :Cout].astype(x.dtype)
