"""Pallas TPU kernel: fused zero-free dilated (atrous) forward convolution.

EcoFlow's dilated-forward dataflow: the atrous filter is applied at tap
spacing D without ever materializing its K_eff = D*(K-1)+1 effective
extent -- the (K_eff^2 - K^2) inserted filter zeros that a naive lowering
schedules as real MACs simply never exist.

TPU mapping (the EcoFlow -> MXU translation, see DESIGN.md Sec. 2.4): the
**dilation taps are the grid** -- ONE `pallas_call` with the useful-tap
index t = kx*Kw + ky as its innermost (sequential) axis.  Each grid step
realizes one per-tap multicast group inside the kernel: the once-padded
input block is VMEM-resident, the step `dynamic_slice`s its tap window at
offset (kx*D_h, ky*D_w), subsamples by the output stride, and contracts
the gathered (Oh*Ow, Cin_t) slab with that tap's (Cin_t, Cout_t) weights
on the MXU.  Partial products accumulate into the fp32 output tile across
the sequential (Cin-tile, tap) steps -- the Pallas equivalent of the
paper's local psum register.

BlockSpec tiling: grid (B, Cout_t, Cin_t, T/u) with the tap steps
innermost (u taps unroll per step -- static offsets when a single step
remains); per step the kernel holds
  x block   (1, Hp, Wp, Ci_t)    -- padded once; index map depends only on
                                    (b, ci), so it is NOT re-fetched
                                    across the tap axis
  w block   (u, Ci_t, Co_t)      -- this step's taps' weights, Cin tile
  out block (1, Oh, Ow, Co_t)    -- fp32 accumulator across (ci, tap)
in VMEM.  The Cin axis is a second sequential-accumulation axis, so the
padded-input working set no longer spans full channel depth (the old
layout held (1, Hp, Wp, Cin) whole).  Tile extents are chosen per
geometry by `kernels/tiling.py` (exact channel counts when small --
no pad/slice -- MXU-aligned 128 tiles at depth); see DESIGN.md Sec. 2.6.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spec import ConvSpec, _pair
from repro.kernels import tiling
from repro.kernels.tap_gather import gather_tap, pad_to_tap_windows


def _df_kernel(x_ref, w_ref, *refs, sh: int, sw: int, dh: int, dw: int,
               oh: int, ow: int, kw: int, u: int, n_t: int, n_ci: int,
               seq1: bool, ep=None):
    # refs = ([bias_ref,] out_ref): the bias input exists only when the
    # epilogue carries one, so the epilogue-free launch keeps the exact
    # legacy in_specs (and jaxpr pins).
    bias_ref = refs[0] if len(refs) == 2 else None
    out_ref = refs[-1]
    ci = pl.program_id(2)
    # With a single tap step, t0 is a python int and every tap gather
    # below lowers to STATIC strided slices of the resident block.
    t0 = pl.program_id(3) * u if n_t > 1 else 0
    ci_t = x_ref.shape[-1]
    xv = x_ref[0]
    acc = None
    for j in range(u):
        t = t0 + j
        kx, ky = t // kw, t % kw
        tap = gather_tap(xv, kx, ky, sh=sh, sw=sw, dh=dh, dw=dw,
                         oh=oh, ow=ow)                 # (oh, ow, ci_t)
        lhs = tap.reshape(oh * ow, ci_t).astype(jnp.float32)
        rhs = w_ref[j].astype(jnp.float32)             # (ci_t, co_t)
        prod = jax.lax.dot(lhs, rhs, preferred_element_type=jnp.float32)
        acc = prod if acc is None else acc + prod
    acc = acc.reshape(oh, ow, out_ref.shape[-1])

    def _tail(vals):  # epilogue on the VMEM-resident block, pre-store
        return ep.apply(vals, None if bias_ref is None else bias_ref[0])

    if seq1:       # single sequential step: every visit initializes
        out_ref[0] = _tail(acc) if ep is not None else acc
        return
    first = (ci == 0) if n_t == 1 else ((ci == 0) & (pl.program_id(3) == 0))

    @pl.when(first)
    def _init():
        out_ref[0] = acc

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[0] += acc

    if ep is not None:
        # Last sequential visit of this output tile: apply the epilogue
        # to the finished accumulator before it leaves VMEM.
        last = (ci == n_ci - 1)
        if n_t > 1:
            last &= pl.program_id(3) == n_t - 1

        @pl.when(last)
        def _epilogue():
            out_ref[0] = _tail(out_ref[0])


@functools.partial(jax.jit, static_argnames=("stride", "padding", "dilation",
                                             "cin_tile", "cout_tile",
                                             "tap_unroll", "interpret",
                                             "epilogue"))
def dconv_forward_pallas(x: jax.Array, w: jax.Array, *, stride=(1, 1),
                         padding=(0, 0), dilation=(2, 2),
                         bias: jax.Array | None = None,
                         epilogue=None,
                         cin_tile: int | None = None,
                         cout_tile: int | None = None,
                         tap_unroll: int | None = None,
                         interpret: bool = True) -> jax.Array:
    """Zero-free dilated forward conv in a SINGLE `pallas_call`.

    x: (B, Nh, Nw, Cin) input.
    w: (Kh, Kw, Cin, Cout) undilated filter, applied at tap spacing D.
    Returns (B, Oh, Ow, Cout) with O = floor((N + 2P - K_eff)/S) + 1.
    Channel tiles default to the geometry-aware planner in
    `kernels/tiling.py`; pass them explicitly to pin a tiling.

    `epilogue` (an `Epilogue`, static) fuses act(scale * conv + bias)
    onto the resident output block before its HBM store; `bias` is the
    (Cout,) vector when the epilogue carries one.
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Nh, Nw, Cin = x.shape
    Kh, Kw, _, Cout = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                         filter_shape=(Kh, Kw), dilation=(dh, dw))
    Oh, Ow = spec.out_size((Nh, Nw))
    if Oh < 1 or Ow < 1:   # ValueError, not assert: survives `python -O`
        raise ValueError(
            f"input {(Nh, Nw)} too small for effective filter "
            f"{spec.dilated_filter_shape} at padding {(ph, pw)}")
    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    if epilogue is not None and epilogue.bias and bias is None:
        raise ValueError("epilogue.bias=True but no bias array was given")
    if None in (cin_tile, cout_tile, tap_unroll):
        plan = tiling.plan_tiles("forward", spec, x_shape=x.shape,
                                 dy_shape=(B, Oh, Ow, Cout),
                                 itemsize=x.dtype.itemsize,
                                 interpret=interpret, epilogue=epilogue)
        cin_tile = plan.cin_tile if cin_tile is None else cin_tile
        cout_tile = plan.cout_tile if cout_tile is None else cout_tile
        tap_unroll = plan.tap_unroll if tap_unroll is None else tap_unroll
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    xp = pad_to_tap_windows(xp, stride=(sh, sw), dilation=(dh, dw),
                            k=(Kh, Kw), out_size=(Oh, Ow))
    hp, wp = xp.shape[1], xp.shape[2]
    T = Kh * Kw
    ci_t = min(cin_tile, Cin)
    co_t = min(cout_tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    w_taps = w.reshape(T, Cin, Cout)
    if Cin % ci_t:
        xp = jnp.pad(xp, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))
        w_taps = jnp.pad(w_taps,
                         ((0, 0), (0, n_ci * ci_t - Cin), (0, 0)))
    if Cout % co_t:
        w_taps = jnp.pad(w_taps,
                         ((0, 0), (0, 0), (0, n_co * co_t - Cout)))
    u = tiling.largest_divisor_leq(T, tap_unroll)
    n_t = T // u
    kern = functools.partial(_df_kernel, sh=sh, sw=sw, dh=dh, dw=dw,
                             oh=Oh, ow=Ow, kw=Kw, u=u, n_t=n_t,
                             n_ci=n_ci, seq1=(n_ci == 1 and n_t == 1),
                             ep=epilogue)
    in_specs = [
        pl.BlockSpec((1, hp, wp, ci_t),
                     lambda b, co, ci, t: (b, 0, 0, ci)),
        pl.BlockSpec((u, ci_t, co_t),
                     lambda b, co, ci, t: (t, ci, co)),
    ]
    ins = [xp, w_taps]
    if epilogue is not None and epilogue.bias:
        bp = bias.astype(jnp.float32).reshape(1, Cout)
        if Cout % co_t:
            bp = jnp.pad(bp, ((0, 0), (0, n_co * co_t - Cout)))
        in_specs.append(pl.BlockSpec((1, co_t),
                                     lambda b, co, ci, t: (0, co)))
        ins.append(bp)
    out = pl.pallas_call(
        kern,
        grid=(B, n_co, n_ci, n_t),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Oh, Ow, co_t),
                               lambda b, co, ci, t: (b, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((B, Oh, Ow, n_co * co_t),
                                       jnp.float32),
        interpret=interpret,
    )(*ins)
    if Cout % co_t:   # slice only when channel padding occurred
        out = out[..., :Cout]
    return out.astype(x.dtype)


def _autotune_runner(spec: ConvSpec, x_shape, dy_shape, epilogue=None):
    """Autotune hook: execute the real kernel at one candidate plan."""
    x = jnp.zeros(x_shape, jnp.float32)
    w = jnp.zeros(spec.filter_shape + (x_shape[-1], dy_shape[-1]),
                  jnp.float32)
    bias = (jnp.zeros((dy_shape[-1],), jnp.float32)
            if epilogue is not None and epilogue.bias else None)
    interp = jax.default_backend() != "tpu"

    def run(plan: tiling.TilePlan):
        return jax.block_until_ready(dconv_forward_pallas(
            x, w, stride=spec.stride, padding=spec.padding,
            dilation=spec.dilation, bias=bias, epilogue=epilogue,
            cin_tile=plan.cin_tile,
            cout_tile=plan.cout_tile, tap_unroll=plan.tap_unroll,
            interpret=interp))

    return run


tiling.register_autotune_runner("forward", _autotune_runner)
