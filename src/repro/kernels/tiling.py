"""Geometry-aware tile selection for the Pallas conv kernels.

Every fused kernel in this package used to hard-code 128-wide channel
tiles (`tile: int = 128`) regardless of geometry -- the right call for a
ResNet trunk, the wrong one for a 3-channel stem, a 29-channel
ShuffleNet block, or an 11x11 AlexNet filter whose tap loop then costs
121 grid launch-steps.  This module makes the tiling a *function of the
geometry*: given a `ConvSpec`, the operand shapes, the dtype, and a VMEM
budget, `plan_tiles` returns a `TilePlan` -- channel tiles, an output-row
(spatial) tile, a tap-unroll factor, and the grid order -- from an
analytical working-set / traffic model (CARLA-style per-layer
reconfigurable tiling, expressed for a BlockSpec machine).

Two modes:

  * **analytical** (default): enumerate the candidate tilings whose VMEM
    working set fits the budget, score each by modeled HBM traffic (block
    re-streams under the kernel's index maps) plus a per-grid-step launch
    cost, and pick the cheapest.  The step cost is weighted heavily in
    interpret mode (where per-step dispatch dominates wall clock) and
    lightly for compiled TPU execution (where traffic dominates).
  * **autotune** (`ECOFLOW_TILING=autotune` or `mode="autotune"`): sweep
    the same candidate set empirically -- each kernel module registers a
    runner that executes the real kernel at a candidate plan -- timing
    with `benchmarks.wallclock._time` (median-of-iters) when the
    benchmarks package is importable, else a local fallback with the same
    semantics.  Winners persist to a JSON cache keyed by (op, geometry)
    (`ECOFLOW_TILE_CACHE`, default ~/.cache/ecoflow/tile_cache.json) so a
    sweep is paid once per geometry per host.

Beyond tiles, the planner picks the *strategy*: `plan_strategy` races the
phase decomposition against the predicated implicit-GEMM formulation
(kernels/implicit_gemm.py) per geometry and returns `(strategy,
TilePlan)`.  The analytical race extends the tile score with a
predicated-lane waste term -- the masked-MAC fraction of the flat GEMM,
exact from the `ConvSpec` geometry via `ecoflow.predicated_mac_fraction`
-- against the phase path's scheduled-tap count and host-side assembly
traffic; autotune mode sweeps BOTH strategies' candidate sets through
their registered runners.  `ECOFLOW_STRATEGY=phase|implicit_gemm|auto`
forces or frees the choice per process (auto is the default), and the
strategy is part of every cache key (memoized and on-disk), so a flip
re-plans instead of serving a stale winner.  See DESIGN.md Sec. 2.10.

The model's constraints encode the kernels' invariants rather than
guessing at them:

  * the working set is computed from the kernels' actual block shapes
    (doubled for the in/out streams, Pallas double-buffers blocks);
  * unrolled taps are consumed one matmul at a time against the resident
    blocks (never a concatenated K^2-replicated tap stack -- peak
    intermediate stays bounded by a small multiple of the padded input,
    pinned by
    `tests/test_dispatch.py::test_filter_grad_memory_not_k2_replicated`),
    and compiled-mode unrolling is capped at `MAX_TAP_UNROLL_COMPILED`
    because Mosaic kernel code size, not VMEM, is the binding constraint;
  * channel tiles prefer the exact channel count when it is small enough
    to fit (no host-side pad/slice at all) and MXU-aligned powers of two
    otherwise.

See DESIGN.md Sec. 2.6 for the policy rules and the cache format.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import json
import math
import os
import pathlib
import warnings
from typing import Callable, Dict, Optional

from repro.core import ecoflow
from repro.core.spec import ConvSpec, Epilogue

# Fraction of a TPU core's ~16 MiB VMEM the planner budgets for one
# kernel's resident blocks (the rest covers double-buffering slack,
# scalar state, and the compiler's own scratch).  Overridable per call
# and via ECOFLOW_VMEM_BUDGET (bytes).
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20

# Modeled cost of one grid step, in traffic-equivalent bytes.  The
# interpret emulation re-materializes every block and re-dispatches the
# kernel body per step, so steps are expensive; compiled TPU steps cost
# roughly a DMA descriptor + pipeline bubble.
STEP_COST_INTERPRET = 1 << 18
STEP_COST_COMPILED = 1 << 12

# Compiled-mode cap on taps unrolled per grid step: each unrolled tap is
# a distinct matmul in the kernel body, and Mosaic code size (not VMEM)
# is the binding constraint.  Interpret mode has no code-size limit and
# profits most from single-step launches, so it may unroll fully.
MAX_TAP_UNROLL_COMPILED = 16

OPS = ("filter_grad", "forward", "input_grad", "backward", "ct_backward")

# Kernel strategies the planner races per geometry.  "phase" is the
# EcoFlow phase decomposition (every op family has a phase kernel);
# "implicit_gemm" is the predicated flat-GEMM formulation
# (kernels/implicit_gemm.py), currently implemented for the standalone
# input gradient only -- the fused dual-gradient backward stays
# phase-decomposed, and `plan_strategy` falls back per op.
STRATEGIES = ("phase", "implicit_gemm")

# Strategy-race weights, in traffic-equivalent bytes.  MAC_COST prices
# one scheduled MXU MAC slot -- predicated (masked) implicit-GEMM lanes
# and the phase path's ragged-slot padding both pay it.  Compiled MACs
# flow through the 128x128 systolic array (cheap per slot but real:
# high-waste geometries like AlexNet S=4 must lose the race); interpret
# MACs run on the host BLAS behind a per-step dispatch that dominates,
# so the slot price is lower.  ASSEMBLY_PASSES charges the phase path's
# host-side residue interleave: the phase-major output tensor is
# rematerialized ~3x by the pad/take/transpose/reshape chain
# (assemble_phase_major) -- traffic the implicit-GEMM path never spends.
MAC_COST_COMPILED = 1 / 32
MAC_COST_INTERPRET = 1 / 64
ASSEMBLY_PASSES = 3


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One kernel launch's tiling decision.

    cin_tile / cout_tile -- channel block extents (<= actual channels;
        equal to them when the planner found the exact count cheapest,
        in which case the kernels skip the pad/slice entirely).
    spatial_tile -- output rows per block (Oh for the filter gradient;
        kernels that do not spatially tile carry their full extent here).
    tap_unroll -- taps computed per grid step (a divisor of the tap
        count; 1 = one tap per step, T = all taps in one step).
    phase_unroll -- stride phases computed per grid step of the unified
        input-gradient kernel (a divisor of the phase count; other
        kernels have no phase axis and carry 1).
    grid_order -- the kernel's grid axes outermost-first, for
        documentation and structural pins.
    source -- "analytical" | "autotune" | "cache".
    """
    cin_tile: int
    cout_tile: int
    spatial_tile: int
    tap_unroll: int = 1
    phase_unroll: int = 1
    grid_order: tuple = ()
    source: str = "analytical"

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["grid_order"] = list(self.grid_order)
        return d


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def _channel_candidates(c: int) -> tuple[int, ...]:
    """Candidate channel-tile extents for a `c`-channel axis: the exact
    count when small enough to be a single unpadded tile, MXU-aligned
    powers of two below it otherwise."""
    cands = {min(c, 256)}
    if c <= 256:
        cands.add(c)  # exact: no pad, no slice
    for p in (256, 128, 64, 32, 16, 8):
        if p < c:
            cands.add(p)
    return tuple(sorted(cands, reverse=True))


def _spatial_candidates(oh: int) -> tuple[int, ...]:
    """Candidate output-row tiles: the full extent, then halvings."""
    cands, v = [], oh
    while v >= 1:
        cands.append(v)
        if v == 1:
            break
        v = -(-v // 2)
    return tuple(dict.fromkeys(cands))


def _divisors(t: int) -> tuple[int, ...]:
    return tuple(d for d in range(t, 0, -1) if t % d == 0)


def largest_divisor_leq(n: int, request: int) -> int:
    """Largest divisor of `n` that is <= max(1, request): the kernels'
    clamp from a planned unroll factor to one their grid can realize.
    Lives here so the kernel-side clamp and the planner's candidate set
    (which only emits exact divisors) cannot drift apart."""
    request = max(1, min(request, n))
    return max(d for d in range(1, request + 1) if n % d == 0)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Analytical model: working set + traffic per op family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Geom:
    """Normalized problem geometry shared by the per-op models."""
    spec: ConvSpec
    b: int
    nh: int
    nw: int
    cin: int
    oh: int
    ow: int
    cout: int
    itemsize: int


def _geom(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize) -> _Geom:
    b, nh, nw, cin = x_shape
    _, oh, ow, cout = dy_shape
    return _Geom(spec, b, nh, nw, cin, oh, ow, cout, itemsize)


def _padded_input_extent(g: _Geom) -> tuple[int, int]:
    """Tap-window extent of the once-padded input (the x block's spatial
    frame): (O-1)*S + D*(K-1) + 1 per axis."""
    sh, sw = g.spec.stride
    dh, dw = g.spec.dilation
    kh, kw = g.spec.filter_shape
    return ((g.oh - 1) * sh + dh * (kh - 1) + 1,
            (g.ow - 1) * sw + dw * (kw - 1) + 1)


def _filter_grad_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """(ws, traffic, steps, step_blk) for the rebuilt filter-grad kernel:
    grid (Cin_t, Cout_t, B, spatial, tap_steps), out block
    (T, ci_t, co_t) stationary across the sequential (B, spatial, tap)
    accumulation axes.  Tap slices are consumed one at a time (per-tap
    matmuls, no concatenated stack), so the unroll factor adds no
    resident transient."""
    sh, _ = g.spec.stride
    dh, _ = g.spec.dilation
    kh, kw = g.spec.filter_shape
    t = kh * kw
    _, wp = _padded_input_extent(g)
    sp = min(sp_t, g.oh)
    rows_x = (sp - 1) * sh + dh * (kh - 1) + 1
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    n_sp, n_t = _cdiv(g.oh, sp), _cdiv(t, u)

    x_blk = rows_x * wp * ci_t * g.itemsize
    dy_blk = sp * g.ow * co_t * g.itemsize
    out_blk = t * ci_t * co_t * 4                      # fp32 accumulator
    ws = 2 * (x_blk + dy_blk) + out_blk + sp * g.ow * ci_t * 4 \
        + ci_t * co_t * 4

    # Compiled traffic (blocks DMA'd on index-map change): x streams once
    # per Cout tile, dy once per Cin tile, out written once.
    traffic = (n_co * (g.b * n_sp * n_ci * x_blk)
               + n_ci * (g.b * n_sp * n_co * dy_blk)
               + t * n_ci * ci_t * n_co * co_t * 4)
    if n_sp > 1:   # host-side overlapping-slab stack: one extra x copy
        traffic += g.b * n_sp * rows_x * wp * g.cin * g.itemsize
    steps = n_ci * n_co * g.b * n_sp * n_t
    return ws, traffic, steps, x_blk + dy_blk


def _forward_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """dconv_forward: grid (B, Cout_t, Cin_t, T/u); x block holds the
    full padded frame at a Cin tile, the w block `u` taps' weights, out
    accumulates over the sequential (Cin_t, tap-step) axes.  An epilogue
    with a bias adds the (1, co_t) bias block to the resident set (the
    activation itself touches only the already-resident out block)."""
    kh, kw = g.spec.filter_shape
    t = kh * kw
    hp, wp = _padded_input_extent(g)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    x_blk = hp * wp * ci_t * g.itemsize
    w_blk = u * ci_t * co_t * g.itemsize
    out_blk = g.oh * g.ow * co_t * 4
    ws = 2 * (x_blk + w_blk) + out_blk + g.oh * g.ow * ci_t * 4
    traffic = (n_co * (g.b * n_ci * x_blk)
               + g.b * t * n_ci * n_co * ci_t * co_t * g.itemsize
               + g.b * g.oh * g.ow * n_co * co_t * 4)
    if ep is not None and ep.bias:
        ws += 2 * co_t * 4
        traffic += n_co * co_t * 4
    steps = g.b * n_co * n_ci * _cdiv(t, u)
    return ws, traffic, steps, x_blk + w_blk


def _phase_frame(spec: ConvSpec, oh: int, ow: int):
    """Padded-dy frame geometry of the unified (phase, tap) kernels
    (tconv_phase and the fused backward): (T phases, TK taps/phase,
    ho, wo phase-plane extent, hp, wp padded frame extent).  One
    definition so the working-set models cannot drift from each other
    (the kernels themselves derive the same quantities from ConvSpec)."""
    tph, tpw = spec.n_tap_phases
    kp, kq = spec.taps_per_phase
    t, tk = tph * tpw, kp * kq
    fh, fw = spec.full_size((oh, ow))
    ho, wo = _cdiv(fh, spec.stride[0]), _cdiv(fw, spec.stride[1])
    pad_h = spec.tap_phase_base(tph - 1, 0) \
        + (kp - 1) * spec.tap_phase_step[0]
    pad_w = spec.tap_phase_base(tpw - 1, 1) \
        + (kq - 1) * spec.tap_phase_step[1]
    return t, tk, ho, wo, pad_h + ho, pad_w + wo


def _input_grad_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """tconv_phase: grid (B, T/pu, Cin_t, Cout_t, TK/u); dy block holds
    the full padded frame at a Cout tile, the w block `pu * u` packed
    (phase, tap)s, the out block `pu` phase planes; out accumulates over
    the sequential (Cout_t, tap-step) axes.  An epilogue with a bias adds
    the (1, ci_t) bias-over-Cin block (the transposed conv's output
    channels are the forward input channels)."""
    t, tk, ho, wo, hp, wp = _phase_frame(g.spec, g.oh, g.ow)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    dy_blk = hp * wp * co_t * g.itemsize
    w_blk = pu * u * co_t * ci_t * g.itemsize
    out_blk = pu * ho * wo * ci_t * 4
    ws = 2 * (dy_blk + w_blk) + out_blk + ho * wo * co_t * 4
    traffic = (g.b * _cdiv(t, pu) * n_ci * n_co * dy_blk
               + g.b * t * tk * n_ci * n_co * co_t * ci_t * g.itemsize
               + g.b * t * ho * wo * n_ci * ci_t * 4)
    if ep is not None and ep.bias:
        ws += 2 * ci_t * 4
        traffic += n_ci * ci_t * 4
    steps = g.b * _cdiv(t, pu) * n_ci * n_co * _cdiv(tk, u)
    return ws, traffic, steps, dy_blk + w_blk


def _backward_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """Fused dual-gradient backward (kernels/dconv_backward.py): grid
    (Cin_t, B, T/pu, Cout_t, TK/u); the dy block holds the full padded
    frame at a Cout tile (the SHARED fetch), the x block the full padded
    input at a Cin tile, and the working set carries BOTH accumulators:
    `pu` phase planes of dx plus the stationary (T_w, ci_t, Cout_pad)
    dW block (full padded Cout width, so the co axis never interrupts
    its visit streak).  An activation epilogue doubles the dy-frame
    residency (the saved output y streams in the SAME padded block shape
    to mask the cotangent in VMEM); a bias epilogue adds the stationary
    (1, Cout_pad) db accumulator as a third output."""
    kh, kw = g.spec.filter_shape
    t, tk, ho, wo, hp, wp = _phase_frame(g.spec, g.oh, g.ow)
    xh, xw = _padded_input_extent(g)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    dy_blk = hp * wp * co_t * g.itemsize
    x_blk = xh * xw * ci_t * g.itemsize
    w_blk = pu * u * co_t * ci_t * g.itemsize
    dx_blk = pu * ho * wo * ci_t * 4
    dw_blk = kh * kw * ci_t * (n_co * co_t) * 4
    ws = 2 * (dy_blk + x_blk + w_blk) + dx_blk + dw_blk \
        + ho * wo * ci_t * 4 + g.oh * g.ow * ci_t * 4 + ci_t * co_t * 4
    # dy stays resident across everything inside (ci, b) when n_co == 1;
    # otherwise it re-streams per (phase-step, co) like tconv.
    dy_streams = g.b * n_ci * (1 if n_co == 1 else _cdiv(t, pu) * n_co)
    traffic = (dy_streams * dy_blk
               + g.b * n_ci * x_blk
               + t * tk * n_ci * n_co * co_t * ci_t * g.itemsize
               + g.b * t * ho * wo * n_ci * ci_t * 4
               + n_ci * kh * kw * ci_t * n_co * co_t * 4)
    if ep is not None:
        if ep.needs_y:                 # y block mirrors the dy block
            ws += 2 * dy_blk
            traffic += dy_streams * dy_blk
        if ep.bias:                    # db third output, constant map
            ws += n_co * co_t * 4
            traffic += n_co * co_t * 4
    steps = n_ci * g.b * _cdiv(t, pu) * n_co * _cdiv(tk, u)
    return ws, traffic, steps, dy_blk + x_blk + w_blk


def _ct_backward_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """Fused transposed-conv backward: grid (B, Cin_t, Cout_t, T/u); the
    g block holds the full padded frame at a Cin tile (the SHARED
    fetch), ddy spans full padded Cout per batch row and dW spans full
    padded channels (constant index map -- one streak over the whole
    grid), so both accumulators are part of every candidate's resident
    working set.  An activation epilogue doubles the g-frame residency
    (the saved transposed-conv output z streams in the same padded block
    shape to mask the cotangent in VMEM); a bias epilogue adds the
    stationary (1, Cin_pad) db accumulator as a third output."""
    kh, kw = g.spec.filter_shape
    t = kh * kw
    hp, wp = _padded_input_extent(g)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    g_blk = hp * wp * ci_t * g.itemsize
    w_blk = u * ci_t * co_t * g.itemsize
    dy_blk = g.oh * g.ow * co_t * g.itemsize
    ddy_blk = g.oh * g.ow * (n_co * co_t) * 4
    dw_blk = t * (n_ci * ci_t) * (n_co * co_t) * 4
    ws = 2 * (g_blk + w_blk + dy_blk) + ddy_blk + dw_blk \
        + g.oh * g.ow * ci_t * 4 + ci_t * co_t * 4
    traffic = (g.b * n_ci * g_blk
               + g.b * n_ci * n_co * dy_blk
               + g.b * t * n_ci * n_co * ci_t * co_t * g.itemsize
               + g.b * g.oh * g.ow * n_co * co_t * 4
               + t * n_ci * ci_t * n_co * co_t * 4)
    if ep is not None:
        if ep.needs_y:                 # z block mirrors the g block
            ws += 2 * g_blk
            traffic += g.b * n_ci * g_blk
        if ep.bias:                    # db third output over Cin
            ws += n_ci * ci_t * 4
            traffic += n_ci * ci_t * 4
    steps = g.b * n_ci * n_co * _cdiv(t, u)
    return ws, traffic, steps, g_blk + w_blk + dy_blk


def _implicit_gemm_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """kernels/implicit_gemm.py: grid (B, Cin_t, Cout_t, T/u); the dy
    block is the UNPADDED (Oh, Ow, Co_t) error tile (resident across the
    tap axis), the w block `u` flat taps' weights, the out block the full
    (Fh, Fw, Ci_t) pre-slice extent accumulated over the sequential
    (Cout_t, tap) axes.  The working set additionally carries the
    in-VMEM zero-interleaved upsampled frame (extent Fh + Dh*(Kh-1) per
    axis) and the per-tap fp32 window product -- the predicated lanes
    live in VMEM, never in HBM traffic."""
    kh, kw = g.spec.filter_shape
    dh, dw = g.spec.dilation
    t = kh * kw
    fh, fw = g.spec.full_size((g.oh, g.ow))
    uh, uw = fh + dh * (kh - 1), fw + dw * (kw - 1)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    dy_blk = g.oh * g.ow * co_t * g.itemsize
    w_blk = u * co_t * ci_t * g.itemsize
    out_blk = fh * fw * ci_t * 4
    ws = 2 * (dy_blk + w_blk) + out_blk \
        + uh * uw * co_t * g.itemsize + fh * fw * co_t * 4 \
        + fh * fw * ci_t * 4
    traffic = (g.b * n_ci * n_co * dy_blk
               + g.b * t * n_ci * n_co * co_t * ci_t * g.itemsize
               + g.b * fh * fw * n_ci * ci_t * 4)
    if ep is not None and ep.bias:
        ws += 2 * ci_t * 4
        traffic += n_ci * ci_t * 4
    steps = g.b * n_ci * n_co * _cdiv(t, u)
    return ws, traffic, steps, dy_blk + w_blk


_MODELS: Dict[str, Callable] = {
    "filter_grad": _filter_grad_model,
    "forward": _forward_model,
    "input_grad": _input_grad_model,
    "backward": _backward_model,
    "ct_backward": _ct_backward_model,
    "input_grad:implicit_gemm": _implicit_gemm_model,
}

_GRID_ORDERS = {
    "filter_grad": ("cin", "cout", "batch", "spatial", "tap"),
    "forward": ("batch", "cout", "cin", "tap"),
    "input_grad": ("batch", "phase", "cin", "cout", "tap"),
    "backward": ("cin", "batch", "phase", "cout", "tap"),
    "ct_backward": ("batch", "cin", "cout", "tap"),
    "input_grad:implicit_gemm": ("batch", "cin", "cout", "tap"),
}


def _model_key(op: str, strategy: str = "phase") -> str:
    """`_MODELS` / `_GRID_ORDERS` key for an (op, strategy) pair.  Phase
    keys are the bare op names (every pre-strategy call site and test
    keeps working); non-phase strategies suffix the op."""
    return op if strategy == "phase" else f"{op}:{strategy}"


def strategy_supported(op: str, strategy: str) -> bool:
    """Whether `strategy` has a kernel family for `op`.  Phase covers
    every op; implicit-GEMM currently covers the standalone input
    gradient only (the fused dual-gradient backward stays
    phase-decomposed), so `plan_strategy` falls back per op."""
    if strategy == "phase":
        return True
    return _model_key(op, strategy) in _MODELS


def _candidates(op: str, g: _Geom, strategy: str = "phase"):
    """The candidate (ci_t, co_t, sp_t, u, pu) lattice for one
    (op, strategy) family.  `u` ranges over divisors of the family's
    tap-axis extent: Kh*Kw for the tap-on-grid kernels (including the
    implicit-GEMM flat-tap grid), KP*KQ packed taps per phase for the
    unified phase input gradient -- whose phase axis additionally unrolls
    by `pu` (a divisor of the non-empty phase count).  Only the
    filter-grad grid spatially tiles."""
    kh, kw = g.spec.filter_shape
    t = kh * kw
    ci_cands = _channel_candidates(g.cin)
    co_cands = _channel_candidates(g.cout)
    sp_cands = _spatial_candidates(g.oh) if op == "filter_grad" \
        else (g.oh,)
    if op in ("input_grad", "backward") and strategy == "phase":
        kp, kq = g.spec.taps_per_phase
        tph, tpw = g.spec.n_tap_phases
        u_cands = _divisors(kp * kq)
        pu_cands = _divisors(tph * tpw)
    else:
        u_cands = _divisors(t)
        pu_cands = (1,)
    for ci_t in ci_cands:
        for co_t in co_cands:
            for sp_t in sp_cands:
                for u in u_cands:
                    for pu in pu_cands:
                        yield ci_t, co_t, sp_t, u, pu


def _score(op: str, g: _Geom, ci_t, co_t, sp_t, u, pu, budget, interpret,
           ep=None, strategy: str = "phase"):
    """Modeled cost of one candidate, or None if it violates a constraint."""
    ws, traffic, steps, step_blk = _MODELS[_model_key(op, strategy)](
        g, ci_t, co_t, sp_t, u, pu, ep=ep)
    if ws > budget:
        return None
    if not interpret and pu * u > MAX_TAP_UNROLL_COMPILED:
        return None   # kernel code size, not VMEM, binds the unroll
    if interpret:
        # The interpret emulation re-materializes every block each step,
        # so its traffic is per-step, not per-index-change.
        traffic = steps * step_blk
        return traffic + steps * STEP_COST_INTERPRET
    return traffic + steps * STEP_COST_COMPILED


def _analytical_best(op: str, spec: ConvSpec, x_shape, dy_shape,
                     itemsize: int, budget: int, interpret: bool,
                     ep: Optional[Epilogue] = None,
                     strategy: str = "phase"):
    """Best candidate for one (op, strategy): (TilePlan, tile cost), with
    cost None when nothing fit and the minimum-footprint fallback was
    taken (the strategy race treats that as a loss)."""
    g = _geom(op, spec, x_shape, dy_shape, itemsize)
    best, best_cost = None, None
    for ci_t, co_t, sp_t, u, pu in _candidates(op, g, strategy):
        cost = _score(op, g, ci_t, co_t, sp_t, u, pu, budget, interpret,
                      ep=ep, strategy=strategy)
        if cost is None:
            continue
        # Deterministic tie-break: prefer larger tiles, then larger unroll
        # (better MXU occupancy at equal modeled cost).
        key = (cost, -ci_t * co_t, -u * pu, -sp_t)
        if best is None or key < best_cost:
            best, best_cost = (ci_t, co_t, sp_t, u, pu), key
    if best is None:   # nothing fits: fall back to the smallest candidate
        best = (min(8, g.cin), min(8, g.cout), 1, 1, 1)
    ci_t, co_t, sp_t, u, pu = best
    plan = TilePlan(cin_tile=ci_t, cout_tile=co_t, spatial_tile=sp_t,
                    tap_unroll=u, phase_unroll=pu,
                    grid_order=_GRID_ORDERS[_model_key(op, strategy)],
                    source="analytical")
    return plan, (None if best_cost is None else best_cost[0])


def _analytical_plan(op: str, spec: ConvSpec, x_shape, dy_shape,
                     itemsize: int, budget: int, interpret: bool,
                     ep: Optional[Epilogue] = None,
                     strategy: str = "phase") -> TilePlan:
    plan, _ = _analytical_best(op, spec, x_shape, dy_shape, itemsize,
                               budget, interpret, ep, strategy)
    return plan


def _strategy_race(op: str, spec: ConvSpec, x_shape, dy_shape,
                   itemsize: int, budget: int, interpret: bool,
                   ep: Optional[Epilogue] = None) -> str:
    """Analytical strategy decision for one geometry: each strategy's
    best tile cost plus what the tile score cannot see --

      * implicit-GEMM pays its predicated lanes: the useful MAC count
        inflated by `1 / (1 - predicated_mac_fraction)` (exact from the
        ConvSpec geometry -- the flat GEMM schedules Fh*Fw rows for
        Oh*Ow useful sites, every tap);
      * phase pays its scheduled taps (ragged-phase padding slots
        included: T * TK >= Kh*Kw) and the host-side residue-interleave
        assembly (ASSEMBLY_PASSES rematerializations of the phase-major
        output tensor, traffic implicit-GEMM never spends).

    Crossover intuition (DESIGN.md Sec. 2.10): high-stride geometries
    (AlexNet S=4/S=8) waste >90% of the flat GEMM's lanes -> phase wins;
    low-stride small-filter geometries (ResNet/ShuffleNet S=2 K=3, any
    S=1 dilated input grad) keep the waste near the 4x floor where the
    flat GEMM's single unpadded residency + zero assembly traffic wins.
    """
    g = _geom(op, spec, x_shape, dy_shape, itemsize)
    kh, kw = spec.filter_shape
    useful = g.b * g.oh * g.ow * kh * kw * g.cin * g.cout
    mac_w = MAC_COST_INTERPRET if interpret else MAC_COST_COMPILED

    _, phase_cost = _analytical_best(op, spec, x_shape, dy_shape, itemsize,
                                     budget, interpret, ep, "phase")
    _, ig_cost = _analytical_best(op, spec, x_shape, dy_shape, itemsize,
                                  budget, interpret, ep, "implicit_gemm")
    if ig_cost is None:
        return "phase"
    if phase_cost is None:
        return "implicit_gemm"

    t, tk, ho, wo, _, _ = _phase_frame(spec, g.oh, g.ow)
    phase_macs = g.b * t * tk * ho * wo * g.cin * g.cout
    assembly = ASSEMBLY_PASSES * g.b * t * ho * wo * g.cin * 4
    waste = ecoflow.predicated_mac_fraction(spec, (g.oh, g.ow))
    ig_macs = useful / max(1e-12, 1.0 - waste)

    phase_total = phase_cost + mac_w * phase_macs + assembly
    ig_total = ig_cost + mac_w * ig_macs
    return "implicit_gemm" if ig_total < phase_total else "phase"


# ---------------------------------------------------------------------------
# Empirical autotune: sweep candidates with the real kernel, cache winners
# ---------------------------------------------------------------------------

# Each kernel module registers `runner(plan) -> seconds` factories here at
# import (keyed by (op, strategy); the strategy defaults to "phase" so
# pre-strategy registrations keep working); tiling itself never imports
# the kernels, so there is no cycle.  A runner factory receives the
# concrete geometry and returns a callable that executes the kernel at
# one candidate plan.
_RUNNERS: Dict[tuple, Callable] = {}


def register_autotune_runner(op: str, factory: Callable,
                             strategy: str = "phase") -> None:
    _RUNNERS[(op, strategy)] = factory


def _median_time_us(fn, iters: int = 5, warmup: int = 2) -> float:
    """Median-of-iters timing, preferring the shared benchmark timer so
    autotune numbers and BENCH_conv.json rows are directly comparable."""
    try:
        from benchmarks.wallclock import _time
        return _time(fn, iters=iters, warmup=warmup)
    except ImportError:
        import statistics
        import time as _t
        fn()
        for _ in range(warmup):
            fn()
        samples = []
        for _ in range(iters):
            t0 = _t.perf_counter()
            fn()
            samples.append(_t.perf_counter() - t0)
        return statistics.median(samples) * 1e6


def cache_path() -> pathlib.Path:
    env = os.environ.get("ECOFLOW_TILE_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path(os.path.expanduser("~")) / ".cache" / "ecoflow" / \
        "tile_cache.json"


def _cache_key(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize,
               budget, interpret, ep: Optional[Epilogue] = None,
               strategy: str = "phase") -> str:
    """Execution mode and budget are part of the key: an interpret-tuned
    winner (which may unroll far past MAX_TAP_UNROLL_COMPILED) must never
    be served to a compiled TPU run, and a tightened VMEM budget must
    re-tune rather than replay a plan scored against the old budget.

    The epilogue descriptor is part of the key too (`|ep:<tag>`): an
    epilogue changes the kernel's block set (bias/y/z inputs, the db
    output) and hence which candidates fit and win, so an epilogue-free
    winner must never be replayed for an epilogue-bearing launch.

    So is the strategy (`|st:<strategy>`, including the "auto" race whose
    row records the measured winner): the two strategies' candidate sets
    and kernels differ, so a phase-swept winner must never be replayed
    for an implicit-GEMM launch -- and an `ECOFLOW_STRATEGY` flip must
    re-plan, not serve the stale row.  Rows written before a dimension
    existed carry no suffix for it; `_legacy_cache_keys` reconstructs the
    older key forms and gates which lookups may fall back to them."""
    sh, sw = spec.stride
    ph, pw = spec.padding
    kh, kw = spec.filter_shape
    dh, dw = spec.dilation
    b, nh, nw, cin = x_shape
    _, oh, ow, cout = dy_shape
    mode = "interp" if interpret else "compiled"
    tag = "none" if ep is None else ep.tag
    return (f"{op}|b{b}|n{nh}x{nw}|o{oh}x{ow}|k{kh}x{kw}|s{sh}x{sw}"
            f"|p{ph}x{pw}|d{dh}x{dw}|ci{cin}|co{cout}|w{itemsize}"
            f"|vm{budget}|{mode}|st:{strategy}|ep:{tag}")


def _legacy_cache_keys(key: str) -> tuple:
    """Older key forms of `key`, most recent generation first:

      * pre-strategy rows (`...|ep:<tag>`, no `|st:`) -- swept against
        the phase kernels, so served ONLY for `st:phase` lookups;
      * pre-epilogue rows (no suffix at all) -- additionally gated to
        `ep:none`, whose candidate set they were actually swept against.

    Empty for implicit-GEMM / auto lookups: no legacy sweep ever timed
    those kernels."""
    head, _, tag = key.rpartition("|ep:")
    stem, _, st = head.rpartition("|st:")
    if st != "phase":
        return ()
    legacy = (f"{stem}|ep:{tag}",)
    if tag == "none":
        legacy += (stem,)
    return legacy


_MEM_CACHE: Dict[str, TilePlan] = {}
# Strategy the "auto" autotune race picked, keyed by the |st:auto cache
# key (the TilePlan itself lives in _MEM_CACHE under the same key).
_MEM_STRATEGY: Dict[str, str] = {}


def _load_disk_cache(path: pathlib.Path) -> dict:
    """Read the on-disk autotune cache; {} when absent.

    A file that exists but does not parse as a JSON object (truncated by
    a pre-atomic-write crash, torn by a non-atomic copy, hand-edited) is
    WARNED about and treated as empty -- the sweep re-tunes and the next
    `_store_disk_cache` replaces the file wholesale -- instead of
    crashing the conv that triggered the lookup."""
    try:
        text = path.read_text()
    except OSError:
        return {}
    except UnicodeDecodeError:
        # Exists but is not even text (torn binary copy): same corrupt-
        # cache policy as a JSON parse failure below.
        text, doc = None, None
    if text is not None:
        try:
            doc = json.loads(text)
        except ValueError:
            doc = None
    if not isinstance(doc, dict):
        warnings.warn(
            f"corrupt autotune tile cache at {path} (not a JSON object); "
            f"ignoring it and re-tuning -- the next sweep rewrites it",
            RuntimeWarning, stacklevel=2)
        return {}
    return doc


def _store_disk_cache(path: pathlib.Path, doc: dict) -> None:
    """Atomic publish: write a temp file in the same directory, then
    `os.replace` it over the cache path.  Concurrent autotuning processes
    (multi-device launchers spawn one per host) each publish a COMPLETE
    document -- a racing reader never sees a torn/truncated file, and the
    last writer wins instead of interleaving partial writes."""
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(f".{path.name}.{os.getpid()}.tmp")
        tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        os.replace(tmp, path)
    except OSError:
        pass   # cache is an optimization; never fail the conv over it


def _plan_from_cache_rec(op: str, rec: dict) -> Optional[TilePlan]:
    """TilePlan from one cache row, or None (with a warning) when the row
    is malformed -- same warn-and-re-tune policy as a corrupt file."""
    try:
        return TilePlan(cin_tile=rec["cin_tile"],
                        cout_tile=rec["cout_tile"],
                        spatial_tile=rec["spatial_tile"],
                        tap_unroll=rec.get("tap_unroll", 1),
                        phase_unroll=rec.get("phase_unroll", 1),
                        grid_order=tuple(rec.get("grid_order",
                                                 _GRID_ORDERS[op])),
                        source="cache")
    except (KeyError, TypeError, AttributeError):
        warnings.warn(
            f"malformed autotune tile cache record for op {op!r}; "
            f"ignoring it and re-tuning", RuntimeWarning, stacklevel=2)
        return None


def _call_runner_factory(factory: Callable, spec: ConvSpec, x_shape,
                         dy_shape, ep: Optional[Epilogue]):
    """Invoke a runner factory, passing the epilogue only when the factory
    accepts it -- pre-epilogue factories (3-positional signature, still
    used by tests and external registrations) keep working, and an
    epilogue-bearing sweep through such a factory would time the wrong
    kernel, so it is rejected instead of silently mistimed."""
    try:
        accepts_ep = "epilogue" in inspect.signature(factory).parameters
    except (TypeError, ValueError):
        accepts_ep = False
    if accepts_ep:
        return factory(spec, x_shape, dy_shape, epilogue=ep)
    if ep is not None:
        raise TypeError(
            f"autotune runner factory {factory!r} does not accept an "
            f"'epilogue' kwarg but the launch carries epilogue {ep.tag!r}")
    return factory(spec, x_shape, dy_shape)


def _sweep(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize, budget,
           interpret, factory: Callable, ep: Optional[Epilogue],
           strategy: str):
    """Time every feasible candidate of one (op, strategy) through its
    runner: (best TilePlan, best us), or (None, inf) when every candidate
    failed to lower/run."""
    g = _geom(op, spec, x_shape, dy_shape, itemsize)
    run = _call_runner_factory(factory, spec, x_shape, dy_shape, ep)
    best_plan, best_us = None, math.inf
    for ci_t, co_t, sp_t, u, pu in _candidates(op, g, strategy):
        if _score(op, g, ci_t, co_t, sp_t, u, pu, budget,
                  interpret, ep=ep, strategy=strategy) is None:
            continue
        plan = TilePlan(cin_tile=ci_t, cout_tile=co_t, spatial_tile=sp_t,
                        tap_unroll=u, phase_unroll=pu,
                        grid_order=_GRID_ORDERS[_model_key(op, strategy)],
                        source="autotune")
        try:
            us = _median_time_us(lambda p=plan: run(p))
        except Exception:   # candidate failed to lower/run: skip it
            continue
        if us < best_us:
            best_plan, best_us = plan, us
    return best_plan, best_us


def _autotune_plan(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize,
                   budget, interpret, path: pathlib.Path,
                   runner_factory: Optional[Callable],
                   ep: Optional[Epilogue] = None,
                   strategy: str = "phase") -> TilePlan:
    key = _cache_key(op, spec, x_shape, dy_shape, itemsize, budget,
                     interpret, ep, strategy)
    if key in _MEM_CACHE:
        return _MEM_CACHE[key]
    disk = _load_disk_cache(path)
    if key in disk:
        plan = _plan_from_cache_rec(op, disk[key])
        if plan is not None:
            _MEM_CACHE[key] = plan
            return plan
    for legacy in _legacy_cache_keys(key):
        if legacy in disk:
            # Row written before the strategy / epilogue dimension
            # existed; `_legacy_cache_keys` gates which lookups may be
            # served one (phase-only, and ep:none for the oldest form).
            plan = _plan_from_cache_rec(op, disk[legacy])
            if plan is not None:
                _MEM_CACHE[key] = plan
                return plan
    factory = runner_factory or _RUNNERS.get((op, strategy))
    if factory is None:
        # No runner registered: analytical fallback, through the memo
        # (a distinct mode string so a later call with the runner's
        # module imported still sweeps instead of replaying this plan).
        return _planned(op, spec, x_shape, dy_shape, itemsize, budget,
                        "autotune:analytical-fallback", interpret, ep,
                        strategy)
    best_plan, best_us = _sweep(op, spec, x_shape, dy_shape, itemsize,
                                budget, interpret, factory, ep, strategy)
    if best_plan is None:   # every candidate failed to lower/run
        return _planned(op, spec, x_shape, dy_shape, itemsize, budget,
                        "autotune:analytical-fallback", interpret, ep,
                        strategy)
    disk[key] = dict(best_plan.as_dict(), us=round(best_us, 1),
                     strategy=strategy)
    _store_disk_cache(path, disk)
    _MEM_CACHE[key] = best_plan
    return best_plan


def _autotune_strategy(op: str, spec: ConvSpec, x_shape, dy_shape,
                       itemsize, budget, interpret, path: pathlib.Path,
                       runner_factory: Optional[Callable],
                       ep: Optional[Epilogue]):
    """Empirical strategy race: sweep BOTH strategies' candidate sets
    through their registered runners, return (winning strategy, its best
    TilePlan), and persist ONE row under the `|st:auto` key whose
    `strategy` field records the measured winner.  An explicit
    `runner_factory` stands in for the phase runner only (the
    pre-strategy contract); implicit-GEMM always sweeps through its
    registered runner.  Strategies with no runner are skipped; when none
    has one, the race degrades to the analytical decision."""
    key = _cache_key(op, spec, x_shape, dy_shape, itemsize, budget,
                     interpret, ep, "auto")
    if key in _MEM_CACHE and key in _MEM_STRATEGY:
        return _MEM_STRATEGY[key], _MEM_CACHE[key]
    disk = _load_disk_cache(path)
    if key in disk:
        rec = disk[key]
        plan = _plan_from_cache_rec(op, rec)
        st = rec.get("strategy") if isinstance(rec, dict) else None
        if plan is not None and st in STRATEGIES:
            _MEM_CACHE[key], _MEM_STRATEGY[key] = plan, st
            return st, plan
    best = None   # (us, strategy, plan)
    for strategy in STRATEGIES:
        if not strategy_supported(op, strategy):
            continue
        factory = _RUNNERS.get((op, strategy))
        if factory is None and strategy == "phase":
            factory = runner_factory
        if factory is None:
            continue
        plan, us = _sweep(op, spec, x_shape, dy_shape, itemsize, budget,
                          interpret, factory, ep, strategy)
        if plan is not None and (best is None or us < best[0]):
            best = (us, strategy, plan)
    if best is None:   # no runners at all: analytical race + memoized plan
        strategy = _auto_strategy(op, spec, x_shape, dy_shape, itemsize,
                                  budget, interpret, ep)
        return strategy, _planned(op, spec, x_shape, dy_shape, itemsize,
                                  budget, "autotune:analytical-fallback",
                                  interpret, ep, strategy)
    us, strategy, plan = best
    disk[key] = dict(plan.as_dict(), us=round(us, 1), strategy=strategy)
    _store_disk_cache(path, disk)
    _MEM_CACHE[key], _MEM_STRATEGY[key] = plan, strategy
    return strategy, plan


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _planned(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize: int,
             budget: int, mode: str, interpret: bool,
             ep: Optional[Epilogue] = None,
             strategy: str = "phase") -> TilePlan:
    """Memoized analytical resolution.  `kernels/ops.py` re-resolves the
    plan on EVERY conv call (so env flips take effect on the next call,
    not the first trace), which previously re-ran the Python planner each
    time; this memo makes the steady-state cost a dict lookup.  The
    env-derived `budget` and `mode` are part of the key -- resolved by
    `plan_tiles` BEFORE the lookup -- so flipping `ECOFLOW_VMEM_BUDGET`
    or `ECOFLOW_TILING` still re-plans instead of replaying a winner
    scored against stale constraints.  `ep` (a frozen `Epilogue`, or
    None) keys too: the epilogue's extra blocks shift the working set.
    So does `strategy` (resolved from ECOFLOW_STRATEGY before the
    lookup): the strategies' candidate sets and models differ, so a flip
    re-plans instead of serving the other strategy's tiles."""
    return _analytical_plan(op, spec, x_shape, dy_shape, itemsize,
                            budget, interpret, ep, strategy)


@functools.lru_cache(maxsize=4096)
def _auto_strategy(op: str, spec: ConvSpec, x_shape, dy_shape,
                   itemsize: int, budget: int, interpret: bool,
                   ep: Optional[Epilogue] = None) -> str:
    """Memoized analytical strategy race (the `ECOFLOW_STRATEGY=auto`
    default path, resolved per geometry on every conv call)."""
    if not strategy_supported(op, "implicit_gemm"):
        return "phase"
    return _strategy_race(op, spec, x_shape, dy_shape, itemsize, budget,
                          interpret, ep)


def plan_cache_info():
    """Hit/miss statistics of the memoized analytical path (tests and
    benchmarks use this to prove the per-call planner cost is a lookup)."""
    return _planned.cache_info()


def plan_tiles(op: str, spec: ConvSpec, *, x_shape, dy_shape,
               itemsize: int = 4, vmem_budget: Optional[int] = None,
               interpret: bool = False, mode: Optional[str] = None,
               runner_factory: Optional[Callable] = None,
               tile_cache_path=None,
               epilogue: Optional[Epilogue] = None) -> TilePlan:
    """Select (cin_tile, cout_tile, spatial_tile, tap_unroll, grid order)
    for one kernel launch.

    op        -- "filter_grad" | "forward" | "input_grad" | "backward"
                 (fused dual-gradient) | "ct_backward" (fused
                 transposed-conv backward).
    x_shape   -- (B, Nh, Nw, Cin) forward-input shape.
    dy_shape  -- (B, Oh, Ow, Cout) forward-output / error shape.
    itemsize  -- operand dtype bytes (accumulators are always fp32).
    interpret -- True when the kernel will run in interpret mode; weights
                 the per-grid-step cost accordingly.
    mode      -- "analytical" (default) | "autotune"; defaults to the
                 ECOFLOW_TILING env var.
    epilogue  -- the launch's fused `Epilogue` (or None): its bias/y/z
                 blocks and db output enter the working-set model, and
                 its tag enters the autotune cache key (DESIGN.md
                 Sec. 2.8).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
    x_shape, dy_shape = tuple(map(int, x_shape)), tuple(map(int, dy_shape))
    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    if vmem_budget is None:
        vmem_budget = int(os.environ.get("ECOFLOW_VMEM_BUDGET",
                                         DEFAULT_VMEM_BUDGET))
    if mode is None:
        mode = os.environ.get("ECOFLOW_TILING", "analytical")
    if mode == "autotune":
        path = pathlib.Path(tile_cache_path) if tile_cache_path \
            else cache_path()
        return _autotune_plan(op, spec, x_shape, dy_shape, itemsize,
                              vmem_budget, interpret, path, runner_factory,
                              epilogue)
    return _planned(op, spec, x_shape, dy_shape, itemsize, vmem_budget,
                    mode, interpret, epilogue)


def plan_strategy(op: str, spec: ConvSpec, *, x_shape, dy_shape,
                  itemsize: int = 4, vmem_budget: Optional[int] = None,
                  interpret: bool = False, mode: Optional[str] = None,
                  runner_factory: Optional[Callable] = None,
                  tile_cache_path=None,
                  epilogue: Optional[Epilogue] = None,
                  strategy: Optional[str] = None
                  ) -> tuple[str, TilePlan]:
    """Select the kernel STRATEGY and its tiles for one launch:
    `("phase" | "implicit_gemm", TilePlan)`.

    Same contract and parameters as `plan_tiles` (which this subsumes --
    `plan_tiles` is the strategy-pinned phase view), plus:

    strategy -- "phase" | "implicit_gemm" | "auto" | None.  None reads
                ECOFLOW_STRATEGY (default "auto").  "auto" races the two
                strategies: analytically via the predicated-lane waste
                term against the phase path's scheduled taps + assembly
                traffic (`_strategy_race`), or empirically when
                `mode="autotune"` -- both strategies' candidate sets
                swept through their registered runners, the winner
                persisted with a `strategy` field in its cache row.  A
                forced strategy skips the race but still falls back to
                phase decomposition for ops implicit-GEMM does not
                support (everything except the standalone input
                gradient; the fused dual-gradient backward stays
                phase-decomposed).

    The returned strategy names the kernel family the caller must
    launch; the TilePlan is valid for that family only.  Every cache
    layer (the analytical memo, the in-memory autotune cache, the JSON
    rows) keys on the strategy, so an env flip re-plans.
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
    x_shape, dy_shape = tuple(map(int, x_shape)), tuple(map(int, dy_shape))
    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    if strategy is None:
        strategy = os.environ.get("ECOFLOW_STRATEGY", "auto")
    if strategy not in STRATEGIES + ("auto",):
        raise ValueError(f"unknown strategy {strategy!r} (set explicitly "
                         f"or via ECOFLOW_STRATEGY); expected one of "
                         f"{STRATEGIES + ('auto',)}")
    if strategy != "phase" and not strategy_supported(op, "implicit_gemm"):
        strategy = "phase"   # per-op fallback: no implicit-GEMM kernel
    if vmem_budget is None:
        vmem_budget = int(os.environ.get("ECOFLOW_VMEM_BUDGET",
                                         DEFAULT_VMEM_BUDGET))
    if mode is None:
        mode = os.environ.get("ECOFLOW_TILING", "analytical")
    if mode == "autotune":
        path = pathlib.Path(tile_cache_path) if tile_cache_path \
            else cache_path()
        if strategy == "auto":
            return _autotune_strategy(op, spec, x_shape, dy_shape,
                                      itemsize, vmem_budget, interpret,
                                      path, runner_factory, epilogue)
        return strategy, _autotune_plan(op, spec, x_shape, dy_shape,
                                        itemsize, vmem_budget, interpret,
                                        path, runner_factory, epilogue,
                                        strategy)
    if strategy == "auto":
        strategy = _auto_strategy(op, spec, x_shape, dy_shape, itemsize,
                                  vmem_budget, interpret, epilogue)
    return strategy, _planned(op, spec, x_shape, dy_shape, itemsize,
                              vmem_budget, mode, interpret, epilogue,
                              strategy)


def warmup_plans(entries, *, tile_cache_path=None, itemsize: int = 4,
                 vmem_budget: Optional[int] = None,
                 interpret: bool = False) -> dict:
    """Serving-startup warmup: resolve `(strategy, TilePlan)` for every
    launch a request bucket will make, WITHOUT ever timing a kernel.

    `entries` is an iterable of ``(op, spec, x_shape, dy_shape)`` or
    ``(op, spec, x_shape, dy_shape, epilogue)`` tuples -- the models'
    `*_plan_requests` helpers produce them per bucket.  Resolution order
    per entry, against the shipped `ECOFLOW_TILE_CACHE` artifact at
    `tile_cache_path` (default `cache_path()`):

      1. the artifact's ``|st:auto`` row -- the measured strategy-race
         winner, strategy field and tiles both taken from the row;
      2. the analytical strategy pick, then that strategy's pinned
         artifact row for the tiles if one exists;
      3. the analytical planner (`_planned` memo) otherwise.

    A corrupt artifact (torn file, malformed row) follows the PR 7
    policy -- `RuntimeWarning` and fall through to the analytical path;
    warmup never fails engine startup and never runs an autotune sweep.
    Artifact hits are primed into the in-memory autotune caches, so a
    serve process running `ECOFLOW_TILING=autotune` replays the shipped
    rows instead of sweeping on the first request.

    Returns ``{cache_key: {"op", "strategy", "plan", "source"}}`` with
    ``source`` in ``{"artifact", "analytical"}``.
    """
    if vmem_budget is None:
        vmem_budget = int(os.environ.get("ECOFLOW_VMEM_BUDGET",
                                         DEFAULT_VMEM_BUDGET))
    path = pathlib.Path(tile_cache_path) if tile_cache_path \
        else cache_path()
    disk = _load_disk_cache(path)   # corrupt artifact -> warn + {}
    out = {}
    for entry in entries:
        op, spec, x_shape, dy_shape = entry[:4]
        ep = entry[4] if len(entry) > 4 else None
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
        if ep is not None and ep.is_identity:
            ep = None
        x_shape = tuple(map(int, x_shape))
        dy_shape = tuple(map(int, dy_shape))

        strategy = plan = None
        source = "artifact"
        key_auto = _cache_key(op, spec, x_shape, dy_shape, itemsize,
                              vmem_budget, interpret, ep, "auto")
        rec = disk.get(key_auto)
        if isinstance(rec, dict):
            p = _plan_from_cache_rec(op, rec)   # warns on a torn row
            st = rec.get("strategy")
            if p is not None and st in STRATEGIES:
                strategy, plan = st, p
                _MEM_CACHE[key_auto] = plan
                _MEM_STRATEGY[key_auto] = strategy
        if plan is None:
            strategy = _auto_strategy(op, spec, x_shape, dy_shape,
                                      itemsize, vmem_budget, interpret, ep)
            key_st = _cache_key(op, spec, x_shape, dy_shape, itemsize,
                                vmem_budget, interpret, ep, strategy)
            rec = disk.get(key_st)
            if isinstance(rec, dict):
                plan = _plan_from_cache_rec(op, rec)
            if plan is not None:
                _MEM_CACHE[key_st] = plan
            else:
                plan = _planned(op, spec, x_shape, dy_shape, itemsize,
                                vmem_budget, "analytical", interpret, ep,
                                strategy)
                source = "analytical"
        out[key_auto] = {"op": op, "strategy": strategy, "plan": plan,
                         "source": source}
    return out
