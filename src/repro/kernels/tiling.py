"""Geometry-aware tile selection for the Pallas conv kernels.

Every fused kernel in this package used to hard-code 128-wide channel
tiles (`tile: int = 128`) regardless of geometry -- the right call for a
ResNet trunk, the wrong one for a 3-channel stem, a 29-channel
ShuffleNet block, or an 11x11 AlexNet filter whose tap loop then costs
121 grid launch-steps.  This module makes the tiling a *function of the
geometry*: given a `ConvSpec`, the operand shapes, the dtype, and a VMEM
budget, `plan_tiles` returns a `TilePlan` -- channel tiles, an output-row
(spatial) tile, a tap-unroll factor, and the grid order -- from an
analytical working-set / traffic model (CARLA-style per-layer
reconfigurable tiling, expressed for a BlockSpec machine).

Two modes:

  * **analytical** (default): enumerate the candidate tilings whose VMEM
    working set fits the budget, score each by modeled HBM traffic (block
    re-streams under the kernel's index maps) plus a per-grid-step launch
    cost, and pick the cheapest.  The step cost is weighted heavily in
    interpret mode (where per-step dispatch dominates wall clock) and
    lightly for compiled TPU execution (where traffic dominates).
  * **autotune** (`ECOFLOW_TILING=autotune` or `mode="autotune"`): sweep
    the same candidate set empirically -- each kernel module registers a
    runner that executes the real kernel at a candidate plan -- timing
    with `benchmarks.wallclock._time` (median-of-iters) when the
    benchmarks package is importable, else a local fallback with the same
    semantics.  Winners persist to a JSON cache keyed by (op, geometry)
    (`ECOFLOW_TILE_CACHE`, default ~/.cache/ecoflow/tile_cache.json) so a
    sweep is paid once per geometry per host.

The model's constraints encode the kernels' invariants rather than
guessing at them:

  * the working set is computed from the kernels' actual block shapes
    (doubled for the in/out streams, Pallas double-buffers blocks);
  * unrolled taps are consumed one matmul at a time against the resident
    blocks (never a concatenated K^2-replicated tap stack -- peak
    intermediate stays bounded by a small multiple of the padded input,
    pinned by
    `tests/test_dispatch.py::test_filter_grad_memory_not_k2_replicated`),
    and compiled-mode unrolling is capped at `MAX_TAP_UNROLL_COMPILED`
    because Mosaic kernel code size, not VMEM, is the binding constraint;
  * channel tiles prefer the exact channel count when it is small enough
    to fit (no host-side pad/slice at all) and MXU-aligned powers of two
    otherwise.

See DESIGN.md Sec. 2.6 for the policy rules and the cache format.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import json
import math
import os
import pathlib
import warnings
from typing import Callable, Dict, Optional

from repro.core.spec import ConvSpec, Epilogue

# Fraction of a TPU core's ~16 MiB VMEM the planner budgets for one
# kernel's resident blocks (the rest covers double-buffering slack,
# scalar state, and the compiler's own scratch).  Overridable per call
# and via ECOFLOW_VMEM_BUDGET (bytes).
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20

# Modeled cost of one grid step, in traffic-equivalent bytes.  The
# interpret emulation re-materializes every block and re-dispatches the
# kernel body per step, so steps are expensive; compiled TPU steps cost
# roughly a DMA descriptor + pipeline bubble.
STEP_COST_INTERPRET = 1 << 18
STEP_COST_COMPILED = 1 << 12

# Compiled-mode cap on taps unrolled per grid step: each unrolled tap is
# a distinct matmul in the kernel body, and Mosaic code size (not VMEM)
# is the binding constraint.  Interpret mode has no code-size limit and
# profits most from single-step launches, so it may unroll fully.
MAX_TAP_UNROLL_COMPILED = 16

OPS = ("filter_grad", "forward", "input_grad", "backward", "ct_backward")


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One kernel launch's tiling decision.

    cin_tile / cout_tile -- channel block extents (<= actual channels;
        equal to them when the planner found the exact count cheapest,
        in which case the kernels skip the pad/slice entirely).
    spatial_tile -- output rows per block (Oh for the filter gradient;
        kernels that do not spatially tile carry their full extent here).
    tap_unroll -- taps computed per grid step (a divisor of the tap
        count; 1 = one tap per step, T = all taps in one step).
    phase_unroll -- stride phases computed per grid step of the unified
        input-gradient kernel (a divisor of the phase count; other
        kernels have no phase axis and carry 1).
    grid_order -- the kernel's grid axes outermost-first, for
        documentation and structural pins.
    source -- "analytical" | "autotune" | "cache".
    """
    cin_tile: int
    cout_tile: int
    spatial_tile: int
    tap_unroll: int = 1
    phase_unroll: int = 1
    grid_order: tuple = ()
    source: str = "analytical"

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["grid_order"] = list(self.grid_order)
        return d


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def _channel_candidates(c: int) -> tuple[int, ...]:
    """Candidate channel-tile extents for a `c`-channel axis: the exact
    count when small enough to be a single unpadded tile, MXU-aligned
    powers of two below it otherwise."""
    cands = {min(c, 256)}
    if c <= 256:
        cands.add(c)  # exact: no pad, no slice
    for p in (256, 128, 64, 32, 16, 8):
        if p < c:
            cands.add(p)
    return tuple(sorted(cands, reverse=True))


def _spatial_candidates(oh: int) -> tuple[int, ...]:
    """Candidate output-row tiles: the full extent, then halvings."""
    cands, v = [], oh
    while v >= 1:
        cands.append(v)
        if v == 1:
            break
        v = -(-v // 2)
    return tuple(dict.fromkeys(cands))


def _divisors(t: int) -> tuple[int, ...]:
    return tuple(d for d in range(t, 0, -1) if t % d == 0)


def largest_divisor_leq(n: int, request: int) -> int:
    """Largest divisor of `n` that is <= max(1, request): the kernels'
    clamp from a planned unroll factor to one their grid can realize.
    Lives here so the kernel-side clamp and the planner's candidate set
    (which only emits exact divisors) cannot drift apart."""
    request = max(1, min(request, n))
    return max(d for d in range(1, request + 1) if n % d == 0)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Analytical model: working set + traffic per op family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Geom:
    """Normalized problem geometry shared by the per-op models."""
    spec: ConvSpec
    b: int
    nh: int
    nw: int
    cin: int
    oh: int
    ow: int
    cout: int
    itemsize: int


def _geom(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize) -> _Geom:
    b, nh, nw, cin = x_shape
    _, oh, ow, cout = dy_shape
    return _Geom(spec, b, nh, nw, cin, oh, ow, cout, itemsize)


def _padded_input_extent(g: _Geom) -> tuple[int, int]:
    """Tap-window extent of the once-padded input (the x block's spatial
    frame): (O-1)*S + D*(K-1) + 1 per axis."""
    sh, sw = g.spec.stride
    dh, dw = g.spec.dilation
    kh, kw = g.spec.filter_shape
    return ((g.oh - 1) * sh + dh * (kh - 1) + 1,
            (g.ow - 1) * sw + dw * (kw - 1) + 1)


def _filter_grad_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """(ws, traffic, steps, step_blk) for the rebuilt filter-grad kernel:
    grid (Cin_t, Cout_t, B, spatial, tap_steps), out block
    (T, ci_t, co_t) stationary across the sequential (B, spatial, tap)
    accumulation axes.  Tap slices are consumed one at a time (per-tap
    matmuls, no concatenated stack), so the unroll factor adds no
    resident transient."""
    sh, _ = g.spec.stride
    dh, _ = g.spec.dilation
    kh, kw = g.spec.filter_shape
    t = kh * kw
    _, wp = _padded_input_extent(g)
    sp = min(sp_t, g.oh)
    rows_x = (sp - 1) * sh + dh * (kh - 1) + 1
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    n_sp, n_t = _cdiv(g.oh, sp), _cdiv(t, u)

    x_blk = rows_x * wp * ci_t * g.itemsize
    dy_blk = sp * g.ow * co_t * g.itemsize
    out_blk = t * ci_t * co_t * 4                      # fp32 accumulator
    ws = 2 * (x_blk + dy_blk) + out_blk + sp * g.ow * ci_t * 4 \
        + ci_t * co_t * 4

    # Compiled traffic (blocks DMA'd on index-map change): x streams once
    # per Cout tile, dy once per Cin tile, out written once.
    traffic = (n_co * (g.b * n_sp * n_ci * x_blk)
               + n_ci * (g.b * n_sp * n_co * dy_blk)
               + t * n_ci * ci_t * n_co * co_t * 4)
    if n_sp > 1:   # host-side overlapping-slab stack: one extra x copy
        traffic += g.b * n_sp * rows_x * wp * g.cin * g.itemsize
    steps = n_ci * n_co * g.b * n_sp * n_t
    return ws, traffic, steps, x_blk + dy_blk


def _forward_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """dconv_forward: grid (B, Cout_t, Cin_t, T/u); x block holds the
    full padded frame at a Cin tile, the w block `u` taps' weights, out
    accumulates over the sequential (Cin_t, tap-step) axes.  An epilogue
    with a bias adds the (1, co_t) bias block to the resident set (the
    activation itself touches only the already-resident out block)."""
    kh, kw = g.spec.filter_shape
    t = kh * kw
    hp, wp = _padded_input_extent(g)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    x_blk = hp * wp * ci_t * g.itemsize
    w_blk = u * ci_t * co_t * g.itemsize
    out_blk = g.oh * g.ow * co_t * 4
    ws = 2 * (x_blk + w_blk) + out_blk + g.oh * g.ow * ci_t * 4
    traffic = (n_co * (g.b * n_ci * x_blk)
               + g.b * t * n_ci * n_co * ci_t * co_t * g.itemsize
               + g.b * g.oh * g.ow * n_co * co_t * 4)
    if ep is not None and ep.bias:
        ws += 2 * co_t * 4
        traffic += n_co * co_t * 4
    steps = g.b * n_co * n_ci * _cdiv(t, u)
    return ws, traffic, steps, x_blk + w_blk


def _phase_frame(spec: ConvSpec, oh: int, ow: int):
    """Padded-dy frame geometry of the unified (phase, tap) kernels
    (tconv_phase and the fused backward): (T phases, TK taps/phase,
    ho, wo phase-plane extent, hp, wp padded frame extent).  One
    definition so the working-set models cannot drift from each other
    (the kernels themselves derive the same quantities from ConvSpec)."""
    tph, tpw = spec.n_tap_phases
    kp, kq = spec.taps_per_phase
    t, tk = tph * tpw, kp * kq
    fh, fw = spec.full_size((oh, ow))
    ho, wo = _cdiv(fh, spec.stride[0]), _cdiv(fw, spec.stride[1])
    pad_h = spec.tap_phase_base(tph - 1, 0) \
        + (kp - 1) * spec.tap_phase_step[0]
    pad_w = spec.tap_phase_base(tpw - 1, 1) \
        + (kq - 1) * spec.tap_phase_step[1]
    return t, tk, ho, wo, pad_h + ho, pad_w + wo


def _input_grad_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """tconv_phase: grid (B, T/pu, Cin_t, Cout_t, TK/u); dy block holds
    the full padded frame at a Cout tile, the w block `pu * u` packed
    (phase, tap)s, the out block `pu` phase planes; out accumulates over
    the sequential (Cout_t, tap-step) axes.  An epilogue with a bias adds
    the (1, ci_t) bias-over-Cin block (the transposed conv's output
    channels are the forward input channels)."""
    t, tk, ho, wo, hp, wp = _phase_frame(g.spec, g.oh, g.ow)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    dy_blk = hp * wp * co_t * g.itemsize
    w_blk = pu * u * co_t * ci_t * g.itemsize
    out_blk = pu * ho * wo * ci_t * 4
    ws = 2 * (dy_blk + w_blk) + out_blk + ho * wo * co_t * 4
    traffic = (g.b * _cdiv(t, pu) * n_ci * n_co * dy_blk
               + g.b * t * tk * n_ci * n_co * co_t * ci_t * g.itemsize
               + g.b * t * ho * wo * n_ci * ci_t * 4)
    if ep is not None and ep.bias:
        ws += 2 * ci_t * 4
        traffic += n_ci * ci_t * 4
    steps = g.b * _cdiv(t, pu) * n_ci * n_co * _cdiv(tk, u)
    return ws, traffic, steps, dy_blk + w_blk


def _backward_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """Fused dual-gradient backward (kernels/dconv_backward.py): grid
    (Cin_t, B, T/pu, Cout_t, TK/u); the dy block holds the full padded
    frame at a Cout tile (the SHARED fetch), the x block the full padded
    input at a Cin tile, and the working set carries BOTH accumulators:
    `pu` phase planes of dx plus the stationary (T_w, ci_t, Cout_pad)
    dW block (full padded Cout width, so the co axis never interrupts
    its visit streak).  An activation epilogue doubles the dy-frame
    residency (the saved output y streams in the SAME padded block shape
    to mask the cotangent in VMEM); a bias epilogue adds the stationary
    (1, Cout_pad) db accumulator as a third output."""
    kh, kw = g.spec.filter_shape
    t, tk, ho, wo, hp, wp = _phase_frame(g.spec, g.oh, g.ow)
    xh, xw = _padded_input_extent(g)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    dy_blk = hp * wp * co_t * g.itemsize
    x_blk = xh * xw * ci_t * g.itemsize
    w_blk = pu * u * co_t * ci_t * g.itemsize
    dx_blk = pu * ho * wo * ci_t * 4
    dw_blk = kh * kw * ci_t * (n_co * co_t) * 4
    ws = 2 * (dy_blk + x_blk + w_blk) + dx_blk + dw_blk \
        + ho * wo * ci_t * 4 + g.oh * g.ow * ci_t * 4 + ci_t * co_t * 4
    # dy stays resident across everything inside (ci, b) when n_co == 1;
    # otherwise it re-streams per (phase-step, co) like tconv.
    dy_streams = g.b * n_ci * (1 if n_co == 1 else _cdiv(t, pu) * n_co)
    traffic = (dy_streams * dy_blk
               + g.b * n_ci * x_blk
               + t * tk * n_ci * n_co * co_t * ci_t * g.itemsize
               + g.b * t * ho * wo * n_ci * ci_t * 4
               + n_ci * kh * kw * ci_t * n_co * co_t * 4)
    if ep is not None:
        if ep.needs_y:                 # y block mirrors the dy block
            ws += 2 * dy_blk
            traffic += dy_streams * dy_blk
        if ep.bias:                    # db third output, constant map
            ws += n_co * co_t * 4
            traffic += n_co * co_t * 4
    steps = n_ci * g.b * _cdiv(t, pu) * n_co * _cdiv(tk, u)
    return ws, traffic, steps, dy_blk + x_blk + w_blk


def _ct_backward_model(g: _Geom, ci_t, co_t, sp_t, u, pu=1, ep=None):
    """Fused transposed-conv backward: grid (B, Cin_t, Cout_t, T/u); the
    g block holds the full padded frame at a Cin tile (the SHARED
    fetch), ddy spans full padded Cout per batch row and dW spans full
    padded channels (constant index map -- one streak over the whole
    grid), so both accumulators are part of every candidate's resident
    working set.  An activation epilogue doubles the g-frame residency
    (the saved transposed-conv output z streams in the same padded block
    shape to mask the cotangent in VMEM); a bias epilogue adds the
    stationary (1, Cin_pad) db accumulator as a third output."""
    kh, kw = g.spec.filter_shape
    t = kh * kw
    hp, wp = _padded_input_extent(g)
    n_ci, n_co = _cdiv(g.cin, ci_t), _cdiv(g.cout, co_t)
    g_blk = hp * wp * ci_t * g.itemsize
    w_blk = u * ci_t * co_t * g.itemsize
    dy_blk = g.oh * g.ow * co_t * g.itemsize
    ddy_blk = g.oh * g.ow * (n_co * co_t) * 4
    dw_blk = t * (n_ci * ci_t) * (n_co * co_t) * 4
    ws = 2 * (g_blk + w_blk + dy_blk) + ddy_blk + dw_blk \
        + g.oh * g.ow * ci_t * 4 + ci_t * co_t * 4
    traffic = (g.b * n_ci * g_blk
               + g.b * n_ci * n_co * dy_blk
               + g.b * t * n_ci * n_co * ci_t * co_t * g.itemsize
               + g.b * g.oh * g.ow * n_co * co_t * 4
               + t * n_ci * ci_t * n_co * co_t * 4)
    if ep is not None:
        if ep.needs_y:                 # z block mirrors the g block
            ws += 2 * g_blk
            traffic += g.b * n_ci * g_blk
        if ep.bias:                    # db third output over Cin
            ws += n_ci * ci_t * 4
            traffic += n_ci * ci_t * 4
    steps = g.b * n_ci * n_co * _cdiv(t, u)
    return ws, traffic, steps, g_blk + w_blk + dy_blk


_MODELS: Dict[str, Callable] = {
    "filter_grad": _filter_grad_model,
    "forward": _forward_model,
    "input_grad": _input_grad_model,
    "backward": _backward_model,
    "ct_backward": _ct_backward_model,
}

_GRID_ORDERS = {
    "filter_grad": ("cin", "cout", "batch", "spatial", "tap"),
    "forward": ("batch", "cout", "cin", "tap"),
    "input_grad": ("batch", "phase", "cin", "cout", "tap"),
    "backward": ("cin", "batch", "phase", "cout", "tap"),
    "ct_backward": ("batch", "cin", "cout", "tap"),
}


def _candidates(op: str, g: _Geom):
    """The candidate (ci_t, co_t, sp_t, u, pu) lattice for one op
    family.  `u` ranges over divisors of the op's tap-axis extent:
    Kh*Kw for the tap-on-grid kernels, KP*KQ packed taps per phase for
    the unified input gradient -- whose phase axis additionally unrolls
    by `pu` (a divisor of the non-empty phase count).  Only the
    filter-grad grid spatially tiles."""
    kh, kw = g.spec.filter_shape
    t = kh * kw
    ci_cands = _channel_candidates(g.cin)
    co_cands = _channel_candidates(g.cout)
    sp_cands = _spatial_candidates(g.oh) if op == "filter_grad" \
        else (g.oh,)
    if op in ("input_grad", "backward"):
        kp, kq = g.spec.taps_per_phase
        tph, tpw = g.spec.n_tap_phases
        u_cands = _divisors(kp * kq)
        pu_cands = _divisors(tph * tpw)
    else:
        u_cands = _divisors(t)
        pu_cands = (1,)
    for ci_t in ci_cands:
        for co_t in co_cands:
            for sp_t in sp_cands:
                for u in u_cands:
                    for pu in pu_cands:
                        yield ci_t, co_t, sp_t, u, pu


def _score(op: str, g: _Geom, ci_t, co_t, sp_t, u, pu, budget, interpret,
           ep=None):
    """Modeled cost of one candidate, or None if it violates a constraint."""
    ws, traffic, steps, step_blk = _MODELS[op](g, ci_t, co_t, sp_t, u, pu,
                                               ep=ep)
    if ws > budget:
        return None
    if not interpret and pu * u > MAX_TAP_UNROLL_COMPILED:
        return None   # kernel code size, not VMEM, binds the unroll
    if interpret:
        # The interpret emulation re-materializes every block each step,
        # so its traffic is per-step, not per-index-change.
        traffic = steps * step_blk
        return traffic + steps * STEP_COST_INTERPRET
    return traffic + steps * STEP_COST_COMPILED


def _analytical_plan(op: str, spec: ConvSpec, x_shape, dy_shape,
                     itemsize: int, budget: int, interpret: bool,
                     ep: Optional[Epilogue] = None) -> TilePlan:
    g = _geom(op, spec, x_shape, dy_shape, itemsize)
    best, best_cost = None, None
    for ci_t, co_t, sp_t, u, pu in _candidates(op, g):
        cost = _score(op, g, ci_t, co_t, sp_t, u, pu, budget, interpret,
                      ep=ep)
        if cost is None:
            continue
        # Deterministic tie-break: prefer larger tiles, then larger unroll
        # (better MXU occupancy at equal modeled cost).
        key = (cost, -ci_t * co_t, -u * pu, -sp_t)
        if best is None or key < best_cost:
            best, best_cost = (ci_t, co_t, sp_t, u, pu), key
    if best is None:   # nothing fits: fall back to the smallest candidate
        best = (min(8, g.cin), min(8, g.cout), 1, 1, 1)
    ci_t, co_t, sp_t, u, pu = best
    return TilePlan(cin_tile=ci_t, cout_tile=co_t, spatial_tile=sp_t,
                    tap_unroll=u, phase_unroll=pu,
                    grid_order=_GRID_ORDERS[op], source="analytical")


# ---------------------------------------------------------------------------
# Empirical autotune: sweep candidates with the real kernel, cache winners
# ---------------------------------------------------------------------------

# Each kernel module registers `runner(plan) -> seconds` factories here at
# import (keyed by op); tiling itself never imports the kernels, so there
# is no cycle.  A runner factory receives the concrete geometry and
# returns a callable that executes the kernel at one candidate plan.
_RUNNERS: Dict[str, Callable] = {}


def register_autotune_runner(op: str, factory: Callable) -> None:
    _RUNNERS[op] = factory


def _median_time_us(fn, iters: int = 5, warmup: int = 2) -> float:
    """Median-of-iters timing, preferring the shared benchmark timer so
    autotune numbers and BENCH_conv.json rows are directly comparable."""
    try:
        from benchmarks.wallclock import _time
        return _time(fn, iters=iters, warmup=warmup)
    except ImportError:
        import statistics
        import time as _t
        fn()
        for _ in range(warmup):
            fn()
        samples = []
        for _ in range(iters):
            t0 = _t.perf_counter()
            fn()
            samples.append(_t.perf_counter() - t0)
        return statistics.median(samples) * 1e6


def cache_path() -> pathlib.Path:
    env = os.environ.get("ECOFLOW_TILE_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path(os.path.expanduser("~")) / ".cache" / "ecoflow" / \
        "tile_cache.json"


def _cache_key(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize,
               budget, interpret, ep: Optional[Epilogue] = None) -> str:
    """Execution mode and budget are part of the key: an interpret-tuned
    winner (which may unroll far past MAX_TAP_UNROLL_COMPILED) must never
    be served to a compiled TPU run, and a tightened VMEM budget must
    re-tune rather than replay a plan scored against the old budget.

    The epilogue descriptor is part of the key too (`|ep:<tag>`): an
    epilogue changes the kernel's block set (bias/y/z inputs, the db
    output) and hence which candidates fit and win, so an epilogue-free
    winner must never be replayed for an epilogue-bearing launch.  Rows
    written before the epilogue slot existed carry no suffix; the disk
    lookup falls back to those legacy keys only for the `ep:none` case,
    whose candidate set they were actually swept against."""
    sh, sw = spec.stride
    ph, pw = spec.padding
    kh, kw = spec.filter_shape
    dh, dw = spec.dilation
    b, nh, nw, cin = x_shape
    _, oh, ow, cout = dy_shape
    mode = "interp" if interpret else "compiled"
    tag = "none" if ep is None else ep.tag
    return (f"{op}|b{b}|n{nh}x{nw}|o{oh}x{ow}|k{kh}x{kw}|s{sh}x{sw}"
            f"|p{ph}x{pw}|d{dh}x{dw}|ci{cin}|co{cout}|w{itemsize}"
            f"|vm{budget}|{mode}|ep:{tag}")


def _legacy_cache_key(key: str) -> Optional[str]:
    """The pre-epilogue form of `key` (no `|ep:` suffix), or None when the
    epilogue is non-trivial and legacy rows must not be consulted."""
    base, _, tag = key.rpartition("|ep:")
    return base if tag == "none" else None


_MEM_CACHE: Dict[str, TilePlan] = {}


def _load_disk_cache(path: pathlib.Path) -> dict:
    """Read the on-disk autotune cache; {} when absent.

    A file that exists but does not parse as a JSON object (truncated by
    a pre-atomic-write crash, torn by a non-atomic copy, hand-edited) is
    WARNED about and treated as empty -- the sweep re-tunes and the next
    `_store_disk_cache` replaces the file wholesale -- instead of
    crashing the conv that triggered the lookup."""
    try:
        text = path.read_text()
    except OSError:
        return {}
    try:
        doc = json.loads(text)
    except ValueError:
        doc = None
    if not isinstance(doc, dict):
        warnings.warn(
            f"corrupt autotune tile cache at {path} (not a JSON object); "
            f"ignoring it and re-tuning -- the next sweep rewrites it",
            RuntimeWarning, stacklevel=2)
        return {}
    return doc


def _store_disk_cache(path: pathlib.Path, doc: dict) -> None:
    """Atomic publish: write a temp file in the same directory, then
    `os.replace` it over the cache path.  Concurrent autotuning processes
    (multi-device launchers spawn one per host) each publish a COMPLETE
    document -- a racing reader never sees a torn/truncated file, and the
    last writer wins instead of interleaving partial writes."""
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(f".{path.name}.{os.getpid()}.tmp")
        tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        os.replace(tmp, path)
    except OSError:
        pass   # cache is an optimization; never fail the conv over it


def _plan_from_cache_rec(op: str, rec: dict) -> Optional[TilePlan]:
    """TilePlan from one cache row, or None (with a warning) when the row
    is malformed -- same warn-and-re-tune policy as a corrupt file."""
    try:
        return TilePlan(cin_tile=rec["cin_tile"],
                        cout_tile=rec["cout_tile"],
                        spatial_tile=rec["spatial_tile"],
                        tap_unroll=rec.get("tap_unroll", 1),
                        phase_unroll=rec.get("phase_unroll", 1),
                        grid_order=tuple(rec.get("grid_order",
                                                 _GRID_ORDERS[op])),
                        source="cache")
    except (KeyError, TypeError, AttributeError):
        warnings.warn(
            f"malformed autotune tile cache record for op {op!r}; "
            f"ignoring it and re-tuning", RuntimeWarning, stacklevel=2)
        return None


def _call_runner_factory(factory: Callable, spec: ConvSpec, x_shape,
                         dy_shape, ep: Optional[Epilogue]):
    """Invoke a runner factory, passing the epilogue only when the factory
    accepts it -- pre-epilogue factories (3-positional signature, still
    used by tests and external registrations) keep working, and an
    epilogue-bearing sweep through such a factory would time the wrong
    kernel, so it is rejected instead of silently mistimed."""
    try:
        accepts_ep = "epilogue" in inspect.signature(factory).parameters
    except (TypeError, ValueError):
        accepts_ep = False
    if accepts_ep:
        return factory(spec, x_shape, dy_shape, epilogue=ep)
    if ep is not None:
        raise TypeError(
            f"autotune runner factory {factory!r} does not accept an "
            f"'epilogue' kwarg but the launch carries epilogue {ep.tag!r}")
    return factory(spec, x_shape, dy_shape)


def _autotune_plan(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize,
                   budget, interpret, path: pathlib.Path,
                   runner_factory: Optional[Callable],
                   ep: Optional[Epilogue] = None) -> TilePlan:
    key = _cache_key(op, spec, x_shape, dy_shape, itemsize, budget,
                     interpret, ep)
    if key in _MEM_CACHE:
        return _MEM_CACHE[key]
    disk = _load_disk_cache(path)
    if key in disk:
        plan = _plan_from_cache_rec(op, disk[key])
        if plan is not None:
            _MEM_CACHE[key] = plan
            return plan
    legacy = _legacy_cache_key(key)
    if legacy is not None and legacy in disk:
        # Row written before the epilogue slot existed; valid only for
        # the epilogue-free candidate set (`_legacy_cache_key` gates).
        plan = _plan_from_cache_rec(op, disk[legacy])
        if plan is not None:
            _MEM_CACHE[key] = plan
            return plan
    factory = runner_factory or _RUNNERS.get(op)
    if factory is None:
        # No runner registered: analytical fallback, through the memo
        # (a distinct mode string so a later call with the runner's
        # module imported still sweeps instead of replaying this plan).
        return _planned(op, spec, x_shape, dy_shape, itemsize, budget,
                        "autotune:analytical-fallback", interpret, ep)
    g = _geom(op, spec, x_shape, dy_shape, itemsize)
    run = _call_runner_factory(factory, spec, x_shape, dy_shape, ep)
    best_plan, best_us = None, math.inf
    for ci_t, co_t, sp_t, u, pu in _candidates(op, g):
        if _score(op, g, ci_t, co_t, sp_t, u, pu, budget,
                  interpret, ep=ep) is None:
            continue
        plan = TilePlan(cin_tile=ci_t, cout_tile=co_t, spatial_tile=sp_t,
                        tap_unroll=u, phase_unroll=pu,
                        grid_order=_GRID_ORDERS[op], source="autotune")
        try:
            us = _median_time_us(lambda p=plan: run(p))
        except Exception:   # candidate failed to lower/run: skip it
            continue
        if us < best_us:
            best_plan, best_us = plan, us
    if best_plan is None:   # every candidate failed to lower/run
        return _planned(op, spec, x_shape, dy_shape, itemsize, budget,
                        "autotune:analytical-fallback", interpret, ep)
    disk[key] = dict(best_plan.as_dict(), us=round(best_us, 1))
    _store_disk_cache(path, disk)
    _MEM_CACHE[key] = best_plan
    return best_plan


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _planned(op: str, spec: ConvSpec, x_shape, dy_shape, itemsize: int,
             budget: int, mode: str, interpret: bool,
             ep: Optional[Epilogue] = None) -> TilePlan:
    """Memoized analytical resolution.  `kernels/ops.py` re-resolves the
    plan on EVERY conv call (so env flips take effect on the next call,
    not the first trace), which previously re-ran the Python planner each
    time; this memo makes the steady-state cost a dict lookup.  The
    env-derived `budget` and `mode` are part of the key -- resolved by
    `plan_tiles` BEFORE the lookup -- so flipping `ECOFLOW_VMEM_BUDGET`
    or `ECOFLOW_TILING` still re-plans instead of replaying a winner
    scored against stale constraints.  `ep` (a frozen `Epilogue`, or
    None) keys too: the epilogue's extra blocks shift the working set."""
    return _analytical_plan(op, spec, x_shape, dy_shape, itemsize,
                            budget, interpret, ep)


def plan_cache_info():
    """Hit/miss statistics of the memoized analytical path (tests and
    benchmarks use this to prove the per-call planner cost is a lookup)."""
    return _planned.cache_info()


def plan_tiles(op: str, spec: ConvSpec, *, x_shape, dy_shape,
               itemsize: int = 4, vmem_budget: Optional[int] = None,
               interpret: bool = False, mode: Optional[str] = None,
               runner_factory: Optional[Callable] = None,
               tile_cache_path=None,
               epilogue: Optional[Epilogue] = None) -> TilePlan:
    """Select (cin_tile, cout_tile, spatial_tile, tap_unroll, grid order)
    for one kernel launch.

    op        -- "filter_grad" | "forward" | "input_grad" | "backward"
                 (fused dual-gradient) | "ct_backward" (fused
                 transposed-conv backward).
    x_shape   -- (B, Nh, Nw, Cin) forward-input shape.
    dy_shape  -- (B, Oh, Ow, Cout) forward-output / error shape.
    itemsize  -- operand dtype bytes (accumulators are always fp32).
    interpret -- True when the kernel will run in interpret mode; weights
                 the per-grid-step cost accordingly.
    mode      -- "analytical" (default) | "autotune"; defaults to the
                 ECOFLOW_TILING env var.
    epilogue  -- the launch's fused `Epilogue` (or None): its bias/y/z
                 blocks and db output enter the working-set model, and
                 its tag enters the autotune cache key (DESIGN.md
                 Sec. 2.8).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
    x_shape, dy_shape = tuple(map(int, x_shape)), tuple(map(int, dy_shape))
    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    if vmem_budget is None:
        vmem_budget = int(os.environ.get("ECOFLOW_VMEM_BUDGET",
                                         DEFAULT_VMEM_BUDGET))
    if mode is None:
        mode = os.environ.get("ECOFLOW_TILING", "analytical")
    if mode == "autotune":
        path = pathlib.Path(tile_cache_path) if tile_cache_path \
            else cache_path()
        return _autotune_plan(op, spec, x_shape, dy_shape, itemsize,
                              vmem_budget, interpret, path, runner_factory,
                              epilogue)
    return _planned(op, spec, x_shape, dy_shape, itemsize, vmem_budget,
                    mode, interpret, epilogue)
