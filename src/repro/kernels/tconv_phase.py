"""Pallas TPU kernel: fused phase-decomposed (zero-free) transposed conv.

ONE `pallas_call` computes all S_h*S_w phases of the EcoFlow transposed
convolution.  The rotated sub-filters are packed into a single

    w_packed : (S_h*S_w, KP, KQ, Cout, Cin)      KP = ceil(Kh/S_h), ...

tensor (ragged phases zero-padded at the tail taps before rotation), the
phase index is a grid dimension, and each grid step writes its phase's
output block into a *phase-major* output `(B, S_h*S_w, ho, wo, Cin)`.
Host-side assembly is then a pure reshape/transpose -- the strided
interleave `dx[p::S, q::S] = phase_pq` falls out of

    (B, ho, S_h, wo, S_w, Cin) -> (B, ho*S_h, wo*S_w, Cin)

because ho = ceil(F_h/S_h) exactly (F = S*(O-1)+K, the pre-slice output).
`dy` is padded ONCE by (KP-1, KQ-1) -- not once per phase -- and the
S*S scatter-writes of the multi-launch formulation disappear entirely.

TPU mapping (the EcoFlow -> MXU translation, see DESIGN.md Sec. 2):
  * the paper's per-PE MAC schedule (one weight broadcast per cycle, one
    error element per PE) becomes a static tap loop of
    (spatial x Cout) @ (Cout x Cin) MXU matmuls;
  * the paper's multicast groups become the shifted static slices of the
    VMEM-resident dy block;
  * the paper's vertical psum chains become the fp32 accumulator tile;
  * the paper's phase enumeration (the symbolic outer product grouped by
    output residue (p, q)) becomes the leading grid dimension.

BlockSpec tiling: grid (B, S*S, Cin_tiles).  Per grid step the kernel holds
  dy block   (1, Hp, Wp, Cout)            -- padded once, reused over phases
  w block    (1, KP, KQ, Cout, Cin_t)     -- this phase's packed sub-filter
  out block  (1, 1, ho, wo, Cin_t)        -- fp32 accumulate, cast on store
in VMEM.  Channel tile Cin_t (default 128) keeps the working set within
VMEM for the layer sizes the paper evaluates (<=130x130 spatial); matmul
dims are multiples of 128 whenever Cout/Cin are, which is MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ecoflow
from repro.core.spec import ConvSpec, _pair


def pack_phase_filters(w: jax.Array, stride) -> jax.Array:
    """Pack the S*S rotated sub-filters into one uniform tensor.

    w: (Kh, Kw, Cin, Cout) forward filter ->
    (S_h*S_w, KP, KQ, Cout, Cin) with KP = ceil(Kh/S_h), KQ = ceil(Kw/S_w).

    The rotation convention (180deg flip + Cout->Cin channel transpose)
    comes from `ecoflow.phase_subfilters` -- the single source of truth
    shared with the dense XLA backend; this function only adds the
    uniform-shape packing: each already-flipped sub-filter is zero-padded
    at the FRONT taps (front-pad-after-flip == tail-pad-before-flip, the
    identity `tests/test_kernels.py` pins).  Only the
    min(S_h,K_h) * min(S_w,K_w) NON-empty phases are packed: phases beyond
    the filter extent (stride > K) are structural zeros of the upsampling
    -- the wrapper zero-fills their output rows host-side instead of
    spending grid steps on all-zero sub-filters.  The intra-phase tap
    padding of ragged phases (K % S != 0) stays: it costs O(K^2) extra
    weight words per phase, not the O(N^2 S^2) dilation zeros the
    dataflow eliminates, and buys a uniform single-launch grid.
    """
    sh, sw = _pair(stride)
    Kh, Kw, _, _ = w.shape
    KP, KQ = -(-Kh // sh), -(-Kw // sw)
    subs = ecoflow.phase_subfilters(w, (sh, sw))
    phases = []
    for p in range(min(sh, Kh)):
        for q in range(min(sw, Kw)):
            sub = subs[p][q]                         # (kp, kq, Cout, Cin)
            kp, kq = sub.shape[0], sub.shape[1]
            sub = jnp.pad(sub, ((KP - kp, 0), (KQ - kq, 0), (0, 0), (0, 0)))
            phases.append(sub)
    return jnp.stack(phases)


def _fused_phase_kernel(dy_ref, w_ref, out_ref, *, kp: int, kq: int,
                        ho: int, wo: int):
    """One phase per grid step: a stride-1 full correlation of the padded
    dy block with this phase's packed sub-filter, as a static tap loop of
    MXU matmuls with an fp32 VMEM accumulator.  Zero-padded taps of ragged
    phases multiply by zero -- the loop body is uniform across phases."""
    acc = jnp.zeros((ho * wo, out_ref.shape[-1]), dtype=jnp.float32)
    for a in range(kp):
        for b in range(kq):
            # Shifted window of the padded dy block: (ho, wo, Cout).
            win = dy_ref[0, a:a + ho, b:b + wo, :]
            lhs = win.reshape(ho * wo, win.shape[-1]).astype(jnp.float32)
            rhs = w_ref[0, a, b].astype(jnp.float32)
            acc += jax.lax.dot(lhs, rhs,
                               preferred_element_type=jnp.float32)
    out_ref[0, 0] = acc.reshape(ho, wo,
                                out_ref.shape[-1]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out",
                                             "cin_tile", "interpret"))
def tconv_fused_pallas(dy: jax.Array, w: jax.Array, *, stride, padding=(0, 0),
                       n_out=None, cin_tile: int = 128,
                       interpret: bool = True) -> jax.Array:
    """Zero-free transposed conv in a SINGLE `pallas_call`.

    dy: (B, Oh, Ow, Cout) error / generator input.
    w:  (Kh, Kw, Cin, Cout) forward filter.
    Returns (B, Nh, Nw, Cin) where (Nh, Nw) = n_out (default exact fit).
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    B, Oh, Ow, Cout = dy.shape
    Kh, Kw, Cin, _ = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                         filter_shape=(Kh, Kw))
    if n_out is None:
        n_out = spec.input_size((Oh, Ow))
    Nh, Nw = _pair(n_out)
    Fh, Fw = spec.full_size((Oh, Ow))
    KP, KQ = spec.packed_phase_shape
    # Grid only the non-empty phases (stride > K leaves sh*sw - TPh*TPw
    # structurally-zero phases whose rows are filled host-side).
    TPh, TPw = min(sh, Kh), min(sw, Kw)
    T = TPh * TPw

    w_packed = pack_phase_filters(w, (sh, sw))       # (T, KP, KQ, Cout, Cin)
    # "Full" correlation: pad dy ONCE (uniform across phases).
    dy_pad = jnp.pad(dy, ((0, 0), (KP - 1, KP - 1), (KQ - 1, KQ - 1),
                          (0, 0)))
    hp, wp = dy_pad.shape[1], dy_pad.shape[2]
    ho, wo = Oh + KP - 1, Ow + KQ - 1                # == ceil(F/S) per axis

    ct = min(cin_tile, Cin)
    n_ct = -(-Cin // ct)
    if Cin % ct:
        w_packed = jnp.pad(w_packed,
                           ((0, 0),) * 4 + ((0, n_ct * ct - Cin),))
    kern = functools.partial(_fused_phase_kernel, kp=KP, kq=KQ, ho=ho, wo=wo)
    out = pl.pallas_call(
        kern,
        grid=(B, T, n_ct),
        in_specs=[
            pl.BlockSpec((1, hp, wp, Cout), lambda b, t, c: (b, 0, 0, 0)),
            pl.BlockSpec((1, KP, KQ, Cout, ct),
                         lambda b, t, c: (t, 0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, ho, wo, ct),
                               lambda b, t, c: (b, t, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, T, ho, wo, n_ct * ct), dy.dtype),
        interpret=interpret,
    )(dy_pad, w_packed)

    # Phase-major -> strided interleave as ONE reshape/transpose chain:
    # rows of dx_full are r = x*S_h + p  <->  (x, p) of phase row x.
    out = out[..., :Cin].reshape(B, TPh, TPw, ho, wo, Cin)
    if TPh < sh or TPw < sw:   # stride > K: structural-zero phase rows
        out = jnp.pad(out, ((0, 0), (0, sh - TPh), (0, sw - TPw),
                            (0, 0), (0, 0), (0, 0)))
    dx_full = out.transpose(0, 3, 1, 4, 2, 5).reshape(
        B, ho * sh, wo * sw, Cin)[:, :Fh, :Fw, :]
    # Non-exact-fit inputs (forward ignored tail rows/cols): zero-pad tail.
    eh, ew = max(0, ph + Nh - Fh), max(0, pw + Nw - Fw)
    if eh or ew:
        dx_full = jnp.pad(dx_full, ((0, 0), (0, eh), (0, ew), (0, 0)))
    return dx_full[:, ph:ph + Nh, pw:pw + Nw, :]
