"""Pallas TPU kernel: fused zero-free transposed conv, stride x dilation
general -- the unified (phase, tap) input-gradient kernel.

ONE `pallas_call` computes the input gradient of a forward conv with ANY
(stride S, filter dilation D) pair.  The decomposition composes the
stride-phase view of the plain transposed conv with the per-tap
enumeration of the dilated-forward kernel:

    dx[i*S + kx*D - P] += dy[i] . W[kx]^T

so tap kx lands in output residue class (kx*D) mod S.  Residues repeat
with period S/gcd(S, D) in kx, hence taps group by kx mod period; within
residue class `a`, tap kx = a + u*period lands on phase row
m = i + (a*D)//S + u*(D/gcd) -- each phase is a stride-1 correlation of
dy with a (D/gcd)-dilated sub-filter.  At D == 1 (period == S, step == 1)
this IS the classic EcoFlow stride-phase decomposition; at S == 1 it is
the self-adjoint per-tap atrous form; in between it is the general
strided+dilated transposed conv that previously fell back to the
multi-launch XLA scatter path.  No dilation zero of either kind (stride
upsampling or filter dilation) is ever stored, moved, or multiplied.

TPU mapping (the EcoFlow -> MXU translation, see DESIGN.md Sec. 2/2.5):
  * the paper's phase enumeration (symbolic outer product grouped by
    output residue) becomes the phase grid axis;
  * the per-tap multicast group becomes a `dynamic_slice` window of the
    VMEM-resident padded dy block at the tap's (base + u*step) offset;
  * the vertical psum chain becomes the fp32 accumulator tile, summed
    sequentially over the (Cout-tile, tap) grid axes;
  * grouping/expansion onto the array becomes channel tiling.

BlockSpec tiling: grid (B, T/pu, Cin_t, Cout_t, TK/u) with T = non-empty
phases, TK = taps per phase (pu phases x u taps unroll per step --
static window offsets when a single step remains); per grid step the
kernel holds
  dy block  (1, Hp, Wp, Co_t)     -- padded once; index map (b, co) only,
                                     so it is NOT re-fetched across the
                                     phase-local (tap) axis
  w block   (pu, u, Co_t, Ci_t)   -- this step's packed (phase, tap)s
  out block (1, pu, ho, wo, Ci_t) -- fp32 accumulator across (co, tap)
in VMEM.  Neither block scales with full channel depth: dy carries a
Cout tile and the output a Cin tile, with extents chosen per geometry by
`kernels/tiling.py` (DESIGN.md Sec. 2.6).  Output
is phase-major (B, T, ho, wo, Cin); host-side assembly places each phase
plane at its stride residue (a gather -- identity at D == 1) and
interleaves with one reshape/transpose, exactly as before.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ecoflow
from repro.core.spec import ConvSpec, _pair
from repro.kernels import tiling


def pack_phase_filters(w: jax.Array, stride, dilation=(1, 1)) -> jax.Array:
    """Pack the rotated per-phase sub-filters into one uniform tensor.

    w: (Kh, Kw, Cin, Cout) forward filter ->
    (TPh*TPw, KP, KQ, Cout, Cin) with TP = min(K, period),
    KP = ceil(K/period), period = S/gcd(S, D) per axis.

    The rotation convention (180deg flip + Cout->Cin channel transpose)
    comes from `ecoflow.phase_subfilters` -- the single source of truth
    shared with the dense XLA backend -- applied at the tap-grouping
    PERIOD rather than the stride (they coincide at dilation 1); this
    function only adds the uniform-shape packing: each already-flipped
    sub-filter is zero-padded at the FRONT taps (front-pad-after-flip ==
    tail-pad-before-flip, the identity `tests/test_kernels.py` pins).
    After the flip + front-pad, slot uf of phase `a` holds tap
    kx = a + (KP-1-uf)*period (zero when kx >= K).  Only the non-empty
    phases are packed: residue classes beyond the filter extent
    (period > K) are structural zeros of the upsampling -- the wrapper
    zero-fills their output rows host-side instead of spending grid steps
    on all-zero sub-filters.  The intra-phase tap padding of ragged
    phases (K % period != 0) stays: it costs O(K^2) extra weight words
    per phase, not the O(N^2 S^2) dilation zeros the dataflow eliminates,
    and buys a uniform single-launch grid.
    """
    sh, sw = _pair(stride)
    dh, dw = _pair(dilation)
    Kh, Kw, _, _ = w.shape
    spec = ConvSpec.make(stride=(sh, sw), filter_shape=(Kh, Kw),
                         dilation=(dh, dw))
    per_h, per_w = spec.tap_phase_period
    KP, KQ = spec.taps_per_phase
    subs = ecoflow.phase_subfilters(w, (per_h, per_w))
    phases = []
    for a in range(min(per_h, Kh)):
        for b in range(min(per_w, Kw)):
            sub = subs[a][b]                         # (kp, kq, Cout, Cin)
            kp, kq = sub.shape[0], sub.shape[1]
            sub = jnp.pad(sub, ((KP - kp, 0), (KQ - kq, 0), (0, 0), (0, 0)))
            phases.append(sub)
    return jnp.stack(phases)


def assemble_phase_major(out: jax.Array, spec: ConvSpec, *, n_out,
                         full_size, fill: jax.Array | None = None
                         ) -> jax.Array:
    """Phase-major kernel output (B, T, ho, wo, Cin) -> dx (B, Nh, Nw,
    Cin): place each phase plane at its stride residue with a static
    gather (identity at D == 1 with S <= K; residues outside the image
    are structural zeros of the upsampling), interleave with one
    reshape/transpose chain (rows of dx_full are r = m*S + p <-> (m, p)
    of phase row m), then crop padding / zero-pad non-exact-fit tails.
    Shared by `tconv_fused_pallas` and the fused dual-gradient backward
    (kernels/dconv_backward.py) so the residue-interleave logic cannot
    drift between them.

    `fill` ((Cin,) vector): value taken by positions NO tap reaches
    (structural-zero residues, non-exact-fit tails).  With a fused
    epilogue those positions are epilogue(0) = act(bias), not 0 -- the
    kernel only ever sees real phase planes, so the assembly supplies it.
    None keeps the plain zero-fill."""
    B, _, ho, wo, cin = out.shape
    sh, sw = spec.stride
    ph, pw = spec.padding
    nh, nw = n_out
    fh, fw = full_size
    tph, tpw = spec.n_tap_phases
    out = out.reshape(B, tph, tpw, ho, wo, cin)
    idx_h = [tph] * sh   # sentinel TPh/TPw -> all-zero plane
    for a in range(tph):
        idx_h[spec.tap_phase_residue(a, 0)] = a
    idx_w = [tpw] * sw
    for b in range(tpw):
        idx_w[spec.tap_phase_residue(b, 1)] = b
    if (tph, tpw) != (sh, sw) or idx_h != list(range(sh)) \
            or idx_w != list(range(sw)):
        if fill is None:
            out = jnp.pad(out, ((0, 0), (0, 1), (0, 1)) + ((0, 0),) * 3)
        else:
            fv = fill.astype(out.dtype)
            out = jnp.concatenate(
                [out, jnp.broadcast_to(fv, (B, 1, tpw, ho, wo, cin))],
                axis=1)
            out = jnp.concatenate(
                [out, jnp.broadcast_to(fv, (B, tph + 1, 1, ho, wo, cin))],
                axis=2)
        out = jnp.take(out, jnp.asarray(idx_h), axis=1)
        out = jnp.take(out, jnp.asarray(idx_w), axis=2)
    dx_full = out.transpose(0, 3, 1, 4, 2, 5).reshape(
        B, ho * sh, wo * sw, cin)[:, :fh, :fw, :]
    # Non-exact-fit inputs (forward ignored tail rows/cols): pad tail with
    # the fill value (zero on the plain path).
    eh, ew = max(0, ph + nh - fh), max(0, pw + nw - fw)
    if eh or ew:
        if fill is None:
            dx_full = jnp.pad(dx_full, ((0, 0), (0, eh), (0, ew), (0, 0)))
        else:
            fv = fill.astype(dx_full.dtype)
            h = dx_full.shape[1]
            if eh:
                dx_full = jnp.concatenate(
                    [dx_full, jnp.broadcast_to(
                        fv, (B, eh, dx_full.shape[2], cin))], axis=1)
            if ew:
                dx_full = jnp.concatenate(
                    [dx_full, jnp.broadcast_to(
                        fv, (B, h + eh, ew, cin))], axis=2)
    return dx_full[:, ph:ph + nh, pw:pw + nw, :]


def _fused_tap_kernel(dy_ref, w_ref, *refs, tpw: int, kp: int, kq: int,
                      kh: int, kwf: int, per_h: int, per_w: int,
                      sh: int, sw: int, dh: int, dw: int, step_h: int,
                      step_w: int, pad_h: int, pad_w: int, ho: int, wo: int,
                      pu: int, n_t: int, u: int, n_k: int, seq1: bool,
                      ep=None):
    """`pu` phases x `u` taps per sequential grid step: `dynamic_slice`
    each tap's window out of the VMEM-resident padded dy block, one MXU
    matmul per tap with its (Cout_t, Cin_t) weights, accumulate each
    phase's fp32 tile across the (Cout-tile, tap-step) axes.
    When a single (phase, tap) grid step remains, every window offset is
    a python int and the gathers lower to STATIC slices -- and the
    zero-padded slots of ragged phases (slot tap index kx >= K) are
    SKIPPED outright via the shared (phase, slot) -> filter-tap validity
    test, the same static skip the fused backward kernel applies
    (dconv_backward.py); on partially unrolled grids the slot index is
    traced, so padded slots fall back to multiplying by zero and the step
    body stays uniform across phases.

    refs = ([bias_ref,] out_ref); `ep` fuses act(scale * . + bias) onto
    each finished phase plane before its HBM store."""
    bias_ref = refs[0] if len(refs) == 2 else None
    out_ref = refs[-1]
    t0 = pl.program_id(1) * pu if n_t > 1 else 0
    co = pl.program_id(3)
    k0 = pl.program_id(4) * u if n_k > 1 else 0
    dyv = dy_ref[0]
    traced = n_t > 1 or n_k > 1
    # seq1: single sequential (Cout-tile, tap) step -> every visit to an
    # out block is its first, the predication compiles away.
    first = None if seq1 else (
        (co == 0) if n_k == 1 else ((co == 0) & (pl.program_id(4) == 0)))
    last = None
    if ep is not None and not seq1:
        last = (co == pl.num_programs(3) - 1)
        if n_k > 1:
            last &= pl.program_id(4) == n_k - 1

    def _tail(vals):
        return ep.apply(vals, None if bias_ref is None else bias_ref[0])

    for p in range(pu):
        t = t0 + p
        a, b = t // tpw, t % tpw
        acc = None
        for j in range(u):
            k = k0 + j
            uf, vf = k // kq, k % kq
            if not traced:
                # Static slot: skip padding slots of ragged phases -- the
                # slot's filter tap falls outside the K x K extent, its
                # packed weights are structurally zero.
                kx = a + (kp - 1 - uf) * per_h
                ky = b + (kq - 1 - vf) * per_w
                if kx >= kh or ky >= kwf:
                    continue
            # Flipped-slot tap index u' = KP-1-uf (see
            # pack_phase_filters): window offset base(a) + u'*step,
            # shifted into the padded frame.
            start_h = pad_h - (a * dh) // sh - (kp - 1 - uf) * step_h
            start_w = pad_w - (b * dw) // sw - (kq - 1 - vf) * step_w
            if isinstance(start_h, int) and isinstance(start_w, int):
                win = dyv[start_h:start_h + ho, start_w:start_w + wo]
            else:
                win = jax.lax.dynamic_slice(
                    dyv, (start_h, start_w, 0), (ho, wo, dyv.shape[-1]))
            lhs = win.reshape(ho * wo, win.shape[-1]).astype(jnp.float32)
            rhs = w_ref[p, j].astype(jnp.float32)    # (co_t, ci_t)
            prod = jax.lax.dot(lhs, rhs,
                               preferred_element_type=jnp.float32)
            acc = prod if acc is None else acc + prod
        acc = acc.reshape(ho, wo, out_ref.shape[-1])
        if first is None:
            out_ref[0, p] = _tail(acc) if ep is not None else acc
        else:
            @pl.when(first)
            def _init(p=p, acc=acc):
                out_ref[0, p] = acc

            @pl.when(jnp.logical_not(first))
            def _acc(p=p, acc=acc):
                out_ref[0, p] += acc

            if ep is not None:
                @pl.when(last)
                def _epilogue(p=p):
                    out_ref[0, p] = _tail(out_ref[0, p])


@functools.partial(jax.jit, static_argnames=("stride", "padding", "n_out",
                                             "dilation", "cin_tile",
                                             "cout_tile", "tap_unroll",
                                             "phase_unroll", "interpret",
                                             "epilogue"))
def tconv_fused_pallas(dy: jax.Array, w: jax.Array, *, stride, padding=(0, 0),
                       n_out=None, dilation=(1, 1),
                       bias: jax.Array | None = None,
                       epilogue=None,
                       cin_tile: int | None = None,
                       cout_tile: int | None = None,
                       tap_unroll: int | None = None,
                       phase_unroll: int | None = None,
                       interpret: bool = True) -> jax.Array:
    """Zero-free transposed conv in a SINGLE `pallas_call`, any (S, D).

    dy: (B, Oh, Ow, Cout) error / generator input.
    w:  (Kh, Kw, Cin, Cout) forward filter (undilated taps; `dilation` is
        the forward filter dilation D whose adjoint this computes).
    Returns (B, Nh, Nw, Cin) where (Nh, Nw) = n_out (default exact fit).
    Channel tiles default to the geometry-aware planner in
    `kernels/tiling.py`; pass them explicitly to pin a tiling.

    `epilogue` (static `Epilogue`) fuses act(scale * . + bias) onto each
    finished phase plane in VMEM; `bias` is the (Cin,) vector (the tconv
    OUTPUT channels) when the epilogue carries one.  Positions no tap
    reaches take the value epilogue(0) via the assembly fill.
    """
    sh, sw = _pair(stride)
    ph, pw = _pair(padding)
    dh, dw = _pair(dilation)
    B, Oh, Ow, Cout = dy.shape
    Kh, Kw, Cin, _ = w.shape
    spec = ConvSpec.make(stride=(sh, sw), padding=(ph, pw),
                         filter_shape=(Kh, Kw), dilation=(dh, dw))
    if n_out is None:
        n_out = spec.input_size((Oh, Ow))
    Nh, Nw = _pair(n_out)
    Fh, Fw = spec.full_size((Oh, Ow))    # S(O-1) + D(K-1) + 1 pre-slice
    step_h, step_w = spec.tap_phase_step
    TPh, TPw = spec.n_tap_phases
    KP, KQ = spec.taps_per_phase
    T, TK = TPh * TPw, KP * KQ

    w_packed = pack_phase_filters(w, (sh, sw), (dh, dw))
    # (T, KP, KQ, Cout, Cin) -> flat tap axis for the (t, k) block index.
    w_flat = w_packed.reshape(T, TK, Cout, Cin)

    # Pad dy ONCE (uniform across phases): front by the largest tap offset
    # base(TPh-1) + (KP-1)*step, tail so every phase window of ho rows fits.
    pad_h = spec.tap_phase_base(TPh - 1, 0) + (KP - 1) * step_h
    pad_w = spec.tap_phase_base(TPw - 1, 1) + (KQ - 1) * step_w
    ho, wo = -(-Fh // sh), -(-Fw // sw)  # uniform phase-plane extent
    dy_pad = jnp.pad(dy, ((0, 0), (pad_h, ho - Oh), (pad_w, wo - Ow),
                          (0, 0)))
    hp, wp = dy_pad.shape[1], dy_pad.shape[2]

    if epilogue is not None and epilogue.is_identity:
        epilogue = None
    if epilogue is not None and epilogue.bias and bias is None:
        raise ValueError("epilogue.bias=True but no bias array was given")
    if None in (cin_tile, cout_tile, tap_unroll, phase_unroll):
        plan = tiling.plan_tiles("input_grad", spec,
                                 x_shape=(B, Nh, Nw, Cin),
                                 dy_shape=dy.shape,
                                 itemsize=dy.dtype.itemsize,
                                 interpret=interpret, epilogue=epilogue)
        cin_tile = plan.cin_tile if cin_tile is None else cin_tile
        cout_tile = plan.cout_tile if cout_tile is None else cout_tile
        tap_unroll = plan.tap_unroll if tap_unroll is None else tap_unroll
        phase_unroll = plan.phase_unroll if phase_unroll is None \
            else phase_unroll
    ci_t = min(cin_tile, Cin)
    co_t = min(cout_tile, Cout)
    n_ci, n_co = -(-Cin // ci_t), -(-Cout // co_t)
    if Cout % co_t:
        dy_pad = jnp.pad(dy_pad, ((0, 0),) * 3 + ((0, n_co * co_t - Cout),))
        w_flat = jnp.pad(w_flat, ((0, 0),) * 2 +
                         ((0, n_co * co_t - Cout), (0, 0)))
    if Cin % ci_t:
        w_flat = jnp.pad(w_flat, ((0, 0),) * 3 + ((0, n_ci * ci_t - Cin),))

    u = tiling.largest_divisor_leq(TK, tap_unroll)
    pu = tiling.largest_divisor_leq(T, phase_unroll)
    n_k, n_t = TK // u, T // pu
    per_h, per_w = spec.tap_phase_period
    kern = functools.partial(_fused_tap_kernel, tpw=TPw, kp=KP, kq=KQ,
                             kh=Kh, kwf=Kw, per_h=per_h, per_w=per_w,
                             sh=sh, sw=sw, dh=dh, dw=dw, step_h=step_h,
                             step_w=step_w, pad_h=pad_h, pad_w=pad_w,
                             ho=ho, wo=wo, pu=pu, n_t=n_t, u=u, n_k=n_k,
                             seq1=(n_co == 1 and n_k == 1), ep=epilogue)
    in_specs = [
        pl.BlockSpec((1, hp, wp, co_t),
                     lambda b, t, ci, co, k: (b, 0, 0, co)),
        pl.BlockSpec((pu, u, co_t, ci_t),
                     lambda b, t, ci, co, k: (t, k, co, ci)),
    ]
    ins = [dy_pad, w_flat]
    if epilogue is not None and epilogue.bias:
        bp = bias.astype(jnp.float32).reshape(1, Cin)
        if Cin % ci_t:
            bp = jnp.pad(bp, ((0, 0), (0, n_ci * ci_t - Cin)))
        in_specs.append(pl.BlockSpec((1, ci_t),
                                     lambda b, t, ci, co, k: (0, ci)))
        ins.append(bp)
    out = pl.pallas_call(
        kern,
        grid=(B, n_t, n_ci, n_co, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, pu, ho, wo, ci_t),
                               lambda b, t, ci, co, k: (b, t, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((B, T, ho, wo, n_ci * ci_t),
                                       jnp.float32),
        interpret=interpret,
    )(*ins)

    if Cin % ci_t:   # slice only when channel padding occurred
        out = out[..., :Cin]
    # Structural-zero residues / tail positions never reach the kernel:
    # under an epilogue their value is epilogue(0) = act(bias), nonzero
    # only when a bias rides along (every supported activation fixes 0).
    fill = None
    if epilogue is not None and epilogue.bias:
        fill = epilogue.apply(jnp.zeros((Cin,), jnp.float32), bias)
    return assemble_phase_major(out, spec, n_out=(Nh, Nw),
                                full_size=(Fh, Fw),
                                fill=fill).astype(dy.dtype)


def _autotune_runner(spec: ConvSpec, x_shape, dy_shape, epilogue=None):
    """Autotune hook: execute the real kernel at one candidate plan."""
    dy = jnp.zeros(dy_shape, jnp.float32)
    w = jnp.zeros(spec.filter_shape + (x_shape[-1], dy_shape[-1]),
                  jnp.float32)
    bias = (jnp.zeros((x_shape[-1],), jnp.float32)
            if epilogue is not None and epilogue.bias else None)
    n_out = (x_shape[1], x_shape[2])
    interp = jax.default_backend() != "tpu"

    def run(plan: tiling.TilePlan):
        return jax.block_until_ready(tconv_fused_pallas(
            dy, w, stride=spec.stride, padding=spec.padding, n_out=n_out,
            dilation=spec.dilation, bias=bias, epilogue=epilogue,
            cin_tile=plan.cin_tile,
            cout_tile=plan.cout_tile, tap_unroll=plan.tap_unroll,
            phase_unroll=plan.phase_unroll, interpret=interp))

    return run


tiling.register_autotune_runner("input_grad", _autotune_runner)
