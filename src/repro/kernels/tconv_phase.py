"""Pallas TPU kernel: phase-decomposed (zero-free) transposed convolution.

One `pallas_call` computes one *phase* of the EcoFlow transposed conv: a
stride-1 "full" correlation of the un-padded error map `dy` with a rotated
sub-filter `w_pq`.  The wrapper in `ops.py` launches S*S phases and
interleaves the results.

TPU mapping (the EcoFlow->MXU translation, see DESIGN.md Sec. 2):
  * the paper's per-PE MAC schedule (one weight broadcast per cycle, one
    error element per PE) becomes a static tap loop of
    (spatial x Cout) @ (Cout x Cin) MXU matmuls;
  * the paper's multicast groups become the shifted static slices of the
    VMEM-resident dy block;
  * the paper's vertical psum chains become the fp32 accumulator tile.

BlockSpec tiling: grid (B, Cin_tiles).  Per grid step the kernel holds
  dy block   (1, Hp, Wp, Cout)          -- zero-padded by (kp-1, kq-1)
  w block    (kp, kq, Cout, Cin_t)
  out block  (1, Ho, Wo, Cin_t)         -- fp32 accumulate, cast on store
in VMEM.  Channel tile Cin_t (default 128) keeps the working set within
VMEM for the layer sizes the paper evaluates (<=130x130 spatial); matmul
dims are multiples of 128 whenever Cout/Cin are, which is MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _phase_kernel(dy_ref, w_ref, out_ref, *, kp: int, kq: int,
                  ho: int, wo: int):
    """out[0,x,y,ci] = sum_{a,b,co} dy_pad[0, x+a', y+b', co] w[a,b,co,ci]
    as a static tap loop of MXU matmuls with an fp32 VMEM accumulator."""
    acc = jnp.zeros((ho * wo, out_ref.shape[-1]), dtype=jnp.float32)
    for a in range(kp):
        for b in range(kq):
            # Shifted window of the padded dy block: (ho, wo, Cout).
            win = dy_ref[0, a:a + ho, b:b + wo, :]
            lhs = win.reshape(ho * wo, win.shape[-1]).astype(jnp.float32)
            rhs = w_ref[a, b].astype(jnp.float32)
            acc += jax.lax.dot(lhs, rhs,
                               preferred_element_type=jnp.float32)
    out_ref[0] = acc.reshape(ho, wo, out_ref.shape[-1]).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("cin_tile", "interpret"))
def tconv_phase_pallas(dy: jax.Array, w_sub: jax.Array, *,
                       cin_tile: int = 128,
                       interpret: bool = True) -> jax.Array:
    """Stride-1 full correlation of dy with one rotated sub-filter.

    dy:    (B, Oh, Ow, Cout)
    w_sub: (kp, kq, Cout, Cin)  already rotated/selected by the wrapper
    returns (B, Oh+kp-1, Ow+kq-1, Cin)
    """
    B, Oh, Ow, Cout = dy.shape
    kp, kq, _, Cin = w_sub.shape
    ho, wo = Oh + kp - 1, Ow + kq - 1
    # "Full" correlation: pad dy once on the host side of the kernel.
    dy_pad = jnp.pad(dy, ((0, 0), (kp - 1, kp - 1), (kq - 1, kq - 1), (0, 0)))
    hp, wp = dy_pad.shape[1], dy_pad.shape[2]
    ct = min(cin_tile, Cin)
    n_ct = -(-Cin // ct)
    if Cin % ct:
        w_sub = jnp.pad(w_sub, ((0, 0), (0, 0), (0, 0), (0, n_ct * ct - Cin)))
    kern = functools.partial(_phase_kernel, kp=kp, kq=kq, ho=ho, wo=wo)
    out = pl.pallas_call(
        kern,
        grid=(B, n_ct),
        in_specs=[
            pl.BlockSpec((1, hp, wp, Cout), lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kp, kq, Cout, ct), lambda b, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, ct), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, ho, wo, n_ct * ct), dy.dtype),
        interpret=interpret,
    )(dy_pad, w_sub)
    return out[..., :Cin]
