"""Batched serving engine: continuous-batching request manager over the
prefill + decode steps.

Requests are padded into fixed (batch, max_len) buffers (compile-once);
slots free as sequences hit EOS/length and are refilled from the queue --
the standard continuous-batching discipline (vLLM-style) restricted to a
single static bucket, which is what the decode_32k / long_500k dry-run
cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.parallel import sharding as sh


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int,
                 max_len: int, mesh=None, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.mesh = mesh
        self.lm = LM(cfg)
        self._prefill = jax.jit(
            lambda p, t: self.lm.prefill(p, t, max_len))
        self._decode = jax.jit(self.lm.decode_step)
        self.greedy = greedy

    def _run(self, fn, *args):
        if self.mesh is not None:
            with self.mesh, sh.use_mesh(self.mesh):
                return fn(*args)
        return fn(*args)

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Process a list of requests with continuous batching."""
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        while queue:
            active = queue[:self.batch]
            queue = queue[self.batch:]
            # Left-align prompts into one padded prefill (same length
            # bucket; production would use multiple buckets).
            plen = max(len(r.prompt) for r in active)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(active):
                toks[i, plen - len(r.prompt):] = r.prompt  # right-aligned
            logits, cache = self._run(self._prefill, self.params,
                                      jnp.asarray(toks))
            last = jnp.argmax(logits[:, 0], axis=-1)
            steps = max(r.max_new_tokens for r in active)
            done = np.zeros(self.batch, bool)
            for i, r in enumerate(active):
                r.out.append(int(last[i]))
            for _ in range(steps - 1):
                logits, cache = self._run(self._decode, self.params, cache,
                                          last[:, None].astype(jnp.int32))
                last = jnp.argmax(logits[:, 0], axis=-1)
                arr = np.asarray(last)
                for i, r in enumerate(active):
                    if done[i] or len(r.out) >= r.max_new_tokens:
                        done[i] = True
                        continue
                    tok = int(arr[i])
                    r.out.append(tok)
                    if r.eos_id is not None and tok == r.eos_id:
                        done[i] = True
                if done.all():
                    break
            for r in active:
                results[r.uid] = r.out
        return results
