"""Batched serving engine: continuous-batching request manager over the
prefill + decode steps.

Requests are padded into fixed (batch, max_len) buffers (compile-once);
slots free as sequences hit EOS/length and are refilled from the queue --
the standard continuous-batching discipline (vLLM-style) restricted to a
single static bucket, which is what the decode_32k / long_500k dry-run
cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.parallel import sharding as sh


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int,
                 max_len: int, mesh=None, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.mesh = mesh
        self.lm = LM(cfg)
        self._prefill = jax.jit(
            lambda p, t: self.lm.prefill(p, t, max_len))
        self._decode = jax.jit(self.lm.decode_step)
        self.greedy = greedy
        # generate() statistics: "refills" counts requests pulled into a
        # slot freed MID-FLIGHT (the continuous-batching property the
        # regression test pins); "prefills" counts batch (re)prefills.
        self.stats: Dict[str, int] = {"refills": 0, "prefills": 0,
                                      "decode_steps": 0}

    def _run(self, fn, *args):
        if self.mesh is not None:
            with self.mesh, sh.use_mesh(self.mesh):
                return fn(*args)
        return fn(*args)

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Process a list of requests with continuous batching.

        Slots free as sequences finish (EOS / length) and are refilled
        from the queue IMMEDIATELY -- mid-flight, not only between
        cohorts.  The KV cache keeps one shared position scalar (see
        `lm.prefill`), so a refill re-prefills the whole batch over each
        live slot's history (prompt + tokens generated so far,
        right-aligned): under greedy decoding the prefill's last-position
        argmax is exactly the next decode token, so continuing slots
        resume where they left off while the new request starts in the
        freed slot."""
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        active: List[Optional[Request]] = [None] * self.batch
        cache = None
        last = None

        def absorb(arr) -> None:
            """Append one predicted token per live slot; retire slots
            that hit EOS or their length budget."""
            for i, r in enumerate(active):
                if r is None:
                    continue
                tok = int(arr[i])
                if len(r.out) < r.max_new_tokens:
                    r.out.append(tok)
                if len(r.out) >= r.max_new_tokens or (
                        r.eos_id is not None and r.out
                        and r.out[-1] == r.eos_id):
                    results[r.uid] = r.out
                    active[i] = None

        while queue or any(r is not None for r in active):
            midflight = any(r is not None for r in active)
            took = 0
            for i in range(self.batch):
                if active[i] is None and queue:
                    active[i] = queue.pop(0)
                    took += 1
            if took:
                if midflight:
                    self.stats["refills"] += took
                # (Re)prefill the whole batch over per-slot histories;
                # empty slots carry a single pad token.
                hists = [list(r.prompt) + r.out if r is not None else [0]
                         for r in active]
                plen = max(len(h) for h in hists)
                toks = np.zeros((self.batch, plen), np.int32)
                for i, h in enumerate(hists):
                    toks[i, plen - len(h):] = h   # right-aligned
                logits, cache = self._run(self._prefill, self.params,
                                          jnp.asarray(toks))
                self.stats["prefills"] += 1
            else:
                logits, cache = self._run(self._decode, self.params, cache,
                                          last[:, None].astype(jnp.int32))
                self.stats["decode_steps"] += 1
            last = jnp.argmax(logits[:, 0], axis=-1)
            absorb(np.asarray(last))
        return results
