"""Deterministic fault injection for the conv serving / training stack.

Serving at the edge (HUGE2-class deployments) and elastic training share
one failure model: a kernel path misbehaves -- a launch raises, an output
comes back NaN/Inf, a tile-cache artifact is torn, a shard straggles, a
device disappears -- and the engine must degrade instead of dying.  This
module is the single source of those failures for tests and benchmarks:

  * `FaultSchedule.seeded(seed, ...)` precomputes, from one RNG seed,
    WHICH invocation of WHICH site fires WHICH fault.  The schedule is a
    pure function of its arguments, so a test that replays the same seed
    sees byte-identical failure timing -- no flaky probabilistic
    injection, no time-of-day dependence.
  * `FaultInjector` walks a schedule at run time: each `step(site)`
    advances that site's invocation counter and returns the scheduled
    event (if any); `raise_or_delay` converts launch-class events into
    exceptions / latency, and `poison` applies output-corruption events
    host-side.  Every fired event is recorded for assertions.
  * `inject_backend` wraps a `repro.core.spec.ConvBackend` so every conv
    op consults the injector -- the hook the graceful-degradation ladder
    (`core/spec.py::fallback_backend`) and `ConvServeEngine` are tested
    against.
  * `corrupt_tile_cache` mangles an `ECOFLOW_TILE_CACHE` artifact in the
    ways a real deployment sees (truncation, garbage, a torn row), to
    prove the warn-and-replan policy end to end.

`train/fault_tolerance.py` builds its host-loss schedules on the same
`FaultSchedule`, so serving and training replay failures from one seeded
source (DESIGN.md Sec. 2.11).
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Launch-class kinds surface as exceptions/latency BEFORE the kernel
# output exists; output-class kinds corrupt the produced values.
LAUNCH_KINDS = ("kernel_exception", "device_loss", "latency_spike")
OUTPUT_KINDS = ("nan_output", "inf_output")
FAULT_KINDS = LAUNCH_KINDS + OUTPUT_KINDS


class InjectedFault(RuntimeError):
    """Base class of every injected failure (site/index/kind attached)."""

    def __init__(self, site: str, index: int, kind: str):
        super().__init__(f"injected {kind} at {site}#{index}")
        self.site, self.index, self.kind = site, index, kind


class InjectedKernelFault(InjectedFault):
    """A kernel launch that raised (Mosaic lowering error, OOM, ...)."""


class InjectedDeviceLoss(InjectedFault):
    """A device that disappeared mid-request (host eviction, preemption)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled failure: the `index`-th invocation of `site` fires
    `kind`.  `magnitude` is the latency-spike duration in seconds (other
    kinds ignore it)."""
    site: str
    index: int
    kind: str
    magnitude: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")


class FaultSchedule:
    """An immutable set of `FaultEvent`s, indexed by (site, index).

    Build explicitly from events (exact placement for state-machine
    tests) or via `seeded` (rate-driven, deterministic in the seed)."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(events)
        self._by_key: Dict[Tuple[str, int], FaultEvent] = {
            (e.site, e.index): e for e in self.events}

    @classmethod
    def seeded(cls, seed: int, *, sites: Sequence[str], rate: float,
               horizon: int = 256, kinds: Sequence[str] = FAULT_KINDS,
               magnitude: float = 0.0) -> "FaultSchedule":
        """Rate-driven schedule: for each site, each invocation index
        below `horizon` fires with probability `rate`, drawing the kind
        uniformly from `kinds`.  A pure function of the arguments -- the
        same seed replays the same schedule exactly."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        events = []
        for site in sites:
            fire = rng.random(horizon) < rate
            pick = rng.integers(0, len(kinds), horizon)
            for i in np.nonzero(fire)[0]:
                events.append(FaultEvent(site, int(i), kinds[int(pick[i])],
                                         magnitude))
        return cls(events)

    def lookup(self, site: str, index: int) -> Optional[FaultEvent]:
        return self._by_key.get((site, index))

    def __len__(self) -> int:
        return len(self.events)


class FaultInjector:
    """Replays a `FaultSchedule` against live invocation counters.

    One injector instance per engine/test run: counters start at zero, so
    the run sees the schedule from its beginning.  `fired` records every
    event actually hit, in order -- tests assert against it."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self._counters: Dict[str, int] = defaultdict(int)
        self.fired: List[FaultEvent] = []

    def step(self, site: str) -> Optional[FaultEvent]:
        """Advance `site`'s invocation counter; return the scheduled
        event for the index just consumed (recorded), or None."""
        i = self._counters[site]
        self._counters[site] = i + 1
        ev = self.schedule.lookup(site, i)
        if ev is not None:
            self.fired.append(ev)
        return ev

    def raise_or_delay(self, site: str) -> Optional[FaultEvent]:
        """Consume one invocation of `site` and act on launch-class
        events: kernel exceptions and device losses raise, latency
        spikes sleep.  Output-class events are RETURNED (the caller
        applies them to the produced value via `poison`); None means the
        invocation is clean."""
        ev = self.step(site)
        if ev is None:
            return None
        if ev.kind == "kernel_exception":
            raise InjectedKernelFault(ev.site, ev.index, ev.kind)
        if ev.kind == "device_loss":
            raise InjectedDeviceLoss(ev.site, ev.index, ev.kind)
        if ev.kind == "latency_spike":
            time.sleep(max(0.0, ev.magnitude))
            return None
        return ev

    def poison(self, ev: Optional[FaultEvent], value):
        """Apply an output-class event to a host array: stamp NaN/Inf
        into the first element of every batch row (enough to trip any
        finite-ness guard, cheap to produce).  No-op for None."""
        if ev is None or ev.kind not in OUTPUT_KINDS:
            return value
        out = np.array(value, copy=True)
        bad = np.nan if ev.kind == "nan_output" else np.inf
        flat = out.reshape(out.shape[0], -1) if out.ndim > 1 \
            else out.reshape(1, -1)
        flat[:, 0] = bad
        return out.reshape(value.shape) if out.ndim > 1 else out[0]


def train_site(workload: str) -> str:
    """Canonical fault-site name for a training workload's step loop
    (`train.cnn`, `train.gan`, `train.gan_gen`): the ConvTrainer
    consults this site once per step ATTEMPT, so retries advance the
    same counter the schedule was seeded against."""
    return f"train.{workload}"


def training_schedule(seed: int, *, workload: str, n_steps: int,
                      rate: float = 0.02,
                      kinds: Sequence[str] = ("nan_output",
                                              "latency_spike",
                                              "kernel_exception"),
                      magnitude: float = 0.0) -> FaultSchedule:
    """Seeded per-step fault schedule for a training run, on the SAME
    registry the serving engine and `host_failure_schedule` draw from:
    one seed replays identical failure timing across a serving test and
    a training drill (DESIGN.md Sec. 2.11/2.12).  Defaults exclude
    `device_loss` -- host losses come from `host_failure_schedule` so
    the two axes of the storm stay independently seedable."""
    return FaultSchedule.seeded(
        seed, sites=[train_site(workload)], rate=rate, horizon=n_steps,
        kinds=kinds, magnitude=magnitude)


def poison_batch(injector: FaultInjector, ev: Optional[FaultEvent],
                 batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Apply an output-class event to a host batch dict: stamp NaN/Inf
    into the first float array (inputs / latents) -- enough for the
    forward pass to propagate non-finites into loss and grads, so the
    trainer's REAL in-graph guard trips instead of a test-only seam.
    Launch-class events and None pass the batch through untouched."""
    if ev is None or ev.kind not in OUTPUT_KINDS:
        return batch
    out = dict(batch)
    for key in sorted(out):
        v = out[key]
        if isinstance(v, np.ndarray) and \
                np.issubdtype(v.dtype, np.floating):
            out[key] = injector.poison(ev, v)
            break
    return out


def inject_backend(base, injector: FaultInjector, *, prefix=None):
    """Wrap a `ConvBackend` so every op consults `injector` first.

    Site names are `<prefix>.<op>` (prefix defaults to the backend
    name).  Launch-class events fire before the base op runs;
    output-class events poison the op's (host-materialized) result.
    Used to test the `core/spec.py::fallback_backend` degradation seam
    with real kernel paths underneath."""
    from repro.core.spec import ConvBackend, resolve_backend

    be = resolve_backend(base)
    pre = prefix if prefix is not None else be.name

    def wrap(op_name, call):
        def op(*args):
            ev = injector.raise_or_delay(f"{pre}.{op_name}")
            out = call(*args)
            if ev is not None:
                if isinstance(out, tuple):
                    out = tuple(
                        o if o is None else injector.poison(ev, np.asarray(o))
                        for o in out)
                else:
                    out = injector.poison(ev, np.asarray(out))
            return out
        return op

    return ConvBackend(
        name=f"{be.name}@inject",
        forward=wrap("forward", be.forward),
        input_grad=wrap("input_grad", be.input_grad),
        filter_grad=wrap("filter_grad", be.filter_grad),
        fused_backward=wrap("backward", be.backward),
        fused_ct_backward=wrap("ct_backward", be.ct_backward),
        fused_forward_ep=wrap("forward_ep", be.forward_ep),
        fused_input_grad_ep=wrap("input_grad_ep", be.input_grad_ep),
        fused_backward_ep=wrap("backward_ep", be.backward_ep),
        fused_ct_backward_ep=wrap("ct_backward_ep", be.ct_backward_ep))


def corrupt_tile_cache(path, mode: str = "truncate", seed: int = 0) -> None:
    """Mangle an ECOFLOW_TILE_CACHE artifact the way real deployments
    see it break -- the warmup/planner side must warn and re-plan
    (kernels/tiling.py's load policy), never crash:

      * "truncate"  -- cut the file mid-document (pre-atomic-write crash);
      * "garbage"   -- overwrite with non-JSON bytes (torn copy);
      * "torn_row"  -- keep valid JSON but replace one row's plan fields
                       with nonsense (partial hand edit / version skew).
    """
    import pathlib
    p = pathlib.Path(path)
    if mode == "truncate":
        text = p.read_text() if p.exists() else json.dumps(
            {"x": {"cin_tile": 8}})
        p.write_text(text[:max(1, len(text) // 2)])
    elif mode == "garbage":
        p.write_bytes(b"\x00\xffnot-json\x13" * 7)
    elif mode == "torn_row":
        try:
            doc = json.loads(p.read_text())
        except (OSError, ValueError):
            doc = {}
        if not isinstance(doc, dict) or not doc:
            doc = {"seed-row": {}}
        rng = np.random.default_rng(seed)
        key = sorted(doc)[int(rng.integers(len(doc)))]
        doc[key] = {"cin_tile": "not-an-int"}
        p.write_text(json.dumps(doc))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}; expected "
                         f"truncate | garbage | torn_row")
