"""Fault-tolerant continuous-batching serving for the conv workloads.

`serve/engine.py` serves the LM; this engine serves what the paper is
actually about -- GAN generation and atrous segmentation on small
low-power accelerators (the HUGE2 edge regime, PAPERS.md).  In that
regime the engine must keep answering when a kernel path misbehaves,
not merely run fast on the happy path, so the robustness layer is the
core of the design (DESIGN.md Sec. 2.11):

  * **Geometry buckets.**  Each request's payload shape normalizes --
    through the models' `*_plan_requests` helpers, i.e. through
    `ConvSpec.make` -- into a bucket keyed by (workload kind, payload
    shape).  Each bucket owns compile-once jitted launch functions at a
    fixed slot batch, so serving never recompiles per request.
  * **Bounded admission.**  Requests enter a bounded queue; submission
    beyond the bound is SHED (counted, rejected) rather than buffered
    without limit -- the engine can fall behind, it can never hang on an
    unbounded backlog.  Slots refill from the queue every launch.
  * **Degradation ladder.**  Per bucket, launches walk
    ``pallas -> xla_zero_free -> reference``.  A rung that raises (or
    NaNs twice) degrades the REQUEST to the next rung immediately, and
    feeds a per-(bucket, rung) circuit breaker: enough consecutive
    failures quarantine the rung (OPEN) so later launches skip it; after
    a cooldown the breaker half-opens and the next launch re-probes the
    rung, closing it again on success.  Eager fallback across rungs for
    everyone else lives in `core/spec.py::fallback_backend`; the engine
    drives its ladder explicitly because it needs breaker state and
    per-attempt stats around every rung.
  * **Deadlines, retries, NaN guard.**  Requests may carry a relative
    deadline: expired requests are dropped at dequeue and counted at
    completion.  Failed attempts back off exponentially (bounded); a
    non-finite output is retried once on the same rung (transient) and
    then degrades (systematic).
  * **Warmup.**  `warmup()` pre-plans `plan_strategy` tiles for every
    launch a bucket will make from a shipped `ECOFLOW_TILE_CACHE`
    artifact (`kernels.tiling.warmup_plans` -- artifact rows replayed,
    corrupt artifacts warned about and re-planned analytically, never an
    autotune sweep) and optionally pre-compiles the primary rung.

Fault injection (`serve/faults.py`) hooks the launch path OUTSIDE jit:
launch-class events fire before the jitted call, output-class events
poison the host-materialized result.  With no injector attached the
fast path is a plain jitted `generator_apply` / `atrous_head_apply`
with `backend="pallas"` -- exactly ONE forward `pallas_call` per conv
layer, same as the training stack (the jaxpr pins hold unmodified).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.faults import FaultInjector

DEFAULT_LADDER = ("pallas", "xla_zero_free", "reference")

KINDS = ("gan_gen", "aspp")


@dataclasses.dataclass
class ConvRequest:
    """One inference request.

    kind       -- "gan_gen" (payload: a (z_dim,) latent) or "aspp"
                  (payload: an (H, W, C) image).
    deadline_s -- optional deadline RELATIVE to submission; the absolute
                  deadline is stamped by `submit`.  An expired request is
                  dropped (counted as a miss), never served late silently.
    """
    uid: Optional[int]
    kind: str
    payload: np.ndarray
    deadline_s: Optional[float] = None
    deadline: Optional[float] = dataclasses.field(default=None, repr=False)
    submitted: Optional[float] = dataclasses.field(default=None, repr=False)


class CircuitBreaker:
    """Per-(bucket, rung) quarantine: CLOSED -> OPEN after
    `fail_threshold` consecutive failures; OPEN counts down `cooldown`
    launch opportunities, then HALF_OPEN admits one probe; the probe's
    outcome closes or re-opens.  `transitions` records every state
    change for the state-machine tests."""

    def __init__(self, fail_threshold: int = 2, cooldown: int = 3):
        if fail_threshold < 1 or cooldown < 1:
            raise ValueError("fail_threshold and cooldown must be >= 1")
        self.fail_threshold = fail_threshold
        self.cooldown = cooldown
        self.state = "closed"
        self.failures = 0
        self._cool = 0
        self.transitions: List[Tuple[str, str]] = []

    def _to(self, state: str) -> None:
        if state != self.state:
            self.transitions.append((self.state, state))
            self.state = state

    def allow(self) -> bool:
        """May the next launch try this rung?  An OPEN breaker consumes
        one cooldown tick per refusal, so quarantine is measured in
        launch opportunities -- deterministic under test, no clocks."""
        if self.state == "closed":
            return True
        if self.state == "open":
            self._cool -= 1
            if self._cool > 0:
                return False
            self._to("half_open")
            return True
        return True   # half_open: the single-threaded engine probes once

    def record_success(self) -> None:
        self.failures = 0
        self._to("closed")

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.fail_threshold:
            self.failures = 0
            self._cool = self.cooldown
            self._to("open")


@dataclasses.dataclass
class _Bucket:
    key: tuple
    kind: str
    payload_shape: tuple
    specs: tuple              # the ConvSpec-normalized launch geometry
    breakers: Dict[str, CircuitBreaker]


class ConvServeEngine:
    """Continuous-batching request manager over the GAN generator and
    the ASPP atrous head.  Single-threaded and synchronous by design
    (the edge-serving regime this models has one accelerator): `submit`
    admits or sheds, `run` drains the queue, `serve` does both."""

    def __init__(self, *, gan_params=None, aspp_params=None,
                 slot_batch: int = 4, queue_limit: int = 32,
                 ladder: Sequence[str] = DEFAULT_LADDER,
                 injector: Optional[FaultInjector] = None,
                 fail_threshold: int = 2, cooldown: int = 3,
                 retry_backoff_s: float = 0.0,
                 max_backoff_s: float = 0.05,
                 rates: Tuple[int, ...] = (1, 2, 4),
                 fuse_epilogue: bool = True,
                 tile_cache_path=None):
        if slot_batch < 1 or queue_limit < 1:
            raise ValueError("slot_batch and queue_limit must be >= 1")
        if not ladder:
            raise ValueError("ladder must name at least one backend")
        self.gan_params = gan_params
        self.aspp_params = aspp_params
        self.slot_batch = int(slot_batch)
        self.queue_limit = int(queue_limit)
        self.ladder = tuple(ladder)
        self.injector = injector
        self.fail_threshold = int(fail_threshold)
        self.cooldown = int(cooldown)
        self.retry_backoff_s = float(retry_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.rates = tuple(rates)
        self.fuse_epilogue = bool(fuse_epilogue)
        self.tile_cache_path = tile_cache_path

        self._queue: deque = deque()
        self._buckets: Dict[tuple, _Bucket] = {}
        self._jit_cache: Dict[tuple, object] = {}
        self._next_uid = 0
        self._latencies_us: List[float] = []
        self.stats: Dict[str, object] = {
            "submitted": 0, "completed": 0, "sheds": 0, "failures": 0,
            "retries": 0, "fallbacks": 0, "nan_events": 0,
            "deadline_misses": 0, "kernel_faults": 0, "quarantines": 0,
            "reprobes": 0, "launches": 0, "warmup": None,
        }

    # -- buckets ----------------------------------------------------------

    def _bucket(self, kind: str, payload_shape: tuple) -> _Bucket:
        key = (kind, tuple(int(s) for s in payload_shape))
        b = self._buckets.get(key)
        if b is not None:
            return b
        entries = self._plan_entries(kind, key[1])
        b = _Bucket(
            key=key, kind=kind, payload_shape=key[1],
            specs=tuple(e[1] for e in entries),
            breakers={name: CircuitBreaker(self.fail_threshold,
                                           self.cooldown)
                      for name in self.ladder})
        self._buckets[key] = b
        return b

    def _plan_entries(self, kind: str, payload_shape: tuple):
        """The bucket's launch geometry, normalized through
        `ConvSpec.make` by the model helpers."""
        if kind == "gan_gen":
            if self.gan_params is None:
                raise ValueError("no gan_params: cannot serve gan_gen")
            from repro.models import gan
            return gan.generator_plan_requests(
                self.gan_params, self.slot_batch,
                fuse_epilogue=self.fuse_epilogue)
        if kind == "aspp":
            if self.aspp_params is None:
                raise ValueError("no aspp_params: cannot serve aspp")
            from repro.models import vision
            return vision.atrous_plan_requests(
                self.aspp_params, (self.slot_batch,) + payload_shape,
                rates=self.rates, fuse_epilogue=self.fuse_epilogue)
        raise ValueError(f"unknown request kind {kind!r}; "
                         f"expected one of {KINDS}")

    def forward_fn(self, kind: str, backend: str):
        """The bucket's raw (unjitted) launch callable for `backend` --
        the jaxpr-pin surface: tracing it with injection off shows
        exactly the training stack's launch structure."""
        if kind == "gan_gen":
            from repro.models import gan
            return lambda batch: gan.generator_apply(
                self.gan_params, batch, backend=backend,
                fuse_epilogue=self.fuse_epilogue)
        if kind == "aspp":
            from repro.models import vision
            return lambda batch: vision.atrous_head_apply(
                self.aspp_params, batch, rates=self.rates,
                backend=backend, fuse_epilogue=self.fuse_epilogue)
        raise ValueError(f"unknown request kind {kind!r}")

    def _jitted(self, bucket: _Bucket, backend: str):
        key = (bucket.key, backend)
        fn = self._jit_cache.get(key)
        if fn is None:
            import jax
            fn = jax.jit(self.forward_fn(bucket.kind, backend))
            self._jit_cache[key] = fn
        return fn

    # -- warmup -----------------------------------------------------------

    def warmup(self, shapes: Sequence[Tuple[str, tuple]], *,
               compile: bool = False) -> dict:
        """Pre-plan every bucket's tiles from the shipped tile-cache
        artifact (never an autotune sweep; a corrupt artifact warns and
        falls back to the analytical planner) and optionally pre-compile
        the primary rung with one dummy batch.  `shapes` lists
        ``(kind, payload_shape)`` pairs."""
        from repro.kernels import tiling
        interpret = self._interpret()
        entries = []
        for kind, payload_shape in shapes:
            bucket = self._bucket(kind, tuple(payload_shape))
            entries.extend(self._plan_entries(kind, bucket.payload_shape))
        plans = tiling.warmup_plans(entries,
                                    tile_cache_path=self.tile_cache_path,
                                    interpret=interpret)
        summary = {
            "buckets": len(self._buckets),
            "plans": len(plans),
            "artifact": sum(1 for v in plans.values()
                            if v["source"] == "artifact"),
            "analytical": sum(1 for v in plans.values()
                              if v["source"] == "analytical"),
        }
        if compile:
            for kind, payload_shape in shapes:
                bucket = self._bucket(kind, tuple(payload_shape))
                batch = np.zeros((self.slot_batch,) + bucket.payload_shape,
                                 np.float32)
                np.asarray(self._jitted(bucket, self.ladder[0])(batch))
        self.stats["warmup"] = summary
        return summary

    @staticmethod
    def _interpret() -> bool:
        import jax
        return jax.default_backend() != "tpu"

    # -- admission --------------------------------------------------------

    def submit(self, req: ConvRequest) -> bool:
        """Admit `req` into the bounded queue; False (and a shed count)
        when the queue is at the admission bound."""
        self.stats["submitted"] += 1
        if len(self._queue) >= self.queue_limit:
            self.stats["sheds"] += 1
            return False
        if req.uid is None:
            req.uid = self._next_uid
            self._next_uid += 1
        req.submitted = time.monotonic()
        if req.deadline_s is not None:
            req.deadline = req.submitted + req.deadline_s
        self._bucket(req.kind, tuple(req.payload.shape))
        self._queue.append(req)
        return True

    # -- serving loop -----------------------------------------------------

    def serve(self, requests: Sequence[ConvRequest]) -> Dict[int, np.ndarray]:
        """Submit a batch of requests (shedding past the admission
        bound) and drain the queue.  Returns {uid: result} for every
        admitted request that completed in deadline."""
        for r in requests:
            self.submit(r)
        return self.run()

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue: take up to `slot_batch` same-bucket requests
        from the front (slots refill from the queue on every launch),
        launch them through the degradation ladder, repeat."""
        results: Dict[int, np.ndarray] = {}
        while self._queue:
            cohort, bucket = self._take_cohort()
            if not cohort:
                continue
            out = self._launch(bucket, cohort)
            if out is None:       # every rung failed for this cohort
                self.stats["failures"] += len(cohort)
                continue
            now = time.monotonic()
            for i, r in enumerate(cohort):
                if r.deadline is not None and now > r.deadline:
                    self.stats["deadline_misses"] += 1
                    continue
                self.stats["completed"] += 1
                self._latencies_us.append((now - r.submitted) * 1e6)
                results[r.uid] = out[i]
        return results

    def _take_cohort(self):
        """Pop up to `slot_batch` requests sharing the front request's
        bucket, preserving the order of everything left behind.
        Already-expired requests are dropped here (deadline miss)."""
        now = time.monotonic()
        while self._queue:
            head = self._queue[0]
            if head.deadline is not None and now > head.deadline:
                self._queue.popleft()
                self.stats["deadline_misses"] += 1
                continue
            break
        if not self._queue:
            return [], None
        head = self._queue[0]
        bucket = self._bucket(head.kind, tuple(head.payload.shape))
        cohort, rest = [], deque()
        while self._queue and len(cohort) < self.slot_batch:
            r = self._queue.popleft()
            if r.deadline is not None and now > r.deadline:
                self.stats["deadline_misses"] += 1
                continue
            if (r.kind, tuple(r.payload.shape)) == bucket.key:
                cohort.append(r)
            else:
                rest.append(r)
        rest.extend(self._queue)
        self._queue = rest
        return cohort, bucket

    def _rungs(self, bucket: _Bucket) -> List[str]:
        """The ladder filtered through the breakers.  When every rung is
        quarantined the LAST rung is forced anyway: a fully-open ladder
        must still answer (never hang, never drop silently)."""
        allowed = [name for name in self.ladder
                   if bucket.breakers[name].allow()]
        return allowed if allowed else [self.ladder[-1]]

    def _launch(self, bucket: _Bucket, cohort) -> Optional[np.ndarray]:
        """One slot-batch launch through the ladder.  Returns the host
        output batch, or None when every rung (and the NaN retry budget)
        is exhausted."""
        batch = np.zeros((self.slot_batch,) + bucket.payload_shape,
                         np.float32)
        for i, r in enumerate(cohort):
            batch[i] = r.payload
        self.stats["launches"] += 1
        n = len(cohort)
        attempt = 0
        rungs = self._rungs(bucket)
        for ri, backend in enumerate(rungs):
            breaker = bucket.breakers[backend]
            probing = breaker.state == "half_open"
            if probing:
                self.stats["reprobes"] += 1
            nan_budget = 1
            while True:
                if attempt > 0:
                    self.stats["retries"] += 1
                    self._backoff(attempt)
                attempt += 1
                try:
                    ev = None
                    if self.injector is not None:
                        ev = self.injector.raise_or_delay(
                            f"{bucket.kind}:{backend}")
                    out = np.asarray(self._jitted(bucket, backend)(batch))
                    if ev is not None:
                        out = self.injector.poison(ev, out)
                except Exception:  # noqa: BLE001 - ladder absorbs faults
                    self.stats["kernel_faults"] += 1
                    self._fail(breaker)
                    break         # degrade: next rung serves this cohort
                if not np.all(np.isfinite(out[:n])):
                    self.stats["nan_events"] += 1
                    if nan_budget > 0:
                        nan_budget -= 1
                        continue  # transient? one retry on the same rung
                    self._fail(breaker)
                    break         # systematic: degrade to the next rung
                breaker.record_success()
                if ri > 0:
                    self.stats["fallbacks"] += 1
                return out
        return None

    def _fail(self, breaker: CircuitBreaker) -> None:
        before = breaker.state
        breaker.record_failure()
        if breaker.state == "open" and before != "open":
            self.stats["quarantines"] += 1

    def _backoff(self, attempt: int) -> None:
        if self.retry_backoff_s <= 0:
            return
        time.sleep(min(self.max_backoff_s,
                       self.retry_backoff_s * (2.0 ** (attempt - 1))))

    # -- health -----------------------------------------------------------

    def health(self) -> dict:
        """Stats snapshot plus latency percentiles and breaker states --
        the surface a deployment scrapes."""
        lat = np.asarray(self._latencies_us, np.float64)
        out = dict(self.stats)
        out["p50_us"] = float(np.percentile(lat, 50)) if lat.size else None
        out["p99_us"] = float(np.percentile(lat, 99)) if lat.size else None
        out["queue_depth"] = len(self._queue)
        out["breakers"] = {
            f"{k[0]}:{name}": br.state
            for k, b in self._buckets.items()
            for name, br in b.breakers.items()}
        return out
