"""The paper's own evaluation domain: CNN training and GAN training with
every conv routed through the EcoFlow zero-free dataflows."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecoflow
from repro.models import cnn, gan
from repro.models.vision import (atrous_head_apply, atrous_head_init,
                                 atrous_seg_loss, patchify_apply,
                                 patchify_init)

from conftest import assert_allclose


def test_cnn_training_loss_decreases(rng):
    params = cnn.simple_cnn_init(jax.random.PRNGKey(0),
                                 widths=(8, 16), n_classes=4)
    x = jnp.asarray(rng.normal(size=(8, 16, 16, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, (8,)), jnp.int32)
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p: cnn.cnn_loss(p, x, y, stride=2)))
    l0, _ = loss_fn(params)
    for _ in range(25):
        l, g = loss_fn(params)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    l_final, _ = loss_fn(params)
    assert float(l_final) < float(l0) * 0.7
    assert np.isfinite(float(l_final))


@pytest.mark.parametrize("backend",
                         ["reference", "xla_zero_free", "pallas"])
def test_cnn_grads_match_plain_conv(rng, backend):
    """Training with EcoFlow backward == training with jax's own conv
    gradients (bit-compatible up to fp accumulation)."""
    params = cnn.simple_cnn_init(jax.random.PRNGKey(0), widths=(4, 8),
                                 n_classes=3)
    x = jnp.asarray(rng.normal(size=(2, 12, 12, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 3, (2,)), jnp.int32)

    def plain_apply(p, x):
        h = x
        for w in p["convs"]:
            h = jax.nn.relu(ecoflow.direct_conv(h, w, 2, 1))
        return h.mean(axis=(1, 2)) @ p["head"]

    def plain_loss(p):
        logits = plain_apply(p, x)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
        return (logz - gold).mean()

    g_eco = jax.grad(lambda p: cnn.cnn_loss(p, x, y, stride=2,
                                            backend=backend))(params)
    g_ref = jax.grad(plain_loss)(params)
    for a, b in zip(jax.tree.leaves(g_eco), jax.tree.leaves(g_ref)):
        assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_gan_step(rng):
    gp = gan.generator_init(jax.random.PRNGKey(0), z_dim=16, base=8)
    dp = gan.discriminator_init(jax.random.PRNGKey(1), base=8)
    z = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    real = jnp.asarray(rng.normal(size=(4, 32, 32, 3)), jnp.float32)
    fake = gan.generator_apply(gp, z)
    assert fake.shape == (4, 32, 32, 3)
    assert bool(jnp.isfinite(fake).all())
    g_loss, d_loss = gan.gan_losses(gp, dp, z, real)
    assert np.isfinite(float(g_loss)) and np.isfinite(float(d_loss))
    # gradients flow through both the transposed-conv generator and the
    # strided-conv discriminator
    gg = jax.grad(lambda p: gan.gan_losses(p, dp, z, real)[0])(gp)
    gd = jax.grad(lambda p: gan.gan_losses(gp, p, z, real)[1])(dp)
    assert all(float(jnp.abs(t).max()) > 0 for t in jax.tree.leaves(gg))
    assert all(float(jnp.abs(t).max()) > 0 for t in jax.tree.leaves(gd))


@pytest.mark.parametrize("backend",
                         ["reference", "xla_zero_free", "pallas"])
def test_gan_grads_match_across_backends(rng, backend):
    """Generator + discriminator gradients agree with the reference
    backend through the dispatch layer (the generator differentiates
    THROUGH the transposed conv, exercising its custom VJP)."""
    gp = gan.generator_init(jax.random.PRNGKey(0), z_dim=8, base=8)
    dp = gan.discriminator_init(jax.random.PRNGKey(1), base=8)
    z = jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)
    real = jnp.asarray(rng.normal(size=(2, 32, 32, 3)), jnp.float32)

    def g_loss(p, be):
        return gan.gan_losses(p, dp, z, real, backend=be)[0]

    def d_loss(p, be):
        return gan.gan_losses(gp, p, z, real, backend=be)[1]

    gg = jax.grad(g_loss)(gp, backend)
    gd = jax.grad(d_loss)(dp, backend)
    gg_ref = jax.grad(g_loss)(gp, "reference")
    gd_ref = jax.grad(d_loss)(dp, "reference")
    for a, b in zip(jax.tree.leaves(gg), jax.tree.leaves(gg_ref)):
        assert_allclose(a, b, rtol=1e-3, atol=1e-3)
    for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gd_ref)):
        assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_gan_training_improves_discriminator(rng):
    gp = gan.generator_init(jax.random.PRNGKey(0), z_dim=8, base=8)
    dp = gan.discriminator_init(jax.random.PRNGKey(1), base=8)
    z = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    real = jnp.asarray(rng.normal(size=(8, 32, 32, 3)), jnp.float32)
    d_loss_fn = jax.jit(jax.value_and_grad(
        lambda d: gan.gan_losses(gp, d, z, real)[1]))
    l0, _ = d_loss_fn(dp)
    for _ in range(20):
        l, g = d_loss_fn(dp)
        dp = jax.tree.map(lambda p, gg: p - 0.02 * gg, dp, g)
    assert float(l) < float(l0)


def test_atrous_head_shapes_and_training(rng):
    """The ASPP-lite segmentation head (the paper's dilated-forward
    workload) keeps full resolution at every rate and trains."""
    params = atrous_head_init(jax.random.PRNGKey(0), in_ch=3, width=8,
                              n_classes=3)
    x = jnp.asarray(rng.normal(size=(2, 17, 17, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 3, (2, 17, 17)), jnp.int32)
    logits = atrous_head_apply(params, x)
    assert logits.shape == (2, 17, 17, 3)       # same-padding at all rates
    assert bool(jnp.isfinite(logits).all())
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p: atrous_seg_loss(p, x, y)))
    l0, _ = loss_fn(params)
    for _ in range(15):
        l, g = loss_fn(params)
        params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
    assert float(l) < float(l0)
    assert np.isfinite(float(l))


@pytest.mark.parametrize("backend",
                         ["reference", "xla_zero_free", "pallas"])
def test_atrous_head_grads_match_across_backends(rng, backend):
    """Atrous-head gradients agree with the reference backend through the
    dispatch layer (forward + both adjoints of the dilated conv)."""
    params = atrous_head_init(jax.random.PRNGKey(0), in_ch=2, width=4,
                              n_classes=2, rates=(1, 2))
    x = jnp.asarray(rng.normal(size=(1, 11, 11, 2)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, (1, 11, 11)), jnp.int32)

    def loss(p, be):
        return atrous_seg_loss(p, x, y, rates=(1, 2), backend=be)

    g = jax.grad(loss)(params, backend)
    g_ref = jax.grad(loss)(params, "reference")
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_patchify_stride14_backward(rng):
    """The ViT patch-embed conv (stride 14 -- the paper's worst case,
    ~99.5% zero MACs naive) trains correctly through EcoFlow."""
    params = patchify_init(jax.random.PRNGKey(0), patch=14, d_model=32)
    img = jnp.asarray(rng.normal(size=(2, 56, 56, 3)), jnp.float32)

    def loss(p):
        return jnp.sum(patchify_apply(p, img, patch=14) ** 2)

    out = patchify_apply(params, img, patch=14)
    assert out.shape == (2, 16, 32)   # (56/14)^2 = 16 patches
    g = jax.grad(loss)(params)

    def plain_loss(p):
        x = ecoflow.direct_conv(img, p["proj"], 14, 0)
        x = x.reshape(2, 16, 32) + p["pos"]
        return jnp.sum(x ** 2)

    g_ref = jax.grad(plain_loss)(params)
    assert_allclose(g["proj"], g_ref["proj"], rtol=1e-3, atol=1e-3)
    # and the naive zero fraction really is extreme at stride 14
    assert ecoflow.dconv_zero_mac_fraction(4, 14) > 0.99
