"""Elastic conv training tests (DESIGN.md Sec. 2.12): ConvTrainer
checkpoint/resume bit-exactness, the in-graph numerics guard + StepGuard
rollback/retry policies, blame localization, the AsyncCheckpointer
error-propagation and `_prune` retention fixes, and the RunSupervisor
recovery state machine.

Single-device tests run in-process.  The elastic drills (8 -> 4 shrink,
mixed fault storm) spawn a subprocess with 8 forced host devices, same
pattern as tests/test_multidevice.py.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ConvDataset
from repro.serve.faults import (FaultEvent, FaultInjector, FaultSchedule,
                                InjectedKernelFault, train_site,
                                training_schedule)
from repro.train import checkpoint as ckpt
from repro.train.conv_trainer import (ConvTrainer, ConvTrainerConfig,
                                      NonFiniteStepError)
from repro.train.fault_tolerance import (StepGuard, elastic_mesh,
                                         host_failure_schedule)

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src",
           JAX_PLATFORMS="cpu")


def _run(body: str, timeout=600):
    code = textwrap.dedent(body)
    p = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def _cnn_cfg(**kw) -> ConvTrainerConfig:
    base = dict(workload="cnn", total_steps=6, widths=(4,), image=8,
                n_classes=4, batch=4, backend="xla_zero_free",
                ckpt_every=2, seed=0)
    base.update(kw)
    return ConvTrainerConfig(**base)


def _gan_gen_cfg(**kw) -> ConvTrainerConfig:
    base = dict(workload="gan_gen", total_steps=6, z_dim=8, base=4,
                batch=4, backend="xla_zero_free", ckpt_every=2, seed=0)
    base.update(kw)
    return ConvTrainerConfig(**base)


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# StepGuard unit tests (the policy state machine shared with the LM Trainer)
# ---------------------------------------------------------------------------

def test_step_guard_skip_policy():
    g = StepGuard(max_retries=2, nonfinite_policy="skip")
    d1 = g.nonfinite()
    assert (d1.action, d1.lr_scale) == ("retry", 1.0)
    d2 = g.nonfinite()
    assert d2.action == "skip"          # failure 2 under skip policy
    # counter reset: a later failure starts over with a retry
    assert g.nonfinite().action == "retry"
    assert g.stats["nonfinite_steps"] == 2
    assert g.stats["skips"] == 1


def test_step_guard_shrink_lr_policy_and_give_up():
    g = StepGuard(max_retries=2, nonfinite_policy="shrink_lr",
                  lr_shrink=0.5)
    d1 = g.nonfinite()
    assert (d1.action, d1.lr_scale) == ("retry", 1.0)
    d2 = g.nonfinite()
    assert (d2.action, d2.lr_scale) == ("retry", 0.5)
    d3 = g.nonfinite()                  # failure 3 > max_retries=2
    assert d3.action == "give_up"
    assert g.stats["give_ups"] == 1
    assert g.stats["lr_shrinks"] == 1


def test_step_guard_good_step_resets():
    g = StepGuard(max_retries=2, nonfinite_policy="skip")
    g.nonfinite()
    g.good_step()
    assert g.nonfinite().action == "retry"   # fresh failure sequence


def test_step_guard_validation():
    with pytest.raises(ValueError):
        StepGuard(nonfinite_policy="explode")
    with pytest.raises(ValueError):
        StepGuard(max_retries=0)


# ---------------------------------------------------------------------------
# In-graph guard: jaxpr pin (guarded step must not add launches)
# ---------------------------------------------------------------------------

def test_guarded_step_jaxpr_pinned_to_unguarded_launch_count():
    from conftest import walk_eqns
    cfg = _cnn_cfg(backend="pallas", total_steps=1)
    tr = ConvTrainer(cfg)
    state = tr.init_state()
    data = tr._put_batch(tr.data.batch_at(0))
    lr = jnp.float32(cfg.lr)

    def count(fn):
        jaxpr = jax.make_jaxpr(fn)(state, data, lr)
        return sum(e.primitive.name == "pallas_call"
                   for e in walk_eqns(jaxpr.jaxpr))

    n_guard = count(tr.build_step(guarded=True))
    n_plain = count(tr.build_step(guarded=False))
    assert n_plain > 0
    assert n_guard == n_plain, (
        f"guard added launches: {n_guard} vs {n_plain}")


# ---------------------------------------------------------------------------
# Checkpoint/resume bit-exactness (same mesh => exact replay)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_cfg", [_cnn_cfg, _gan_gen_cfg],
                         ids=["cnn", "gan_gen"])
def test_resume_bit_exact(tmp_path, make_cfg):
    d = str(tmp_path / "ckpt")
    # interrupted run: train to step 4, then a FRESH trainer resumes
    # from the checkpoint and finishes to 6
    first = ConvTrainer(make_cfg(total_steps=4, ckpt_dir=d))
    first.run()
    resumed = ConvTrainer(make_cfg(total_steps=6, ckpt_dir=d))
    out_r = resumed.run()
    assert out_r["start_step"] == 4
    assert [h["step"] for h in out_r["history"]] == [5, 6]
    # straight run: no interruption, no checkpoint involvement
    out_s = ConvTrainer(make_cfg(total_steps=6)).run()
    # the deterministic (seed, step) data contract makes these bit-equal
    _assert_trees_equal(out_r["state"], out_s["state"])


# ---------------------------------------------------------------------------
# Non-finite policy through the real trainer loop
# ---------------------------------------------------------------------------

def test_nan_poison_rollback_retry_matches_fault_free():
    site = train_site("cnn")
    inj = FaultInjector(FaultSchedule(
        [FaultEvent(site, 1, "nan_output")]))
    faulted = ConvTrainer(_cnn_cfg(), injector=inj).run()
    clean = ConvTrainer(_cnn_cfg()).run()
    # first failure -> rollback + retry the SAME step with a clean
    # re-fetch: the final params are EXACTLY the fault-free ones
    _assert_trees_equal(faulted["state"], clean["state"])
    assert faulted["guard_stats"]["nonfinite_steps"] == 1
    assert faulted["guard_stats"]["retries"] == 1
    assert [h["step"] for h in faulted["history"]] == [1, 2, 3, 4, 5, 6]
    # blame localization ran on the failure path and named the injection
    assert len(faulted["blames"]) == 1
    assert faulted["blames"][0]["injected"] is True


def test_skip_policy_abandons_step():
    site = train_site("cnn")
    inj = FaultInjector(FaultSchedule(
        [FaultEvent(site, 1, "nan_output"),
         FaultEvent(site, 2, "nan_output")]))   # poison the retry too
    out = ConvTrainer(_cnn_cfg(nonfinite_policy="skip"),
                      injector=inj).run()
    assert out["guard_stats"]["skips"] == 1
    # the skipped step has no history entry; later steps still ran
    steps = [h["step"] for h in out["history"]]
    assert len(steps) == 5 and steps[-1] == 6


def test_shrink_lr_policy_retries_at_reduced_lr():
    site = train_site("cnn")
    inj = FaultInjector(FaultSchedule(
        [FaultEvent(site, 1, "nan_output"),
         FaultEvent(site, 2, "nan_output")]))
    out = ConvTrainer(_cnn_cfg(nonfinite_policy="shrink_lr",
                               max_retries=3), injector=inj).run()
    assert out["guard_stats"]["lr_shrinks"] == 1
    assert out["guard_stats"]["give_ups"] == 0
    # every step eventually completed (the second retry had clean data)
    assert [h["step"] for h in out["history"]] == [1, 2, 3, 4, 5, 6]


def test_bounded_retries_give_up_raises():
    site = train_site("cnn")
    inj = FaultInjector(FaultSchedule(
        [FaultEvent(site, i, "nan_output") for i in range(4)]))
    tr = ConvTrainer(_cnn_cfg(nonfinite_policy="shrink_lr",
                              max_retries=2), injector=inj)
    with pytest.raises(NonFiniteStepError) as ei:
        tr.run()
    assert ei.value.step == 0
    assert len(ei.value.blame) > 0      # localization names the layers
    assert tr.guard.stats["give_ups"] == 1


def test_kernel_fault_annotated_with_train_step():
    site = train_site("cnn")
    inj = FaultInjector(FaultSchedule(
        [FaultEvent(site, 2, "kernel_exception")]))
    with pytest.raises(InjectedKernelFault) as ei:
        ConvTrainer(_cnn_cfg(), injector=inj).run()
    # the supervisor accounts steps lost by TRAIN step, not site index
    assert ei.value.train_step == 2


# ---------------------------------------------------------------------------
# Checkpoint-layer fixes: dtype cast on the sharded branch, async error
# propagation, intact-aware pruning
# ---------------------------------------------------------------------------

def test_restore_casts_dtype_on_sharded_branch(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.arange(6, dtype=np.float64).reshape(2, 3)}
    ckpt.save(d, 1, tree)
    like = {"w": jax.ShapeDtypeStruct((2, 3), jnp.float32)}
    shd = {"w": jax.sharding.SingleDeviceSharding(jax.devices()[0])}
    out = ckpt.restore(d, 1, like, shd)
    assert out["w"].dtype == jnp.float32        # sharded branch casts
    out2 = ckpt.restore(d, 1, like, None)
    assert out2["w"].dtype == jnp.float32       # unsharded branch too
    np.testing.assert_allclose(np.asarray(out["w"]),
                               tree["w"].astype(np.float32))


def test_async_checkpointer_reraises_background_failure(
        tmp_path, monkeypatch):
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep_last=2)

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt, "save", boom)
    acp.save_async(1, {"w": np.zeros(2)})
    with pytest.raises(RuntimeError, match="async checkpoint write"):
        acp.wait()
    # the error is consumed: the checkpointer is usable again
    acp.wait()
    monkeypatch.undo()
    acp.save_async(2, {"w": np.zeros(2)})
    acp.wait()
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_async_checkpointer_reraises_on_next_save(tmp_path, monkeypatch):
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep_last=2)
    monkeypatch.setattr(ckpt, "save",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            OSError("torn fs")))
    acp.save_async(1, {"w": np.zeros(2)})
    # save_async joins the previous write thread first, so the parked
    # error surfaces here rather than being silently overwritten
    with pytest.raises(RuntimeError, match="async checkpoint write"):
        acp.save_async(2, {"w": np.zeros(2)})


def _tear(ckpt_dir, step):
    with open(os.path.join(ckpt_dir, f"step_{step}", "leaf_0.npy"),
              "r+b") as f:
        f.truncate(8)


def test_prune_counts_keep_last_over_intact_steps(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.ones(4, np.float32)}
    for s in (2, 4, 6):
        ckpt.save(d, s, tree, keep_last=0)      # no pruning yet
    _tear(d, 6)
    ckpt._prune(d, keep_last=1)
    # newest INTACT step survives; the torn-but-newer step_6 also stays
    # (it may be a concurrent mid-write); only step_2 is pruned
    assert sorted(ckpt.available_steps(d)) == [4, 6]
    assert ckpt.step_intact(d, 4)
    with pytest.warns(RuntimeWarning):
        assert ckpt.latest_step(d) == 4


# ---------------------------------------------------------------------------
# Elastic drills: 8 forced devices in a subprocess
# ---------------------------------------------------------------------------

def test_elastic_shrink_8_to_4_matches_fault_free():
    _run("""
    import tempfile, numpy as np, jax
    from repro.train.conv_trainer import ConvTrainer, ConvTrainerConfig
    from repro.train.supervisor import RunSupervisor

    cfg = dict(workload="cnn", total_steps=6, widths=[4], image=8,
               n_classes=4, batch=8, backend="xla_zero_free",
               ckpt_every=2, seed=0)
    with tempfile.TemporaryDirectory() as d:
        sup = RunSupervisor(
            ConvTrainerConfig(**cfg, ckpt_dir=d),
            devices_per_host=2, model_parallel=2,
            host_schedule={3: [2, 3]})      # 4 hosts -> lose 2 -> 8->4
        out = sup.run()
    rep = out["report"]
    assert rep["host_losses"] == 1, rep
    assert rep["meshes"] == [{"data": 4, "model": 2},
                             {"data": 2, "model": 2}], rep["meshes"]
    assert rep["recompiles"] == 1 and rep["steps_lost"] >= 1, rep
    assert [h["step"] for h in out["history"]][-1] == 6

    clean = ConvTrainer(ConvTrainerConfig(**cfg)).run()
    for a, b in zip(jax.tree_util.tree_leaves(out["state"]),
                    jax.tree_util.tree_leaves(clean["state"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    print("ok")
    """)
    # (assert inside the subprocess; _run already checks returncode)


def test_supervisor_mixed_storm_host_loss_nan_torn_ckpt():
    _run("""
    import os, tempfile, warnings, numpy as np, jax
    from repro.serve.faults import (FaultEvent, FaultInjector,
                                    FaultSchedule)
    from repro.train.conv_trainer import ConvTrainer, ConvTrainerConfig
    from repro.train.supervisor import RunSupervisor

    cfg = dict(workload="cnn", total_steps=8, widths=[4], image=8,
               n_classes=4, batch=8, backend="xla_zero_free",
               ckpt_every=2, seed=0)

    with tempfile.TemporaryDirectory() as d:
        class StormSupervisor(RunSupervisor):
            '''Tears the newest checkpoint right before the scheduled
            host loss fires, so recovery must fall back a step.'''
            torn = False

            def _hook(self):
                inner = super()._hook()

                def hook(step):
                    if step >= 5 and not StormSupervisor.torn:
                        StormSupervisor.torn = True
                        leaf = os.path.join(d, "step_4", "leaf_0.npy")
                        with open(leaf, "r+b") as f:
                            f.truncate(8)
                    inner(step)
                return hook

        inj = FaultInjector(FaultSchedule(
            [FaultEvent("train.cnn", 1, "nan_output")]))
        sup = StormSupervisor(
            ConvTrainerConfig(**cfg, ckpt_dir=d),
            devices_per_host=2, model_parallel=2,
            host_schedule={5: [3]}, injector=inj)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            out = sup.run()

    rep = out["report"]
    assert rep["host_losses"] == 1, rep
    assert rep["guard"]["nonfinite_steps"] == 1, rep["guard"]
    assert rep["guard"]["retries"] == 1, rep["guard"]
    # torn step_4 forced the restore back to step_2: 5 - 2 = 3 lost
    assert rep["steps_lost"] == 3, rep
    assert rep["meshes"] == [{"data": 4, "model": 2},
                             {"data": 3, "model": 2}], rep["meshes"]
    assert [h["step"] for h in out["history"]][-1] == 8

    clean = ConvTrainer(ConvTrainerConfig(**cfg)).run()
    for a, b in zip(jax.tree_util.tree_leaves(out["state"]),
                    jax.tree_util.tree_leaves(clean["state"])):
        assert np.all(np.isfinite(np.asarray(a)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    print("ok")
    """)


def test_supervisor_requires_ckpt_dir():
    with pytest.raises(ValueError, match="ckpt_dir"):
        from repro.train.supervisor import RunSupervisor
        RunSupervisor(_cnn_cfg())


# ---------------------------------------------------------------------------
# Deterministic scaffolding the elastic contract rests on
# ---------------------------------------------------------------------------

def test_conv_dataset_pure_in_seed_and_step():
    ds = ConvDataset(kind="cnn", batch=4, image=8, n_classes=4, seed=7)
    a = ds.batch_at(5)
    b = ConvDataset(kind="cnn", batch=4, image=8, n_classes=4,
                    seed=7).batch_at(5)
    np.testing.assert_array_equal(a["x"], b["x"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = ds.batch_at(6)
    assert not np.array_equal(a["x"], c["x"])


def test_host_failure_schedule_deterministic():
    a = host_failure_schedule(4, n_hosts=2, n_steps=8, rate=0.12)
    b = host_failure_schedule(4, n_hosts=2, n_steps=8, rate=0.12)
    assert a == b
    sched = training_schedule(4, workload="cnn", n_steps=8, rate=0.2,
                              kinds=("nan_output",))
    assert all(ev.site == "train.cnn" and ev.kind == "nan_output"
               for ev in sched.events)


def test_elastic_mesh_halves_model_axis():
    # one surviving device: mp halves 4 -> 2 -> 1 until it divides
    m = elastic_mesh(jax.devices()[:1], model_parallel=4)
    assert m.shape["model"] == 1 and m.shape["data"] == 1
    with pytest.raises(ValueError):
        elastic_mesh([], model_parallel=2)
