"""ConvSpec dispatch layer: every backend x odd geometries, plus the
fused-kernel structural guarantees (exactly ONE pallas_call per conv;
filter-grad peak memory no longer scales with K^2 input replication).

Gradient parity reference is `jax.grad` of `lax.conv_general_dilated`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecoflow
from repro.core.conv import ecoflow_conv
from repro.core.spec import (ConvSpec, available_backends, resolve_backend)
from repro.kernels import ops

from conftest import (assert_allclose,
                      count_pallas_calls as _count_pallas_calls,
                      max_intermediate_size as _max_intermediate_size,
                      pallas_grids as _pallas_grids)

BACKENDS = ["reference", "xla_zero_free", "pallas"]


# ---------------------------------------------------------------------------
# registry behavior
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert set(BACKENDS) <= set(available_backends())
    assert resolve_backend(None).name == "xla_zero_free"
    assert resolve_backend(False).name == "xla_zero_free"  # legacy bool
    assert resolve_backend(True).name == "pallas"
    assert resolve_backend("reference").name == "reference"
    with pytest.raises(ValueError, match="unknown conv backend"):
        resolve_backend("cuda")


def test_convspec_rejects_degenerate_geometry():
    """`ConvSpec.make` raises ValueError (NOT assert -- must survive
    `python -O`) on degenerate geometry; previously stride=0 surfaced as
    a ZeroDivisionError deep inside the phase math."""
    for kwargs in [dict(stride=0), dict(stride=(2, 0)), dict(stride=-1),
                   dict(padding=-1), dict(padding=(0, -2)),
                   dict(filter_shape=0), dict(dilation=0)]:
        with pytest.raises(ValueError):
            ConvSpec.make(**kwargs)
    with pytest.raises(ValueError, match="2 elements"):
        ConvSpec.make(stride=(1, 2, 3))
    # ... and through the public conv entry point.
    x = jnp.zeros((1, 5, 5, 2), jnp.float32)
    w = jnp.zeros((3, 3, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="stride"):
        ecoflow_conv(x, w, 0, 0)


def test_geometry_guards_are_valueerrors_not_asserts():
    """The too-small-input / missing-k guards of the zero-free paths are
    ValueErrors, so optimized bytecode cannot strip them."""
    from repro.kernels.dconv_forward import dconv_forward_pallas
    x = jnp.zeros((1, 3, 3, 2), jnp.float32)
    w = jnp.zeros((3, 3, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="too small"):
        ecoflow.dilated_forward_zero_free(x, w, stride=1, padding=0,
                                          dilation=4)
    with pytest.raises(ValueError, match="too small"):
        dconv_forward_pallas(x, w, stride=(1, 1), padding=(0, 0),
                             dilation=(4, 4), interpret=True)
    with pytest.raises(ValueError, match="required"):
        ecoflow.dilated_conv_filter_grad_zero_free(
            x, jnp.zeros((1, 1, 1, 2), jnp.float32), stride=(1, 1),
            padding=0, k=None)


def test_geometry_guards_survive_python_O():
    """End to end under `python -O` (asserts stripped): the geometry
    guards still fire as ValueErrors instead of letting the zero-free
    paths mis-slice."""
    import subprocess
    import sys
    code = (
        "import jax.numpy as jnp\n"
        "from repro.core import ecoflow\n"
        "from repro.core.spec import ConvSpec\n"
        "x = jnp.zeros((1, 3, 3, 2), jnp.float32)\n"
        "w = jnp.zeros((3, 3, 2, 2), jnp.float32)\n"
        "for fn in (lambda: ecoflow.dilated_forward_zero_free(\n"
        "               x, w, stride=1, padding=0, dilation=4),\n"
        "           lambda: ConvSpec.make(stride=0),\n"
        "           lambda: ecoflow.dilated_conv_filter_grad_zero_free(\n"
        "               x, x, stride=(1, 1), padding=0, k=None)):\n"
        "    try:\n"
        "        fn()\n"
        "    except ValueError:\n"
        "        pass\n"
        "    else:\n"
        "        raise SystemExit('guard did not fire under -O')\n"
        "print('OK')\n")
    proc = subprocess.run([sys.executable, "-O", "-c", code],
                          capture_output=True, text=True)
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr


def test_convspec_geometry():
    s = ConvSpec.make(stride=(2, 3), padding=(1, 0), filter_shape=(5, 4))
    assert s.out_size((11, 12)) == ((11 + 2 - 5) // 2 + 1, (12 - 4) // 3 + 1)
    assert s.input_size((4, 3)) == (2 * 3 + 5 - 2, 3 * 2 + 4)
    assert s.full_size((4, 3)) == (2 * 3 + 5, 3 * 2 + 4)
    assert s.n_phases == 6
    assert s.packed_phase_shape == (3, 2)
    # every tap in exactly one phase (the zero-free property)
    assert s.useful_taps() == 5 * 4
    # stride > K: phases beyond the filter extent are empty
    s2 = ConvSpec.make(stride=4, padding=0, filter_shape=2)
    assert s2.phase_filter_shape(3, 3) == (0, 0)
    assert s2.useful_taps() == 4


def test_convspec_tap_phase_geometry():
    """Stride x dilation general tap-phase bookkeeping: taps group by
    kx mod (S/gcd(S, D)), residues (kx*D) mod S are distinct within one
    period, every tap lands in exactly one (phase, slot), and the D == 1
    view coincides with the classic stride-phase properties."""
    s = ConvSpec.make(stride=4, filter_shape=3, dilation=2)   # gcd 2
    assert s.tap_phase_period == (2, 2)
    assert s.tap_phase_step == (1, 1)
    assert s.n_tap_phases == (2, 2)
    assert s.taps_per_phase == (2, 2)
    assert [s.tap_phase_residue(a, 0) for a in range(2)] == [0, 2]
    assert [s.tap_phase_base(a, 0) for a in range(2)] == [0, 0]
    s = ConvSpec.make(stride=3, filter_shape=3, dilation=2)   # coprime
    assert s.tap_phase_period == (3, 3) and s.tap_phase_step == (2, 2)
    assert [s.tap_phase_residue(a, 0) for a in range(3)] == [0, 2, 1]
    assert [s.tap_phase_base(a, 0) for a in range(3)] == [0, 0, 1]
    # D == 1 degenerates to the stride-phase view.
    s = ConvSpec.make(stride=(2, 3), filter_shape=(5, 4))
    assert s.tap_phase_period == s.stride
    assert s.tap_phase_step == (1, 1)
    assert s.taps_per_phase == s.packed_phase_shape
    assert s.n_tap_phases == (min(5, 2), min(4, 3))
    # S == 1: one phase holding every tap at spacing D.
    s = ConvSpec.make(stride=1, filter_shape=3, dilation=4)
    assert s.tap_phase_period == (1, 1) and s.n_tap_phases == (1, 1)
    assert s.taps_per_phase == (3, 3) and s.tap_phase_step == (4, 4)
    # Exhaustiveness: every tap kx in exactly one (phase, slot) pair.
    for S, D, K in [(4, 2, 5), (3, 2, 7), (6, 4, 5), (2, 2, 3)]:
        s = ConvSpec.make(stride=S, filter_shape=K, dilation=D)
        per, = set(s.tap_phase_period)
        kp, = set(s.taps_per_phase)
        seen = sorted(a + u * per
                      for a in range(s.n_tap_phases[0])
                      for u in range(kp) if a + u * per < K)
        assert seen == list(range(K)), (S, D, K, seen)


# ---------------------------------------------------------------------------
# odd geometries through every backend, vs jax.grad of the plain conv
# ---------------------------------------------------------------------------

# (name, B, (Nh, Nw), K, (sh, sw), (ph, pw), Ci, Co)
ODD_GEOMS = [
    ("stride_gt_k",        1, (14, 14), 2, (4, 4), (0, 0), 4, 3),
    ("stride8_gt_k",       1, (17, 17), 3, (8, 8), (0, 0), 3, 3),
    ("asym_stride_pad",    2, (12, 11), 3, (2, 3), (1, 0), 3, 4),
    ("asym_rect_input",    1, (9, 14),  4, (3, 2), (0, 1), 2, 5),
    ("cin_not_tile_mult",  1, (7, 7),   3, (2, 2), (1, 1), 129, 3),
    ("cout_not_tile_mult", 1, (7, 7),   3, (2, 2), (0, 0), 3, 5),
    ("non_exact_fit",      2, (10, 10), 3, (2, 2), (0, 0), 3, 4),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,B,N,K,S,P,Ci,Co", ODD_GEOMS)
def test_odd_geometry_grads_all_backends(rng, backend, name, B, N, K, S, P,
                                         Ci, Co):
    Nh, Nw = N
    sh, sw = S
    ph, pw = P
    Oh = (Nh + 2 * ph - K) // sh + 1
    Ow = (Nw + 2 * pw - K) // sw + 1
    x = jnp.asarray(rng.normal(size=(B, Nh, Nw, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, Oh, Ow, Co)), jnp.float32)

    def plain(x_, w_):
        return jax.lax.conv_general_dilated(
            x_, w_, (sh, sw), [(ph, ph), (pw, pw)],
            dimension_numbers=ecoflow.DN)

    _, vjp = jax.vjp(plain, x, w)
    dx_ref, dw_ref = vjp(dy)

    def loss(x_, w_):
        return jnp.vdot(ecoflow_conv(x_, w_, (sh, sw), (ph, pw), backend),
                        dy)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert_allclose(dx, dx_ref, rtol=2e-4, atol=2e-4,
                    err_msg=f"{name}/{backend} dx")
    assert_allclose(dw, dw_ref, rtol=2e-4, atol=2e-4,
                    err_msg=f"{name}/{backend} dw")


# ---------------------------------------------------------------------------
# structural guarantees of the fused Pallas path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [2, 4, 8])
def test_tconv_single_pallas_launch(rng, S):
    """The fused transposed conv issues exactly ONE pallas_call per conv,
    for every stride the paper evaluates -- and its output matches the
    multi-launch xla_zero_free formulation."""
    B, O, K, Ci, Co = 1, 5, 3, 4, 4
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    N = S * (O - 1) + K
    fn = lambda dy_, w_: ops.tconv_phase(dy_, w_, stride=(S, S),
                                         padding=(0, 0), n_out=(N, N))
    assert _count_pallas_calls(fn, dy, w) == 1
    got = fn(dy, w)
    want = ecoflow.transposed_conv_zero_free(dy, w, stride=(S, S),
                                             padding=(0, 0), n_out=(N, N))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_filter_grad_single_pallas_launch(rng):
    B, N, K, S, Ci, Co = 1, 9, 3, 2, 4, 4
    O = (N - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    fn = lambda x_, dy_: ops.dconv_filter_grad(x_, dy_, stride=(S, S),
                                               padding=(0, 0), k=(K, K))
    assert _count_pallas_calls(fn, x, dy) == 1


def test_backward_pass_is_single_fused_launch(rng):
    """One training conv backward = ONE fused dual-output launch (dx and
    dW from the same dy fetch, kernels/dconv_backward.py) -- down from
    the 1 tconv + 1 filter-grad pair of earlier revisions."""
    x = jnp.asarray(rng.normal(size=(1, 9, 9, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 4)), jnp.float32)
    loss = lambda x_, w_: jnp.sum(ecoflow_conv(x_, w_, 2, 0, "pallas") ** 2)
    g = lambda x_, w_: jax.grad(loss, argnums=(0, 1))(x_, w_)
    assert _count_pallas_calls(g, x, w) == 1


def test_filter_grad_batch_sequential_no_hbm_partials(rng):
    """Batch is an IN-KERNEL sequential accumulation axis: the grid is
    (Cin_t, Cout_t, B, SP, T'), the single pallas output is the
    (T, Cin, Cout) gradient itself -- no (B, T, Cin, Cout) HBM partial
    slab anywhere in the jaxpr and no host-side batch reduction (the
    out block is stationary across every (B, SP, tap) step).  The
    padded-input block's index map still ignores the tap axis, so the
    PR 2 B>1 re-fetch cannot recur.  Gradient matches `reference`."""
    B, N, K, S, Ci, Co = 3, 9, 2, 2, 4, 4
    T = K * K
    O = (N - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    fn = lambda x_, dy_: ops.dconv_filter_grad(x_, dy_, stride=(S, S),
                                               padding=(0, 0), k=(K, K))
    grids = _pallas_grids(fn, x, dy)
    assert len(grids) == 1
    grid = grids[0]
    # grid = (Cin_t, Cout_t, B, SP, T'): batch is the third, SEQUENTIAL
    # axis (inside the output-tile axes, outside the tap axis).
    assert len(grid) == 5 and grid[2] == B, grid
    # No (B, T, Cin, Cout) partial slab in the traced computation ...
    from conftest import walk_eqns
    jaxpr = jax.make_jaxpr(fn)(x, dy)
    for e in walk_eqns(jaxpr.jaxpr):
        for v in e.outvars:
            shape = getattr(v.aval, "shape", ())
            assert tuple(shape[:2]) != (B, T), (e.primitive, shape)
        # ... and no host-side batch `sum` after the launch.
        assert e.primitive.name != "reduce_sum", e

    dw = fn(x, dy)
    be = resolve_backend("reference")
    spec = ConvSpec.make(stride=S, padding=0, filter_shape=K)
    want = be.filter_grad(x, dy, spec)
    assert_allclose(dw, want, rtol=1e-4, atol=1e-4)


# (name, B, N, K, S, P, Ci, Co): B > 1 and channels that are NOT
# multiples of any planner tile the pallas path might choose -- Cin/Cout
# above 128 force a 128 tile with a ragged remainder through the
# planner itself, not just through explicit test tiles.
FILTER_GRAD_RAGGED_GEOMS = [
    ("ragged_cin", 2, 7, 3, 2, 1, 130, 3),
    ("ragged_cout", 3, 7, 3, 2, 0, 3, 131),
    ("ragged_both_b3", 3, 5, 2, 1, 0, 29, 21),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,B,N,K,S,P,Ci,Co", FILTER_GRAD_RAGGED_GEOMS)
def test_filter_grad_ragged_batched_all_backends(rng, backend, name, B, N,
                                                 K, S, P, Ci, Co):
    """Filter-grad parity at B > 1 with ragged channel counts, through
    every backend's dispatch path (the pallas planner must keep the
    in-kernel batch accumulation and channel pad/slice exact)."""
    O = (N + 2 * P - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K)
    got = resolve_backend(backend).filter_grad(x, dy, spec)
    want = resolve_backend("reference").filter_grad(x, dy, spec)
    assert_allclose(got, want, rtol=2e-4, atol=2e-4,
                    err_msg=f"{name}/{backend}")


def test_filter_grad_memory_not_k2_replicated(rng):
    """Peak intermediate size of the filter gradient is bounded by a small
    multiple of the padded input -- NOT the K^2-replicated x_taps stack of
    the old formulation (121x the strided gather for K=11)."""
    B, N, K, S, P, Ci, Co = 1, 23, 11, 4, 2, 8, 8
    O = (N + 2 * P - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    fn = lambda x_, dy_: ops.dconv_filter_grad(x_, dy_, stride=(S, S),
                                               padding=(P, P), k=(K, K))
    old_stack_elems = K * K * B * O * O * Ci          # x_taps of the old path
    padded_in_elems = B * (N + 2 * P) ** 2 * Ci
    peak = _max_intermediate_size(fn, x, dy)
    assert peak < old_stack_elems, (peak, old_stack_elems)
    assert peak <= 4 * padded_in_elems, (peak, padded_in_elems)
