"""Deterministic fault injection + the backend degradation seam.

Covers `serve/faults.py` (seeded schedules replay exactly; injectors
raise/delay/poison on schedule), the `core/spec.py::fallback_backend`
ladder (a failing rung degrades, the observer sees it, a fully-failing
ladder re-raises), the tile-cache corruption helper against
`kernels.tiling.warmup_plans` (warn-and-replan, never crash), and the
shared host-failure schedule in `train/fault_tolerance.py`.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from conftest import assert_allclose
from repro.core.spec import ConvSpec, fallback_backend, resolve_backend
from repro.serve.faults import (FAULT_KINDS, FaultEvent, FaultInjector,
                                FaultSchedule, InjectedKernelFault,
                                corrupt_tile_cache, inject_backend)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def test_seeded_schedule_replays_exactly():
    kw = dict(sites=["a:pallas", "b:pallas"], rate=0.3, horizon=64)
    s1 = FaultSchedule.seeded(7, **kw)
    s2 = FaultSchedule.seeded(7, **kw)
    assert s1.events == s2.events
    assert len(s1) > 0
    # a different seed produces a different schedule (holds for these
    # fixed seeds; both draws are pure functions of their seed)
    s3 = FaultSchedule.seeded(8, **kw)
    assert s1.events != s3.events


def test_seeded_schedule_validation():
    with pytest.raises(ValueError):
        FaultSchedule.seeded(0, sites=["s"], rate=1.5)
    with pytest.raises(ValueError):
        FaultSchedule.seeded(0, sites=["s"], rate=0.5, kinds=("bogus",))
    with pytest.raises(ValueError):
        FaultEvent("s", 0, "bogus")


def test_injector_counters_and_fired_log():
    sched = FaultSchedule([FaultEvent("s", 1, "nan_output"),
                           FaultEvent("t", 0, "inf_output")])
    inj = FaultInjector(sched)
    assert inj.step("s") is None              # s#0 clean
    ev = inj.step("s")                        # s#1 fires
    assert ev is not None and ev.kind == "nan_output"
    assert inj.step("s") is None              # s#2 clean (past horizon)
    assert inj.step("t").kind == "inf_output"
    assert [e.kind for e in inj.fired] == ["nan_output", "inf_output"]


def test_raise_or_delay_and_poison():
    sched = FaultSchedule([FaultEvent("s", 0, "kernel_exception"),
                           FaultEvent("s", 1, "nan_output"),
                           FaultEvent("s", 2, "latency_spike",
                                      magnitude=0.0)])
    inj = FaultInjector(sched)
    with pytest.raises(InjectedKernelFault):
        inj.raise_or_delay("s")
    ev = inj.raise_or_delay("s")              # output-class: returned
    assert ev.kind == "nan_output"
    out = inj.poison(ev, np.ones((2, 3), np.float32))
    assert np.isnan(out[0, 0]) and np.isnan(out[1, 0])
    assert out[0, 1] == 1.0                   # only one element per row
    assert inj.raise_or_delay("s") is None    # latency spike: slept, clean
    assert inj.poison(None, np.ones(3)) is not None  # no-op path


# ---------------------------------------------------------------------------
# The spec.py degradation seam
# ---------------------------------------------------------------------------

def _geom(rng):
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=4)
    x = rng.standard_normal((2, 8, 8, 3), np.float32)
    w = rng.standard_normal((4, 4, 3, 5), np.float32)
    return spec, jax.numpy.asarray(x), jax.numpy.asarray(w)


def test_fallback_backend_degrades_and_notifies(rng):
    spec, x, w = _geom(rng)
    # A rung that ALWAYS raises (kernel_exception on every invocation).
    always = FaultInjector(FaultSchedule.seeded(
        3, sites=[f"xla_zero_free.{op}" for op in
                  ("forward", "input_grad", "filter_grad", "backward",
                   "ct_backward", "forward_ep", "input_grad_ep",
                   "backward_ep", "ct_backward_ep")],
        rate=1.0, horizon=512, kinds=("kernel_exception",)))
    broken = inject_backend("xla_zero_free", always)
    seen = []
    ladder = fallback_backend(
        (broken, "reference"),
        on_fallback=lambda name, op, exc: seen.append((name, op)))
    y = ladder.forward(x, w, spec)
    ref = resolve_backend("reference").forward(x, w, spec)
    assert_allclose(y, ref)
    assert seen == [("xla_zero_free@inject", "forward")]
    # fused method routing: a rung without fused kernels still serves
    dx, dw = ladder.backward(x, ref, w, spec, (8, 8))
    assert dx.shape == x.shape and dw.shape == w.shape
    assert ("xla_zero_free@inject", "backward") in seen


def test_fallback_backend_exhausted_reraises(rng):
    spec, x, w = _geom(rng)
    always = FaultInjector(FaultSchedule.seeded(
        3, sites=["reference.forward"], rate=1.0, horizon=64,
        kinds=("kernel_exception",)))
    broken = inject_backend("reference", always)
    ladder = fallback_backend((broken,))
    with pytest.raises(InjectedKernelFault):
        ladder.forward(x, w, spec)
    with pytest.raises(ValueError):
        fallback_backend(())


def test_resolve_backend_accepts_tuple_and_memoizes(rng):
    spec, x, w = _geom(rng)
    a = resolve_backend(("pallas", "xla_zero_free", "reference"))
    b = resolve_backend(("pallas", "xla_zero_free", "reference"))
    assert a is b                  # memoized: stable identity for caches
    assert a.name == "pallas>xla_zero_free>reference"
    assert_allclose(a.forward(x, w, spec),
                    resolve_backend("reference").forward(x, w, spec))


def test_inject_backend_poisons_outputs(rng):
    spec, x, w = _geom(rng)
    inj = FaultInjector(FaultSchedule([
        FaultEvent("reference.forward", 0, "inf_output")]))
    be = inject_backend("reference", inj)
    y = np.asarray(be.forward(x, w, spec))
    assert not np.all(np.isfinite(y))
    y2 = np.asarray(be.forward(x, w, spec))   # next invocation clean
    assert np.all(np.isfinite(y2))


# ---------------------------------------------------------------------------
# Tile-cache corruption vs the warmup path
# ---------------------------------------------------------------------------

def _warmup_entries():
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=4)
    return [("input_grad", spec, (2, 8, 8, 3), (2, 4, 4, 5))]


@pytest.mark.parametrize("mode", ["truncate", "garbage", "torn_row"])
def test_corrupt_tile_cache_warn_and_replan(tmp_path, mode):
    from repro.kernels import tiling
    path = tmp_path / "tile_cache.json"
    # seed a valid artifact first so every corruption mode has a victim
    entry = _warmup_entries()[0]
    st, plan = tiling.plan_strategy(entry[0], entry[1],
                                    x_shape=entry[2], dy_shape=entry[3])
    key = tiling._cache_key(entry[0], entry[1], entry[2], entry[3], 4,
                            tiling.DEFAULT_VMEM_BUDGET, False, None, "auto")
    path.write_text(__import__("json").dumps(
        {key: dict(plan.as_dict(), strategy=st)}))
    corrupt_tile_cache(path, mode)
    with pytest.warns(RuntimeWarning):
        plans = tiling.warmup_plans(_warmup_entries(), tile_cache_path=path)
    assert len(plans) == 1
    (info,) = plans.values()
    assert info["source"] == "analytical"
    assert info["strategy"] in tiling.STRATEGIES
    with pytest.raises(ValueError):
        corrupt_tile_cache(path, "bogus")


def test_warmup_plans_replays_artifact(tmp_path):
    from repro.kernels import tiling
    path = tmp_path / "tile_cache.json"
    entry = _warmup_entries()[0]
    st, plan = tiling.plan_strategy(entry[0], entry[1],
                                    x_shape=entry[2], dy_shape=entry[3])
    key = tiling._cache_key(entry[0], entry[1], entry[2], entry[3], 4,
                            tiling.DEFAULT_VMEM_BUDGET, False, None, "auto")
    path.write_text(__import__("json").dumps(
        {key: dict(plan.as_dict(), strategy=st, us=12.0)}))
    plans = tiling.warmup_plans(_warmup_entries(), tile_cache_path=path)
    (info,) = plans.values()
    assert info["source"] == "artifact"
    assert info["strategy"] == st
    assert info["plan"].cin_tile == plan.cin_tile


def test_warmup_plans_missing_artifact_is_analytical(tmp_path):
    from repro.kernels import tiling
    plans = tiling.warmup_plans(
        _warmup_entries(), tile_cache_path=tmp_path / "absent.json")
    (info,) = plans.values()
    assert info["source"] == "analytical"
    assert info["plan"] is not None


# ---------------------------------------------------------------------------
# Shared schedule: training host losses from the same registry
# ---------------------------------------------------------------------------

def test_host_failure_schedule_deterministic():
    from repro.train.fault_tolerance import host_failure_schedule
    a = host_failure_schedule(11, n_hosts=4, n_steps=50, rate=0.1)
    b = host_failure_schedule(11, n_hosts=4, n_steps=50, rate=0.1)
    assert a == b
    assert a                                   # fires at rate 0.1 over 200
    for step, hosts in a.items():
        assert 0 <= step < 50
        assert hosts == sorted(hosts)
        assert all(0 <= h < 4 for h in hosts)


def test_fault_kinds_closed_set():
    # the engine, the bench fault arm, and the docs all enumerate kinds;
    # growing the set must be a conscious change
    assert set(FAULT_KINDS) == {"kernel_exception", "device_loss",
                                "latency_spike", "nan_output",
                                "inf_output"}
