"""Property-based backend-parity grid for the dilated-conv dataflows.

Hypothesis-driven (real install or tests/_hypothesis_shim.py fallback)
sampling of (stride, dilation, K, padding, B, Cin, Cout, odd n) asserting
forward + gradient parity of every backend against `reference` (= jax.grad
of `lax.conv_general_dilated` with `rhs_dilation`) -- including the
GENERAL strided+dilated (S > 1 AND D > 1) input gradient, which the
unified (phase, tap) kernel now runs fused -- plus the structural
guarantees of the zero-free paths: exactly ONE `pallas_call` per dilated
forward and per input gradient, no scatter, and no materialized dilation
zeros anywhere in the zero-free lowerings (no lhs-/rhs-dilated conv
primitive, no intermediate at the dilated-filter extent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ecoflow
from repro.core.conv import ecoflow_dilated_conv
from repro.core.spec import ConvSpec, resolve_backend
from repro.kernels import ops

from conftest import (assert_allclose, walk_eqns as _walk_eqns,
                      count_pallas_calls as _count_pallas_calls)

BACKENDS = ["reference", "xla_zero_free", "pallas"]


def _reference(x, w, S, P, D):
    return jax.lax.conv_general_dilated(
        x, w, (S, S), [(P, P), (P, P)], rhs_dilation=(D, D),
        dimension_numbers=ecoflow.DN)


def _case(seed, B, N, K, S, P, D, Ci, Co):
    rng = np.random.default_rng(seed)
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K, dilation=D)
    Oh, Ow = spec.out_size((N, N))
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, Oh, Ow, Co)), jnp.float32)
    return spec, x, w, dy


# ---------------------------------------------------------------------------
# the property grid: every backend == reference, forward and both grads
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31), s=st.sampled_from([1, 1, 2, 3]),
       d=st.sampled_from([2, 3, 4]), k=st.sampled_from([2, 3]),
       p=st.integers(0, 2), b=st.sampled_from([1, 2]),
       ci=st.sampled_from([1, 3]), co=st.sampled_from([1, 4]),
       extra=st.integers(0, 4))
def test_dilated_parity_grid(seed, s, d, k, p, b, ci, co, extra):
    """Forward/dx/dw of every backend match `reference` to fp32 tolerance
    over random (stride, dilation, K, padding, B, Cin, Cout, odd n)."""
    k_eff = d * (k - 1) + 1
    n = k_eff + s + extra           # guarantees Oh >= 2, incl. odd sizes
    spec, x, w, dy = _case(seed, b, n, k, s, p, d, ci, co)

    y_ref = _reference(x, w, s, p, d)
    _, vjp = jax.vjp(lambda x_, w_: _reference(x_, w_, s, p, d), x, w)
    dx_ref, dw_ref = vjp(dy)

    for backend in BACKENDS:
        y = ecoflow_dilated_conv(x, w, s, p, d, backend)
        assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4,
                        err_msg=f"{backend} forward "
                                f"(s={s},d={d},k={k},p={p},n={n})")
        loss = lambda x_, w_, be=backend: jnp.vdot(
            ecoflow_dilated_conv(x_, w_, s, p, d, be), dy)
        dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
        assert_allclose(dx, dx_ref, rtol=2e-4, atol=2e-4,
                        err_msg=f"{backend} dx "
                                f"(s={s},d={d},k={k},p={p},n={n})")
        assert_allclose(dw, dw_ref, rtol=2e-4, atol=2e-4,
                        err_msg=f"{backend} dw "
                                f"(s={s},d={d},k={k},p={p},n={n})")


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 31), s=st.sampled_from([2, 3]),
       d=st.sampled_from([2, 3]), k=st.sampled_from([2, 3]),
       p=st.integers(0, 2), b=st.sampled_from([2, 3]),
       ci=st.sampled_from([1, 3]), co=st.sampled_from([1, 4]),
       extra=st.integers(0, 4))
def test_strided_dilated_input_grad_parity_grid(seed, s, d, k, p, b, ci,
                                                co, extra):
    """The GENERAL strided+dilated (S > 1 AND D > 1) input gradient --
    previously the XLA scatter fallback on the `pallas` backend -- matches
    `reference` on every backend over random (S, D, K, padding, B > 1,
    Cin, Cout, odd n) geometries, both through the backend interface and
    through `jax.grad`."""
    k_eff = d * (k - 1) + 1
    n = k_eff + s + extra           # guarantees Oh >= 2, incl. odd sizes
    spec, x, w, dy = _case(seed, b, n, k, s, p, d, ci, co)

    _, vjp = jax.vjp(lambda x_: _reference(x_, w, s, p, d), x)
    dx_ref, = vjp(dy)
    for backend in BACKENDS:
        dx = resolve_backend(backend).input_grad(dy, w, spec, (n, n))
        assert_allclose(dx, dx_ref, rtol=2e-4, atol=2e-4,
                        err_msg=f"{backend} input_grad "
                                f"(s={s},d={d},k={k},p={p},b={b},n={n})")
        loss = lambda x_, be=backend: jnp.vdot(
            ecoflow_dilated_conv(x_, w, s, p, d, be), dy)
        dx_g = jax.grad(loss)(x)
        assert_allclose(dx_g, dx_ref, rtol=2e-4, atol=2e-4,
                        err_msg=f"{backend} grad dx "
                                f"(s={s},d={d},k={k},p={p},b={b},n={n})")


def test_convspec_accepts_dilation():
    """`ConvSpec.make(dilation=2)` constructs (the old reserved-geometry
    rejection is gone) and derives the effective receptive field."""
    s = ConvSpec.make(stride=1, padding=2, filter_shape=3, dilation=2)
    assert s.dilated_filter_shape == (5, 5)
    assert s.out_size((13, 13)) == (13, 13)         # atrous same-padding
    assert s.input_size((13, 13)) == (13, 13)
    s2 = ConvSpec.make(stride=(2, 1), padding=0, filter_shape=(3, 2),
                       dilation=(2, 4))
    assert s2.dilated_filter_shape == (5, 5)
    with pytest.raises(ValueError, match="dilation"):
        ConvSpec.make(dilation=0)


# ---------------------------------------------------------------------------
# structural guarantees of the zero-free paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,D", [(1, 2), (1, 4), (2, 2)])
def test_dilated_forward_single_pallas_launch(rng, S, D):
    """Exactly ONE pallas_call per dilated forward on the pallas backend,
    and its output matches the dense xla_zero_free decomposition."""
    K, Ci, Co = 3, 3, 4
    N = D * (K - 1) + 1 + 2 * S
    x = jnp.asarray(rng.normal(size=(1, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    fn = lambda x_, w_: ops.dconv_forward(x_, w_, stride=(S, S),
                                          padding=(0, 0), dilation=(D, D))
    assert _count_pallas_calls(fn, x, w) == 1
    got = fn(x, w)
    want = ecoflow.dilated_forward_zero_free(x, w, stride=(S, S),
                                             padding=(0, 0),
                                             dilation=(D, D))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,P", [(1, 2), (2, 1)])
def test_dilated_backward_stays_fused(rng, S, P):
    """Atrous conv backward on the `pallas` backend: the forward is one
    fused launch and the ENTIRE backward (input-grad AND filter-grad,
    stride 1 and the general strided case alike) is one fused
    dual-output launch -- a full jax.grad traces exactly 2 pallas_calls
    (down from 3 before the fused dual-gradient backward)."""
    K, D, Ci, Co = 3, 2, 3, 3
    N = 11
    x = jnp.asarray(rng.normal(size=(1, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    loss = lambda x_, w_: jnp.sum(
        ecoflow_dilated_conv(x_, w_, S, P, D, "pallas") ** 2)
    g = lambda x_, w_: jax.grad(loss, argnums=(0, 1))(x_, w_)
    assert _count_pallas_calls(g, x, w) == 2


@pytest.mark.parametrize("S,D", [(2, 2), (2, 3), (3, 2), (3, 3)])
def test_strided_dilated_input_grad_single_launch(rng, S, D):
    """Structural pin of the tentpole: the general strided+dilated input
    gradient on the `pallas` backend executes as exactly ONE pallas_call,
    with NO scatter and NO lhs-/rhs-dilated conv anywhere in the traced
    jaxpr (no materialized dilation zeros of either kind) -- and matches
    the multi-launch xla_zero_free decomposition it replaced."""
    K, P, Ci, Co = 3, 1, 3, 4
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K, dilation=D)
    O = 4
    n_out = spec.input_size((O, O))
    dy = jnp.asarray(rng.normal(size=(2, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    fn = lambda dy_, w_: resolve_backend("pallas").input_grad(
        dy_, w_, spec, n_out)
    assert _count_pallas_calls(fn, dy, w) == 1
    jaxpr = jax.make_jaxpr(fn)(dy, w)
    for e in _walk_eqns(jaxpr.jaxpr):
        assert not e.primitive.name.startswith("scatter"), (
            f"(S={S},D={D}): scatter in the fused pallas input-grad path")
        if e.primitive.name == "conv_general_dilated":
            assert tuple(e.params["rhs_dilation"]) == (1, 1), (S, D)
            assert tuple(e.params["lhs_dilation"]) == (1, 1), (S, D)
    got = fn(dy, w)
    want = resolve_backend("xla_zero_free").input_grad(dy, w, spec, n_out)
    assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                    err_msg=f"pallas vs xla_zero_free (S={S},D={D})")


@pytest.mark.parametrize("backend", ["xla_zero_free", "pallas"])
def test_no_materialized_dilation_zeros(rng, backend):
    """The zero-free paths never build the dilated filter: no conv
    primitive with rhs_dilation != 1 appears in the traced forward or
    backward jaxpr, and no intermediate has the dilated-filter extent
    (K_eff, K_eff, ...)."""
    K, S, D, P, Ci, Co = 3, 1, 4, 4, 3, 5
    k_eff = D * (K - 1) + 1
    N = k_eff + 4
    spec, x, w, dy = _case(0, 2, N, K, S, P, D, Ci, Co)

    def fwd(x_, w_):
        return ecoflow_dilated_conv(x_, w_, S, P, D, backend)

    def grads(x_, w_):
        return jax.grad(lambda a, b: jnp.vdot(fwd(a, b), dy),
                        argnums=(0, 1))(x_, w_)

    for fn in (fwd, grads):
        jaxpr = jax.make_jaxpr(fn)(x, w)
        for e in _walk_eqns(jaxpr.jaxpr):
            if e.primitive.name == "conv_general_dilated":
                assert tuple(e.params["rhs_dilation"]) == (1, 1), (
                    f"{backend}: materialized-filter dilated conv in "
                    f"{fn.__name__}")
                assert tuple(e.params["lhs_dilation"]) == (1, 1), (
                    f"{backend}: materialized input dilation in "
                    f"{fn.__name__}")
            for v in e.outvars:
                shape = getattr(v.aval, "shape", ())
                assert tuple(shape[:2]) != (k_eff, k_eff), (
                    f"{backend}: intermediate at the dilated-filter "
                    f"extent in {fn.__name__}: {shape}")


def test_dilated_input_grad_honors_n_out(rng):
    """Backend-interface contract: input_grad crops/pads to ANY requested
    n_out identically on every backend -- the unified pallas kernel's
    wrapper must crop/pad rather than silently return its natural
    extent."""
    K, S, P, D, Ci, Co = 3, 1, 1, 2, 2, 3
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K, dilation=D)
    N = 11
    Oh, Ow = spec.out_size((N, N))
    dy = jnp.asarray(rng.normal(size=(1, Oh, Ow, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    # `reference` needs a consistent n_out (it round-trips through
    # jax.vjp); the zero-free backends crop/pad to whatever is asked.
    for n_out in [(N, N), (N - 2, N - 2), (N + 1, N + 1)]:
        outs = [resolve_backend(be).input_grad(dy, w, spec, n_out)
                for be in ("xla_zero_free", "pallas")]
        for be, dx in zip(("xla_zero_free", "pallas"), outs):
            assert dx.shape == (1, *n_out, Ci), (be, n_out, dx.shape)
        assert_allclose(outs[1], outs[0], rtol=1e-5, atol=1e-5,
                        err_msg=f"pallas vs xla_zero_free n_out={n_out}")


def test_dilated_conv_bf16(rng):
    """bf16 inputs accumulate in fp32 on every backend (DESIGN Sec 2.3)."""
    spec, x, w, dy = _case(3, 1, 9, 3, 1, 2, 2, 4, 4)
    x, w = x.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
    y_ref = ecoflow.direct_conv(x, w, 1, 2, dilation=2)
    for backend in ("xla_zero_free", "pallas"):
        y = ecoflow_dilated_conv(x, w, 1, 2, 2, backend)
        assert y.dtype == jnp.bfloat16
        assert_allclose(y, y_ref, rtol=5e-2, atol=5e-2, err_msg=backend)
