"""Multi-device tests: run in a subprocess with 8 forced host devices so
the main test process keeps the default single CPU device (the dry-run's
512-device setting is likewise process-local).

Covers: sharding-rule inference on a real mesh, sharded train step
numerics vs single-device, the GPipe ppermute pipeline, elastic-mesh
resharding restore, and a miniature dry-run (lower+compile with
in/out shardings).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src",
           JAX_PLATFORMS="cpu")


def _run(body: str, timeout=600):
    code = textwrap.dedent(body)
    p = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_sharding_rules_on_mesh():
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.parallel import sharding as sh

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    # FSDP+TP rule: (D, F) weight shards (fsdp, tp)
    spec = sh.leaf_pspec("blocks/mlp/wi", (64, 128), mesh)
    assert spec == P("data", "model"), spec
    # divisibility guard: odd dim stays unsharded
    spec = sh.leaf_pspec("blocks/mlp/wi", (63, 128), mesh)
    assert spec == P(None, "model"), spec
    # expert dim over model axis (EP)
    spec = sh.leaf_pspec("blocks/moe/experts_wi", (8, 64, 128), mesh)
    assert spec == P("model", "data", None), spec
    # vocab sharding
    spec = sh.leaf_pspec("embed/tok", (512, 64), mesh)
    assert spec == P("model", "data"), spec
    # scalars/norms replicated (P() and P(None) are equivalent)
    spec = sh.leaf_pspec("final_norm/scale", (64,), mesh)
    assert spec in (P(), P(None)), spec
    # leading scan dim stays unsharded
    spec = sh.leaf_pspec("blocks/attn/wq", (4, 64, 128), mesh)
    assert spec == P(None, "data", "model"), spec
    print("ok")
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_train_step
    from repro.models.lm import LM
    from repro.optim.optimizer import AdamWConfig, adamw_init
    from repro.parallel import sharding as sh

    cfg = get_smoke_config("qwen2_1_5b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0)
    opt = adamw_init(params, ocfg)
    rng = np.random.default_rng(0)
    batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                   jnp.int32)}
    step = make_train_step(cfg, ocfg)
    p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    with mesh, sh.use_mesh(mesh):
        p_sh = sh.tree_shardings(params, mesh)
        o_sh = sh.tree_shardings(opt, mesh)
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, o_sh)
        batch_s = jax.device_put(batch, NamedSharding(
            mesh, sh.batch_pspec(mesh, 2, 0, 8)))
        p_out, _, m_out = jax.jit(step, in_shardings=(p_sh, o_sh, None),
                                  out_shardings=(p_sh, o_sh, None))(
            params_s, opt_s, batch_s)
    la, lb = float(m_out["loss"]), float(m_ref["loss"])
    assert abs(la - lb) / max(abs(lb), 1.0) < 1e-3, (la, lb)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=2e-2, atol=2e-2)
    print("ok")
    """)


def test_gpipe_pipeline_matches_sequential():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.parallel.pipeline import gpipe

    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = Mesh(np.asarray(jax.devices()[:n_stages]).reshape(n_stages),
                ("stage",))
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(size=(n_stages, d, d)) / np.sqrt(d),
                     jnp.float32)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)

    def stage_fn(W, h):
        return jnp.tanh(h @ W)

    y = gpipe(mesh, "stage", stage_fn, Ws, x, n_micro)
    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ Ws[s])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
    print("ok")
    """)


def test_elastic_restore_across_meshes():
    _run("""
    import jax, numpy as np, jax.numpy as jnp, tempfile
    from jax.sharding import Mesh, NamedSharding
    from repro.configs import get_smoke_config
    from repro.models.lm import LM
    from repro.parallel import sharding as sh
    from repro.train import checkpoint as ckpt
    from repro.train.fault_tolerance import elastic_mesh, survivors

    cfg = get_smoke_config("gemma_2b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    mesh8 = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                 ("data", "model"))
    params8 = jax.device_put(params, sh.tree_shardings(params, mesh8))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"params": params8})
        # two "hosts" of 4 devices; host 1 fails -> 4 survivors
        surv = survivors(mesh8, [1], devices_per_host=4)
        assert len(surv) == 4
        mesh4 = elastic_mesh(surv, model_parallel=2)
        assert mesh4.devices.size == 4
        like = {"params": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)}
        shard4 = {"params": sh.tree_shardings(params, mesh4)}
        out = ckpt.restore(d, 1, like, shard4)
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(out["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ok")
    """)


def test_mini_dryrun_lower_compile():
    """A miniature of the production dry-run: lower+compile a smoke arch
    on a (4,2) mesh with the exact production sharding logic, then check
    collectives exist in the HLO."""
    out = _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_smoke_config
    from repro.launch.steps import lower_cell
    from repro.launch import dryrun
    from repro.models.config import ShapeConfig
    import repro.launch.mesh as M

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                ("data", "model"))
    cfg = get_smoke_config("qwen3_moe_235b_a22b")
    shape = ShapeConfig("mini_train", 64, 8, "train")
    lowered = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    colls = dryrun.parse_collectives(compiled.as_text())
    total = sum(v["count"] for k, v in colls.items() if k != "group_sizes")
    assert total > 0, colls
    print("collectives:", total)

    shape_d = ShapeConfig("mini_decode", 64, 8, "decode")
    lowered = lower_cell(cfg, shape_d, mesh)
    lowered.compile()
    print("ok")
    """)
    assert "ok" in out


def test_serve_sharding_and_cache_rules():
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.parallel import sharding as sh
    from repro.launch.steps import cache_pspecs

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    # serve mode: plain matrices fold data into tp
    spec = sh.leaf_pspec("blocks/mlp/wi", (64, 128), mesh, serve=True)
    assert spec == P(None, ("model", "data")), spec
    spec = sh.leaf_pspec("blocks/mlp/wo", (128, 64), mesh, serve=True)
    assert spec == P(("model", "data"), None), spec
    # experts: E over model, FFN over data -- fully resident
    spec = sh.leaf_pspec("blocks/moe/experts_wi", (8, 64, 128), mesh,
                         serve=True)
    assert spec == P("model", None, "data"), spec
    # moe_ffn_data train variant
    spec = sh.leaf_pspec("blocks/moe/experts_wi", (8, 64, 128), mesh,
                         moe_ffn_data=True)
    assert spec == P("model", None, "data"), spec
    # KV cache: batch over data, SEQUENCE over model (flash-decoding)
    import jax.numpy as jnp
    cache = {"k": jax.ShapeDtypeStruct((2, 8, 64, 4, 16), jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((2, 8, 64, 4, 16), jnp.bfloat16),
             "len": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = cache_pspecs(cache, mesh)
    assert specs["k"] == P(None, "data", "model", None, None), specs["k"]
    print("ok")
    """)


def test_decode_lowering_has_no_cache_gather():
    """The Perf A1 fix at test scale: decode lowers with the cache
    sharded and without whole-cache all-gathers."""
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_smoke_config
    from repro.launch.steps import lower_cell
    from repro.launch import dryrun
    from repro.models.config import ShapeConfig

    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                ("data", "model"))
    cfg = get_smoke_config("gemma_7b").scaled(attn_chunk=32)
    shape = ShapeConfig("mini_decode", 64, 8, "decode")
    compiled = lower_cell(cfg, shape, mesh).compile()
    colls = dryrun.parse_collectives(compiled.as_text())
    # cache (layers, B, 64, H, D) bf16: a whole-cache gather would move
    # >= L*B*S*H*D*2 bytes; assert total gather volume stays well below.
    import math
    cache_bytes = cfg.n_layers * 8 * 64 * cfg.n_kv_heads * \
        cfg.head_dim * 2 * 2
    assert colls["all-gather"]["bytes"] < cache_bytes, \
        (colls["all-gather"], cache_bytes)
    print("ok")
    """)


def test_compressed_allreduce_across_pods():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.compression import compressed_psum

    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)  # per-pod grads
    e = jnp.zeros_like(g)
    f = shard_map(lambda gg, ee: compressed_psum(gg, "pod", ee),
                  mesh=mesh, in_specs=(P("pod"), P("pod")),
                  out_specs=(P("pod"), P("pod")))
    out, err = f(g, e)
    want = g.mean(axis=0)
    # each pod's shard now holds (approximately) the mean
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               rtol=0.15, atol=0.05)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]),
                               rtol=1e-6, atol=1e-6)
    print("ok")
    """)
