"""Multi-device tests: run in a subprocess with 8 forced host devices so
the main test process keeps the default single CPU device (the dry-run's
512-device setting is likewise process-local).

Covers: sharding-rule inference on a real mesh, sharded train step
numerics vs single-device, the GPipe ppermute pipeline, elastic-mesh
resharding restore, a miniature dry-run (lower+compile with in/out
shardings), and the conv stack (DESIGN.md Sec. 2.9): the structural
4-D conv-filter rule on real CNN/GAN trees, the batch_pspec size guard,
CNN/GAN train-step parity through the shard_map conv dispatch layer,
the plan-tiles-sees-local-shapes contract, and the
one-pallas_call-per-shard structural pin.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src",
           JAX_PLATFORMS="cpu")


def _run(body: str, timeout=600):
    code = textwrap.dedent(body)
    p = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_sharding_rules_on_mesh():
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.parallel import sharding as sh

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    # FSDP+TP rule: (D, F) weight shards (fsdp, tp)
    spec = sh.leaf_pspec("blocks/mlp/wi", (64, 128), mesh)
    assert spec == P("data", "model"), spec
    # divisibility guard: odd dim stays unsharded
    spec = sh.leaf_pspec("blocks/mlp/wi", (63, 128), mesh)
    assert spec == P(None, "model"), spec
    # expert dim over model axis (EP)
    spec = sh.leaf_pspec("blocks/moe/experts_wi", (8, 64, 128), mesh)
    assert spec == P("model", "data", None), spec
    # vocab sharding
    spec = sh.leaf_pspec("embed/tok", (512, 64), mesh)
    assert spec == P("model", "data"), spec
    # scalars/norms replicated (P() and P(None) are equivalent)
    spec = sh.leaf_pspec("final_norm/scale", (64,), mesh)
    assert spec in (P(), P(None)), spec
    # leading scan dim stays unsharded
    spec = sh.leaf_pspec("blocks/attn/wq", (4, 64, 128), mesh)
    assert spec == P(None, "data", "model"), spec
    print("ok")
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_train_step
    from repro.models.lm import LM
    from repro.optim.optimizer import AdamWConfig, adamw_init
    from repro.parallel import sharding as sh

    cfg = get_smoke_config("qwen2_1_5b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0)
    opt = adamw_init(params, ocfg)
    rng = np.random.default_rng(0)
    batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                   jnp.int32)}
    step = make_train_step(cfg, ocfg)
    p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    with mesh, sh.use_mesh(mesh):
        p_sh = sh.tree_shardings(params, mesh)
        o_sh = sh.tree_shardings(opt, mesh)
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, o_sh)
        batch_s = jax.device_put(batch, NamedSharding(
            mesh, sh.batch_pspec(mesh, 2, 0, 8)))
        p_out, _, m_out = jax.jit(step, in_shardings=(p_sh, o_sh, None),
                                  out_shardings=(p_sh, o_sh, None))(
            params_s, opt_s, batch_s)
    la, lb = float(m_out["loss"]), float(m_ref["loss"])
    assert abs(la - lb) / max(abs(lb), 1.0) < 1e-3, (la, lb)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=2e-2, atol=2e-2)
    print("ok")
    """)


def test_gpipe_pipeline_matches_sequential():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.parallel.pipeline import gpipe

    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = Mesh(np.asarray(jax.devices()[:n_stages]).reshape(n_stages),
                ("stage",))
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(size=(n_stages, d, d)) / np.sqrt(d),
                     jnp.float32)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)

    def stage_fn(W, h):
        return jnp.tanh(h @ W)

    y = gpipe(mesh, "stage", stage_fn, Ws, x, n_micro)
    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ Ws[s])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
    print("ok")
    """)


def test_elastic_restore_across_meshes():
    _run("""
    import jax, numpy as np, jax.numpy as jnp, tempfile
    from jax.sharding import Mesh, NamedSharding
    from repro.configs import get_smoke_config
    from repro.models.lm import LM
    from repro.parallel import sharding as sh
    from repro.train import checkpoint as ckpt
    from repro.train.fault_tolerance import elastic_mesh, survivors

    cfg = get_smoke_config("gemma_2b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    mesh8 = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                 ("data", "model"))
    params8 = jax.device_put(params, sh.tree_shardings(params, mesh8))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"params": params8})
        # two "hosts" of 4 devices; host 1 fails -> 4 survivors
        surv = survivors(mesh8, [1], devices_per_host=4)
        assert len(surv) == 4
        mesh4 = elastic_mesh(surv, model_parallel=2)
        assert mesh4.devices.size == 4
        like = {"params": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)}
        shard4 = {"params": sh.tree_shardings(params, mesh4)}
        out = ckpt.restore(d, 1, like, shard4)
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(out["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ok")
    """)


def test_elastic_mesh_non_power_of_two_survivors():
    """Losing 2 of 8 devices leaves 6: the TP axis halves until it
    divides the survivor count (16 -> 2 here, keeping TP a divisor of
    the original power-of-two layout), and every survivor is used."""
    _run("""
    import jax
    from repro.train.fault_tolerance import elastic_mesh, survivors
    from jax.sharding import Mesh
    import numpy as np

    devs = jax.devices()
    assert len(devs) == 8
    m6 = elastic_mesh(devs[:6], model_parallel=16)
    assert m6.shape["model"] == 2 and m6.shape["data"] == 3
    assert m6.devices.size == 6
    # 5 survivors: no even split exists, TP collapses to 1 (pure DP)
    m5 = elastic_mesh(devs[:5], model_parallel=4)
    assert m5.shape["model"] == 1 and m5.shape["data"] == 5
    # mp already divides: unchanged
    m8 = elastic_mesh(devs, model_parallel=4)
    assert m8.shape["model"] == 4 and m8.shape["data"] == 2
    # mp larger than the whole device set halves down into range
    m_big = elastic_mesh(devs[:6], model_parallel=64)
    assert m_big.shape["model"] == 2 and m_big.shape["data"] == 3
    # survivors() on a multi-host mesh: drop host 0 of 4x2-hosts
    mesh8 = Mesh(np.asarray(devs).reshape(4, 2), ("data", "model"))
    surv = survivors(mesh8, [0], devices_per_host=2)
    assert len(surv) == 6
    assert all(d.id >= 2 for d in surv)
    print("ok")
    """)


def test_mini_dryrun_lower_compile():
    """A miniature of the production dry-run: lower+compile a smoke arch
    on a (4,2) mesh with the exact production sharding logic, then check
    collectives exist in the HLO."""
    out = _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_smoke_config
    from repro.launch.steps import lower_cell
    from repro.launch import dryrun
    from repro.models.config import ShapeConfig
    import repro.launch.mesh as M

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                ("data", "model"))
    cfg = get_smoke_config("qwen3_moe_235b_a22b")
    shape = ShapeConfig("mini_train", 64, 8, "train")
    lowered = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    colls = dryrun.parse_collectives(compiled.as_text())
    total = sum(v["count"] for k, v in colls.items() if k != "group_sizes")
    assert total > 0, colls
    print("collectives:", total)

    shape_d = ShapeConfig("mini_decode", 64, 8, "decode")
    lowered = lower_cell(cfg, shape_d, mesh)
    lowered.compile()
    print("ok")
    """)
    assert "ok" in out


def test_serve_sharding_and_cache_rules():
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.parallel import sharding as sh
    from repro.launch.steps import cache_pspecs

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    # serve mode: plain matrices fold data into tp
    spec = sh.leaf_pspec("blocks/mlp/wi", (64, 128), mesh, serve=True)
    assert spec == P(None, ("model", "data")), spec
    spec = sh.leaf_pspec("blocks/mlp/wo", (128, 64), mesh, serve=True)
    assert spec == P(("model", "data"), None), spec
    # experts: E over model, FFN over data -- fully resident
    spec = sh.leaf_pspec("blocks/moe/experts_wi", (8, 64, 128), mesh,
                         serve=True)
    assert spec == P("model", None, "data"), spec
    # moe_ffn_data train variant
    spec = sh.leaf_pspec("blocks/moe/experts_wi", (8, 64, 128), mesh,
                         moe_ffn_data=True)
    assert spec == P("model", None, "data"), spec
    # KV cache: batch over data, SEQUENCE over model (flash-decoding)
    import jax.numpy as jnp
    cache = {"k": jax.ShapeDtypeStruct((2, 8, 64, 4, 16), jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((2, 8, 64, 4, 16), jnp.bfloat16),
             "len": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = cache_pspecs(cache, mesh)
    assert specs["k"] == P(None, "data", "model", None, None), specs["k"]
    print("ok")
    """)


def test_decode_lowering_has_no_cache_gather():
    """The Perf A1 fix at test scale: decode lowers with the cache
    sharded and without whole-cache all-gathers."""
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_smoke_config
    from repro.launch.steps import lower_cell
    from repro.launch import dryrun
    from repro.models.config import ShapeConfig

    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                ("data", "model"))
    cfg = get_smoke_config("gemma_7b").scaled(attn_chunk=32)
    shape = ShapeConfig("mini_decode", 64, 8, "decode")
    compiled = lower_cell(cfg, shape, mesh).compile()
    colls = dryrun.parse_collectives(compiled.as_text())
    # cache (layers, B, 64, H, D) bf16: a whole-cache gather would move
    # >= L*B*S*H*D*2 bytes; assert total gather volume stays well below.
    import math
    cache_bytes = cfg.n_layers * 8 * 64 * cfg.n_kv_heads * \
        cfg.head_dim * 2 * 2
    assert colls["all-gather"]["bytes"] < cache_bytes, \
        (colls["all-gather"], cache_bytes)
    print("ok")
    """)


def test_compressed_allreduce_across_pods():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.compression import compressed_psum

    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("pod", "data"))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)  # per-pod grads
    e = jnp.zeros_like(g)
    f = shard_map(lambda gg, ee: compressed_psum(gg, "pod", ee),
                  mesh=mesh, in_specs=(P("pod"), P("pod")),
                  out_specs=(P("pod"), P("pod")))
    out, err = f(g, e)
    want = g.mean(axis=0)
    # each pod's shard now holds (approximately) the mean
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               rtol=0.15, atol=0.05)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]),
                               rtol=1e-6, atol=1e-6)
    print("ok")
    """)


# ---------------------------------------------------------------------------
# Conv stack: shard_map dispatch layer + conv-filter sharding rules
# ---------------------------------------------------------------------------


def test_conv_filter_sharding_rules():
    """The structural rank-4 rule: real CNN/GAN param trees get
    non-trivial conv-filter PartitionSpecs (the old behavior -- list
    indices / GAN layer names falling to the replicate-all catch-all --
    would leave every one of them P())."""
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.models import cnn, gan
    from repro.parallel import sharding as sh

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    params = cnn.simple_cnn_init(jax.random.PRNGKey(0), in_ch=3,
                                 widths=(32, 64, 128), n_classes=10)
    specs = sh.tree_pspecs(params, mesh)
    # Cin=3 stem: fsdp(4) does not divide 3 -> Cin stays unsharded, but
    # Cout=32 shards over tp
    assert specs["convs"][0] == P(None, None, None, "model"), specs
    # interior filters: full (.., Cin@fsdp, Cout@tp)
    assert specs["convs"][1] == P(None, None, "data", "model"), specs
    assert specs["convs"][2] == P(None, None, "data", "model"), specs
    # the 2-D head still follows its name rule, not the conv rule
    assert specs["head"] == P("data", "model"), specs

    g = gan.generator_init(jax.random.PRNGKey(1), z_dim=64, base=64)
    d = gan.discriminator_init(jax.random.PRNGKey(2), in_ch=3, base=64)
    gs, ds = sh.tree_pspecs(g, mesh), sh.tree_pspecs(d, mesh)
    assert gs["t1"] == P(None, None, "data", "model"), gs
    assert gs["t2"] == P(None, None, "data", "model"), gs
    # t3 has Cin=3 (the RGB output side of the tconv): guard drops fsdp
    assert gs["t3"] == P(None, None, None, "model"), gs
    assert ds["c2"] == P(None, None, "data", "model"), ds
    # serve layout: conv filters fully sharded over model+data on Cout
    gss = sh.tree_pspecs(g, mesh, serve=True)
    assert gss["t1"] == P(None, None, None, ("model", "data")), gss
    # the depthwise (K, C) name rule is untouched by the structural rule
    spec = sh.leaf_pspec("blocks/conv_w", (4, 64), mesh)
    assert spec == P(None, "model"), spec
    print("ok")
    """)


def test_batch_pspec_requires_size():
    """batch_pspec only shards when the batch size is known AND divides
    the dp axes -- an unknown (None) or ragged size stays unsharded."""
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.parallel import sharding as sh

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    # divisible batch: sharded over the data axes
    assert sh.batch_pspec(mesh, 4, 0, 8) == P("data", None, None, None)
    # unknown size: UNSHARDED (the old code sharded unconditionally and
    # a ragged last batch then failed to lower)
    assert sh.batch_pspec(mesh, 4, 0, None) == P(None, None, None, None)
    # ragged size: guard drops the axis
    assert sh.batch_pspec(mesh, 2, 0, 6) == P(None, None)
    print("ok")
    """)


def test_sharded_cnn_sgd_step_matches_single_device():
    """Tentpole numerics: the CNN SGD step on the pallas backend, 8 fake
    devices FSDP+TP vs single device, same seed -> same params."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding
    from repro.models import cnn
    from repro.parallel import sharding as sh

    params = cnn.simple_cnn_init(jax.random.PRNGKey(0), in_ch=3,
                                 widths=(8, 16), n_classes=10)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 12, 12, 3)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, size=8))
    step = lambda p, x_: cnn.sgd_step(p, x_, labels, lr=0.05, stride=2,
                                      backend="pallas", fuse_epilogue=True)
    p_ref, loss_ref = jax.jit(step)(params, x)

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    with mesh, sh.use_mesh(mesh):
        psh = sh.tree_shardings(params, mesh)
        p_s = jax.device_put(params, psh)
        x_s = jax.device_put(x, NamedSharding(
            mesh, sh.batch_pspec(mesh, 4, 0, 8)))
        p_out, loss = jax.jit(step)(p_s, x_s)
    assert abs(float(loss) - float(loss_ref)) < 1e-5, (loss, loss_ref)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    print("ok")
    """)


def test_sharded_gan_gen_step_matches_single_device():
    """Tentpole numerics for the GAN side: generator SGD step (zero-free
    tconv forward + fused ct-backward) under the 8-device mesh."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding
    from repro.models import gan
    from repro.parallel import sharding as sh

    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    gp = gan.generator_init(k1, z_dim=16, base=8, out_ch=3)
    dp = gan.discriminator_init(k2, in_ch=3, base=8)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    step = lambda g, z_: gan.gen_sgd_step(g, dp, z_, lr=0.05,
                                          backend="pallas",
                                          fuse_epilogue=True)
    g_ref, loss_ref = jax.jit(step)(gp, z)

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    with mesh, sh.use_mesh(mesh):
        g_s = jax.device_put(gp, sh.tree_shardings(gp, mesh))
        z_s = jax.device_put(z, NamedSharding(
            mesh, sh.batch_pspec(mesh, 2, 0, 8)))
        g_out, loss = jax.jit(step)(g_s, z_s)
    assert abs(float(loss) - float(loss_ref)) < 1e-5, (loss, loss_ref)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    print("ok")
    """)


def test_plan_tiles_under_shard_map_sees_local_shapes():
    """The local-shapes contract (DESIGN.md Sec. 2.9): inside the
    shard_map body the kernels resolve `tiling.plan_tiles` from traced
    LOCAL block shapes -- batch/dp and channel/tp already divided out --
    so the planner's Cin/Cout tiles are the per-shard geometry."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.conv import ecoflow_conv
    from repro.core.spec import Epilogue
    from repro.kernels import tiling
    from repro.parallel import sharding as sh

    seen = []
    orig = tiling.plan_tiles
    def spy(op, spec, **kw):
        seen.append((op, tuple(kw["x_shape"]), tuple(kw["dy_shape"])))
        return orig(op, spec, **kw)
    tiling.plan_tiles = spy

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    B, N, Ci, Co = 8, 10, 4, 8
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, Ci, Co)), jnp.float32)
    ep = Epilogue(activation="relu")

    def loss(x_, w_):
        return ecoflow_conv(x_, w_, 2, 1, "pallas", epilogue=ep).sum()

    with mesh, sh.use_mesh(mesh):
        jax.grad(loss, argnums=(0, 1))(x, w)

    assert seen, "plan_tiles was never consulted"
    for op, xs, dys in seen:
        # batch divided by |dp|=4, Cout by |tp|=2; Ci=4 is the full Cin
        # (contracted dim -- never sharded on the forward path)
        assert xs[0] == B // 4, (op, xs)
        assert xs[3] == Ci, (op, xs)
        assert dys[0] == B // 4, (op, dys)
        assert dys[3] == Co // 2, (op, dys)
    print("ok", sorted({op for op, _, _ in seen}))
    """)


def test_conv_layer_single_launch_per_shard():
    """Structural pin: under the mesh one conv layer's forward+backward
    jaxpr contains exactly TWO pallas_calls (one fused forward launch,
    one fused dual-gradient backward launch), each inside a shard_map
    body, with the explicit dx/dW/db psums alongside -- and none outside
    any shard_map."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.conv import ecoflow_conv
    from repro.core.spec import Epilogue
    from repro.parallel import sharding as sh

    def subjaxprs(eqn):
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                yield v.jaxpr
            elif hasattr(v, "eqns"):
                yield v

    def walk(jaxpr, skip_shard_map=False):
        for e in jaxpr.eqns:
            yield e
            if skip_shard_map and e.primitive.name == "shard_map":
                continue
            for sub in subjaxprs(e):
                yield from walk(sub, skip_shard_map)

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 10, 10, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    ep = Epilogue(activation="relu", bias=True)

    def loss(x_, w_, b_):
        return ecoflow_conv(x_, w_, 2, 1, "pallas", bias=b_,
                            epilogue=ep).sum()

    with mesh, sh.use_mesh(mesh):
        jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(x, w, b)

    names = [e.primitive.name for e in walk(jaxpr.jaxpr)]
    assert names.count("pallas_call") == 2, names
    assert names.count("shard_map") == 2, names
    assert names.count("psum") >= 3, names   # dx@tp, dW@dp, db@dp
    outside = [e.primitive.name
               for e in walk(jaxpr.jaxpr, skip_shard_map=True)]
    assert outside.count("pallas_call") == 0, outside
    print("ok")
    """)
