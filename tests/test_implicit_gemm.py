"""Predicated implicit-GEMM input-gradient family (DESIGN.md Sec. 2.10).

Property-based parity grid of `tconv_implicit_gemm_pallas` against the
reference adjoint, the dense `xla_zero_free` decomposition, and the
pallas phase kernel across (stride, dilation, K, ragged channels, B > 1)
-- standalone transposed-conv forward AND the input gradient inside a
full `jax.grad` -- plus the structural pins the one-launch invariant
rests on: exactly ONE `pallas_call`, no scatter, no `rhs_dilation` conv
anywhere outside the kernel (the predicate is realized structurally
in-register; zeros exist only in VMEM, never in HBM), and the strategy
planner's analytical crossover + autotune override on the bench
geometries.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import conv as cconv
from repro.core import ecoflow
from repro.core.spec import ConvSpec, Epilogue
from repro.kernels import ops as kops
from repro.kernels import tiling
from repro.kernels.implicit_gemm import tconv_implicit_gemm_pallas
from repro.kernels.tconv_phase import tconv_fused_pallas

from conftest import (assert_allclose, count_pallas_calls,
                      walk_eqns_outside_pallas)


def _case(seed, B, O, K, S, P, D, Ci, Co, slack=0):
    rng = np.random.default_rng(seed)
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K, dilation=D)
    nh, nw = spec.input_size((O, O))
    nh, nw = nh + slack, nw + slack
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    return spec, dy, w, (nh, nw)


# ---------------------------------------------------------------------------
# parity grid
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 16), s=st.integers(1, 4),
       d=st.integers(1, 3), k=st.integers(1, 4), p=st.integers(0, 1),
       b=st.integers(1, 3), ci=st.sampled_from([3, 5, 8]),
       co=st.sampled_from([3, 4, 7]), o=st.integers(2, 5),
       slack=st.integers(0, 2))
def test_implicit_gemm_vs_phase_and_reference(seed, s, d, k, p, b, ci,
                                              co, o, slack):
    if p >= ecoflow_min_pad_exclusive(k, d):
        p = 0
    spec, dy, w, n_out = _case(seed, b, o, k, s, p, d, ci, co,
                               slack=min(slack, s - 1))
    want_ref = ecoflow.transposed_conv_zero_free(
        dy, w, stride=spec.stride, padding=spec.padding, n_out=n_out,
        dilation=spec.dilation)
    want_phase = tconv_fused_pallas(
        dy, w, stride=spec.stride, padding=spec.padding, n_out=n_out,
        dilation=spec.dilation, interpret=True)
    got = tconv_implicit_gemm_pallas(
        dy, w, stride=spec.stride, padding=spec.padding, n_out=n_out,
        dilation=spec.dilation, cin_tile=min(4, ci), cout_tile=min(4, co),
        tap_unroll=min(3, k * k), interpret=True)
    assert_allclose(got, want_ref, rtol=1e-3, atol=1e-3)
    assert_allclose(got, want_phase, rtol=1e-3, atol=1e-3)


def ecoflow_min_pad_exclusive(k, d):
    """Largest pad p with full_size still positive for an O>=2 output at
    any stride: keep p below the dilated half-filter so the geometry
    stays valid across the sampled grid."""
    return max(1, (d * (k - 1) + 1) // 2 + 1)


@pytest.mark.parametrize("s,d,k,p", [(2, 1, 3, 1), (4, 1, 11, 2),
                                     (1, 2, 3, 1), (2, 1, 4, 1),
                                     (3, 2, 3, 0)])
def test_input_grad_parity_through_jax_grad(s, d, k, p):
    """jax.grad through the pallas backend under a FORCED implicit-GEMM
    strategy equals the reference gradients -- the strategy routing sits
    inside the conv custom-VJP without touching its contract."""
    rng = np.random.default_rng(7)
    spec = ConvSpec.make(stride=s, padding=p, filter_shape=k, dilation=d)
    n = spec.input_size((4, 4))[0]
    x = jnp.asarray(rng.normal(size=(2, n, n, 5)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, k, 5, 6)), jnp.float32)

    def loss(backend):
        def f(x_, w_):
            y = cconv.ecoflow_conv(x_, w_, s, p, backend, d)
            return jnp.sum(y * jnp.cos(y))
        return f

    gx_r, gw_r = jax.grad(loss("reference"), argnums=(0, 1))(x, w)
    strategies = [None, "implicit_gemm", "phase"]
    for strategy in strategies:
        plan_kw = {} if strategy is None else {"strategy": strategy}
        orig = kops.tconv_phase
        try:
            if strategy is not None:
                def pinned(*a, **kw):
                    kw["strategy"] = strategy
                    return orig(*a, **kw)
                kops.tconv_phase = pinned
            gx_p, gw_p = jax.grad(loss("pallas"), argnums=(0, 1))(x, w)
        finally:
            kops.tconv_phase = orig
        assert_allclose(gx_p, gx_r, rtol=1e-3, atol=1e-3)
        assert_allclose(gw_p, gw_r, rtol=1e-3, atol=1e-3)


def test_epilogue_parity_both_strategies(rng):
    """The fused act(scale * tconv + bias) epilogue produces identical
    results through both kernel families."""
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=3)
    n_out = spec.input_size((4, 4))
    dy = jnp.asarray(rng.normal(size=(2, 4, 4, 6)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 5, 6)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(5,)), jnp.float32)
    for ep in (Epilogue(activation="relu", bias=True),
               Epilogue(activation="tanh", scale=0.5),
               Epilogue(activation="leaky_relu", bias=True, scale=2.0)):
        bias = b if ep.bias else None
        kw = dict(stride=(2, 2), padding=(1, 1), n_out=n_out,
                  dilation=(1, 1), bias=bias, epilogue=ep, interpret=True)
        want = tconv_fused_pallas(dy, w, **kw)
        got = tconv_implicit_gemm_pallas(dy, w, cin_tile=3, cout_tile=3,
                                         tap_unroll=3, **kw)
        assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_bf16_output_dtype():
    """The kernel accumulates fp32 and casts back to the operand dtype."""
    rng = np.random.default_rng(11)
    dy = jnp.asarray(rng.normal(size=(1, 4, 4, 4)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 4)), jnp.bfloat16)
    out = tconv_implicit_gemm_pallas(dy, w, stride=(2, 2), padding=(1, 1),
                                     n_out=(7, 7), interpret=True)
    assert out.dtype == jnp.bfloat16
    want = tconv_fused_pallas(dy, w, stride=(2, 2), padding=(1, 1),
                              n_out=(7, 7), interpret=True)
    assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                    rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# structural pins
# ---------------------------------------------------------------------------

def _structural_pins(fn, *args):
    """ONE pallas_call; no scatter and no rhs-dilated conv outside it --
    the predicate is structural (in-register zero interleave), never a
    materialized HBM dilation or an index scatter."""
    assert count_pallas_calls(fn, *args) == 1
    jaxpr = jax.make_jaxpr(fn)(*args)
    for eqn in walk_eqns_outside_pallas(jaxpr.jaxpr):
        assert "scatter" not in eqn.primitive.name, eqn.primitive.name
        if eqn.primitive.name == "conv_general_dilated":
            assert tuple(eqn.params.get("rhs_dilation")
                         or (1, 1)) == (1, 1), eqn
            assert tuple(eqn.params.get("lhs_dilation")
                         or (1, 1)) == (1, 1), eqn


@pytest.mark.parametrize("s,d,k", [(2, 1, 3), (4, 1, 11), (1, 2, 3),
                                   (3, 2, 2)])
def test_single_launch_no_scatter_no_dilated_conv(s, d, k):
    rng = np.random.default_rng(5)
    spec = ConvSpec.make(stride=s, padding=1 if k > 1 else 0,
                         filter_shape=k, dilation=d)
    n_out = spec.input_size((3, 3))
    dy = jnp.asarray(rng.normal(size=(2, 3, 3, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, k, 4, 4)), jnp.float32)
    _structural_pins(
        lambda dy_, w_: tconv_implicit_gemm_pallas(
            dy_, w_, stride=spec.stride, padding=spec.padding,
            n_out=n_out, dilation=spec.dilation, interpret=True),
        dy, w)


def test_backend_single_launch_under_forced_strategy(monkeypatch):
    """Through the full pallas ConvBackend route (`ecoflow_conv_transpose`)
    the forced implicit-GEMM strategy still lowers to exactly ONE
    launch -- the jaxpr pin the strategy refactor must not disturb."""
    monkeypatch.setenv("ECOFLOW_STRATEGY", "implicit_gemm")
    rng = np.random.default_rng(6)
    dy = jnp.asarray(rng.normal(size=(2, 5, 5, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)), jnp.float32)
    _structural_pins(
        lambda dy_, w_: cconv.ecoflow_conv_transpose(
            dy_, w_, 2, 1, n_out=(9, 9), backend="pallas"), dy, w)
    got = cconv.ecoflow_conv_transpose(dy, w, 2, 1, n_out=(9, 9),
                                       backend="pallas")
    want = cconv.ecoflow_conv_transpose(dy, w, 2, 1, n_out=(9, 9),
                                        backend="reference")
    assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# strategy selection: analytical crossover + autotune override
# ---------------------------------------------------------------------------

def _layer_race(L, **kw):
    spec = ConvSpec.make(stride=L.stride, padding=L.padding,
                         filter_shape=L.k, dilation=L.dilation)
    st_, _ = tiling.plan_strategy(
        "input_grad", spec,
        x_shape=(L.batch, L.n_in, L.n_in, L.c_in),
        dy_shape=(L.batch, L.n_out, L.n_out, L.m), **kw)
    return st_


def test_analytical_crossover_on_paper_geometries():
    """The acceptance pin: under the analytical model, at least one
    Table 5/7 geometry plans implicit-GEMM and at least one other plans
    phase decomposition -- the high-waste AlexNet S=4 stem (94% masked
    lanes) goes phase, the S=1 dilated ASPP layers go implicit-GEMM."""
    from repro.core import dataflow_sim as ds
    for interpret in (True, False):
        kw = dict(interpret=interpret, strategy="auto")
        picks = {L.name: _layer_race(L, **kw)
                 for L in (list(ds.TABLE5_LAYERS)
                           + list(ds.TABLE7_GAN_LAYERS)
                           + list(ds.DILATED_LAYERS))}
        assert picks["alexnet-CONV1"] == "phase", picks
        assert picks["deeplab-ASPP-d2"] == "implicit_gemm", picks
        assert set(picks.values()) == {"phase", "implicit_gemm"}, picks


def test_autotune_overrides_analytical_choice(tmp_path):
    """The empirical race can override the analytical pick in EITHER
    direction: rig the runners so the analytically-losing strategy times
    faster and the autotuned plan follows the measurement, persisting
    the measured winner in its `|st:auto` row."""
    spec = ConvSpec.make(stride=4, padding=2, filter_shape=11)
    x_shape = (1, 21, 21, 4)
    dy_shape = (1, 4, 4, 4)
    analytical = tiling._auto_strategy("input_grad", spec, x_shape,
                                       dy_shape, 4,
                                       tiling.DEFAULT_VMEM_BUDGET, True)
    other = ("phase" if analytical == "implicit_gemm"
             else "implicit_gemm")

    import repro.kernels.tiling as t

    saved_runners = dict(t._RUNNERS)
    saved_median = t._median_time_us
    cache = tmp_path / "c.json"
    try:
        rig = {analytical: 100.0, other: 1.0}

        def median(thunk):
            thunk()
            return median.current

        t._median_time_us = median

        def factory_for(strategy):
            def factory(spec_, x_s, dy_s, epilogue=None):
                def run(plan):
                    median.current = rig[strategy]
                    return None
                return run
            return factory

        t._RUNNERS.clear()
        t._RUNNERS[("input_grad", "phase")] = factory_for("phase")
        t._RUNNERS[("input_grad", "implicit_gemm")] = \
            factory_for("implicit_gemm")
        t._MEM_CACHE.clear()
        t._MEM_STRATEGY.clear()
        st_, plan = tiling.plan_strategy(
            "input_grad", spec, x_shape=x_shape, dy_shape=dy_shape,
            interpret=True, mode="autotune", tile_cache_path=cache,
            strategy="auto")
        assert st_ == other, \
            "measured race must override the analytical pick"
        doc = json.loads(cache.read_text())
        (key, rec), = doc.items()
        assert "|st:auto|" in key and rec["strategy"] == other

        # ... and the other direction.
        rig[analytical], rig[other] = 1.0, 100.0
        t._MEM_CACHE.clear()
        t._MEM_STRATEGY.clear()
        cache.unlink()
        st_, _ = tiling.plan_strategy(
            "input_grad", spec, x_shape=x_shape, dy_shape=dy_shape,
            interpret=True, mode="autotune", tile_cache_path=cache,
            strategy="auto")
        assert st_ == analytical
    finally:
        t._RUNNERS.clear()
        t._RUNNERS.update(saved_runners)
        t._median_time_us = saved_median
