"""Correctness of the zero-free EcoFlow dataflows against jax.vjp of a
plain convolution -- the ground-truth gradients.

The sweep covers the geometry space of the paper's Table 5/7 layers:
strides 1-8 (paper evaluates up to 8), filters 1-11, exact and non-exact
fit, border padding, rectangular strides.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecoflow, naive
from repro.core.conv import ecoflow_conv, ecoflow_conv_transpose

from conftest import assert_allclose


def _grads_ref(x, w, stride, padding, dy):
    """(dx, dw) from jax.vjp of the plain direct conv."""
    f = lambda x_, w_: ecoflow.direct_conv(x_, w_, stride, padding)
    _, vjp = jax.vjp(f, x, w)
    return vjp(dy)


def _case(rng, B, N, K, S, P, Ci, Co, dtype=jnp.float32):
    O = (N + 2 * P - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), dtype)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), dtype)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), dtype)
    return x, w, dy


# Geometry sweep: (N, K, S, P) covering exact fit, non-exact fit, padding,
# K < S (sub-filters with zero taps), K == 1, large strides.
GEOMS = [
    (8, 3, 1, 0), (8, 3, 1, 1), (9, 3, 2, 0), (8, 3, 2, 1),
    (10, 3, 2, 0),                      # non-exact fit (tail rows ignored)
    (11, 5, 2, 2), (13, 4, 3, 0), (12, 2, 4, 0),  # K < S
    (17, 1, 2, 0),                      # pointwise
    (23, 11, 4, 2),                     # alexnet-CONV1-like
    (17, 3, 8, 0),                      # stride-8 (paper's extreme case)
]


@pytest.mark.parametrize("N,K,S,P", GEOMS)
def test_input_grad_matches_vjp(rng, N, K, S, P):
    x, w, dy = _case(rng, 2, N, K, S, P, 3, 5)
    dx_ref, _ = _grads_ref(x, w, S, P, dy)
    dx = ecoflow.transposed_conv_zero_free(
        dy, w, stride=(S, S), padding=(P, P), n_out=(N, N))
    assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,K,S,P", GEOMS)
def test_filter_grad_matches_vjp(rng, N, K, S, P):
    x, w, dy = _case(rng, 2, N, K, S, P, 3, 5)
    _, dw_ref = _grads_ref(x, w, S, P, dy)
    dw = ecoflow.dilated_conv_filter_grad_zero_free(
        x, dy, stride=(S, S), padding=(P, P), k=(K, K))
    assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,K,S,P", GEOMS)
def test_naive_baselines_match_vjp(rng, N, K, S, P):
    """The materialized-zero baselines are also exact (they're the paper's
    baselines, not approximations)."""
    x, w, dy = _case(rng, 2, N, K, S, P, 3, 5)
    dx_ref, dw_ref = _grads_ref(x, w, S, P, dy)
    dx = naive.transposed_conv_naive(dy, w, stride=(S, S), padding=(P, P),
                                     n_out=(N, N))
    dw = naive.dilated_conv_filter_grad_naive(
        x, dy, stride=(S, S), padding=(P, P), k=(K, K))
    assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
    assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)


def test_rectangular_stride(rng):
    B, Ci, Co = 2, 3, 4
    N, K = 12, 3
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    Oh, Ow = (N - K) // 2 + 1, (N - K) // 3 + 1
    dy = jnp.asarray(rng.normal(size=(B, Oh, Ow, Co)), jnp.float32)
    f = lambda x_, w_: jax.lax.conv_general_dilated(
        x_, w_, (2, 3), [(0, 0), (0, 0)], dimension_numbers=ecoflow.DN)
    _, vjp = jax.vjp(f, x, w)
    dx_ref, dw_ref = vjp(dy)
    dx = ecoflow.transposed_conv_zero_free(dy, w, stride=(2, 3),
                                           padding=(0, 0), n_out=(N, N))
    dw = ecoflow.dilated_conv_filter_grad_zero_free(
        x, dy, stride=(2, 3), padding=(0, 0), k=(K, K))
    assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
    assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend",
                         ["reference", "xla_zero_free", "pallas"])
def test_ecoflow_conv_custom_vjp(rng, backend):
    """jax.grad through ecoflow_conv == jax.grad through the plain conv,
    for every dispatch backend."""
    x, w, _ = _case(rng, 2, 9, 3, 2, 1, 3, 4)

    def loss_eco(x_, w_):
        return jnp.sum(ecoflow_conv(x_, w_, 2, 1, backend) ** 2)

    def loss_ref(x_, w_):
        return jnp.sum(ecoflow.direct_conv(x_, w_, 2, 1) ** 2)

    gx_e, gw_e = jax.grad(loss_eco, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    assert_allclose(gx_e, gx_r, rtol=1e-3, atol=1e-3)
    assert_allclose(gw_e, gw_r, rtol=1e-3, atol=1e-3)


def test_conv_transpose_standalone(rng):
    """ecoflow_conv_transpose equals lax.conv_transpose semantics (via the
    input-gradient identity)."""
    B, O, K, S, Ci, Co = 2, 6, 4, 2, 5, 3
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    N = S * (O - 1) + K - 2 * 1
    up = ecoflow_conv_transpose(dy, w, 2, 1, n_out=(N, N))
    ref = naive.transposed_conv_naive(dy, w, stride=(S, S), padding=(1, 1),
                                      n_out=(N, N))
    assert_allclose(up, ref, rtol=1e-4, atol=1e-4)


def test_conv_transpose_normalizes_scalar_geometry(rng):
    """Regression: `_conv_transpose` / `_ct_bwd` construct their spec via
    `ConvSpec.make` (int -> pair normalization + validation), not the raw
    dataclass -- a direct call with SCALAR stride/padding previously
    built a spec whose `stride[i]` indexing failed deep inside the
    backend, and degenerate geometry slipped past validation entirely."""
    from repro.core.conv import _conv_transpose
    B, O, K, S, Ci, Co = 2, 5, 4, 2, 3, 4
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    N = S * (O - 1) + K - 2
    # Un-normalized scalar stride/padding/dilation through the custom-vjp
    # primitive directly (the public wrapper normalizes before calling).
    up = _conv_transpose(dy, w, S, 1, (N, N), None, 1)
    want = _conv_transpose(dy, w, (S, S), (1, 1), (N, N), None, (1, 1))
    assert_allclose(up, want, rtol=0, atol=0)
    # ... and through its backward rule (the _ct_bwd spec construction).
    loss = lambda dy_, w_: jnp.sum(
        _conv_transpose(dy_, w_, S, 1, (N, N), None, 1) ** 2)
    g_dy, g_w = jax.grad(loss, argnums=(0, 1))(dy, w)
    loss_t = lambda dy_, w_: jnp.sum(
        _conv_transpose(dy_, w_, (S, S), (1, 1), (N, N), None,
                        (1, 1)) ** 2)
    g_dy_t, g_w_t = jax.grad(loss_t, argnums=(0, 1))(dy, w)
    assert_allclose(g_dy, g_dy_t, rtol=1e-6, atol=1e-6)
    assert_allclose(g_w, g_w_t, rtol=1e-6, atol=1e-6)
    # Validation now fires on degenerate geometry too.
    import pytest
    with pytest.raises(ValueError, match="stride"):
        _conv_transpose(dy, w, 0, 1, (N, N), None, 1)


def test_bf16_inputs(rng):
    x, w, dy = _case(rng, 2, 9, 3, 2, 0, 4, 4, jnp.bfloat16)
    dx = ecoflow.transposed_conv_zero_free(dy, w, stride=(2, 2),
                                           padding=(0, 0), n_out=(9, 9))
    assert dx.dtype == jnp.bfloat16
    ref = naive.transposed_conv_naive(dy, w, stride=(2, 2), padding=(0, 0),
                                      n_out=(9, 9))
    assert_allclose(dx, ref, rtol=5e-2, atol=5e-2)


def test_zero_free_mac_count_tconv():
    """The phase decomposition enumerates exactly |W| x |err| products --
    the zero-free MAC set (paper's symbolic outer product)."""
    K, S, O = 3, 2, 4
    subs_taps = 0
    for p in range(S):
        for q in range(S):
            kp = len(range(p, K, S))
            kq = len(range(q, K, S))
            subs_taps += kp * kq
    assert subs_taps == K * K  # every tap in exactly one phase
