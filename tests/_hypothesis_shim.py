"""Deterministic fallback for `hypothesis` when it is not installed.

The tier-1 suite uses a small slice of the hypothesis API (`given`,
`settings`, `strategies.{integers,floats,booleans,sampled_from}`) for
property tests.  This shim provides drop-in replacements that run each
property test against a fixed number of deterministic pseudo-random draws
(seeded per test name), so the suite stays green -- with reduced (but
reproducible) coverage -- on machines without the optional dependency.

Installed by tests/conftest.py via `install()` *before* test modules are
imported; a real `hypothesis` install always takes precedence.
"""
from __future__ import annotations

import functools
import itertools
import sys
import types
import zlib

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    """A draw rule: maps an np.random.Generator to one example value."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    """Decorator recording how many examples `given` should run."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    """Run the wrapped test for N deterministic draws of each strategy.

    Draw sequences are seeded from the test's qualified name, so failures
    reproduce run to run and are independent of test execution order.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # Read at call time from the outermost decorated object:
            # `@settings` above `@given` sets the attribute on `wrapper`;
            # `@given` above `@settings` leaves it on `fn` (and
            # functools.wraps copies it up).  Cap to keep the shim cheap.
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples",
                                _DEFAULT_EXAMPLES))
            n = min(n, _DEFAULT_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for i in itertools.count():
                if i >= n:
                    break
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"property test failed on shim example {drawn!r}"
                    ) from e
        # Mark so pytest does not try to inject the strategy kwargs as
        # fixtures.
        wrapper.__signature__ = _signature_without(fn, strategies)
        return wrapper
    return deco


def _signature_without(fn, strategies):
    import inspect
    sig = inspect.signature(fn)
    params = [p for name, p in sig.parameters.items()
              if name not in strategies]
    return sig.replace(parameters=params)


def install() -> None:
    """Register this shim as the `hypothesis` package in sys.modules."""
    if "hypothesis" in sys.modules:  # real install (or already shimmed)
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    mod.strategies = st
    mod.__shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
