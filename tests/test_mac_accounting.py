"""Invariants of the MAC-accounting formulas (paper Sec. 3.1 closed forms).

Each closed-form zero-MAC fraction is cross-checked against a brute-force
count over an explicitly materialized zero map on small geometries, and
the ConvSpec size formulas are pinned by round-trip properties on random
specs (including dilation).
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ecoflow, naive
from repro.core.spec import ConvSpec


def _window_sums(arr: np.ndarray, k: int) -> np.ndarray:
    """Sum of every k x k sliding window of a 2D array."""
    v = np.lib.stride_tricks.sliding_window_view(arr, (k, k))
    return v.sum(axis=(2, 3))


def _brute_tconv_zero_frac(n: int, k: int, s: int) -> float:
    """Exact zero-MAC fraction of the naive transposed conv: dilate the
    n x n error map by s, add the k-1 border halo, slide the k x k filter
    over every output position, count MACs touching an inserted zero."""
    dil = s * (n - 1) + 1
    ind = np.zeros((dil, dil))
    ind[::s, ::s] = 1.0                       # real error elements
    padded = np.pad(ind, k - 1)               # border halo
    useful = _window_sums(padded, k).sum()    # MACs touching a real elem
    n_windows = (padded.shape[0] - k + 1) ** 2
    total = n_windows * k * k
    return 1.0 - useful / total


@pytest.mark.parametrize("n,k,s", [(8, 3, 2), (16, 3, 2), (8, 5, 4),
                                   (12, 11, 4), (16, 3, 8), (27, 5, 2)])
def test_tconv_zero_mac_fraction_brute_force(n, k, s):
    """`tconv_zero_mac_fraction` is the padded map's zero *density*
    (paper Sec. 3.1 accounting, pinned bitwise by test_mapping).  The
    brute-force MAC-level count differs only in the border halo -- every
    real tap sits >= K-1 from the edge, so its sharp closed form is
    1 - n^2/(S(n-1)+K)^2.  The density form bounds it from above and the
    gap (all-zero halo windows) stays < 0.05 on the paper's geometries."""
    exact = _brute_tconv_zero_frac(n, k, s)
    n_out = s * (n - 1) + k
    assert exact == pytest.approx(1.0 - n * n / n_out ** 2, abs=1e-12)
    formula = ecoflow.tconv_zero_mac_fraction(n, k, s)
    assert exact <= formula + 1e-12, (exact, formula)
    assert formula - exact < 0.05, (exact, formula)


@pytest.mark.parametrize("n,s", [(8, 2), (16, 2), (8, 4), (27, 2), (7, 8)])
def test_dconv_zero_mac_fraction_brute_force(n, s):
    """Filter-gradient conv uses the s-dilated error as the filter: every
    window position schedules dil^2 MACs of which exactly n^2 touch real
    elements, independent of position -- the closed form is exact."""
    dil = s * (n - 1) + 1
    ind = np.zeros((dil, dil))
    ind[::s, ::s] = 1.0
    exact = 1.0 - ind.sum() / ind.size
    assert ecoflow.dconv_zero_mac_fraction(n, s) == pytest.approx(
        exact, abs=1e-12)


@pytest.mark.parametrize("k,d", [(3, 2), (3, 4), (5, 2), (2, 3), (1, 4)])
def test_dilated_forward_zero_mac_fraction_brute_force(k, d):
    """Dilated forward conv uses the d-dilated filter: k_eff^2 scheduled
    MACs per output position, k^2 useful -- exact at every position."""
    w = np.zeros((d * (k - 1) + 1, d * (k - 1) + 1))
    w[::d, ::d] = 1.0
    exact = 1.0 - w.sum() / w.size
    assert naive.dilated_forward_zero_mac_fraction(k, d) == pytest.approx(
        exact, abs=1e-12)
    # Consistency with the materialized baseline: the dilated filter the
    # naive path builds has exactly that zero density.
    import jax.numpy as jnp
    wf = jnp.ones((k, k, 1, 1), jnp.float32)
    w_dil = naive.dilate_filter_insert_zeros(wf, d)
    assert int((w_dil == 0).sum()) / w_dil.size == pytest.approx(
        naive.dilated_forward_zero_mac_fraction(k, d), abs=1e-12)


# ---------------------------------------------------------------------------
# ConvSpec size-formula round-trips on random specs
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 4), k=st.integers(1, 5), p=st.integers(0, 2),
       d=st.integers(1, 4), o=st.integers(1, 9), slack=st.integers(0, 5))
def test_spec_size_round_trip(s, k, p, d, o, slack):
    spec = ConvSpec.make(stride=s, padding=p, filter_shape=k, dilation=d)
    k_eff = d * (k - 1) + 1
    assert spec.dilated_filter_shape == (k_eff, k_eff)
    # Exact-fit round trip: out_size(input_size(o)) == o whenever the
    # exact-fit input is a valid (positive, >= filter) geometry.
    n_exact = spec.input_size((o, o))[0]
    if n_exact + 2 * p >= k_eff:
        assert spec.out_size((n_exact, n_exact)) == (o, o)
        # Non-exact fit: up to S-1 ignored tail rows never change O.
        n = n_exact + min(slack, s - 1)
        assert spec.out_size((n, n)) == (o, o)
    # The full (pre-padding-slice) transposed extent covers the exact fit.
    assert spec.full_size((o, o))[0] == n_exact + 2 * p


@settings(max_examples=10, deadline=None)
@given(sh=st.integers(1, 4), sw=st.integers(1, 4), kh=st.integers(1, 5),
       kw=st.integers(1, 5))
def test_useful_taps_is_zero_free(sh, sw, kh, kw):
    """Every filter tap lands in exactly one stride phase -- the zero-free
    property the phase decomposition relies on."""
    spec = ConvSpec.make(stride=(sh, sw), filter_shape=(kh, kw))
    assert spec.useful_taps() == kh * kw


# ---------------------------------------------------------------------------
# Predicated-lane fraction of the implicit-GEMM lowering (Sec. 2.10)
# ---------------------------------------------------------------------------

def _brute_predicated_frac(o: int, k: int, s: int, d: int) -> float:
    """Brute-force masked-lane fraction of the flat implicit GEMM: for
    every tap (kx, ky), count the full-frame output sites (r, c) whose
    contributing dy index (r - kx*d)/s x (c - ky*d)/s is integral and
    in-bounds; everything else is a predicated-off lane."""
    spec = ConvSpec.make(stride=s, padding=0, filter_shape=k, dilation=d)
    fh, fw = spec.full_size((o, o))
    live = 0
    for kx in range(k):
        for ky in range(k):
            for r in range(fh):
                for c in range(fw):
                    ih, iw = r - kx * d, c - ky * d
                    if (ih >= 0 and iw >= 0 and ih % s == 0
                            and iw % s == 0 and ih // s < o
                            and iw // s < o):
                        live += 1
    return 1.0 - live / (k * k * fh * fw)


@pytest.mark.parametrize("o,k,s,d", [(4, 3, 2, 1), (5, 11, 4, 1),
                                     (3, 3, 1, 2), (4, 4, 2, 1),
                                     (3, 3, 3, 2), (6, 1, 2, 1),
                                     (4, 2, 2, 3)])
def test_predicated_mac_fraction_brute_force(o, k, s, d):
    """`predicated_mac_fraction` is EXACT: each tap contributes exactly
    o live sites per axis (r = kx*d + i*s, max index kx*d + (o-1)*s <=
    Fh-1 always in frame), so the fraction is tap-independent and equals
    1 - (Oh*Ow)/(Fh*Fw) with no halo correction term."""
    spec = ConvSpec.make(stride=s, padding=0, filter_shape=k, dilation=d)
    exact = _brute_predicated_frac(o, k, s, d)
    assert ecoflow.predicated_mac_fraction(spec, (o, o)) == pytest.approx(
        exact, abs=1e-12)


@settings(max_examples=10, deadline=None)
@given(o=st.integers(1, 6), k=st.integers(1, 4), s=st.integers(1, 4),
       d=st.integers(1, 3))
def test_predicated_mac_fraction_properties(o, k, s, d):
    """Range and monotonicity properties: the fraction lives in [0, 1),
    is 0 exactly when the full frame IS the output frame (S=1, K=1), and
    never decreases when the stride grows (more inserted zeros)."""
    spec = ConvSpec.make(stride=s, padding=0, filter_shape=k, dilation=d)
    f = ecoflow.predicated_mac_fraction(spec, (o, o))
    assert 0.0 <= f < 1.0
    if s == 1 and k == 1:
        assert f == 0.0
    spec2 = ConvSpec.make(stride=s + 1, padding=0, filter_shape=k,
                          dilation=d)
    if o > 1:
        assert ecoflow.predicated_mac_fraction(spec2, (o, o)) >= f


def test_predicated_lane_fraction_sim_consistency():
    """`dataflow_sim.predicated_lane_fraction` delegates to the same
    closed form the strategy planner charges -- the two accountings can
    never drift apart."""
    from repro.core import dataflow_sim as ds
    for L in list(ds.TABLE5_LAYERS) + list(ds.DILATED_LAYERS):
        spec = ConvSpec.make(stride=L.stride, padding=L.padding,
                             filter_shape=L.k, dilation=L.dilation)
        assert ds.predicated_lane_fraction(L) == pytest.approx(
            ecoflow.predicated_mac_fraction(spec, (L.n_out, L.n_out)),
            abs=1e-12)
