"""SASiML-lite validation: the analytical cycle/energy model reproduces
the paper's headline ratios (Fig. 3/8/9/10, Tables 6/8).

The model cannot reproduce absolute milliseconds of a 200MHz 65nm ASIC --
the paper's own simulator deviates 0.07-10% from the real chip -- so these
tests pin the *ratios* the paper reports, with generous bands.
"""
from __future__ import annotations

import pytest

from repro.core import dataflow_sim as ds


def test_useful_macs_shared_across_ops():
    l = ds.layer_by_name("resnet50-CONV3")
    assert ds.useful_macs(l, "forward") == ds.useful_macs(l, "input_grad")
    assert ds.useful_macs(l, "forward") == ds.useful_macs(l, "filter_grad")


def test_zero_fraction_grows_with_stride():
    """Paper Sec. 3.1: zero padding grows quadratically with stride."""
    base = dict(c_in=64, n_in=57, k=3, m=64, batch=4)
    fr = []
    for s in (1, 2, 4, 8):
        n_out = (57 - 3) // s + 1
        l = ds.ConvLayer("t", n_out=n_out, stride=s, **base)
        fr.append(ds.zero_mac_fraction(l, "input_grad"))
    assert fr[0] < 0.5          # stride 1: only boundary halo zeros
    assert fr[1] > 0.70         # paper: >70% at stride 2
    assert fr[2] > 0.90
    assert fr[3] > 0.97
    assert fr == sorted(fr)


def test_ecoflow_schedules_only_useful_macs():
    for l in ds.TABLE5_LAYERS:
        for op in ("input_grad", "filter_grad"):
            assert ds.scheduled_macs(l, op, "ecoflow") == \
                ds.useful_macs(l, op)


def test_fig8_input_grad_speedup_bands():
    """~4x @ stride 2, ~11x @ stride 4, ~52x @ stride 8 (vs TPU)."""
    sp2 = ds.speedup(ds.layer_by_name("resnet50-CONV3"), "input_grad",
                     "ecoflow")
    assert 2.5 < sp2 < 6.0
    sp4 = ds.speedup(ds.layer_by_name("alexnet-CONV1"), "input_grad",
                     "ecoflow")
    # paper measures ~11x; the analytical model yields the MAC-ratio upper
    # bound (~16.6x = 224^2/55^2) since it does not model SASiML's
    # cycle-level NoC contention -- see EXPERIMENTS.md Sec. Paper-tables.
    assert 7.0 < sp4 < 17.0
    sp8 = ds.speedup(ds.layer_by_name("alexnet-o-CONV1"), "input_grad",
                     "ecoflow")
    assert 30.0 < sp8 < 80.0


def test_fig9_filter_grad_speedup_bands():
    """>3x @ stride 2, ~15.6x @ stride 4, ~60x @ stride 8 (vs TPU)."""
    sp2 = ds.speedup(ds.layer_by_name("resnet50-CONV3"), "filter_grad",
                     "ecoflow")
    assert sp2 > 2.5
    sp4 = ds.speedup(ds.layer_by_name("alexnet-CONV1"), "filter_grad",
                     "ecoflow")
    assert 8.0 < sp4 < 25.0
    sp8 = ds.speedup(ds.layer_by_name("alexnet-o-CONV1"), "filter_grad",
                     "ecoflow")
    assert 35.0 < sp8 < 100.0


def test_stride1_zero_mac_fraction_is_exactly_zero():
    """Stride 1 inserts no dilation zeros: every dataflow schedules only
    useful MACs and zero_mac_fraction is exactly 0 for the gradient ops
    (regression: the tpu/rs stride-1 case used to fall through to the
    padded-MAC formulas)."""
    base = dict(c_in=64, n_in=31, k=5, m=192, batch=4)
    l = ds.ConvLayer("s1", n_out=27, stride=1, **base)
    for op in ("forward", "input_grad", "filter_grad"):
        assert ds.zero_mac_fraction(l, op) == 0.0
        for df in ("tpu", "rs", "ecoflow"):
            assert ds.scheduled_macs(l, op, df) == ds.useful_macs(l, op)


def test_stride1_near_parity():
    """Paper: 0-10% gains at stride 1 (no padding zeros to remove)."""
    l = ds.layer_by_name("alexnet-CONV2")
    sp = ds.speedup(l, "input_grad", "ecoflow")
    assert 0.8 < sp < 1.6


def test_table6_end_to_end_bands():
    """End-to-end CNN training 7-85% faster (paper Table 6): every network
    lands inside the paper's [1.07, 1.85] speedup band with the profiled
    stride-1 fraction carried explicitly at parity in the Amdahl
    combination."""
    paper = {"alexnet": 1.83, "resnet50": 1.07, "shufflenet": 1.08,
             "inception": 1.08, "xception": 1.11, "mobilenet": 1.09}
    for net, ref in paper.items():
        v = ds.end_to_end_speedup(net, "ecoflow")
        assert 1.07 <= v <= 1.85, (net, v)
        # within ~25% of the paper's number
        assert abs(v - ref) / ref < 0.25, (net, v, ref)


def test_end_to_end_fractions_wired_and_valid():
    """The profiled fractions are a valid partition (strided + stride-1
    <= 1) and the stride-1 share participates in the Amdahl combination
    at parity: growing it while shrinking the strided share strictly
    lowers the end-to-end speedup, and invalid fractions are rejected."""
    for frac_strided, _, frac_s1 in ds.END2END_FRACTIONS.values():
        assert 0.0 <= frac_strided and 0.0 <= frac_s1
        assert frac_strided + frac_s1 <= 1.0
    base = ds.END2END_FRACTIONS["alexnet"]
    try:
        ds.END2END_FRACTIONS["alexnet"] = (base[0] / 2, base[1],
                                           base[2] + base[0] / 2)
        shifted = ds.end_to_end_speedup("alexnet", "ecoflow")
        ds.END2END_FRACTIONS["alexnet"] = (0.9, base[1], 0.2)
        with pytest.raises(ValueError, match="fractions"):
            ds.end_to_end_speedup("alexnet", "ecoflow")
    finally:
        ds.END2END_FRACTIONS["alexnet"] = base
    assert shifted < ds.end_to_end_speedup("alexnet", "ecoflow")


def test_table8_gan_bands():
    """GAN training 29-42% faster (paper Table 8)."""
    for net, ref in {"pix2pix": 1.39, "cyclegan": 1.42}.items():
        v = ds.gan_end_to_end_speedup(net, "ecoflow")
        assert 1.25 <= v <= 1.55, (net, v)
        assert abs(v - ref) / ref < 0.15, (net, v, ref)


def test_energy_savings_in_spad_noc_not_dram():
    """Paper Fig. 10/12: savings concentrated in SPAD+NoC; DRAM energy is
    maintained across dataflows."""
    l = ds.layer_by_name("resnet50-CONV3")
    e_tpu = ds.energy_breakdown_pj(l, "input_grad", "tpu")
    e_eco = ds.energy_breakdown_pj(l, "input_grad", "ecoflow")
    assert e_eco["SPAD"] < 0.5 * e_tpu["SPAD"]
    assert e_eco["NoC"] < 0.5 * e_tpu["NoC"]
    assert e_eco["DRAM"] == e_tpu["DRAM"]
    assert sum(e_eco.values()) < sum(e_tpu.values())


def test_energy_max_savings_band():
    """Max energy savings ~26x for alexnet-o-CONV1 input grads (paper)."""
    l = ds.layer_by_name("alexnet-o-CONV1")
    r = ds.energy_pj(l, "input_grad", "tpu") / \
        ds.energy_pj(l, "input_grad", "ecoflow")
    assert 8.0 < r < 40.0


def test_rs_not_faster_than_ecoflow():
    for l in ds.TABLE5_LAYERS:
        for op in ("input_grad", "filter_grad"):
            assert ds.cycles(l, op, "ecoflow") <= \
                ds.cycles(l, op, "rs") * 1.05


def test_padding_property_of_layers():
    for l in ds.TABLE5_LAYERS + ds.TABLE7_GAN_LAYERS:
        # ofmap geometry consistent: N_out = (N_in + 2P - K)//S + 1
        assert (l.n_in + 2 * l.padding - l.k) // l.stride + 1 == l.n_out


# ---------------------------------------------------------------------------
# dilated forward (atrous segmentation layers)
# ---------------------------------------------------------------------------

def test_dilated_forward_scheduled_macs():
    """Naive dataflows sweep the materialized K_eff-extent filter; EcoFlow
    schedules only the K^2 useful taps -- the MAC ratio is exactly the
    naive path's zero density (K_eff/K)^2."""
    from repro.core import naive
    for l in ds.DILATED_LAYERS:
        useful = ds.useful_macs(l, "dilated_forward")
        for df in ("tpu", "rs"):
            sched = ds.scheduled_macs(l, "dilated_forward", df)
            assert sched == useful * l.k_eff ** 2 // l.k ** 2
        assert ds.scheduled_macs(l, "dilated_forward", "ecoflow") == useful
        assert ds.zero_mac_fraction(l, "dilated_forward") == \
            pytest.approx(naive.dilated_forward_zero_mac_fraction(
                l.k, l.dilation), abs=1e-12)


def test_dilated_forward_speedup_grows_with_rate():
    """Cycle-count speedup over the TPU dataflow grows with the atrous
    rate (more filter zeros eliminated) and is >1 for every rate."""
    sp = [ds.speedup(l, "dilated_forward", "ecoflow")
          for l in ds.DILATED_LAYERS]                # d = 2, 4
    assert all(s > 1.5 for s in sp), sp
    assert sp == sorted(sp), sp


def test_dilated_forward_dilation1_is_plain_forward():
    """At dilation 1 the dilated-forward op degenerates to the plain
    forward op for every dataflow: same scheduled MACs, same cycles."""
    l = ds.layer_by_name("resnet50-CONV3")
    assert l.dilation == 1 and l.k_eff == l.k
    for df in ("tpu", "rs", "ecoflow"):
        assert ds.scheduled_macs(l, "dilated_forward", df) == \
            ds.scheduled_macs(l, "forward", df)
    assert ds.zero_mac_fraction(l, "dilated_forward") == 0.0


def test_dilated_forward_energy_model_covers_op():
    """The energy breakdown schedules the dilated-forward op: naive
    dataflows pay for staging the materialized filter, DRAM is
    maintained."""
    l = ds.DILATED_LAYERS[1]
    e_tpu = ds.energy_breakdown_pj(l, "dilated_forward", "tpu")
    e_eco = ds.energy_breakdown_pj(l, "dilated_forward", "ecoflow")
    assert e_eco["SPAD"] < e_tpu["SPAD"]
    assert e_eco["DRAM"] == e_tpu["DRAM"]
    assert sum(e_eco.values()) < sum(e_tpu.values())
