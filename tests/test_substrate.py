"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, gradient compression, sharding-rule inference."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, TokenDataset
from repro.optim.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_schedule,
                                   global_norm)
from repro.parallel import compression
from repro.train import checkpoint as ckpt

from conftest import assert_allclose


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=1e9)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    assert abs(float(global_norm(g)) - 10.0) < 1e-5
    clipped, gn = clip_by_global_norm(g, 5.0)
    assert abs(float(global_norm(clipped)) - 5.0) < 1e-4
    assert abs(float(gn) - 10.0) < 1e-5
    same, _ = clip_by_global_norm(g, 20.0)
    assert_allclose(same["a"], g["a"])


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    s = lambda t: float(cosine_schedule(cfg, jnp.asarray(t)))
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 1e-6
    assert s(60) < s(10)
    assert s(110) < 1e-6
    # warmup is linear
    assert abs(s(5) - 0.5) < 1e-6


def test_adamw_moment_dtype_bf16():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((8, 8))}
    opt = adamw_init(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8, 8))}
    _, opt2, _ = adamw_update(g, opt, params, cfg)
    assert opt2["m"]["w"].dtype == jnp.bfloat16


def test_adamw_bf16_params_matches_fp32():
    """bf16 storage params + fp32 master track the fp32 reference run
    closely (master bootstraps from the bf16 copy on step 1)."""
    import jax.numpy as jnp
    tgt = jnp.asarray([[1.0, -2.0], [3.0, 0.5]])
    cfg32 = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=300,
                        weight_decay=0.0, clip_norm=1e9)
    cfgbf = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=300,
                        weight_decay=0.0, clip_norm=1e9, bf16_params=True)
    from repro.optim.optimizer import cast_params_for_storage
    p32 = {"w": jnp.zeros((2, 2))}
    pbf = cast_params_for_storage({"w": jnp.zeros((2, 2))}, cfgbf)
    assert pbf["w"].dtype == jnp.bfloat16
    o32, obf = adamw_init(p32, cfg32), adamw_init(pbf, cfgbf)
    assert "master" in obf and obf["master"]["w"].dtype == jnp.float32
    loss = lambda p: jnp.sum((p["w"].astype(jnp.float32) - tgt) ** 2)
    for _ in range(150):
        p32, o32, _ = adamw_update(jax.grad(loss)(p32), o32, p32, cfg32)
        pbf, obf, _ = adamw_update(jax.grad(loss)(pbf), obf, pbf, cfgbf)
    assert pbf["w"].dtype == jnp.bfloat16
    assert float(loss(p32)) < 1e-3
    assert float(loss(pbf)) < 1e-2   # bf16 working copy: slightly looser
    # master tracks the fp32 trajectory closely
    assert float(jnp.abs(obf["master"]["w"] - p32["w"]).max()) < 0.05


def test_weight_decay_matrices_only():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                      clip_norm=1e9)
    params = {"mat": jnp.ones((2, 2)), "bias": jnp.ones((2,))}
    opt = adamw_init(params, cfg)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(zeros, opt, params, cfg)
    assert float(jnp.abs(p2["mat"] - 1.0).max()) > 1e-3   # decayed
    assert_allclose(p2["bias"], params["bias"])            # not decayed


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_dataset_determinism_and_skip_ahead():
    ds = TokenDataset(vocab=100, seq_len=8, global_batch=4, seed=7)
    b1 = ds.batch(13)
    ds2 = TokenDataset(vocab=100, seq_len=8, global_batch=4, seed=7)
    b2 = ds2.batch(13)   # fresh instance, direct skip-ahead
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(ds.batch(14)["inputs"], b1["inputs"])
    # labels are the shifted continuation of inputs
    assert b1["inputs"].shape == (4, 8)


def test_dataset_token_file(tmp_path):
    toks = np.arange(1000, dtype=np.uint32)
    f = tmp_path / "tokens.bin"
    toks.tofile(f)
    ds = TokenDataset(vocab=2000, seq_len=16, global_batch=2, seed=0,
                      token_file=str(f))
    b = ds.batch(0)
    # shifted-by-one labels
    np.testing.assert_array_equal(b["labels"][:, :-1], b["inputs"][:, 1:])


def test_prefetcher():
    ds = TokenDataset(vocab=100, seq_len=8, global_batch=2, seed=0)
    pf = Prefetcher(ds, start_step=5, depth=2)
    b = next(pf)
    np.testing.assert_array_equal(b["inputs"], ds.batch(5)["inputs"])
    b2 = next(pf)
    np.testing.assert_array_equal(b2["inputs"], ds.batch(6)["inputs"])
    pf.close()


def test_dataset_embed_stub():
    ds = TokenDataset(vocab=100, seq_len=8, global_batch=2, seed=0,
                      embed_dim=32)
    b = ds.batch(0)
    assert b["inputs"].shape == (2, 8, 32)
    assert b["labels"].shape == (2, 8)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(r.normal(size=(4, 4)), jnp.float32),
                       "b": jnp.asarray(r.normal(size=(4,)), jnp.float32)},
            "opt": {"count": jnp.asarray(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    t = _tree()
    ckpt.save(d, 10, t)
    assert ckpt.latest_step(d) == 10
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    out = ckpt.restore(d, 10, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, _tree(s), keep_last=2)
    assert sorted(ckpt.available_steps(d)) == [4, 5]
    assert ckpt.latest_step(d) == 5


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    c = ckpt.AsyncCheckpointer(d, keep_last=3)
    t = _tree()
    c.save_async(7, t)
    c.wait()
    assert ckpt.latest_step(d) == 7
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    out = ckpt.restore(d, 7, like)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_restore_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    bad = {"params": {"w": jax.ShapeDtypeStruct((5, 5), jnp.float32),
                      "b": jax.ShapeDtypeStruct((4,), jnp.float32)},
           "opt": {"count": jax.ShapeDtypeStruct((), jnp.int32)}}
    with pytest.raises(ValueError):
        ckpt.restore(d, 1, bad)


def _truncate_leaf(d, step, nbytes=16):
    p = os.path.join(d, f"step_{step}", "leaf_0.npy")
    with open(p, "r+b") as f:
        f.truncate(nbytes)


def _like(t):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)


def test_latest_step_skips_truncated(tmp_path):
    """A leaf truncated by a disk-full crash: latest_step warns and
    returns the newest INTACT step instead of the torn one."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    ckpt.save(d, 2, _tree(2))
    _truncate_leaf(d, 2)
    with pytest.warns(RuntimeWarning, match="step_2"):
        assert ckpt.latest_step(d) == 1
    # torn manifest counts as corrupt too
    with open(os.path.join(d, "step_1", "manifest.json"), "w") as f:
        f.write('{"step": 1, "leav')
    with pytest.warns(RuntimeWarning):
        assert ckpt.latest_step(d) is None


def test_restore_falls_back_to_intact(tmp_path):
    d = str(tmp_path)
    t1, t2 = _tree(1), _tree(2)
    ckpt.save(d, 1, t1)
    ckpt.save(d, 2, t2)
    _truncate_leaf(d, 2)
    with pytest.warns(RuntimeWarning, match="step_1"):
        out = ckpt.restore(d, 2, _like(t2))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t1["params"]["w"]))
    # callers that need the exact step can refuse the fallback
    with pytest.raises(RuntimeError, match="truncated"):
        ckpt.restore(d, 2, _like(t2), fallback=False)


def test_restore_no_intact_step_raises(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 3, _tree())
    _truncate_leaf(d, 3)
    with pytest.raises(FileNotFoundError):
        ckpt.restore(d, 3, _like(_tree()))


def test_latest_step_unreadable_pointer(tmp_path):
    """A garbage LATEST pointer warns and falls back to the newest
    intact step directory rather than crashing the restart."""
    d = str(tmp_path)
    ckpt.save(d, 4, _tree())
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("not-a-step")
    with pytest.warns(RuntimeWarning, match="LATEST"):
        assert ckpt.latest_step(d) == 4
    assert ckpt.step_intact(d, 4)
    assert not ckpt.step_intact(d, 99)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantize_bound(rng):
    x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    q, scale = compression.quantize_int8(x)
    deq = compression.dequantize_int8(q, scale)
    assert float(jnp.abs(x - deq).max()) <= float(scale) / 2 + 1e-7


def test_error_feedback_unbiased_over_steps(rng):
    """With error feedback, the accumulated quantization error stays
    bounded (it does not grow with steps) -- the 1-bit-Adam property."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("pod",))
    g = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    err = jnp.zeros_like(g)
    f = shard_map(lambda gg, ee: compression.compressed_psum(gg, "pod", ee),
                  mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    total_true, total_sent = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        out, err = f(g, err)
        total_true += g
        total_sent += out
    # cumulative transmitted == cumulative true up to one quantization step
    resid = jnp.abs(total_true - total_sent).max()
    _, scale = compression.quantize_int8(g)
    assert float(resid) < 3 * float(scale)


def test_lion_converges_quadratic():
    from repro.optim.optimizer import LionConfig, lion_init, lion_update
    import jax.numpy as jnp
    cfg = LionConfig(lr=0.05, warmup_steps=0, total_steps=400,
                     weight_decay=0.0, clip_norm=1e9)
    target = jnp.asarray([[1.0, -2.0], [0.5, 3.0]])
    params = {"w": jnp.zeros((2, 2))}
    opt = lion_init(params, cfg)
    assert set(opt) == {"m", "count"}   # one moment: half of Adam's state
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(400):
        g = jax.grad(loss)(params)
        params, opt, metrics = lion_update(g, opt, params, cfg)
    # sign-update optimizer oscillates within +-lr of the optimum
    assert float(jnp.abs(params["w"] - target).max()) < 0.15
    assert bool(jnp.isfinite(metrics["grad_norm"]))
