"""The faithful EcoFlow compile-time mapping (paper Sec. 4.1/4.2): the
symbolic outer-product schedule, PE assignment, circular-shift column
alignment and vertical psum chains -- functionally simulated and checked
against numpy convolution ground truth.

Property tests (hypothesis) assert the paper's structural claims for all
geometries: zero-free MAC counts, multicast-group sizes, chain verticality.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mapping
from repro.core.ecoflow import (tconv_inner_padding, tconv_outer_padding,
                                tconv_zero_mac_fraction)


def _tconv_numpy(err, w, stride):
    """Ground truth: full transposed conv (VALID, P=0) by scatter-add."""
    O = err.shape[0]
    K = w.shape[0]
    N = stride * (O - 1) + K
    out = np.zeros((N, N))
    for i in range(O):
        for j in range(O):
            out[stride * i:stride * i + K, stride * j:stride * j + K] += \
                err[i, j] * w
    return out


def _dconv_numpy(x, err, k, stride):
    """Ground truth filter gradient."""
    O = err.shape[0]
    dw = np.zeros((k, k))
    for kx in range(k):
        for ky in range(k):
            s = 0.0
            for i in range(O):
                for j in range(O):
                    xi, xj = i * stride + kx, j * stride + ky
                    if xi < x.shape[0] and xj < x.shape[1]:
                        s += x[xi, xj] * err[i, j]
            dw[kx, ky] = s
    return dw


@pytest.mark.parametrize("O,K,S", [(2, 3, 2), (3, 3, 1), (4, 3, 2),
                                   (2, 5, 2), (3, 4, 3), (4, 2, 4),
                                   (5, 3, 2), (2, 11, 4)])
def test_tconv_mapping_functional(rng, O, K, S):
    err = rng.normal(size=(O, O))
    w = rng.normal(size=(K, K))
    m = mapping.build_tconv_mapping(O, K, S)
    out = mapping.simulate_tconv(m, err, w)
    np.testing.assert_allclose(out, _tconv_numpy(err, w, S), rtol=1e-10)


@pytest.mark.parametrize("N,O,K,S", [(5, 2, 3, 2), (7, 3, 3, 2),
                                     (9, 4, 3, 2), (10, 3, 4, 3)])
def test_dconv_mapping_functional(rng, N, O, K, S):
    x = rng.normal(size=(N, N))
    err = rng.normal(size=(O, O))
    m = mapping.build_dconv_mapping(N, O, K, S)
    dw = mapping.simulate_dconv(m, x, err)
    np.testing.assert_allclose(dw, _dconv_numpy(x, err, K, S), rtol=1e-10)


@settings(max_examples=40, deadline=None)
@given(O=st.integers(2, 5), K=st.integers(1, 6), S=st.integers(1, 5))
def test_tconv_mapping_properties(O, K, S):
    m = mapping.build_tconv_mapping(O, K, S)
    # 1. zero-free: exactly K^2 * O^2 scheduled MACs (the symbolic outer
    #    product has |w| x |err| entries, none of them padding zeros).
    assert m.n_useful_macs == K * K * O * O
    # 2. psum chains are strictly vertical (single column) -- reducible
    #    over the existing vertical point-to-point links.
    for chain in m.chains.values():
        cols = {c for _, c in chain}
        assert len(cols) == 1
    # 3. every *contributing* output label is owned by exactly one PE.
    #    (For K < S some output positions have no contribution -- they are
    #    structural zeros of the upsampling and are never scheduled.)
    want_labels = {(S * i + a, S * j + b)
                   for i in range(O) for j in range(O)
                   for a in range(K) for b in range(K)}
    owned = [l for pe in m.pes.values() for l in pe.owned_labels]
    assert len(owned) == len(set(owned))
    assert set(owned) == want_labels == set(m.chains)
    # 4. load balance: the column-alignment spreads work within a factor
    #    of the chain fan-in; no PE exceeds K^2 * ceil(K/S) ops.
    import math
    cap = K * K * math.ceil(K / S)
    assert max(len(pe.ops) for pe in m.pes.values()) <= cap


@settings(max_examples=40, deadline=None)
@given(O=st.integers(2, 4), K=st.integers(1, 5), S=st.integers(1, 4))
def test_tconv_mapping_functional_property(O, K, S):
    rng = np.random.default_rng(O * 100 + K * 10 + S)
    err = rng.normal(size=(O, O))
    w = rng.normal(size=(K, K))
    m = mapping.build_tconv_mapping(O, K, S)
    out = mapping.simulate_tconv(m, err, w)
    np.testing.assert_allclose(out, _tconv_numpy(err, w, S), rtol=1e-9,
                               atol=1e-9)


@settings(max_examples=30, deadline=None)
@given(O=st.integers(1, 4), K=st.integers(1, 4), S=st.integers(1, 4))
def test_dconv_mapping_properties(O, K, S):
    N = S * (O - 1) + K  # exact fit
    m = mapping.build_dconv_mapping(N, O, K, S)
    # One PE per filter-gradient element, fully local accumulation.
    assert len(m.pes) == K * K
    assert m.n_useful_macs == K * K * O * O
    for (kx, ky), pe in m.pes.items():
        assert pe.owned_labels == {(kx, ky)}
        # multicast group = the strided gather of x for this tap
        assert len(pe.multicast) == O * O


def test_cycle_counts_beat_naive():
    """EcoFlow's schedule length (cycles) on the paper's Fig. 5 example is
    far below the naive padded schedule."""
    O, K, S = 2, 3, 2
    m = mapping.build_tconv_mapping(O, K, S)
    # Naive: direct conv over the padded error (N^2 positions x K^2 MACs)
    # on O^2 PEs -> N^2*K^2/O^2 cycles.
    N = S * (O - 1) + K
    naive_cycles = N * N * K * K / (O * O)
    assert m.cycle_count() < naive_cycles


def test_padding_formulas_vs_bruteforce():
    """Paper Sec. 3.1 closed forms vs brute-force counting."""
    for N, K, S in [(2, 3, 2), (3, 3, 2), (4, 5, 3), (5, 4, 2)]:
        dil = S * (N - 1) + 1
        inner = dil * dil - N * N
        assert tconv_inner_padding(N, S) == inner
        padded = dil + 2 * (K - 1)
        outer = padded * padded - dil * dil
        assert tconv_outer_padding(N, K, S) == outer
        frac = 1.0 - (N * N) / (padded * padded)
        assert abs(tconv_zero_mac_fraction(N, K, S) - frac) < 1e-12


def test_paper_fig3_claim():
    """>70% of multiplications are zero at stride 2 (paper Fig. 3) for
    representative layer geometries."""
    # resnet50-CONV3: err 28x28, K=3, S=2
    assert tconv_zero_mac_fraction(28, 3, 2) > 0.70
    # alexnet-CONV1: err 55x55, K=11, S=4
    assert tconv_zero_mac_fraction(55, 11, 4) > 0.90


# ---------------------------------------------------------------------------
# Grouping / expansion (paper Sec. 4.1.1)
# ---------------------------------------------------------------------------

def test_grouping_occupancy():
    m = mapping.build_tconv_mapping(4, 3, 2)     # logical 4x4 set
    fit, occ = mapping.group_pe_sets(m, 13, 15)  # paper's 13x15 array
    assert fit == (13 // 4) * (15 // 4) == 9
    assert abs(occ - 9 * 16 / 195) < 1e-12
    fit, occ = mapping.group_pe_sets(m, 3, 3)    # set larger than array
    assert fit == 0 and occ == 0.0


def test_expansion_preserves_function(rng):
    O, K, S = 6, 3, 2                            # logical 6x6 set
    m = mapping.build_tconv_mapping(O, K, S)
    ex = mapping.expand_tconv_mapping(m, 4, 4)   # physical 4x4 array
    assert ex.pe_rows == 4 and ex.pe_cols == 4
    assert ex.n_useful_macs == m.n_useful_macs   # same zero-free MAC set
    err = rng.normal(size=(O, O))
    w = rng.normal(size=(K, K))
    out = mapping.simulate_tconv_expanded(
        mapping.build_tconv_mapping(O, K, S), err, w)
    np.testing.assert_allclose(out, _tconv_numpy(err, w, S), rtol=1e-10)


@settings(max_examples=20, deadline=None)
@given(O=st.integers(2, 6), K=st.integers(1, 4), S=st.integers(1, 3),
       pr=st.integers(2, 5), pc=st.integers(2, 5))
def test_expansion_properties(O, K, S, pr, pc):
    m = mapping.build_tconv_mapping(O, K, S)
    ex = mapping.expand_tconv_mapping(m, pr, pc)
    # expansion never loses or duplicates MACs
    assert ex.n_useful_macs == K * K * O * O
    # all physical coordinates are within the array
    for (r, c) in ex.pes:
        assert 0 <= r < max(pr, O if O <= pr else pr)
        assert 0 <= c < max(pc, O if O <= pc else pc)
