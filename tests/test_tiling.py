"""Geometry-aware tile planner (`kernels/tiling.py`): analytical model
invariants (budget respected, exact channel tiles preferred, spatial
tiling under VMEM pressure, interpret-vs-compiled step weighting) and the
empirical autotune mode (candidate sweep through a registered runner,
JSON cache persistence, memory + disk cache hits)."""
from __future__ import annotations

import json

import pytest

from repro.core.spec import ConvSpec
from repro.kernels import tiling


def _shapes(B, N, O, Ci, Co):
    return (B, N, N, Ci), (B, O, O, Co)


def test_plan_respects_vmem_budget():
    """Every returned plan's modeled working set fits the budget, across
    op families and budgets."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=3)
    x_shape, dy_shape = _shapes(2, 127, 63, 256, 256)
    # filter_grad can always shrink its spatial slab to fit a tight
    # budget; forward/input_grad/backward hold a full spatial frame, so
    # only test budgets a frame can fit; ct_backward's working set has
    # an irreducible floor (full-Cout ddy row + full-channel stationary
    # dW block), so only the default budget is guaranteed feasible at
    # this 256-channel geometry.  Below the listed budgets the planner
    # falls back to the minimum-footprint candidate by design.
    budgets_by_op = {
        "filter_grad": (1 << 20, 4 << 20, tiling.DEFAULT_VMEM_BUDGET),
        "ct_backward": (tiling.DEFAULT_VMEM_BUDGET,),
    }
    for op in tiling.OPS:
        budgets = budgets_by_op.get(op,
                                    (4 << 20, tiling.DEFAULT_VMEM_BUDGET))
        for budget in budgets:
            plan = tiling.plan_tiles(op, spec, x_shape=x_shape,
                                     dy_shape=dy_shape,
                                     vmem_budget=budget, interpret=False)
            g = tiling._geom(op, spec, x_shape, dy_shape, 4)
            ws, _, _, _ = tiling._MODELS[op](
                g, plan.cin_tile, plan.cout_tile, plan.spatial_tile,
                plan.tap_unroll, plan.phase_unroll)
            assert ws <= budget, (op, budget, plan)
            assert plan.grid_order == tiling._GRID_ORDERS[op]
            assert plan.source == "analytical"


def test_exact_channel_tiles_preferred_when_small():
    """Sub-128 channel counts get their EXACT extent as the tile (no
    host pad/slice at all) -- the ShuffleNet-29 case that a hard-coded
    128 default handled with pad-to-128 waste."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 29, 14, 29, 29)
    for interpret in (False, True):
        plan = tiling.plan_tiles("filter_grad", spec, x_shape=x_shape,
                                 dy_shape=dy_shape, interpret=interpret)
        assert plan.cin_tile == 29 and plan.cout_tile == 29, plan


def test_spatial_tiling_engages_under_vmem_pressure():
    """A big padded frame with a tight budget forces the filter-grad x
    block down to a spatial slab (spatial_tile < Oh), instead of either
    busting the budget or shrinking channel tiles to nothing."""
    spec = ConvSpec.make(stride=1, padding=1, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 256, 256, 64, 64)
    plan = tiling.plan_tiles("filter_grad", spec, x_shape=x_shape,
                             dy_shape=dy_shape, vmem_budget=1 << 20,
                             interpret=False)
    assert plan.spatial_tile < 256, plan
    g = tiling._geom("filter_grad", spec, x_shape, dy_shape, 4)
    ws, _, _, _ = tiling._MODELS["filter_grad"](
        g, plan.cin_tile, plan.cout_tile, plan.spatial_tile,
        plan.tap_unroll)
    assert ws <= 1 << 20


def test_interpret_mode_prefers_fewer_steps():
    """Interpret mode pays per grid step, so the planner unrolls the tap
    loop (fewer, fatter steps); compiled mode caps the unroll at the
    code-size bound."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 29, 14, 29, 29)
    interp = tiling.plan_tiles("filter_grad", spec, x_shape=x_shape,
                               dy_shape=dy_shape, interpret=True)
    comp = tiling.plan_tiles("filter_grad", spec, x_shape=x_shape,
                             dy_shape=dy_shape, interpret=False)
    assert interp.tap_unroll == 9, interp       # all taps in one step
    assert comp.tap_unroll <= tiling.MAX_TAP_UNROLL_COMPILED, comp


def test_plan_is_deterministic():
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=5, dilation=2)
    x_shape, dy_shape = _shapes(2, 33, 13, 48, 96)
    for op in tiling.OPS:
        a, b = (tiling.plan_tiles(op, spec, x_shape=x_shape,
                                  dy_shape=dy_shape, interpret=True)
                for _ in range(2))
        assert a == b, op


def test_plan_tiles_memoized_with_env_in_key():
    """The analytical `plan_tiles` path is memoized (ops.py re-resolves
    the plan on every conv call -- the steady-state cost must be a dict
    lookup), and the env-derived budget/mode are PART OF THE KEY: an
    `ECOFLOW_VMEM_BUDGET` flip re-plans instead of replaying a winner
    scored against the old constraints."""
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 65, 32, 64, 64)
    kw = dict(x_shape=x_shape, dy_shape=dy_shape, interpret=True)
    tiling._planned.cache_clear()
    p1 = tiling.plan_tiles("backward", spec, **kw)
    miss1 = tiling.plan_cache_info().misses
    p2 = tiling.plan_tiles("backward", spec, **kw)
    info = tiling.plan_cache_info()
    assert p1 == p2
    assert info.misses == miss1 and info.hits >= 1, info
    # A different budget is a different key (re-plan, not a cache hit) --
    # plan_tiles resolves the env BEFORE the lookup, so this is exactly
    # the ECOFLOW_VMEM_BUDGET-flip path.
    tiling.plan_tiles("backward", spec, vmem_budget=1 << 22, **kw)
    assert tiling.plan_cache_info().misses == miss1 + 1
    # ... and so is a different ECOFLOW_TILING mode string.
    tiling.plan_tiles("backward", spec, mode="analytical-v2", **kw)
    assert tiling.plan_cache_info().misses == miss1 + 2


def test_unknown_op_rejected():
    spec = ConvSpec.make(stride=1, filter_shape=1)
    with pytest.raises(ValueError, match="unknown op"):
        tiling.plan_tiles("nope", spec, x_shape=(1, 4, 4, 1),
                          dy_shape=(1, 4, 4, 1))


def test_autotune_sweeps_caches_and_persists(tmp_path):
    """Autotune mode sweeps the candidate set through the registered
    runner exactly once per geometry: the winner persists to the JSON
    cache and later calls hit the in-memory / on-disk caches without
    re-running a single candidate."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=2)
    x_shape, dy_shape = _shapes(1, 8, 4, 4, 4)
    cache = tmp_path / "tile_cache.json"
    calls = []

    def factory(spec_, x_s, dy_s):
        assert spec_ == spec and x_s == x_shape and dy_s == dy_shape

        def run(plan):
            calls.append(plan)
            return None

        return run

    kw = dict(x_shape=x_shape, dy_shape=dy_shape, mode="autotune",
              runner_factory=factory, tile_cache_path=cache)
    tiling._MEM_CACHE.clear()
    plan = tiling.plan_tiles("filter_grad", spec, **kw)
    assert calls, "autotune never invoked the runner"
    assert plan.source == "autotune"
    n_swept = len(calls)

    # Second call: in-memory cache, no new runner invocations.
    plan2 = tiling.plan_tiles("filter_grad", spec, **kw)
    assert len(calls) == n_swept
    assert (plan2.cin_tile, plan2.cout_tile) == (plan.cin_tile,
                                                 plan.cout_tile)

    # Fresh "process": disk cache only.
    tiling._MEM_CACHE.clear()
    plan3 = tiling.plan_tiles("filter_grad", spec, **kw)
    assert len(calls) == n_swept
    assert plan3.source == "cache"
    assert plan3.cin_tile == plan.cin_tile

    doc = json.loads(cache.read_text())
    assert len(doc) == 1
    (key, rec), = doc.items()
    assert key.startswith("filter_grad|") and "us" in rec
    assert rec["cin_tile"] == plan.cin_tile


def test_autotune_without_runner_falls_back_analytical(tmp_path):
    """No registered runner for an op -> autotune degrades to the
    analytical model instead of failing the conv."""
    spec = ConvSpec.make(stride=1, filter_shape=1)
    saved = dict(tiling._RUNNERS)
    tiling._RUNNERS.clear()
    try:
        plan = tiling.plan_tiles(
            "forward", spec, x_shape=(1, 4, 4, 3), dy_shape=(1, 4, 4, 5),
            mode="autotune", tile_cache_path=tmp_path / "c.json")
    finally:
        tiling._RUNNERS.update(saved)
    assert plan.source == "analytical"


def test_autotune_through_real_kernel(tmp_path):
    """End to end: the filter-grad kernel's registered runner really
    executes the kernel per candidate and the cached winner reproduces
    the reference gradient when used."""
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ref
    from repro.kernels.dconv_filtergrad import dconv_filter_grad_pallas
    rng = np.random.default_rng(0)
    B, N, K, S, Ci, Co = 1, 7, 2, 2, 3, 4
    O = (N - K) // S + 1
    x_shape, dy_shape = (B, N, N, Ci), (B, O, O, Co)
    spec = ConvSpec.make(stride=S, padding=0, filter_shape=K)
    tiling._MEM_CACHE.clear()
    plan = tiling.plan_tiles("filter_grad", spec, x_shape=x_shape,
                             dy_shape=dy_shape, mode="autotune",
                             tile_cache_path=tmp_path / "c.json")
    assert plan.source == "autotune"
    assert (tmp_path / "c.json").exists()
    x = jnp.asarray(rng.normal(size=x_shape), jnp.float32)
    dy = jnp.asarray(rng.normal(size=dy_shape), jnp.float32)
    dw = dconv_filter_grad_pallas(
        x, dy, stride=(S, S), padding=(0, 0), k=(K, K),
        cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
        spatial_tile=plan.spatial_tile, tap_unroll=plan.tap_unroll,
        interpret=True)
    want = ref.dconv_filter_grad_ref(x, dy, stride=(S, S),
                                     padding=(0, 0), k=(K, K))
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Epilogue-aware planning + cache keys (DESIGN.md Sec. 2.8)
# ---------------------------------------------------------------------------

def test_cache_key_includes_epilogue():
    """The autotune cache key carries the epilogue tag: an epilogue
    changes the kernel's block set, so an epilogue-free winner must never
    be replayed for an epilogue-bearing launch (and vice versa)."""
    from repro.core.spec import Epilogue
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 9, 5, 4, 8)
    base = tiling._cache_key("backward", spec, x_shape, dy_shape, 4,
                             1 << 23, True, None)
    relu = tiling._cache_key("backward", spec, x_shape, dy_shape, 4,
                             1 << 23, True, Epilogue(activation="relu"))
    brelu = tiling._cache_key("backward", spec, x_shape, dy_shape, 4,
                              1 << 23, True,
                              Epilogue(activation="relu", bias=True))
    assert base.endswith("|ep:none")
    assert relu.endswith("|ep:relu")
    assert brelu.endswith("|ep:b+relu")
    assert len({base, relu, brelu}) == 3


def test_autotune_reads_legacy_keyless_rows(tmp_path):
    """Rows written before the epilogue slot existed (no `|ep:` suffix)
    are still served -- but ONLY for epilogue-free lookups, whose
    candidate set they were actually swept against.  An epilogue-bearing
    lookup must NOT match a legacy row."""
    from repro.core.spec import Epilogue
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=2)
    x_shape, dy_shape = _shapes(1, 8, 4, 4, 4)
    cache = tmp_path / "tile_cache.json"
    key = tiling._cache_key("filter_grad", spec, x_shape, dy_shape, 4,
                            tiling.DEFAULT_VMEM_BUDGET, True, None)
    # A pre-epilogue row predates the |st:/|ep: suffixes entirely.
    pre_strategy, _, tag = key.replace("|st:phase|", "|").rpartition("|ep:")
    legacy_key = pre_strategy
    assert tag == "none"
    legacy_rec = {"cin_tile": 4, "cout_tile": 4, "spatial_tile": 2,
                  "tap_unroll": 1, "phase_unroll": 1,
                  "grid_order": ["cin", "cout", "batch", "spatial", "tap"],
                  "source": "autotune", "us": 1.0}
    cache.write_text(json.dumps({legacy_key: legacy_rec}))

    calls = []

    def factory(spec_, x_s, dy_s, epilogue=None):
        def run(plan):
            calls.append(plan)
            return None
        return run

    kw = dict(x_shape=x_shape, dy_shape=dy_shape, mode="autotune",
              interpret=True, runner_factory=factory,
              tile_cache_path=cache)
    tiling._MEM_CACHE.clear()
    plan = tiling.plan_tiles("filter_grad", spec, **kw)
    assert not calls, "legacy keyless row should have been served"
    assert plan.source == "cache" and plan.spatial_tile == 2

    # An epilogue-bearing lookup misses the legacy row and re-sweeps.
    tiling._MEM_CACHE.clear()
    plan_ep = tiling.plan_tiles("filter_grad", spec,
                                epilogue=Epilogue(activation="relu"), **kw)
    assert calls, "epilogue lookup must not be served a legacy row"
    assert plan_ep.source == "autotune"
    doc = json.loads(cache.read_text())
    assert legacy_key in doc                      # legacy row untouched
    assert any(k.endswith("|ep:relu") for k in doc)


def test_autotune_passes_epilogue_to_runner_factory(tmp_path):
    """Epilogue-aware runner factories receive the descriptor; legacy
    3-arg factories still work for epilogue-free sweeps but are rejected
    (not silently mistimed) when the launch carries an epilogue."""
    from repro.core.spec import Epilogue
    ep = Epilogue(activation="relu", bias=True)
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=2)
    x_shape, dy_shape = _shapes(1, 8, 4, 4, 4)
    seen = []

    def factory(spec_, x_s, dy_s, epilogue=None):
        seen.append(epilogue)

        def run(plan):
            return None
        return run

    kw = dict(x_shape=x_shape, dy_shape=dy_shape, mode="autotune",
              tile_cache_path=tmp_path / "c.json")
    tiling._MEM_CACHE.clear()
    tiling.plan_tiles("filter_grad", spec, epilogue=ep,
                      runner_factory=factory, **kw)
    assert seen == [ep]

    def legacy_factory(spec_, x_s, dy_s):
        def run(plan):
            return None
        return run

    tiling._MEM_CACHE.clear()
    with pytest.raises(TypeError, match="epilogue"):
        tiling.plan_tiles("forward", spec, epilogue=ep,
                          runner_factory=legacy_factory, **kw)


def test_epilogue_shifts_working_set_model():
    """The backward model charges the epilogue's extra blocks: the
    y-mask stream doubles the dy-frame residency and the db output adds
    its accumulator, so a tight budget can force a smaller tile than the
    epilogue-free plan chooses."""
    from repro.core.spec import Epilogue
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 65, 33, 64, 64)
    g = tiling._geom("backward", spec, x_shape, dy_shape, 4)
    ep = Epilogue(activation="relu", bias=True)
    ws0, _, _, _ = tiling._MODELS["backward"](g, 64, 64, 33, 1, 1)
    ws1, _, _, _ = tiling._MODELS["backward"](g, 64, 64, 33, 1, 1, ep=ep)
    assert ws1 > ws0
    # ct_backward: z block mirrors the g block.
    g2 = tiling._geom("ct_backward", spec, x_shape, dy_shape, 4)
    ws0, _, _, _ = tiling._MODELS["ct_backward"](g2, 64, 64, 33, 1, 1)
    ws1, _, _, _ = tiling._MODELS["ct_backward"](g2, 64, 64, 33, 1, 1,
                                                 ep=ep)
    assert ws1 > ws0


def test_cache_store_is_atomic_and_leaves_no_temp(tmp_path):
    """The cache publish goes through a same-directory temp file +
    os.replace: after a store the path holds complete, parseable JSON
    and no temp litter remains (the atomic-rename contract concurrent
    autotuners rely on)."""
    cache = tmp_path / "tile_cache.json"
    tiling._store_disk_cache(cache, {"k": {"cin_tile": 4}})
    assert json.loads(cache.read_text()) == {"k": {"cin_tile": 4}}
    assert [p.name for p in tmp_path.iterdir()] == ["tile_cache.json"]
    # overwrite replaces wholesale, again atomically
    tiling._store_disk_cache(cache, {"k2": {"cout_tile": 8}})
    assert json.loads(cache.read_text()) == {"k2": {"cout_tile": 8}}
    assert [p.name for p in tmp_path.iterdir()] == ["tile_cache.json"]


def test_corrupt_cache_file_warns_and_retunes(tmp_path):
    """A truncated/corrupt cache file (pre-atomic-write crash, torn
    copy) must warn and re-tune -- not crash the conv that looked it up
    -- and the re-tuned winner must rewrite the file as valid JSON."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=2)
    x_shape, dy_shape = _shapes(1, 8, 4, 4, 4)
    cache = tmp_path / "tile_cache.json"
    cache.write_text('{"filter_grad|truncated-mid-wri')   # torn write
    calls = []

    def factory(spec_, x_s, dy_s):
        def run(plan):
            calls.append(plan)
            return None
        return run

    kw = dict(x_shape=x_shape, dy_shape=dy_shape, mode="autotune",
              runner_factory=factory, tile_cache_path=cache)
    tiling._MEM_CACHE.clear()
    with pytest.warns(RuntimeWarning, match="corrupt autotune tile cache"):
        plan = tiling.plan_tiles("filter_grad", spec, **kw)
    assert calls, "corrupt cache should trigger a fresh sweep"
    assert plan.source == "autotune"
    doc = json.loads(cache.read_text())   # file rewritten, valid again
    assert any(k.startswith("filter_grad|") for k in doc)


def test_malformed_cache_record_warns_and_retunes(tmp_path):
    """A parseable file whose matching ROW is missing required fields is
    equally tolerated: warn, ignore the row, sweep, rewrite."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=2)
    x_shape, dy_shape = _shapes(1, 8, 4, 4, 4)
    cache = tmp_path / "tile_cache.json"
    calls = []

    def factory(spec_, x_s, dy_s):
        def run(plan):
            calls.append(plan)
            return None
        return run

    kw = dict(x_shape=x_shape, dy_shape=dy_shape, mode="autotune",
              runner_factory=factory, tile_cache_path=cache)
    tiling._MEM_CACHE.clear()
    good = tiling.plan_tiles("filter_grad", spec, **kw)
    (key, rec), = json.loads(cache.read_text()).items()
    cache.write_text(json.dumps({key: {"us": 1.0}}))   # fields gone
    tiling._MEM_CACHE.clear()
    n = len(calls)
    with pytest.warns(RuntimeWarning, match="malformed autotune tile"):
        plan = tiling.plan_tiles("filter_grad", spec, **kw)
    assert len(calls) > n, "malformed row should re-sweep"
    assert plan.source == "autotune"
    assert plan.cin_tile == good.cin_tile


# ---------------------------------------------------------------------------
# Strategy planner (`plan_strategy`, DESIGN.md Sec. 2.10)
# ---------------------------------------------------------------------------

def test_cache_key_includes_strategy():
    """The strategy segment keys the cache: a phase-swept winner must
    never be replayed for an implicit-GEMM launch, and the `|st:` slot
    sits BEFORE `|ep:` so the epilogue tag keeps its suffix position."""
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 9, 5, 4, 8)
    keys = {st: tiling._cache_key("input_grad", spec, x_shape, dy_shape,
                                  4, 1 << 23, True, None, st)
            for st in ("phase", "implicit_gemm", "auto")}
    assert len(set(keys.values())) == 3
    for st, key in keys.items():
        assert f"|st:{st}|" in key
        assert key.endswith("|ep:none")


def test_legacy_rows_served_only_to_phase_lookups():
    """`_legacy_cache_keys`: pre-strategy and pre-epilogue key forms are
    reconstructed ONLY for `st:phase` lookups -- the legacy rows were
    swept against the phase kernels, so an implicit-GEMM (or auto)
    lookup gets no fallback."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 9, 4, 4, 4)
    phase_key = tiling._cache_key("input_grad", spec, x_shape, dy_shape,
                                  4, 1 << 23, True, None, "phase")
    legacy = tiling._legacy_cache_keys(phase_key)
    assert len(legacy) == 2
    assert legacy[0] == phase_key.replace("|st:phase|", "|")
    assert legacy[1] == legacy[0].rpartition("|ep:")[0]
    for st in ("implicit_gemm", "auto"):
        key = tiling._cache_key("input_grad", spec, x_shape, dy_shape,
                                4, 1 << 23, True, None, st)
        assert tiling._legacy_cache_keys(key) == ()


def test_strategy_env_flip_replans(monkeypatch):
    """Flipping ECOFLOW_STRATEGY re-plans on the next call instead of
    serving the other strategy's memoized plan: the strategy is part of
    the `_planned` lru key, and the returned plan actually differs
    (implicit-GEMM plans carry no phase axis)."""
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=3)
    x_shape, dy_shape = _shapes(2, 9, 5, 16, 32)
    kw = dict(x_shape=x_shape, dy_shape=dy_shape, interpret=True)

    monkeypatch.setenv("ECOFLOW_STRATEGY", "phase")
    st_p, plan_p = tiling.plan_strategy("input_grad", spec, **kw)
    assert st_p == "phase"
    assert plan_p.grid_order == tiling._GRID_ORDERS["input_grad"]

    monkeypatch.setenv("ECOFLOW_STRATEGY", "implicit_gemm")
    st_g, plan_g = tiling.plan_strategy("input_grad", spec, **kw)
    assert st_g == "implicit_gemm"
    assert plan_g.grid_order == \
        tiling._GRID_ORDERS["input_grad:implicit_gemm"]
    assert "phase" not in plan_g.grid_order
    assert plan_g.phase_unroll == 1

    # back to phase: served again (memoized per strategy, not clobbered)
    monkeypatch.setenv("ECOFLOW_STRATEGY", "phase")
    st_p2, plan_p2 = tiling.plan_strategy("input_grad", spec, **kw)
    assert (st_p2, plan_p2) == (st_p, plan_p)

    monkeypatch.setenv("ECOFLOW_STRATEGY", "bogus")
    with pytest.raises(ValueError, match="ECOFLOW_STRATEGY"):
        tiling.plan_strategy("input_grad", spec, **kw)


def test_plan_strategy_unsupported_op_falls_back_to_phase():
    """Ops the implicit-GEMM family does not cover (the fused
    dual-gradient backwards, forward, filter_grad) silently plan phase
    even when implicit_gemm is requested -- the per-op fallback that
    keeps the fused backward launches phase-decomposed."""
    spec = ConvSpec.make(stride=2, padding=1, filter_shape=3)
    x_shape, dy_shape = _shapes(1, 9, 5, 8, 8)
    for op in ("forward", "filter_grad", "backward", "ct_backward"):
        st, plan = tiling.plan_strategy(op, spec, x_shape=x_shape,
                                        dy_shape=dy_shape, interpret=True,
                                        strategy="implicit_gemm")
        assert st == "phase", op
        assert plan.grid_order == tiling._GRID_ORDERS[op]


def test_strategy_cache_roundtrip_and_isolation(tmp_path):
    """Autotune rows are strategy-keyed end to end: a phase row plus
    both legacy forms in the cache must NOT be served to an
    implicit-GEMM lookup (it sweeps its own candidates), and the auto
    race persists ONE `|st:auto` row whose `strategy` field records the
    winner and is replayed as (strategy, plan)."""
    spec = ConvSpec.make(stride=2, padding=0, filter_shape=2)
    x_shape, dy_shape = _shapes(1, 8, 4, 4, 4)
    cache = tmp_path / "tile_cache.json"
    phase_key = tiling._cache_key("input_grad", spec, x_shape, dy_shape,
                                  4, tiling.DEFAULT_VMEM_BUDGET, True,
                                  None, "phase")
    pre_strategy = phase_key.replace("|st:phase|", "|")
    rec = {"cin_tile": 4, "cout_tile": 4, "spatial_tile": 8,
           "tap_unroll": 1, "phase_unroll": 1,
           "grid_order": ["batch", "phase", "cin", "cout", "tap"],
           "source": "autotune", "us": 1.0}
    cache.write_text(json.dumps({
        phase_key: rec, pre_strategy: rec,
        pre_strategy.rpartition("|ep:")[0]: rec}))

    calls = []

    def factory(spec_, x_s, dy_s, epilogue=None):
        def run(plan):
            calls.append(plan)
            return None
        return run

    kw = dict(x_shape=x_shape, dy_shape=dy_shape, mode="autotune",
              interpret=True, tile_cache_path=cache)
    tiling._MEM_CACHE.clear()
    tiling._MEM_STRATEGY.clear()

    st, plan = tiling.plan_strategy("input_grad", spec, strategy="phase",
                                    runner_factory=factory, **kw)
    assert not calls, "phase lookup should be served its cached row"
    assert (st, plan.source) == ("phase", "cache")

    ig_runner = tiling._RUNNERS.get(("input_grad", "implicit_gemm"))
    saved = dict(tiling._RUNNERS)
    tiling._RUNNERS.clear()
    try:
        tiling._RUNNERS[("input_grad", "implicit_gemm")] = factory
        st, plan = tiling.plan_strategy("input_grad", spec,
                                        strategy="implicit_gemm", **kw)
        assert calls, "implicit-GEMM lookup must not be served phase rows"
        assert (st, plan.source) == ("implicit_gemm", "autotune")
        doc = json.loads(cache.read_text())
        ig_key = phase_key.replace("|st:phase|", "|st:implicit_gemm|")
        assert doc[ig_key]["strategy"] == "implicit_gemm"

        # auto race: both runners registered, one |st:auto row persisted
        tiling._RUNNERS[("input_grad", "phase")] = factory
        tiling._MEM_CACHE.clear()
        tiling._MEM_STRATEGY.clear()
        st, plan = tiling.plan_strategy("input_grad", spec,
                                        strategy="auto", **kw)
        assert st in tiling.STRATEGIES
        auto_key = phase_key.replace("|st:phase|", "|st:auto|")
        doc = json.loads(cache.read_text())
        assert doc[auto_key]["strategy"] == st
        # replay from disk: same (strategy, plan) without a sweep
        tiling._MEM_CACHE.clear()
        tiling._MEM_STRATEGY.clear()
        n = len(calls)
        st2, plan2 = tiling.plan_strategy("input_grad", spec,
                                          strategy="auto", **kw)
        assert len(calls) == n
        assert st2 == st and plan2.source == "cache"
        tiles = lambda p: (p.cin_tile, p.cout_tile, p.spatial_tile,
                           p.tap_unroll, p.phase_unroll, p.grid_order)
        assert tiles(plan2) == tiles(plan)
    finally:
        tiling._RUNNERS.clear()
        tiling._RUNNERS.update(saved)
        if ig_runner is not None:
            tiling._RUNNERS[("input_grad", "implicit_gemm")] = ig_runner


def test_analytical_race_crossover_on_bench_geometries():
    """The analytical strategy model reproduces the paper's crossover on
    the Table 5 / Table 7 geometries: the high-waste AlexNet S=4 stem
    plans phase decomposition while at least one S<=2 / dilated layer
    plans implicit-GEMM -- in BOTH execution modes."""
    from repro.core import dataflow_sim as ds
    layers = {L.name: L for L in (list(ds.TABLE5_LAYERS)
                                  + list(ds.TABLE7_GAN_LAYERS)
                                  + list(ds.DILATED_LAYERS))}

    def race(L, interpret):
        spec = ConvSpec.make(stride=L.stride, padding=L.padding,
                             filter_shape=L.k, dilation=L.dilation)
        st, _ = tiling.plan_strategy(
            "input_grad", spec,
            x_shape=(L.batch, L.n_in, L.n_in, L.c_in),
            dy_shape=(L.batch, L.n_out, L.n_out, L.m),
            interpret=interpret, strategy="auto")
        return st

    for interpret in (True, False):
        picks = {name: race(L, interpret) for name, L in layers.items()}
        assert picks["alexnet-CONV1"] == "phase", picks
        assert "implicit_gemm" in picks.values(), picks
