"""Shared test fixtures/helpers.

NOTE: tests must see the default single CPU device -- do NOT set
XLA_FLAGS=--xla_force_host_platform_device_count here (the dry-run sets it
in its own process).  Tests that need a multi-device mesh spawn a
subprocess (see tests/test_multidevice.py).
"""
from __future__ import annotations

import numpy as np
import pytest

try:  # optional dev dependency (see requirements-dev.txt)
    import hypothesis  # noqa: F401
except ImportError:  # graceful fallback: deterministic property-test shim
    from _hypothesis_shim import install as _install_hypothesis_shim
    _install_hypothesis_shim()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_allclose(a, b, rtol=1e-4, atol=1e-4, err_msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float64),
                               np.asarray(b, np.float64),
                               rtol=rtol, atol=atol, err_msg=err_msg)
