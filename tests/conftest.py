"""Shared test fixtures/helpers.

NOTE: tests must see the default single CPU device -- do NOT set
XLA_FLAGS=--xla_force_host_platform_device_count here (the dry-run sets it
in its own process).  Tests that need a multi-device mesh spawn a
subprocess (see tests/test_multidevice.py).
"""
from __future__ import annotations

import numpy as np
import pytest

try:  # optional dev dependency (see requirements-dev.txt)
    import hypothesis  # noqa: F401
except ImportError:  # graceful fallback: deterministic property-test shim
    from _hypothesis_shim import install as _install_hypothesis_shim
    _install_hypothesis_shim()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_allclose(a, b, rtol=1e-4, atol=1e-4, err_msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float64),
                               np.asarray(b, np.float64),
                               rtol=rtol, atol=atol, err_msg=err_msg)


# ---------------------------------------------------------------------------
# jaxpr inspection helpers (shared by the structural-guarantee tests in
# test_dispatch.py and test_dilated_parity.py -- one traversal, so a fix
# for a new higher-order primitive reaches every suite)
# ---------------------------------------------------------------------------

def walk_eqns(jaxpr):
    """Yield every eqn in a (closed) jaxpr, recursing into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)  # ClosedJaxpr
            if sub is not None:
                yield from walk_eqns(sub)
            elif hasattr(v, "eqns"):         # raw Jaxpr
                yield from walk_eqns(v)


def walk_eqns_outside_pallas(jaxpr):
    """Like `walk_eqns`, but does NOT descend into pallas_call kernel
    bodies: the epilogue-fusion pins assert that bias/activation/mask
    eqns exist ONLY inside the kernels, so the in-kernel eqns must not
    leak into the 'outside' traversal."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                yield from walk_eqns_outside_pallas(sub)
            elif hasattr(v, "eqns"):
                yield from walk_eqns_outside_pallas(v)


def count_pallas_calls(fn, *args) -> int:
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(1 for e in walk_eqns(jaxpr.jaxpr)
               if e.primitive.name == "pallas_call")


def pallas_grids(fn, *args):
    """Grid tuples of every pallas_call in the traced jaxpr."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    return [tuple(e.params["grid_mapping"].grid)
            for e in walk_eqns(jaxpr.jaxpr)
            if e.primitive.name == "pallas_call"]


def pallas_block_shapes(fn, *args):
    """Per pallas_call in the traced jaxpr: the list of block shapes of
    every in/out BlockSpec (the kernel's VMEM working set)."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    return [[tuple(bm.block_shape)
             for bm in e.params["grid_mapping"].block_mappings]
            for e in walk_eqns(jaxpr.jaxpr)
            if e.primitive.name == "pallas_call"]


def max_intermediate_size(fn, *args) -> int:
    """Largest array (elements) produced by any eqn in the traced jaxpr."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    sizes = [int(np.prod(v.aval.shape))
             for e in walk_eqns(jaxpr.jaxpr) for v in e.outvars
             if hasattr(v.aval, "shape")]
    return max(sizes)
