"""MoE dispatch/combine correctness: with ample capacity the capacity-based
GShard dispatch must equal the dense per-token top-k mixture; with tight
capacity, dropped tokens pass through with zero contribution."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import ModelConfig

from conftest import assert_allclose


def _cfg(**kw):
    base = dict(name="t", family="moe", n_layers=1, d_model=16, d_ff=32,
                vocab=64, n_experts=4, top_k=2, moe_dff=32,
                capacity_factor=8.0)
    base.update(kw)
    return ModelConfig(**base)


def _dense_reference(params, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    B, S, D = x.shape
    logits = (x @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    out = jnp.zeros((B, S, D), jnp.float32)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ params["experts_wg"][e]) * \
            (x @ params["experts_wi"][e])
        ye = h @ params["experts_wo"][e]
        w_e = (gv * (gi == e)).sum(-1)
        out = out + w_e[..., None] * ye
    if cfg.n_shared_experts:
        hs = jax.nn.silu(x @ params["shared_wg"]) * (x @ params["shared_wi"])
        out = out + hs @ params["shared_wo"]
    return out


@pytest.mark.parametrize("shared", [0, 1])
def test_moe_matches_dense_with_ample_capacity(rng, shared):
    cfg = _cfg(n_shared_experts=shared)
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    out, aux = moe.moe_block(params, x, cfg)
    want = _dense_reference(params, x, cfg)
    assert_allclose(out, want, rtol=1e-4, atol=1e-4)
    assert float(aux) >= 1.0 - 1e-5   # Switch aux loss lower bound is 1


def test_moe_capacity_drops_overflow(rng):
    """With capacity 1 slot per expert, overflow tokens contribute zero
    (residual pass-through happens in the caller)."""
    cfg = _cfg(capacity_factor=1e-6)   # floor -> minimum capacity
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)), jnp.float32)
    out, _ = moe.moe_block(params, x, cfg)
    dense = _dense_reference(params, x, cfg)
    # Some tokens must be dropped (all-equal would mean capacity was ample)
    per_tok = jnp.abs(out - dense).sum(-1)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out).sum()) > 0.0
    assert bool((per_tok > 1e-3).any())


def test_capacity_formula():
    cfg = _cfg(capacity_factor=1.25)
    c = moe.capacity(cfg, 128)
    # ceil(128 * 2 / 4 * 1.25) = 80, multiple of 4
    assert c == 80
    assert moe.capacity(cfg, 4) >= 4


def test_aux_loss_balanced_router_is_minimal(rng):
    """A perfectly uniform router gives aux == 1 (the minimum)."""
    cfg = _cfg()
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)), jnp.float32)
    _, aux = moe.moe_block(params, x, cfg)
    assert abs(float(aux) - 1.0) < 0.05
