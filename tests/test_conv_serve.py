"""`ConvServeEngine`: geometry buckets, degradation ladder, breakers,
deadlines, shedding -- plus the LM `ServeEngine` mid-flight slot refill.

The acceptance pins (ISSUE 9): under a seeded fault schedule injecting
kernel exceptions, NaN outputs, and a corrupt tile cache, the engine
completes 100% of in-deadline requests with results bit-matching the
reference backend; the failing backend is quarantined and later
re-probed; requests beyond the admission bound are shed, never hung on;
and with injection off the fast path stays at ONE forward `pallas_call`
per conv layer.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import assert_allclose, count_pallas_calls
from repro.models import gan, vision
from repro.serve.conv_engine import (ConvRequest, ConvServeEngine,
                                     CircuitBreaker, DEFAULT_LADDER)
from repro.serve.faults import (FaultEvent, FaultInjector, FaultSchedule,
                                corrupt_tile_cache)

Z_DIM, BASE = 8, 8
IMG = (8, 8, 3)


@pytest.fixture(scope="module")
def gan_params():
    return gan.generator_init(jax.random.PRNGKey(0), z_dim=Z_DIM,
                              base=BASE, out_ch=3)


@pytest.fixture(scope="module")
def aspp_params():
    return vision.atrous_head_init(jax.random.PRNGKey(1), in_ch=IMG[2],
                                   width=4, n_classes=4)


def _gan_reqs(rng, n, **kw):
    return [ConvRequest(None, "gan_gen",
                        rng.standard_normal(Z_DIM).astype(np.float32), **kw)
            for _ in range(n)]


def _aspp_reqs(rng, n, **kw):
    return [ConvRequest(None, "aspp",
                        rng.standard_normal(IMG).astype(np.float32), **kw)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Clean path
# ---------------------------------------------------------------------------

def test_serves_both_buckets_clean(gan_params, aspp_params, rng):
    eng = ConvServeEngine(gan_params=gan_params, aspp_params=aspp_params,
                          slot_batch=2, queue_limit=16)
    reqs = _gan_reqs(rng, 3) + _aspp_reqs(rng, 2) + _gan_reqs(rng, 1)
    res = eng.serve(reqs)
    assert len(res) == 6                       # interleaved buckets all land
    for r in reqs:
        out = res[r.uid]
        assert np.all(np.isfinite(out))
        assert out.shape == ((32, 32, 3) if r.kind == "gan_gen"
                             else (8, 8, 4))
    h = eng.health()
    assert h["completed"] == 6 and h["sheds"] == 0 and h["failures"] == 0
    assert h["p50_us"] is not None and h["p99_us"] >= h["p50_us"]


def test_clean_parity_vs_direct_apply(gan_params, rng):
    """Bucketed, padded serving returns exactly what a direct jitted
    batch apply returns for the same rows."""
    n = 3
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=n,
                          queue_limit=8)
    reqs = _gan_reqs(rng, n)
    res = eng.serve(reqs)
    batch = np.stack([r.payload for r in reqs])
    direct = np.asarray(jax.jit(
        lambda z: gan.generator_apply(gan_params, z,
                                      backend=DEFAULT_LADDER[0]))(batch))
    for i, r in enumerate(reqs):
        assert np.array_equal(res[r.uid], direct[i])


# ---------------------------------------------------------------------------
# Acceptance: seeded faults -> 100% in-deadline completion, reference parity
# ---------------------------------------------------------------------------

def _always_fail(sites, seed=5):
    return FaultInjector(FaultSchedule.seeded(
        seed, sites=list(sites), rate=1.0, horizon=1024,
        kinds=("kernel_exception",)))


@pytest.mark.parametrize("kind", ["gan_gen", "aspp"])
def test_full_degradation_bit_matches_reference(gan_params, aspp_params,
                                                rng, kind):
    """Kernel exceptions on every non-reference rung force each bucket
    down to `reference`; served results must be BIT-identical to the
    reference backend's own jitted batch output."""
    n = 2
    inj = _always_fail([f"{kind}:pallas", f"{kind}:xla_zero_free"])
    eng = ConvServeEngine(gan_params=gan_params, aspp_params=aspp_params,
                          slot_batch=n, queue_limit=8, injector=inj)
    reqs = _gan_reqs(rng, n) if kind == "gan_gen" else _aspp_reqs(rng, n)
    res = eng.serve(reqs)
    assert len(res) == n                       # 100% completion
    batch = np.stack([r.payload for r in reqs])
    if kind == "gan_gen":
        fn = lambda b: gan.generator_apply(gan_params, b,
                                           backend="reference")
    else:
        fn = lambda b: vision.atrous_head_apply(aspp_params, b,
                                                backend="reference")
    expect = np.asarray(jax.jit(fn)(batch))
    for i, r in enumerate(reqs):
        assert np.array_equal(res[r.uid], expect[i]), r.uid
    h = eng.health()
    assert h["kernel_faults"] >= 2 and h["fallbacks"] >= 1


def test_mixed_fault_storm_completes_all(gan_params, rng, tmp_path):
    """The ISSUE's composite scenario: kernel exceptions AND NaN outputs
    on the fast rungs AND a corrupt tile-cache artifact.  Warmup warns
    (and re-plans); every admitted request still completes with a finite
    result."""
    cache = tmp_path / "tile_cache.json"
    corrupt_tile_cache(cache, "garbage")
    inj = FaultInjector(FaultSchedule.seeded(
        13, sites=["gan_gen:pallas", "gan_gen:xla_zero_free"], rate=0.4,
        horizon=1024, kinds=("kernel_exception", "nan_output")))
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=2,
                          queue_limit=32, injector=inj,
                          tile_cache_path=cache)
    with pytest.warns(RuntimeWarning):
        summary = eng.warmup([("gan_gen", (Z_DIM,))])
    assert summary["analytical"] == summary["plans"] > 0
    reqs = _gan_reqs(rng, 10)
    res = eng.serve(reqs)
    assert len(res) == 10                      # 100% of in-deadline requests
    for r in reqs:
        assert np.all(np.isfinite(res[r.uid]))
    assert len(inj.fired) > 0                  # the storm actually fired


def test_nan_guard_retries_once_then_degrades(gan_params, rng):
    """nan_output twice in a row on the first rung: one same-rung retry,
    then degrade -- the result comes from the next rung, finite."""
    inj = FaultInjector(FaultSchedule([
        FaultEvent("gan_gen:pallas", 0, "nan_output"),
        FaultEvent("gan_gen:pallas", 1, "nan_output")]))
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=1,
                          queue_limit=4, injector=inj)
    res = eng.serve(_gan_reqs(rng, 1))
    assert len(res) == 1 and np.all(np.isfinite(next(iter(res.values()))))
    h = eng.health()
    assert h["nan_events"] == 2                # original + one retry
    assert h["retries"] >= 1 and h["fallbacks"] == 1


def test_transient_nan_recovers_on_same_rung(gan_params, rng):
    inj = FaultInjector(FaultSchedule([
        FaultEvent("gan_gen:pallas", 0, "nan_output")]))
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=1,
                          queue_limit=4, injector=inj)
    res = eng.serve(_gan_reqs(rng, 1))
    assert len(res) == 1
    h = eng.health()
    assert h["nan_events"] == 1 and h["fallbacks"] == 0
    assert h["breakers"]["gan_gen:pallas"] == "closed"


# ---------------------------------------------------------------------------
# Acceptance: circuit breaker quarantine -> re-probe state transitions
# ---------------------------------------------------------------------------

def test_quarantine_then_reprobe_state_machine(gan_params, rng):
    """pallas raises on its first two launches (threshold 2 -> OPEN);
    quarantined launches skip it; after the cooldown the breaker
    half-opens, the probe succeeds, and the rung closes again."""
    inj = FaultInjector(FaultSchedule([
        FaultEvent("gan_gen:pallas", 0, "kernel_exception"),
        FaultEvent("gan_gen:pallas", 1, "kernel_exception")]))
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=1,
                          queue_limit=8, injector=inj,
                          fail_threshold=2, cooldown=2)
    res = eng.serve(_gan_reqs(rng, 4))
    assert len(res) == 4
    br = eng._buckets[("gan_gen", (Z_DIM,))].breakers["pallas"]
    assert br.transitions == [("closed", "open"), ("open", "half_open"),
                              ("half_open", "closed")]
    h = eng.health()
    assert h["quarantines"] == 1 and h["reprobes"] == 1
    assert h["breakers"]["gan_gen:pallas"] == "closed"
    # launches 1-2 degraded, 3 was quarantined, 4 was the probe: the
    # injector only ever saw pallas three times
    assert inj._counters["gan_gen:pallas"] == 3


def test_reprobe_failure_reopens(gan_params, rng):
    inj = FaultInjector(FaultSchedule([
        FaultEvent("gan_gen:pallas", 0, "kernel_exception"),
        FaultEvent("gan_gen:pallas", 1, "kernel_exception"),
        FaultEvent("gan_gen:pallas", 2, "kernel_exception")]))
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=1,
                          queue_limit=8, injector=inj,
                          fail_threshold=2, cooldown=2)
    res = eng.serve(_gan_reqs(rng, 4))
    assert len(res) == 4
    br = eng._buckets[("gan_gen", (Z_DIM,))].breakers["pallas"]
    assert br.transitions == [("closed", "open"), ("open", "half_open"),
                              ("half_open", "open")]


def test_breaker_unit_semantics():
    br = CircuitBreaker(fail_threshold=2, cooldown=3)
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed"                # below threshold
    br.record_failure()
    assert br.state == "open"
    assert not br.allow() and not br.allow()   # cooldown ticks 2, 1
    assert br.allow() and br.state == "half_open"
    br.record_success()
    assert br.state == "closed"
    with pytest.raises(ValueError):
        CircuitBreaker(fail_threshold=0)


def test_fully_open_ladder_still_answers(gan_params, rng):
    """Even with EVERY rung quarantined the engine forces the last rung:
    it may be slow, it may fail, but it never refuses to try."""
    inj = _always_fail(["gan_gen:pallas", "gan_gen:xla_zero_free",
                        "gan_gen:reference"])
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=1,
                          queue_limit=8, injector=inj,
                          fail_threshold=1, cooldown=100)
    res = eng.serve(_gan_reqs(rng, 3))
    assert res == {}                           # everything fails...
    h = eng.health()
    assert h["failures"] == 3                  # ...but is ACCOUNTED, no hang
    assert h["launches"] == 3


# ---------------------------------------------------------------------------
# Acceptance: bounded admission -> shed, never hang
# ---------------------------------------------------------------------------

def test_admission_bound_sheds(gan_params, rng):
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=2,
                          queue_limit=3)
    reqs = _gan_reqs(rng, 8)
    admitted = [eng.submit(r) for r in reqs]
    assert admitted == [True] * 3 + [False] * 5
    res = eng.run()
    assert len(res) == 3
    h = eng.health()
    assert h["sheds"] == 5 and h["completed"] == 3
    assert h["queue_depth"] == 0


def test_deadline_expired_request_is_dropped(gan_params, rng):
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=2,
                          queue_limit=8)
    live = _gan_reqs(rng, 2, deadline_s=60.0)
    dead = _gan_reqs(rng, 1, deadline_s=0.0)
    res = eng.serve(live + dead)
    assert set(res) == {r.uid for r in live}
    assert eng.health()["deadline_misses"] == 1


def test_latency_spike_misses_deadline(gan_params, rng):
    """A straggler (injected latency spike) pushes completion past the
    request's deadline: the result is withheld and counted as a miss."""
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=1,
                          queue_limit=4)
    eng.serve(_gan_reqs(rng, 1))               # compile outside the window
    eng.injector = FaultInjector(FaultSchedule([
        FaultEvent("gan_gen:pallas", 0, "latency_spike", magnitude=0.3)]))
    res = eng.serve(_gan_reqs(rng, 1, deadline_s=0.05))
    assert res == {}
    assert eng.health()["deadline_misses"] == 1
    assert eng.health()["completed"] == 1      # only the warm request


# ---------------------------------------------------------------------------
# Acceptance: injection off -> ONE forward pallas_call per conv layer
# ---------------------------------------------------------------------------

def test_fast_path_single_launch_per_layer(gan_params, aspp_params):
    eng = ConvServeEngine(gan_params=gan_params, aspp_params=aspp_params,
                          slot_batch=2, queue_limit=4)
    z = jnp.zeros((2, Z_DIM), jnp.float32)
    # three transposed-conv layers -> exactly three pallas_calls
    assert count_pallas_calls(eng.forward_fn("gan_gen", "pallas"), z) == 3
    img = jnp.zeros((2,) + IMG, jnp.float32)
    # three dilated branches -> three pallas_calls (the 1x1 fuse conv is
    # an XLA matmul-shaped conv by design, same as training)
    assert count_pallas_calls(eng.forward_fn("aspp", "pallas"), img) == 3
    # and the reference rung launches no pallas at all
    assert count_pallas_calls(eng.forward_fn("gan_gen", "reference"),
                              z) == 0


def test_bucket_normalizes_through_convspec(gan_params, aspp_params):
    from repro.core.spec import ConvSpec
    eng = ConvServeEngine(gan_params=gan_params, aspp_params=aspp_params,
                          slot_batch=2, queue_limit=4)
    b = eng._bucket("gan_gen", (Z_DIM,))
    assert all(isinstance(s, ConvSpec) for s in b.specs)
    assert [s.stride for s in b.specs] == [(2, 2)] * 3
    b2 = eng._bucket("aspp", IMG)
    assert [s.dilation for s in b2.specs] == [(1, 1), (2, 2), (4, 4),
                                              (1, 1)]
    # same geometry -> same bucket object (compile-once)
    assert eng._bucket("gan_gen", (Z_DIM,)) is b
    with pytest.raises(ValueError):
        eng._bucket("bogus", (1,))


def test_warmup_pre_compiles_primary(gan_params):
    eng = ConvServeEngine(gan_params=gan_params, slot_batch=2,
                          queue_limit=4)
    eng.warmup([("gan_gen", (Z_DIM,))], compile=True)
    assert (("gan_gen", (Z_DIM,)), "pallas") in eng._jit_cache
    assert eng.health()["warmup"]["buckets"] == 1


# ---------------------------------------------------------------------------
# Satellite: LM ServeEngine continuous batching (mid-flight slot refill)
# ---------------------------------------------------------------------------

def _lm_engine(batch=2, max_len=32):
    from repro.models.config import ModelConfig
    from repro.models.lm import LM
    from repro.serve.engine import ServeEngine
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=16,
                      d_ff=32, vocab=13, n_heads=2, n_kv_heads=2,
                      head_dim=8, dtype="float32", remat="none")
    params = LM(cfg).init(jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, batch=batch, max_len=max_len)


def test_lm_slot_refill_mid_flight(rng):
    """3 requests, batch 2, one short request: the short sequence's slot
    must be reused by the queued request BEFORE the long one finishes."""
    from repro.serve.engine import Request
    eng = _lm_engine(batch=2)
    reqs = [Request(0, np.array([1, 2, 3], np.int32), max_new_tokens=8),
            Request(1, np.array([4, 5], np.int32), max_new_tokens=2),
            Request(2, np.array([6, 7, 8], np.int32), max_new_tokens=8)]
    res = eng.generate(reqs)
    assert set(res) == {0, 1, 2}
    assert len(res[0]) == 8 and len(res[1]) == 2 and len(res[2]) == 8
    # the regression pin: request 2 entered a slot freed MID-FLIGHT
    assert eng.stats["refills"] >= 1
    assert eng.stats["prefills"] >= 2


def test_lm_generate_single_cohort_unchanged(rng):
    from repro.serve.engine import Request
    eng = _lm_engine(batch=2)
    reqs = [Request(0, np.array([1, 2], np.int32), max_new_tokens=4),
            Request(1, np.array([3, 4], np.int32), max_new_tokens=4)]
    res = eng.generate(reqs)
    assert len(res[0]) == 4 and len(res[1]) == 4
    assert eng.stats["refills"] == 0           # no queue pressure
    assert all(0 <= t < 13 for t in res[0] + res[1])


def test_lm_eos_frees_slot(rng):
    """EOS retirement: whatever token the tiny model greedily emits
    first is declared EOS for request 0, so its slot frees after one
    token and the queued request refills it."""
    from repro.serve.engine import Request
    eng = _lm_engine(batch=1)
    probe = eng.generate([Request(9, np.array([1, 2], np.int32),
                                  max_new_tokens=1)])
    eos = probe[9][0]
    eng2 = _lm_engine(batch=1)
    reqs = [Request(0, np.array([1, 2], np.int32), max_new_tokens=8,
                    eos_id=int(eos)),
            Request(1, np.array([5, 6], np.int32), max_new_tokens=2)]
    res = eng2.generate(reqs)
    assert res[0] == [int(eos)]                # stopped at EOS
    assert len(res[1]) == 2
