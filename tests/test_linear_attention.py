"""Chunked linear attention (the SSM/RWKV training scan) against the
step-by-step recurrent oracle, plus chunk-size invariance and numeric
boundedness properties."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ssm

from conftest import assert_allclose


def _inputs(rng, B, S, H, dk, dv, decay_scale=1.0):
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    log_w = -decay_scale * jnp.asarray(
        rng.uniform(0.01, 1.0, size=(B, S, H, dk)), jnp.float32)
    return q, k, v, log_w


@pytest.mark.parametrize("pre_update", [False, True])
@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_chunked_matches_recurrent(rng, pre_update, chunk):
    B, S, H, dk, dv = 2, 33, 3, 8, 8   # S not a multiple of chunk
    q, k, v, log_w = _inputs(rng, B, S, H, dk, dv)
    u = jnp.asarray(rng.normal(size=(H, dk)), jnp.float32) \
        if pre_update else None
    y, st_ = ssm.chunked_linear_attention(q, k, v, log_w, chunk=chunk,
                                          u=u, pre_update_read=pre_update)
    y_ref, st_ref = ssm.recurrent_reference(q, k, v, log_w, u=u,
                                            pre_update_read=pre_update)
    assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    assert_allclose(st_, st_ref, rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance(rng):
    B, S, H, dk, dv = 1, 48, 2, 4, 4
    q, k, v, log_w = _inputs(rng, B, S, H, dk, dv)
    outs = [ssm.chunked_linear_attention(q, k, v, log_w, chunk=c)[0]
            for c in (4, 8, 24, 48)]
    for o in outs[1:]:
        assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


def test_state_carry_across_segments(rng):
    """Processing [0:S/2] then [S/2:S] with the carried state equals one
    pass -- the property prefill/decode handoff relies on."""
    B, S, H, dk, dv = 1, 32, 2, 4, 4
    q, k, v, log_w = _inputs(rng, B, S, H, dk, dv)
    y_full, st_full = ssm.chunked_linear_attention(q, k, v, log_w, chunk=8)
    h = S // 2
    y1, st1 = ssm.chunked_linear_attention(
        q[:, :h], k[:, :h], v[:, :h], log_w[:, :h], chunk=8)
    y2, st2 = ssm.chunked_linear_attention(
        q[:, h:], k[:, h:], v[:, h:], log_w[:, h:], chunk=8, state0=st1)
    assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=2e-4,
                    atol=2e-4)
    assert_allclose(st2, st_full, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 40), chunk=st.sampled_from([4, 8, 16]),
       pre=st.booleans(), decay=st.floats(0.01, 5.0))
def test_chunked_property(s, chunk, pre, decay):
    rng = np.random.default_rng(s * 17 + chunk)
    q, k, v, log_w = _inputs(rng, 1, s, 2, 4, 4, decay)
    y, _ = ssm.chunked_linear_attention(q, k, v, log_w, chunk=chunk,
                                        pre_update_read=pre)
    y_ref, _ = ssm.recurrent_reference(q, k, v, log_w,
                                       pre_update_read=pre)
    # Strong decay is clamped inside the chunked path (numerics guard);
    # compare only where the clamp is inactive.
    if decay <= 80.0 / chunk:
        assert_allclose(y, y_ref, rtol=5e-4, atol=5e-4)
    assert bool(jnp.isfinite(y).all())


def test_extreme_decay_is_finite(rng):
    """log_w far below the clamp must not produce inf/nan (the clamp is
    the guard; exactness is intentionally traded away)."""
    B, S, H, dk, dv = 1, 16, 1, 4, 4
    q, k, v, _ = _inputs(rng, B, S, H, dk, dv)
    log_w = jnp.full((B, S, H, dk), -1e4, jnp.float32)
    y, st_ = ssm.chunked_linear_attention(q, k, v, log_w, chunk=8)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(st_).all())


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def test_causal_conv1d_matches_lax(rng):
    B, S, C, K = 2, 20, 6, 4
    x = jnp.asarray(rng.normal(size=(B, S, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, C)), jnp.float32)
    y = ssm.causal_conv1d(x, w)
    # oracle: explicit shifted-tap sum
    xp = np.zeros((B, S + K - 1, C))
    xp[:, K - 1:] = np.asarray(x)
    want = sum(xp[:, kk:kk + S] * np.asarray(w)[kk] for kk in range(K))
    assert_allclose(y, want, rtol=1e-5, atol=1e-5)


def test_causal_conv1d_step_matches_batch(rng):
    B, S, C, K = 2, 12, 5, 4
    x = jnp.asarray(rng.normal(size=(B, S, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, C)), jnp.float32)
    y_batch = ssm.causal_conv1d(x, w)
    state = jnp.zeros((B, K - 1, C), jnp.float32)
    ys = []
    for t in range(S):
        yt, state = ssm.causal_conv1d_step(x[:, t], state, w)
        ys.append(yt)
    assert_allclose(jnp.stack(ys, 1), y_batch, rtol=1e-5, atol=1e-5)
