"""End-to-end trainer tests on a 1-device debug mesh: loss goes down,
checkpoint/restart resumes bit-identically (fault tolerance), straggler
watchdog, serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import TokenDataset
from repro.launch.mesh import make_debug_mesh
from repro.models.lm import LM
from repro.optim.optimizer import AdamWConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.fault_tolerance import elastic_mesh
from repro.train.trainer import Trainer, TrainerConfig


def _trainer(tmp_dir=None, total=8, arch="qwen3_0_6b", **tkw):
    cfg = get_smoke_config(arch)
    mesh = make_debug_mesh()
    ds = TokenDataset(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
    tcfg = TrainerConfig(total_steps=total, ckpt_dir=tmp_dir, ckpt_every=4,
                         log_every=2, **tkw)
    opt = AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=total)
    return Trainer(cfg, mesh, ds, opt, tcfg)


def test_loss_decreases():
    t = _trainer(total=12)
    out = t.run()
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_checkpoint_restart_bit_identical(tmp_path):
    """Train 8 steps straight vs train->crash at 5->restart: identical
    final params (determinism contract of the data pipeline + optimizer)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    ref = _trainer(d1, total=8, async_checkpoint=False).run()

    t2 = _trainer(d2, total=8, async_checkpoint=False)
    with pytest.raises(RuntimeError, match="injected failure"):
        t2.run(fail_at_step=5)
    # "restart": a fresh Trainer on the same dir resumes from step 4 ckpt
    t3 = _trainer(d2, total=8, async_checkpoint=False)
    out = t3.run()
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog_checkpoints(tmp_path):
    d = str(tmp_path)
    t = _trainer(d, total=3, step_timeout_s=0.0, async_checkpoint=False)
    t.run()   # every step "times out" -> forced checkpoints, still finishes
    from repro.train import checkpoint as ckpt
    assert ckpt.latest_step(d) == 3


def test_elastic_mesh_shrink():
    m = elastic_mesh(jax.devices()[:1], model_parallel=16)
    assert m.shape["model"] == 1 and m.shape["data"] == 1
    # with 1 device nothing else is possible; the policy logic is exercised
    # at 8 devices in tests/test_multidevice.py


def test_elastic_mesh_edge_cases():
    # all hosts failed: explicit error, not a zero-device mesh that
    # detonates later inside jit
    with pytest.raises(ValueError, match="no surviving devices"):
        elastic_mesh([])
    # a nonsensical TP request fails loudly too
    with pytest.raises(ValueError, match="model_parallel"):
        elastic_mesh(jax.devices()[:1], model_parallel=0)
    with pytest.raises(ValueError, match="model_parallel"):
        elastic_mesh(jax.devices()[:1], model_parallel=-2)
    # model_parallel far beyond the device set halves down to fit
    m = elastic_mesh(jax.devices()[:1], model_parallel=1024)
    assert m.shape["model"] == 1 and m.shape["data"] == 1


def test_survivors_edge_cases():
    from jax.sharding import Mesh
    from repro.train.fault_tolerance import survivors

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # the lone device lives on host 0
    assert len(survivors(mesh, [])) == 1
    assert len(survivors(mesh, [1], devices_per_host=1)) == 1
    # every host failed -> empty survivor set, which elastic_mesh rejects
    surv = survivors(mesh, [0], devices_per_host=1)
    assert surv == []
    with pytest.raises(ValueError, match="no surviving devices"):
        elastic_mesh(surv)


def test_serve_engine_greedy_matches_manual():
    cfg = get_smoke_config("qwen2_1_5b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, max_len=48)
    prompts = [np.asarray([5, 7, 11], np.int32),
               np.asarray([3, 1], np.int32)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = eng.generate(reqs)
    assert set(results) == {0, 1}
    assert all(len(v) == 4 for v in results.values())
    # continuous batching: a third request queues behind the batch of 2
    reqs = [Request(uid=i, prompt=prompts[i % 2], max_new_tokens=3)
            for i in range(3)]
    results = eng.generate(reqs)
    assert set(results) == {0, 1, 2}
    assert results[0] == results[2]   # same prompt -> same greedy output
