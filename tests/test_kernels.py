"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode on
CPU) against its pure-jnp oracle in kernels/ref.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ecoflow
from repro.kernels import ops, ref
from repro.kernels.attention import flash_attention_pallas
from repro.kernels.dconv_filtergrad import dconv_filter_grad_pallas
from repro.kernels.dconv_forward import dconv_forward_pallas
from repro.kernels.tconv_phase import pack_phase_filters, tconv_fused_pallas

from conftest import (assert_allclose, pallas_block_shapes,
                      pallas_grids as _pallas_grids)


# ---------------------------------------------------------------------------
# tconv_phase (phase-decomposed transposed conv)
# ---------------------------------------------------------------------------

TCONV_SWEEP = [
    # (B, O, K, S, P, Ci, Co)
    (1, 4, 3, 2, 0, 4, 4),
    (2, 5, 3, 2, 1, 3, 5),
    (2, 7, 4, 3, 0, 8, 2),
    (1, 3, 11, 4, 2, 2, 3),
    (1, 6, 2, 4, 0, 5, 5),       # K < S: empty phases exist
    (2, 4, 1, 1, 0, 4, 4),       # pointwise stride 1
    (1, 8, 5, 2, 2, 130, 7),     # Cin > default tile
    (1, 4, 3, 2, 0, 3, 130),     # Cout > default tile (dy block tiled)
]


@pytest.mark.parametrize("B,O,K,S,P,Ci,Co", TCONV_SWEEP)
def test_tconv_phase_sweep(rng, B, O, K, S, P, Ci, Co):
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    N = S * (O - 1) + K - 2 * P
    out = ops.tconv_phase(dy, w, stride=(S, S), padding=(P, P),
                          n_out=(N, N))
    want = ref.tconv_phase_ref(dy, w, stride=(S, S), padding=(P, P),
                               n_out=(N, N))
    assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_tconv_phase_dtypes(rng, dtype, tol):
    B, O, K, S, Ci, Co = 2, 5, 3, 2, 4, 6
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), dtype)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), dtype)
    N = S * (O - 1) + K
    out = ops.tconv_phase(dy, w, stride=(S, S), padding=(0, 0),
                          n_out=(N, N))
    assert out.dtype == dtype
    want = ref.tconv_phase_ref(dy, w, stride=(S, S), padding=(0, 0),
                               n_out=(N, N))
    assert_allclose(out, want, rtol=tol, atol=tol)


def test_tconv_fused_direct_call(rng):
    """The fused kernel entry point itself (not via ops) matches the
    oracle, including the default exact-fit n_out."""
    B, O, K, S, Ci, Co = 2, 6, 5, 2, 5, 4
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    out = tconv_fused_pallas(dy, w, stride=(S, S), interpret=True)
    N = S * (O - 1) + K
    want = ref.tconv_phase_ref(dy, w, stride=(S, S), padding=(0, 0),
                               n_out=(N, N))
    assert_allclose(out, want, rtol=1e-4, atol=1e-4)


TCONV_DILATED_SWEEP = [
    # (B, O, K, S, P, D, Ci, Co): input gradient of a forward conv with
    # stride S AND filter dilation D -- the unified (phase, tap) kernel.
    (1, 5, 3, 2, 1, 2, 3, 4),    # gcd(S,D)=2: half the residues empty
    (2, 4, 3, 2, 0, 3, 2, 3),    # coprime S, D
    (1, 4, 3, 3, 2, 2, 3, 2),
    (2, 5, 2, 3, 0, 3, 2, 2),    # S == D: one tap-phase per axis
    (2, 6, 3, 1, 2, 2, 3, 3),    # stride-1 atrous adjoint
    (1, 3, 5, 6, 1, 4, 2, 2),    # period 3, ragged phases
]


@pytest.mark.parametrize("B,O,K,S,P,D,Ci,Co", TCONV_DILATED_SWEEP)
def test_tconv_phase_dilated_sweep(rng, B, O, K, S, P, D, Ci, Co):
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    N = S * (O - 1) + D * (K - 1) + 1 - 2 * P
    out = ops.tconv_phase(dy, w, stride=(S, S), padding=(P, P),
                          n_out=(N, N), dilation=(D, D))
    want = ref.tconv_phase_ref(dy, w, stride=(S, S), padding=(P, P),
                               n_out=(N, N), dilation=(D, D))
    assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_tconv_cout_tiled_dy_block(rng):
    """The dy block carries a Cout TILE, not full channel depth: with
    Cout > cout_tile the grid gains a sequential Cout axis and the
    in-kernel dy/weight blocks are capped at the tile -- and the result
    still matches the oracle (accumulation across Cout tiles)."""
    B, O, K, S, P, Ci, Co, tile = 1, 4, 3, 2, 0, 5, 20, 8
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    N = S * (O - 1) + K
    fn = lambda dy_, w_: tconv_fused_pallas(
        dy_, w_, stride=(S, S), padding=(P, P), n_out=(N, N),
        cout_tile=tile, cin_tile=4, tap_unroll=1, interpret=True)
    grids = _pallas_grids(fn, dy, w)
    assert len(grids) == 1
    # grid (B, T, Cin_t, Cout_t, TK): sequential Cout axis of ceil(Co/tile).
    assert grids[0][3] == -(-Co // tile), grids[0]
    blocks = pallas_block_shapes(fn, dy, w)[0]
    dy_block, w_block, out_block = blocks
    assert dy_block[-1] == tile, blocks        # dy: Cout tile, not Co
    assert w_block[-2:] == (tile, 4), blocks   # w: (Co_t, Ci_t)
    assert out_block[-1] == 4, blocks          # out: Cin tile
    out = fn(dy, w)
    want = ref.tconv_phase_ref(dy, w, stride=(S, S), padding=(P, P),
                               n_out=(N, N))
    assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S", [2, 3, 4])
@pytest.mark.parametrize("K", [3, 4, 5])
def test_pack_phase_filters_single_source_of_truth(rng, S, K):
    """`pack_phase_filters` consumes `ecoflow.phase_subfilters` (the one
    rotation convention shared with the dense XLA backend) and only adds
    uniform-shape packing.  This pins the padding/rotation commutation the
    refactor relies on: FRONT-padding the flipped sub-filter equals
    TAIL-padding before the flip (the old inline convention)."""
    Ci, Co = 3, 4
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    packed = pack_phase_filters(w, (S, S))
    KP = -(-K // S)
    # Old convention, inlined: tail-pad the raw sub-filter, then rotate.
    expect = []
    for p in range(min(S, K)):
        for q in range(min(S, K)):
            sub = w[p::S, q::S]
            kp, kq = sub.shape[0], sub.shape[1]
            sub = jnp.pad(sub, ((0, KP - kp), (0, KP - kq), (0, 0), (0, 0)))
            sub = jnp.flip(sub, axis=(0, 1))
            expect.append(jnp.swapaxes(sub, 2, 3))
    expect = jnp.stack(expect)
    assert packed.shape == expect.shape
    assert_allclose(packed, expect, rtol=0, atol=0)
    # And the packed taps are exactly the phase_subfilters' taps.
    subs = ecoflow.phase_subfilters(w, (S, S))
    for p in range(min(S, K)):
        for q in range(min(S, K)):
            sub = subs[p][q]
            kp, kq = sub.shape[0], sub.shape[1]
            got = packed[p * min(S, K) + q, KP - kp:, KP - kq:]
            assert_allclose(got, sub, rtol=0, atol=0)


def test_pack_phase_filters_zero_free(rng):
    """Packing is tap-exhaustive and zero-free: every filter tap lands in
    exactly one phase slot, ragged phases are zero-padded."""
    K, S, Ci, Co = 5, 2, 3, 4
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    packed = pack_phase_filters(w, (S, S))      # (S*S, KP, KQ, Co, Ci)
    KP = -(-K // S)
    assert packed.shape == (S * S, KP, KP, Co, Ci)
    # sum over all phase slots of |packed| == sum over all taps of |w|
    assert_allclose(jnp.abs(packed).sum(), jnp.abs(w).sum(), rtol=1e-5)
    # stride > K: only the min(S,K)^2 non-empty phases are packed; the
    # structurally-zero phases get no grid steps (wrapper zero-fills them)
    w1 = jnp.asarray(rng.normal(size=(2, 2, 3, 4)), jnp.float32)
    packed1 = pack_phase_filters(w1, (4, 4))
    assert packed1.shape[0] == 4  # (p,q) in {0,1}^2
    assert all(float(jnp.abs(packed1[t]).sum()) > 0 for t in range(4))


# ---------------------------------------------------------------------------
# dconv_filtergrad (zero-free filter gradient)
# ---------------------------------------------------------------------------

DCONV_SWEEP = [
    (1, 9, 3, 2, 0, 4, 4),
    (2, 9, 3, 2, 1, 3, 5),
    (3, 13, 4, 3, 0, 2, 7),
    (1, 23, 11, 4, 2, 2, 3),
    (2, 8, 1, 2, 0, 5, 6),
    (1, 10, 3, 1, 1, 130, 3),    # Cin > default tile, stride 1
]


@pytest.mark.parametrize("B,N,K,S,P,Ci,Co", DCONV_SWEEP)
def test_dconv_filtergrad_sweep(rng, B, N, K, S, P, Ci, Co):
    O = (N + 2 * P - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    dw = ops.dconv_filter_grad(x, dy, stride=(S, S), padding=(P, P),
                               k=(K, K))
    want = ref.dconv_filter_grad_ref(x, dy, stride=(S, S), padding=(P, P),
                                     k=(K, K))
    assert_allclose(dw, want, rtol=1e-4, atol=1e-4)


DCONV_DILATED_SWEEP = [
    # (B, N, K, S, P, D, Ci, Co): forward filter dilation D
    (1, 11, 3, 1, 2, 2, 3, 4),
    (2, 15, 3, 1, 4, 4, 2, 3),
    (1, 14, 3, 2, 1, 2, 3, 2),
    (2, 17, 2, 3, 0, 4, 2, 5),
]


@pytest.mark.parametrize("B,N,K,S,P,D,Ci,Co", DCONV_DILATED_SWEEP)
def test_dconv_filtergrad_dilated_sweep(rng, B, N, K, S, P, D, Ci, Co):
    """Filter gradient of a *dilated* forward conv: tap windows at
    spacing D inside the kernel."""
    k_eff = D * (K - 1) + 1
    O = (N + 2 * P - k_eff) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    dw = ops.dconv_filter_grad(x, dy, stride=(S, S), padding=(P, P),
                               k=(K, K), dilation=(D, D))
    want = ref.dconv_filter_grad_ref(x, dy, stride=(S, S), padding=(P, P),
                                     k=(K, K), dilation=(D, D))
    assert_allclose(dw, want, rtol=1e-4, atol=1e-4)


def test_filter_grad_spatially_tiled_batch_sequential(rng):
    """Block-shape pins for the rebuilt filter-grad grid: with a spatial
    tile the x block holds ONE overlapping slab -- never the full
    Hp x Wp padded frame -- the out block carries ALL taps of a channel
    tile (stationary across the sequential (B, SP, tap) axes, no
    (B, T, Ci, Co) HBM partials), and the result still matches the
    oracle (fp32 accumulation across batch and spatial slabs)."""
    B, N, K, S, P, Ci, Co = 2, 33, 3, 2, 0, 12, 20
    O = (N - K) // S + 1                     # 16 output rows
    ci_t, co_t, sp, u = 8, 8, 4, 3
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    fn = lambda x_, dy_: dconv_filter_grad_pallas(
        x_, dy_, stride=(S, S), padding=(P, P), k=(K, K),
        cin_tile=ci_t, cout_tile=co_t, spatial_tile=sp, tap_unroll=u,
        interpret=True)
    grids = _pallas_grids(fn, x, dy)
    assert len(grids) == 1
    n_sp = -(-O // sp)
    # grid (Cin_t, Cout_t, B, SP, T'): batch + spatial SEQUENTIAL.
    assert grids[0] == (-(-Ci // ci_t), -(-Co // co_t), B, n_sp,
                        K * K // u), grids[0]
    x_block, dy_block, out_block = pallas_block_shapes(fn, x, dy)[0]
    rows_x = (sp - 1) * S + (K - 1) + 1      # slab rows incl. tap halo
    hp = (O - 1) * S + K                     # full padded frame rows
    assert x_block[2] == rows_x < hp, (x_block, hp)
    assert x_block[-1] == ci_t, x_block      # channel tile, not Ci
    assert dy_block[2:] == (sp, O, co_t), dy_block
    # out block: ALL K*K taps of one (ci, co) tile -- the accumulator is
    # stationary, so there is no (B, T, Ci, Co) partial to reduce.
    assert out_block == (K * K, ci_t, co_t), out_block
    dw = fn(x, dy)
    want = ref.dconv_filter_grad_ref(x, dy, stride=(S, S), padding=(P, P),
                                     k=(K, K))
    assert_allclose(dw, want, rtol=1e-4, atol=1e-4)


RAGGED_TILE_SWEEP = [
    # (B, N, K, S, P, Ci, Co, ci_t, co_t, sp, u): tiles that do NOT
    # divide the channel counts, plus spatial tiles that do not divide O.
    (2, 9, 3, 2, 0, 13, 21, 8, 16, 3, 9),
    (3, 11, 3, 1, 1, 5, 7, 4, 4, 4, 1),
    (1, 23, 11, 4, 2, 3, 5, 2, 4, 2, 11),
]


@pytest.mark.parametrize("B,N,K,S,P,Ci,Co,ci_t,co_t,sp,u",
                         RAGGED_TILE_SWEEP)
def test_dconv_filtergrad_ragged_tiles(rng, B, N, K, S, P, Ci, Co, ci_t,
                                       co_t, sp, u):
    """Explicitly pinned tilings with ragged channel/spatial remainders
    (pad-then-slice paths) still match the oracle at B > 1."""
    O = (N + 2 * P - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    dw = dconv_filter_grad_pallas(x, dy, stride=(S, S), padding=(P, P),
                                  k=(K, K), cin_tile=ci_t, cout_tile=co_t,
                                  spatial_tile=sp, tap_unroll=u,
                                  interpret=True)
    want = ref.dconv_filter_grad_ref(x, dy, stride=(S, S), padding=(P, P),
                                     k=(K, K))
    assert_allclose(dw, want, rtol=1e-4, atol=1e-4)


def test_dconv_filtergrad_bf16(rng):
    B, N, K, S, Ci, Co = 2, 9, 3, 2, 4, 4
    O = (N - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.bfloat16)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.bfloat16)
    dw = dconv_filter_grad_pallas(x, dy, stride=(S, S), padding=(0, 0),
                                  k=(K, K), interpret=True)
    want = ref.dconv_filter_grad_ref(x, dy, stride=(S, S), padding=(0, 0),
                                     k=(K, K))
    assert_allclose(dw, want, rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# dconv_forward (fused zero-free dilated forward conv)
# ---------------------------------------------------------------------------

DFWD_SWEEP = [
    # (B, N, K, S, P, D, Ci, Co)
    (1, 13, 3, 1, 2, 2, 3, 4),       # atrous same-padding
    (2, 15, 3, 1, 4, 4, 2, 3),       # d=4 same-padding
    (1, 14, 3, 2, 1, 2, 3, 2),       # stride 2 + dilation 2
    (2, 17, 2, 3, 0, 4, 2, 2),       # non-exact fit
    (1, 12, 1, 2, 0, 3, 2, 2),       # pointwise: K_eff == 1
    (1, 13, 3, 1, 2, 2, 5, 130),     # Cout > default tile
    (1, 9, 3, 1, 2, 2, 130, 3),      # Cin > default tile (x block tiled)
]


@pytest.mark.parametrize("B,N,K,S,P,D,Ci,Co", DFWD_SWEEP)
def test_dconv_forward_sweep(rng, B, N, K, S, P, D, Ci, Co):
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    y = ops.dconv_forward(x, w, stride=(S, S), padding=(P, P),
                          dilation=(D, D))
    want = ref.dconv_forward_ref(x, w, stride=(S, S), padding=(P, P),
                                 dilation=(D, D))
    assert_allclose(y, want, rtol=1e-4, atol=1e-4)


def test_dconv_forward_cin_tiled(rng):
    """The padded-input block no longer spans full channel depth: with
    Cin > cin_tile the grid gains a sequential Cin-accumulation axis and
    the x/w blocks are capped at the tile -- and the output still matches
    the oracle (fp32 accumulation across (Cin-tile, tap) steps)."""
    B, N, K, S, P, D, Ci, Co, tile = 2, 11, 3, 1, 2, 2, 20, 12, 8
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    fn = lambda x_, w_: dconv_forward_pallas(
        x_, w_, stride=(S, S), padding=(P, P), dilation=(D, D),
        cin_tile=tile, cout_tile=tile, tap_unroll=1, interpret=True)
    grids = _pallas_grids(fn, x, w)
    assert len(grids) == 1
    # grid (B, Cout_t, Cin_t, T): batch leads, taps innermost, and a
    # sequential Cin axis of ceil(Ci/tile) blocks.
    assert grids[0] == (B, -(-Co // tile), -(-Ci // tile), K * K), grids[0]
    blocks = pallas_block_shapes(fn, x, w)[0]
    x_block, w_block, out_block = blocks
    assert x_block[-1] == tile, blocks         # padded input: Cin tile
    assert w_block[-2:] == (tile, tile), blocks
    y = fn(x, w)
    want = ref.dconv_forward_ref(x, w, stride=(S, S), padding=(P, P),
                                 dilation=(D, D))
    assert_allclose(y, want, rtol=1e-4, atol=1e-4)


def test_dconv_forward_bf16(rng):
    B, N, K, D, Ci, Co = 1, 11, 3, 2, 4, 4
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.bfloat16)
    y = dconv_forward_pallas(x, w, stride=(1, 1), padding=(2, 2),
                             dilation=(2, 2), interpret=True)
    assert y.dtype == jnp.bfloat16
    want = ref.dconv_forward_ref(x, w, stride=(1, 1), padding=(2, 2),
                                 dilation=(2, 2))
    assert_allclose(y, want, rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# ops wrappers
# ---------------------------------------------------------------------------

def test_ops_import_does_not_initialize_backend():
    """The interpret/compiled decision is resolved per call, NOT at
    import: importing `repro.kernels.ops` must not force jax backend
    initialization (the old module-level `_INTERPRET` constant did, and
    went stale if the device set changed after import)."""
    import subprocess
    import sys
    code = (
        "import repro.kernels.ops\n"
        "try:\n"
        "    from jax._src.xla_bridge import _backends\n"
        "except ImportError:   # private jax surface moved: can't probe\n"
        "    print('SKIP')\n"
        "    raise SystemExit(0)\n"
        "assert not _backends, list(_backends)\n"
        "print('OK')\n")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True)
    assert proc.returncode == 0 and ("OK" in proc.stdout
                                     or "SKIP" in proc.stdout), (
        proc.stdout, proc.stderr)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SWEEP = [
    # (B, Sq, Sk, Hq, Hk, D, causal, bq, bk)
    (2, 64, 64, 4, 2, 32, True, 32, 32),
    (1, 128, 128, 8, 8, 64, True, 64, 32),
    (2, 48, 96, 4, 1, 32, True, 16, 32),    # MQA, decode-style suffix
    (1, 33, 70, 8, 2, 16, False, 32, 32),   # ragged, non-causal
    (1, 1, 40, 4, 4, 32, True, 8, 16),      # single-token decode
    (2, 70, 70, 2, 2, 128, True, 32, 64),   # head_dim 128
]


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hk,D,causal,bq,bk", ATTN_SWEEP)
def test_flash_attention_sweep(rng, B, Sq, Sk, Hq, Hk, D, causal, bq, bk):
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hk, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hk, D)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, blk_q=bq,
                                 blk_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16(rng):
    B, S, H, D = 2, 64, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, blk_q=32, blk_k=32,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    assert out.dtype == jnp.bfloat16
    assert_allclose(out, want, rtol=5e-2, atol=5e-2)


@settings(max_examples=15, deadline=None)
@given(sq=st.integers(1, 40), extra=st.integers(0, 40),
       hk=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]),
       causal=st.booleans())
def test_flash_attention_property(sq, extra, hk, g, causal):
    """Any (Sq <= Sk, GQA group, mask) combination matches the oracle."""
    rng = np.random.default_rng(sq * 1000 + extra * 10 + hk + g)
    sk = sq + extra
    B, D = 1, 16
    q = jnp.asarray(rng.normal(size=(B, sq, hk * g, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, sk, hk, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, sk, hk, D)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, blk_q=16,
                                 blk_k=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_tconv_fully_unrolled_skips_padding_slots(rng):
    """Backported static padding-slot skip (the fused backward kernel's
    shared (phase, slot) -> filter-tap validity test): at full
    (phase, tap) unroll every slot index is a python int, so slots whose
    flipped tap kx = a + (KP-1-u)*period falls outside the KxK filter
    are skipped outright -- the kernel body carries exactly Kh*Kw
    matmuls, not T*TK (the zero-padded slots of ragged phases never
    become MACs).  S=2, K=3: 4 phases x 4 packed slots = 16 slots but
    only 9 real taps."""
    from conftest import walk_eqns
    B, O, K, S, Ci, Co = 1, 4, 3, 2, 4, 4
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    N = S * (O - 1) + K
    fn = lambda dy_, w_: tconv_fused_pallas(
        dy_, w_, stride=(S, S), padding=(0, 0), n_out=(N, N),
        tap_unroll=4, phase_unroll=4, cin_tile=Ci, cout_tile=Co)
    jaxpr = jax.make_jaxpr(fn)(dy, w)
    dots = [e for e in walk_eqns(jaxpr.jaxpr)
            if e.primitive.name == "dot_general"]
    assert len(dots) == K * K, len(dots)         # 9, not 16
    # ... and the skip changes nothing numerically.
    assert_allclose(fn(dy, w),
                    ref.tconv_phase_ref(dy, w, stride=(S, S),
                                        padding=(0, 0), n_out=(N, N)),
                    rtol=1e-4, atol=1e-4)
