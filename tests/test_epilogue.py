"""Epilogue fusion: bias/activation tails folded into the fused conv
launches, forward and backward (DESIGN.md Sec. 2.8).

Two layers of guarantees:

  * **Parity**: for every epilogue kind (bias-only, relu, leaky_relu with
    a non-default slope, tanh, and a scaled variant), every backend
    (reference | xla_zero_free | pallas) computes the identical forward
    value AND identical (dx, dw, db) under `jax.grad` -- the fused
    in-kernel epilogue is numerically the same function as the separate
    bias-add / activation / mask / reduce composition it replaces.

  * **Structure**: on the pallas backend the tail is *gone* from the
    jaxpr -- each conv forward is ONE pallas_call with no trailing
    bias/activation eqn, each conv backward is ONE pallas_call with no
    activation-gradient mask eqn (the mask is applied to the VMEM-resident
    cotangent block inside the kernel), and the bias gradient is a THIRD
    output of the same launch, not a separate reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv import (ecoflow_conv, ecoflow_conv_transpose,
                             ecoflow_dilated_conv)
from repro.core.spec import Epilogue
from repro.kernels import ops

from conftest import (assert_allclose, count_pallas_calls, walk_eqns,
                      walk_eqns_outside_pallas)

BACKENDS = ["reference", "xla_zero_free", "pallas"]

# Every epilogue kind the slot supports, including a non-default
# leaky_relu slope and a scale rider.
EPILOGUES = [
    ("bias", Epilogue(bias=True)),
    ("relu", Epilogue(activation="relu")),
    ("bias_relu", Epilogue(activation="relu", bias=True)),
    ("bias_leaky02", Epilogue(activation="leaky_relu", slope=0.2,
                              bias=True)),
    ("tanh", Epilogue(activation="tanh")),
    ("scaled_bias_relu", Epilogue(activation="relu", bias=True,
                                  scale=0.5)),
]

# Primitives an unfused tail would leave in the jaxpr: the activations
# themselves (max / tanh) and their backward masks (select_n / gt).
_TAIL_PRIMS = {"max", "tanh", "select_n", "gt", "lt"}


def _manual_tail(raw, b, ep):
    """The separate-ops composition the epilogue slot replaces."""
    v = raw if ep.scale is None else raw * ep.scale
    if ep.bias:
        v = v + b
    if ep.activation == "relu":
        v = jnp.maximum(v, 0)
    elif ep.activation == "leaky_relu":
        v = jnp.where(v > 0, v, ep.slope * v)
    elif ep.activation == "tanh":
        v = jnp.tanh(v)
    return v


def _grad_args(ep):
    return (0, 1, 2) if ep.bias else (0, 1)


@pytest.mark.parametrize("kind,ep", EPILOGUES, ids=[k for k, _ in EPILOGUES])
@pytest.mark.parametrize("backend", BACKENDS)
def test_conv_epilogue_parity(rng, backend, kind, ep):
    """Direct conv (stride 2, pad 1): fused epilogue == reference conv
    followed by the manual tail, for the value and all of (dx, dw, db)."""
    x = jnp.asarray(rng.normal(size=(2, 9, 9, 5)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 5, 7)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(7,)), jnp.float32) if ep.bias else None

    got = ecoflow_conv(x, w, 2, 1, backend, bias=b, epilogue=ep)
    want = _manual_tail(ecoflow_conv(x, w, 2, 1, "reference"), b, ep)
    assert_allclose(got, want)

    f = lambda x_, w_, b_: jnp.sum(jnp.sin(
        ecoflow_conv(x_, w_, 2, 1, backend, bias=b_, epilogue=ep)))
    g = lambda x_, w_, b_: jnp.sum(jnp.sin(_manual_tail(
        ecoflow_conv(x_, w_, 2, 1, "reference"), b_, ep)))
    got_g = jax.grad(f, _grad_args(ep))(x, w, b)
    want_g = jax.grad(g, _grad_args(ep))(x, w, b)
    for name, a_, b_ in zip(("dx", "dw", "db"), got_g, want_g):
        assert_allclose(a_, b_, err_msg=f"{name} {backend} {kind}")


@pytest.mark.parametrize("kind,ep", EPILOGUES, ids=[k for k, _ in EPILOGUES])
@pytest.mark.parametrize("backend", BACKENDS)
def test_tconv_epilogue_parity(rng, backend, kind, ep):
    """Transposed conv (DCGAN layer shape, stride 2 K4): fused epilogue
    parity for the value and (ddy, dw, db); the bias rides over the tconv
    OUTPUT channels (the forward conv's input side)."""
    dy = jnp.asarray(rng.normal(size=(2, 5, 5, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4, 6, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(6,)), jnp.float32) if ep.bias else None

    got = ecoflow_conv_transpose(dy, w, 2, 1, (10, 10), backend,
                                 bias=b, epilogue=ep)
    want = _manual_tail(
        ecoflow_conv_transpose(dy, w, 2, 1, (10, 10), "reference"), b, ep)
    assert_allclose(got, want)

    f = lambda dy_, w_, b_: jnp.sum(jnp.sin(ecoflow_conv_transpose(
        dy_, w_, 2, 1, (10, 10), backend, bias=b_, epilogue=ep)))
    g = lambda dy_, w_, b_: jnp.sum(jnp.sin(_manual_tail(
        ecoflow_conv_transpose(dy_, w_, 2, 1, (10, 10), "reference"),
        b_, ep)))
    got_g = jax.grad(f, _grad_args(ep))(dy, w, b)
    want_g = jax.grad(g, _grad_args(ep))(dy, w, b)
    for name, a_, b_ in zip(("ddy", "dw", "db"), got_g, want_g):
        assert_allclose(a_, b_, err_msg=f"{name} {backend} {kind}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_dilated_conv_epilogue_parity(rng, backend):
    """Atrous branch (D=2, same-padding) with a relu+bias epilogue."""
    ep = Epilogue(activation="relu", bias=True)
    x = jnp.asarray(rng.normal(size=(2, 12, 12, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 6)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(6,)), jnp.float32)
    got = ecoflow_dilated_conv(x, w, 1, 2, 2, backend, bias=b, epilogue=ep)
    want = _manual_tail(
        ecoflow_dilated_conv(x, w, 1, 2, 2, "reference"), b, ep)
    assert_allclose(got, want)
    f = lambda x_, w_, b_: jnp.sum(jnp.cos(ecoflow_dilated_conv(
        x_, w_, 1, 2, 2, backend, bias=b_, epilogue=ep)))
    g = lambda x_, w_, b_: jnp.sum(jnp.cos(_manual_tail(
        ecoflow_dilated_conv(x_, w_, 1, 2, 2, "reference"), b_, ep)))
    got_g = jax.grad(f, (0, 1, 2))(x, w, b)
    want_g = jax.grad(g, (0, 1, 2))(x, w, b)
    for name, a_, b_ in zip(("dx", "dw", "db"), got_g, want_g):
        assert_allclose(a_, b_, err_msg=f"{name} {backend}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_tconv_epilogue_structural_fill(rng, backend):
    """K < S leaves whole stride phases with no tap (structural zeros of
    the upsampling), and non-exact fits leave tail rows no tap reaches:
    under a bias epilogue those positions must take act(0 + bias), not 0.
    S=4, K=2 exercises the sentinel-plane fill; the geometry's tail the
    pad fill."""
    ep = Epilogue(activation="relu", bias=True)
    dy = jnp.asarray(rng.normal(size=(1, 3, 3, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 2, 3, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
    got = ecoflow_conv_transpose(dy, w, 4, 0, None, backend,
                                 bias=b, epilogue=ep)
    want = _manual_tail(
        ecoflow_conv_transpose(dy, w, 4, 0, None, "reference"), b, ep)
    assert_allclose(got, want)
    # The structural-zero positions really did take the fill value.
    assert np.asarray(jnp.abs(want) > 0).any()


def _tail_eqns_outside_pallas(fn, *args, ndim=4, min_spatial=1):
    """Activation/mask eqns with conv-output-rank results OUTSIDE the
    pallas kernel bodies -- the tail ops an unfused graph would carry.
    `min_spatial` scopes the pin to conv outputs when the model also
    applies a legitimate non-conv activation (e.g. the GAN generator's
    dense-projection relu at 4x4)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    hits = []
    for e in walk_eqns_outside_pallas(jaxpr.jaxpr):
        if e.primitive.name not in _TAIL_PRIMS:
            continue
        for v in e.outvars:
            shape = tuple(getattr(v.aval, "shape", ()))
            if len(shape) == ndim and shape[1] >= min_spatial:
                hits.append((e.primitive.name, shape))
    return hits


def test_structural_cnn_forward_fused(rng):
    """CNN forward on pallas with declarative epilogues: one pallas_call
    per conv layer, and NO relu eqn on any conv-shaped tensor outside
    the kernels."""
    from repro.models import cnn
    params = cnn.simple_cnn_init(jax.random.PRNGKey(0), in_ch=3,
                                 widths=(4, 6), n_classes=4)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)), jnp.float32)
    fwd = lambda p: cnn.simple_cnn_apply(p, x, stride=2, backend="pallas")
    assert count_pallas_calls(fwd, params) == 2    # exactly one per layer
    assert _tail_eqns_outside_pallas(fwd, params) == []


def test_structural_gan_generator_step_fused(rng):
    """GAN generator gradient step on pallas: each tconv layer is one
    forward launch + one fused backward launch, with the relu/tanh tails
    and their backward masks entirely in-kernel (no 4-D activation or
    select eqn outside the kernels)."""
    from repro.models import gan
    gp = gan.generator_init(jax.random.PRNGKey(0), z_dim=8, base=8)
    dp = gan.discriminator_init(jax.random.PRNGKey(1), base=8)
    z = jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)
    real = jnp.asarray(rng.normal(size=(2, 32, 32, 3)), jnp.float32)
    step = lambda gp_: jax.grad(
        lambda p: gan.gan_losses(p, dp, z, real, backend="pallas")[0])(gp_)
    # min_spatial=8: the 4x4 dense-projection relu is not a conv tail.
    assert _tail_eqns_outside_pallas(step, gp, min_spatial=8) == []


def test_structural_atrous_head_fused(rng):
    """ASPP-lite forward on pallas: one pallas launch per atrous branch
    (the 1x1 fuse conv stays on the XLA fast path at dilation 1 with no
    epilogue), relu tails in-kernel."""
    from repro.models import vision
    params = vision.atrous_head_init(jax.random.PRNGKey(0), width=8)
    im = jnp.asarray(rng.normal(size=(1, 12, 12, 3)), jnp.float32)
    fwd = lambda p: vision.atrous_head_apply(p, im, backend="pallas")
    assert count_pallas_calls(fwd, params) == 3    # one per rate branch
    assert _tail_eqns_outside_pallas(fwd, params) == []


def test_structural_backward_three_outputs(rng):
    """jax.grad of a pallas conv with a bias epilogue traces exactly TWO
    pallas_calls (fused forward, fused backward); the backward launch
    emits THREE outputs -- dx, dW, and the in-kernel-accumulated db --
    and no mask/reduce tail follows it."""
    ep = Epilogue(activation="relu", bias=True)
    x = jnp.asarray(rng.normal(size=(2, 9, 9, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 5)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(5,)), jnp.float32)
    f = lambda x_, w_, b_: jnp.sum(
        ecoflow_conv(x_, w_, 2, 1, "pallas", bias=b_, epilogue=ep))
    g = lambda x_, w_, b_: jax.grad(f, (0, 1, 2))(x_, w_, b_)
    assert count_pallas_calls(g, x, w, b) == 2
    jaxpr = jax.make_jaxpr(g)(x, w, b)
    pallas_eqns = [e for e in walk_eqns(jaxpr.jaxpr)
                   if e.primitive.name == "pallas_call"]
    n_outs = sorted(len(e.outvars) for e in pallas_eqns)
    assert n_outs == [1, 3], n_outs     # fwd: y; bwd: (dx, dW, db)
    assert _tail_eqns_outside_pallas(g, x, w, b) == []


def test_structural_ct_backward_three_outputs(rng):
    """Same pin for the transposed conv: the generator layer's entire
    backward (ddy, dW, db) is one launch."""
    ep = Epilogue(activation="tanh", bias=True)
    dy = jnp.asarray(rng.normal(size=(2, 5, 5, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4, 6, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(6,)), jnp.float32)
    f = lambda dy_, w_, b_: jnp.sum(ecoflow_conv_transpose(
        dy_, w_, 2, 1, (10, 10), "pallas", bias=b_, epilogue=ep))
    g = lambda dy_, w_, b_: jax.grad(f, (0, 1, 2))(dy_, w_, b_)
    assert count_pallas_calls(g, dy, w, b) == 2
    jaxpr = jax.make_jaxpr(g)(dy, w, b)
    pallas_eqns = [e for e in walk_eqns(jaxpr.jaxpr)
                   if e.primitive.name == "pallas_call"]
    n_outs = sorted(len(e.outvars) for e in pallas_eqns)
    assert n_outs == [1, 3], n_outs
    assert _tail_eqns_outside_pallas(g, dy, w, b) == []


def test_identity_epilogue_keeps_legacy_jaxpr(rng):
    """An identity Epilogue (or none at all) routes through the plain
    custom_vjp: same eqn count, same launch count -- the epilogue slot
    costs nothing when unused."""
    x = jnp.asarray(rng.normal(size=(2, 9, 9, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 5)), jnp.float32)
    plain = jax.make_jaxpr(
        lambda x_, w_: ecoflow_conv(x_, w_, 2, 1, "pallas"))(x, w)
    ident = jax.make_jaxpr(
        lambda x_, w_: ecoflow_conv(x_, w_, 2, 1, "pallas",
                                    epilogue=Epilogue()))(x, w)
    names = lambda j: [e.primitive.name for e in walk_eqns(j.jaxpr)]
    assert names(plain) == names(ident)


def test_epilogue_bias_requires_array():
    x = jnp.zeros((1, 8, 8, 3))
    w = jnp.zeros((3, 3, 3, 4))
    with pytest.raises(ValueError, match="bias"):
        ecoflow_conv(x, w, 2, 1, "pallas",
                     epilogue=Epilogue(activation="relu", bias=True))


def test_epilogue_validation():
    with pytest.raises(ValueError):
        Epilogue(activation="gelu")
    with pytest.raises(ValueError):
        # slope <= 0 would make the output-side mask ambiguous at y < 0
        Epilogue(activation="leaky_relu", slope=0.0)
    assert Epilogue().is_identity
    assert Epilogue(activation="relu").tag == "relu"
    assert Epilogue(bias=True).tag == "b"
    assert Epilogue(activation="leaky_relu", slope=0.2,
                    bias=True).tag == "b+leaky_relu0.2"
    assert Epilogue(activation="relu", bias=True,
                    scale=0.5).tag == "b+relu+s0.5"


def test_kernel_wrappers_accept_epilogue(rng):
    """The kernel-level wrappers (ops.py) take bias/epilogue directly --
    the declarative path the benchmarks drive."""
    ep = Epilogue(activation="relu", bias=True)
    x = jnp.asarray(rng.normal(size=(1, 9, 9, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
    y = ops.dconv_forward(x, w, stride=(2, 2), padding=(1, 1),
                          dilation=(1, 1), bias=b, epilogue=ep)
    want = _manual_tail(ecoflow_conv(x, w, 2, 1, "reference"), b, ep)
    assert_allclose(y, want)
