"""Unit tests for the dry-run HLO analysis tooling (collective parsing,
shape-byte accounting) and the analytic roofline cost model."""
from __future__ import annotations

import pytest

from repro.launch import dryrun
from repro.models.config import SHAPES
from repro.configs import ARCH_IDS, get_config
from benchmarks import flops as F


def test_shape_bytes():
    assert dryrun._shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert dryrun._shape_bytes("bf16[2,4,8]") == 2 * 4 * 8 * 2
    assert dryrun._shape_bytes("(f32[8], bf16[8])") == 8 * 4 + 8 * 2
    assert dryrun._shape_bytes("s32[]") == 0 or True  # scalars: no dims
    assert dryrun._shape_bytes("pred[16]") == 16


def test_parse_collectives():
    hlo = """
  %ag = bf16[64,128] all-gather(bf16[8,128] %x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %ar = f32[256] all-reduce(f32[256] %y), replica_groups={{0,1},{2,3}}, to_apply=%add
  %rs = f32[32,16] reduce-scatter(f32[256,16] %z), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = bf16[4,64] all-to-all(bf16[4,64] %w), replica_groups={{0,1,2,3}}
  %cp = f32[8] collective-permute(f32[8] %v), source_target_pairs={{0,1}}
  %mm = f32[64,64] dot(f32[64,64] %a, f32[64,64] %b)
"""
    c = dryrun.parse_collectives(hlo)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 64 * 128 * 2
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["bytes"] == 256 * 4
    assert c["reduce-scatter"]["count"] == 1
    assert c["all-to-all"]["count"] == 1
    assert c["collective-permute"]["count"] == 1
    assert 8 in c["group_sizes"] and 2 in c["group_sizes"]


def test_parse_collectives_start_variants():
    hlo = "%a = bf16[8] all-gather-start(bf16[1] %x), replica_groups={{0}}\n"
    c = dryrun.parse_collectives(hlo)
    # async variants (all-gather-start) must be counted once
    assert c["all-gather"]["count"] == 1


# ---------------------------------------------------------------------------
# analytic cost model sanity
# ---------------------------------------------------------------------------

def test_total_params_match_known_sizes():
    """Parameter counts within tolerance of the published model sizes."""
    expect = {   # (billions, rtol)
        "qwen3_moe_235b_a22b": (235, 0.10),
        "rwkv6_7b": (7.6, 0.15),
        "gemma_2b": (2.5, 0.20),    # 2B excluding/including embeddings
        "gemma_7b": (8.5, 0.20),
        "qwen2_1_5b": (1.5, 0.25),
        "zamba2_2_7b": (2.7, 0.30),
    }
    for arch, (bn, tol) in expect.items():
        n = F.total_params(get_config(arch)) / 1e9
        assert abs(n - bn) / bn < tol, (arch, n, bn)


def test_active_params_moe():
    cfg = get_config("qwen3_moe_235b_a22b")
    na = F.active_params(cfg) / 1e9
    nt = F.total_params(cfg) / 1e9
    assert 15 < na < 30      # A22B
    assert na < nt / 5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cell_cost_positive(arch):
    cfg = get_config(arch)
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        cc = F.cell_cost(cfg, SHAPES[s])
        assert cc.model_flops > 0
        assert cc.impl_flops >= cc.model_flops * 0.9
        assert cc.hbm_bytes > 0


def test_train_flops_ratio_reasonable():
    """model/impl FLOPs ratio (useful-compute fraction) in (0.3, 1.0] --
    full remat costs ~1 extra forward of the 6N."""
    for arch in ("gemma_2b", "qwen2_1_5b", "internvl2_76b"):
        cc = F.cell_cost(get_config(arch), SHAPES["train_4k"])
        r = cc.model_flops / cc.impl_flops
        assert 0.3 < r <= 1.0, (arch, r)
