"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU with correct output
shapes and no NaNs, plus prefill->decode consistency (teacher forcing)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, \
    supported_shapes
from repro.models.config import SHAPES
from repro.models.lm import LM
from repro.launch.steps import input_specs, make_train_step
from repro.optim.optimizer import AdamWConfig, adamw_init


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.embed_input:
        inputs = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                             jnp.bfloat16)
    else:
        inputs = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    b = _batch(cfg)
    x, aux = jax.jit(lm.forward)(params, b["inputs"])
    B = b["labels"].shape[0]
    assert x.shape == (B, 32, cfg.d_model)
    assert bool(jnp.isfinite(x.astype(jnp.float32)).all())
    loss, parts = jax.jit(lm.loss)(params, b["inputs"], b["labels"])
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates_params(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    b = _batch(cfg)
    p2, opt2, metrics = step(params, opt, b)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    # at least one leaf actually changed
    changed = any(
        bool(jnp.any(a != b_)) for a, b_ in
        zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed
    assert int(opt2["count"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_teacher_forcing(arch):
    """Prefill over [t0..tn] then decode tn+1 must equal a longer prefill:
    the cache semantics (KV / conv / SSM state) are consistent."""
    # float32 compute isolates cache *semantics* from bf16 rounding drift
    # (bf16 drift through stacked layers is ~0.2 logits for the hybrid
    # arch; verified numerics-only -- see test history).
    cfg = get_smoke_config(arch).scaled(dtype="float32")
    if cfg.embed_input:
        pytest.skip("frontend-stub archs drive decode via token embeds")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, S + 1)), jnp.int32)
    # Reference: prefill the full S+1 prompt; its last-token logits.
    ref_logits, _ = jax.jit(lambda p, t: lm.prefill(p, t, S + 9))(
        params, toks)
    # Candidate: prefill S, then one decode step with token S.
    _, cache = jax.jit(lambda p, t: lm.prefill(p, t, S + 9))(
        params, toks[:, :S])
    dec_logits, cache2 = jax.jit(lm.decode_step)(params, cache,
                                                 toks[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(ref_logits[:, 0]),
                               rtol=1e-3, atol=1e-3)
    assert int(cache2["len"]) == S + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_all_cells(arch):
    """input_specs() builds abstract inputs for every supported shape cell
    of the FULL config without allocating."""
    cfg = get_config(arch)
    for s in supported_shapes(cfg):
        shape = SHAPES[s]
        specs = input_specs(cfg, shape)
        assert specs, (arch, s)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        if shape.kind == "train":
            lead = specs["inputs"].shape[0]
            assert lead == shape.global_batch
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch, 1)


def test_long_500k_only_subquadratic():
    """Assignment rule: long_500k runs for SSM/hybrid only."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = supported_shapes(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes, arch
        else:
            assert "long_500k" not in shapes, arch


def test_full_configs_match_assignment():
    """Pin the exact assigned architecture hyperparameters."""
    want = {
        "qwen3_moe_235b_a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, d_ff=1536, vocab=151936,
                                    n_experts=128, top_k=8),
        "moonshot_v1_16b_a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    n_kv_heads=16, d_ff=1408, vocab=163840,
                                    n_experts=64, top_k=6),
        "rwkv6_7b": dict(n_layers=32, d_model=4096, d_ff=14336,
                         vocab=65536),
        "qwen3_0_6b": dict(n_layers=28, d_model=1024, n_heads=16,
                           n_kv_heads=8, d_ff=3072, vocab=151936,
                           qk_norm=True),
        "qwen2_1_5b": dict(n_layers=28, d_model=1536, n_heads=12,
                           n_kv_heads=2, d_ff=8960, vocab=151936,
                           qkv_bias=True),
        "gemma_2b": dict(n_layers=18, d_model=2048, n_heads=8,
                         n_kv_heads=1, d_ff=16384, vocab=256000,
                         head_dim=256),
        "gemma_7b": dict(n_layers=28, d_model=3072, n_heads=16,
                         n_kv_heads=16, d_ff=24576, vocab=256000,
                         head_dim=256),
        "musicgen_medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                n_kv_heads=24, d_ff=6144, vocab=2048),
        "internvl2_76b": dict(n_layers=80, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=28672, vocab=128256),
        "zamba2_2_7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab=32000,
                            ssm_state=64),
    }
    for arch, fields in want.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "qwen3_moe_235b_a22b",
                                  "gemma_2b"])
def test_int8_kv_cache_decode(arch):
    """Perf A3: int8 KV cache -- decode distributions match the bf16
    cache to quantization tolerance, and the cache really is int8."""
    cfg = get_smoke_config(arch).scaled(dtype="float32", kv_quant=True)
    cfg_ref = get_smoke_config(arch).scaled(dtype="float32")
    lm, lmr = LM(cfg), LM(cfg_ref)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, S + 1)), jnp.int32)
    ref_logits, _ = jax.jit(lambda p, t: lmr.prefill(p, t, S + 9))(
        params, toks)
    _, cache = jax.jit(lambda p, t: lm.prefill(p, t, S + 9))(
        params, toks[:, :S])
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].dtype == jnp.float32
    dec, cache2 = jax.jit(lm.decode_step)(params, cache, toks[:, S:S + 1])
    diff = jnp.abs(jax.nn.softmax(dec[:, 0]) -
                   jax.nn.softmax(ref_logits[:, 0])).max()
    assert float(diff) < 0.05, float(diff)
    assert int(cache2["len"]) == S + 1
    # multi-step decode stays finite and consistent
    for _ in range(3):
        dec, cache2 = jax.jit(lm.decode_step)(
            params, cache2, jnp.argmax(dec[:, 0], -1)[:, None]
            .astype(jnp.int32))
    assert bool(jnp.isfinite(dec).all())
