"""Fused dual-gradient backward (`kernels/dconv_backward.py`): parity of
the single-launch (dx, dW) / (ddy, dW) pairs against `jax.grad` of
`lax.conv_general_dilated`, over stride x dilation x ragged channels x
B > 1 -- plus the structural pins of the fusion: exactly ONE
`pallas_call` per conv backward on the `pallas` backend, BOTH outputs
emitted by that same launch, and no duplicated dy-shaped intermediate
anywhere in the traced jaxpr (the error map is fetched once).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecoflow
from repro.core.conv import ecoflow_conv, ecoflow_conv_transpose
from repro.core.spec import ConvSpec, resolve_backend
from repro.kernels import ops
from repro.kernels.dconv_backward import (conv_backward_pallas,
                                          tconv_backward_pallas)

from conftest import (assert_allclose, count_pallas_calls, pallas_grids,
                      pallas_block_shapes, walk_eqns)

BACKENDS = ["reference", "xla_zero_free", "pallas"]

# (name, B, N, K, S, P, D, Ci, Co): stride x dilation x ragged channels
# x batch > 1 -- the parity grid of the fused backward.
BACKWARD_GRID = [
    ("s1",            2, 8,  3, 1, 1, 1, 3,  4),
    ("s2",            2, 9,  3, 2, 0, 1, 4,  4),
    ("s2_pad",        2, 9,  3, 2, 1, 1, 3,  5),
    ("s2_ragged",     2, 9,  3, 2, 1, 1, 29, 21),
    ("s3_k4",         1, 13, 4, 3, 0, 1, 2,  5),
    ("s4_klt_s",      1, 12, 2, 4, 0, 1, 5,  5),   # K < S: empty phases
    ("s2_nonexact",   2, 10, 3, 2, 0, 1, 3,  4),   # tail rows ignored
    ("s1_d2_atrous",  2, 11, 3, 1, 2, 2, 3,  3),
    ("s2_d2",         2, 14, 3, 2, 1, 2, 3,  2),   # gcd(S, D) = 2
    ("s3_d2_coprime", 1, 14, 3, 3, 0, 2, 2,  3),
    ("ragged_cin_gt_tile", 1, 7, 3, 2, 1, 1, 130, 3),
]


def _ref_grads(x, w, S, P, D, dy):
    """(dx, dw) from jax.vjp of the plain (rhs-dilated) lax conv."""
    f = lambda x_, w_: jax.lax.conv_general_dilated(
        x_, w_, (S, S), [(P, P), (P, P)], rhs_dilation=(D, D),
        dimension_numbers=ecoflow.DN)
    _, vjp = jax.vjp(f, x, w)
    return vjp(dy)


def _case(rng, B, N, K, S, P, D, Ci, Co):
    k_eff = D * (K - 1) + 1
    O = (N + 2 * P - k_eff) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    return x, w, dy


# ---------------------------------------------------------------------------
# parity: fused backward == jax.grad of the plain conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,B,N,K,S,P,D,Ci,Co", BACKWARD_GRID)
def test_fused_backward_parity_grid(rng, name, B, N, K, S, P, D, Ci, Co):
    x, w, dy = _case(rng, B, N, K, S, P, D, Ci, Co)
    dx_ref, dw_ref = _ref_grads(x, w, S, P, D, dy)
    dx, dw = ops.conv_backward(x, dy, w, stride=(S, S), padding=(P, P),
                               n_out=(N, N), dilation=(D, D))
    assert dx.shape == x.shape and dw.shape == w.shape
    assert_allclose(dx, dx_ref, rtol=2e-4, atol=2e-4, err_msg=f"{name} dx")
    assert_allclose(dw, dw_ref, rtol=2e-4, atol=2e-4, err_msg=f"{name} dw")


@pytest.mark.parametrize("backend", BACKENDS)
def test_backward_method_all_backends(rng, backend):
    """`ConvBackend.backward` (fused on pallas, two-launch composition on
    reference/xla_zero_free) agrees with jax.grad of the plain conv."""
    B, N, K, S, P, D, Ci, Co = 2, 9, 3, 2, 1, 1, 3, 4
    x, w, dy = _case(rng, B, N, K, S, P, D, Ci, Co)
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K, dilation=D)
    dx, dw = resolve_backend(backend).backward(x, dy, w, spec, (N, N))
    dx_ref, dw_ref = _ref_grads(x, w, S, P, D, dy)
    assert_allclose(dx, dx_ref, rtol=2e-4, atol=2e-4,
                    err_msg=f"{backend} dx")
    assert_allclose(dw, dw_ref, rtol=2e-4, atol=2e-4,
                    err_msg=f"{backend} dw")


RAGGED_TILE_SWEEP = [
    # (B, N, K, S, P, D, Ci, Co, ci_t, co_t, u, pu): pinned tilings with
    # ragged remainders, multiple Cout tiles, and partial phase/tap
    # unrolls (the traced-slot kernel path with masked dW accumulation).
    (2, 9, 3, 2, 0, 1, 5, 20, 4, 8, 1, 1),
    (2, 9, 3, 2, 0, 1, 5, 20, 4, 8, 2, 2),
    (3, 9, 3, 2, 1, 1, 13, 7, 8, 4, 4, 1),
    (2, 14, 3, 2, 1, 2, 3, 5, 2, 2, 1, 1),    # strided + dilated, traced
    (1, 23, 11, 4, 2, 1, 3, 5, 2, 4, 3, 2),   # big filter, ragged phases
]


@pytest.mark.parametrize("B,N,K,S,P,D,Ci,Co,ci_t,co_t,u,pu",
                         RAGGED_TILE_SWEEP)
def test_fused_backward_ragged_tiles(rng, B, N, K, S, P, D, Ci, Co, ci_t,
                                     co_t, u, pu):
    x, w, dy = _case(rng, B, N, K, S, P, D, Ci, Co)
    dx, dw = conv_backward_pallas(
        x, dy, w, stride=(S, S), padding=(P, P), n_out=(N, N),
        dilation=(D, D), cin_tile=ci_t, cout_tile=co_t, tap_unroll=u,
        phase_unroll=pu, interpret=True)
    dx_ref, dw_ref = _ref_grads(x, w, S, P, D, dy)
    assert_allclose(dx, dx_ref, rtol=2e-4, atol=2e-4)
    assert_allclose(dw, dw_ref, rtol=2e-4, atol=2e-4)


def test_fused_backward_bf16(rng):
    B, N, K, S, Ci, Co = 2, 9, 3, 2, 4, 4
    O = (N - K) // S + 1
    x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.bfloat16)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.bfloat16)
    dx, dw = conv_backward_pallas(x, dy, w, stride=(S, S), padding=(0, 0),
                                  n_out=(N, N), interpret=True)
    assert dx.dtype == jnp.bfloat16 and dw.dtype == jnp.bfloat16
    dx_ref, dw_ref = _ref_grads(x.astype(jnp.float32),
                                w.astype(jnp.float32), S, 0, 1,
                                dy.astype(jnp.float32))
    assert_allclose(dx, dx_ref, rtol=5e-2, atol=5e-2)
    assert_allclose(dw, dw_ref, rtol=5e-2, atol=5e-2)


def test_fused_backward_rejects_inconsistent_geometry(rng):
    x, w, dy = _case(rng, 1, 9, 3, 2, 0, 1, 3, 4)
    with pytest.raises(ValueError, match="inconsistent"):
        conv_backward_pallas(x, dy[:, :-1], w, stride=(2, 2),
                             padding=(0, 0), interpret=True)


# ---------------------------------------------------------------------------
# parity: fused transposed-conv backward (the GAN generator layer)
# ---------------------------------------------------------------------------

CT_GRID = [
    # (name, B, O, K, S, P, D, Ci, Co)
    ("gan_gen",     2, 8, 4, 2, 1, 1, 8, 16),
    ("s2_ragged",   2, 5, 3, 2, 0, 1, 29, 21),
    ("s3",          1, 6, 4, 3, 0, 1, 3, 5),
    ("s1_d2",       2, 6, 3, 1, 2, 2, 3, 3),
    ("s2_d2",       2, 5, 3, 2, 1, 2, 2, 3),
]


@pytest.mark.parametrize("name,B,O,K,S,P,D,Ci,Co", CT_GRID)
def test_fused_ct_backward_parity_grid(rng, name, B, O, K, S, P, D, Ci,
                                       Co):
    """(ddy, dW) of the transposed conv from one launch == jax.grad of
    the standalone transposed conv through the reference backend."""
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K, dilation=D)
    n = spec.input_size((O, O))[0]
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(B, n, n, Ci)), jnp.float32)

    def loss(dy_, w_, backend):
        z = ecoflow_conv_transpose(dy_, w_, S, P, n_out=(n, n),
                                   backend=backend, dilation=D)
        return jnp.vdot(z, g)

    ddy, dw = jax.grad(loss, argnums=(0, 1))(dy, w, "pallas")
    ddy_ref, dw_ref = jax.grad(loss, argnums=(0, 1))(dy, w, "reference")
    assert_allclose(ddy, ddy_ref, rtol=2e-4, atol=2e-4,
                    err_msg=f"{name} ddy")
    assert_allclose(dw, dw_ref, rtol=2e-4, atol=2e-4, err_msg=f"{name} dw")


CT_RAGGED_TILES = [
    # (B, O, K, S, P, Ci, Co, ci_t, co_t, u)
    (2, 5, 3, 2, 0, 5, 20, 2, 8, 1),
    (1, 5, 3, 2, 0, 5, 20, 2, 8, 3),
    (3, 4, 4, 2, 1, 7, 9, 4, 4, 16),
]


@pytest.mark.parametrize("B,O,K,S,P,Ci,Co,ci_t,co_t,u", CT_RAGGED_TILES)
def test_fused_ct_backward_ragged_tiles(rng, B, O, K, S, P, Ci, Co, ci_t,
                                        co_t, u):
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K)
    n = spec.input_size((O, O))[0]
    g = jnp.asarray(rng.normal(size=(B, n, n, Ci)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    ddy, dw = tconv_backward_pallas(g, dy, w, stride=(S, S),
                                    padding=(P, P), cin_tile=ci_t,
                                    cout_tile=co_t, tap_unroll=u,
                                    interpret=True)
    be = resolve_backend("reference")
    assert_allclose(ddy, be.forward(g, w, spec), rtol=2e-4, atol=2e-4)
    assert_allclose(dw, be.filter_grad(g, dy, spec), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# structural pins of the fusion
# ---------------------------------------------------------------------------

def test_backward_single_launch_both_outputs(rng):
    """jax.grad of a pallas-backend conv traces exactly ONE pallas_call,
    and that launch emits BOTH gradients (two output refs: the
    phase-major dx accumulator and the stationary tap-major dW block)."""
    B, N, K, S, Ci, Co = 2, 9, 3, 2, 3, 5
    x, w, dy = _case(rng, B, N, K, S, 0, 1, Ci, Co)
    loss = lambda x_, w_: jnp.vdot(ecoflow_conv(x_, w_, S, 0, "pallas"),
                                   dy)
    g = lambda x_, w_: jax.grad(loss, argnums=(0, 1))(x_, w_)
    assert count_pallas_calls(g, x, w) == 1
    jaxpr = jax.make_jaxpr(g)(x, w)
    pallas_eqns = [e for e in walk_eqns(jaxpr.jaxpr)
                   if e.primitive.name == "pallas_call"]
    out_shapes = [tuple(v.aval.shape) for v in pallas_eqns[0].outvars]
    assert len(out_shapes) == 2, out_shapes
    # (B, T, ho, wo, Cin) phase-major dx + (Kh*Kw, Cin, Cout) dW.
    assert out_shapes[0][0] == B and out_shapes[0][-1] == Ci, out_shapes
    assert out_shapes[1] == (K * K, Ci, Co), out_shapes


def test_backward_no_duplicated_dy_intermediates(rng):
    """The error map is fetched ONCE: exactly one dy-sized Cout-channel
    intermediate (the single padded dy) appears in the traced backward --
    the two-launch path's second dy staging (the filter-grad slab
    reshape) is gone."""
    B, N, K, S, Ci, Co = 2, 9, 3, 2, 3, 5
    x, w, dy = _case(rng, B, N, K, S, 0, 1, Ci, Co)
    fn = lambda x_, dy_, w_: ops.conv_backward(
        x_, dy_, w_, stride=(S, S), padding=(0, 0), n_out=(N, N))
    jaxpr = jax.make_jaxpr(fn)(x, dy, w)
    dy_sized = []
    for e in walk_eqns(jaxpr.jaxpr):
        if e.primitive.name in ("pjit", "custom_jvp_call",
                                "custom_vjp_call_jaxpr"):
            continue   # call wrappers re-report their sub-jaxpr's output
        for v in e.outvars:
            shape = tuple(getattr(v.aval, "shape", ()))
            if len(shape) >= 4 and shape[-1] == Co \
                    and int(np.prod(shape)) >= dy.size:
                dy_sized.append((e.primitive.name, shape))
    assert len(dy_sized) == 1, dy_sized
    assert dy_sized[0][0] == "pad", dy_sized      # the one padded dy


def test_backward_grid_and_block_shapes(rng):
    """Grid (Cin_t, B, T/pu, Cout_t, TK/u) with the phase axis OUTSIDE
    the Cout axis; the dy block carries a Cout tile of the full padded
    frame (the shared fetch), the x block a Cin tile, and the dW block
    is stationary across (b, phase, co, tap): (T_w, ci_t, Cout_pad)."""
    B, N, K, S, Ci, Co, ci_t, co_t = 2, 9, 3, 2, 8, 20, 4, 8
    x, w, dy = _case(rng, B, N, K, S, 0, 1, Ci, Co)
    fn = lambda x_, dy_, w_: conv_backward_pallas(
        x_, dy_, w_, stride=(S, S), padding=(0, 0), n_out=(N, N),
        cin_tile=ci_t, cout_tile=co_t, tap_unroll=1, phase_unroll=1,
        interpret=True)
    grids = pallas_grids(fn, x, dy, w)
    assert len(grids) == 1
    T = min(S, K) ** 2
    TK = (-(-K // S)) ** 2
    n_ci, n_co = -(-Ci // ci_t), -(-Co // co_t)
    assert grids[0] == (n_ci, B, T, n_co, TK), grids[0]
    blocks = pallas_block_shapes(fn, x, dy, w)[0]
    dy_blk, w_blk, x_blk, dx_blk, dw_blk = blocks
    assert dy_blk[-1] == co_t, blocks             # dy: Cout tile
    assert x_blk[-1] == ci_t, blocks              # x: Cin tile
    assert dx_blk[-1] == ci_t, blocks             # dx: Cin tile
    # dW: stationary block spans ALL taps and full (padded) Cout width,
    # so the sequential co axis never interrupts its visit streak.
    assert dw_blk == (K * K, ci_t, n_co * co_t), blocks


def test_ct_backward_single_launch_both_outputs(rng):
    """The transposed conv's ENTIRE backward is one pallas_call emitting
    (ddy, dW) -- the generator layer's gradient no longer pays a
    separate forward-conv launch plus a filter-grad launch."""
    B, O, K, S, P, Ci, Co = 2, 5, 4, 2, 1, 4, 6
    spec = ConvSpec.make(stride=S, padding=P, filter_shape=K)
    n = spec.input_size((O, O))[0]
    dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(B, n, n, Ci)), jnp.float32)
    fn = lambda g_, dy_, w_: ops.tconv_backward(
        g_, dy_, w_, stride=(S, S), padding=(P, P))
    assert count_pallas_calls(fn, g, dy, w) == 1
    jaxpr = jax.make_jaxpr(fn)(g, dy, w)
    pallas_eqns = [e for e in walk_eqns(jaxpr.jaxpr)
                   if e.primitive.name == "pallas_call"]
    out_shapes = [tuple(v.aval.shape) for v in pallas_eqns[0].outvars]
    assert len(out_shapes) == 2, out_shapes
    assert out_shapes[0] == (B, O, O, Co), out_shapes
    assert out_shapes[1] == (K * K, Ci, Co), out_shapes


def test_grad_through_models_single_backward_launch(rng):
    """End to end through jax.grad of a two-conv model on the pallas
    backend: one fused backward launch PER LAYER (plus the dilation-1
    forward convs, which are XLA on the unfused path) -- zero call-site
    changes.  With the declarative relu epilogue (the model default) the
    forward also becomes one fused pallas launch per layer, so the whole
    train step is exactly two launches per layer."""
    from repro.models import cnn
    params = cnn.simple_cnn_init(jax.random.PRNGKey(0), in_ch=3,
                                 widths=(4, 6), n_classes=4)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)), jnp.float32)
    y = jnp.asarray([0, 1])
    loss = lambda p: cnn.cnn_loss(p, x, y, stride=2, backend="pallas",
                                  fuse_epilogue=False)
    g = lambda p: jax.grad(loss)(p)
    assert count_pallas_calls(g, params) == 2      # one per conv layer
    loss_ep = lambda p: cnn.cnn_loss(p, x, y, stride=2, backend="pallas")
    g_ep = lambda p: jax.grad(loss_ep)(p)
    # fwd + bwd fused launches per layer, relu tails in-kernel.
    assert count_pallas_calls(g_ep, params) == 4
