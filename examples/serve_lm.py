"""Batched serving example: continuous batching over prefill + decode.

Loads a reduced-config architecture, enqueues more requests than the
batch size, and generates greedily -- slots are refilled as sequences
finish (the static-bucket continuous-batching discipline the decode_32k /
long_500k dry-run cells lower at production scale).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.lm import LM
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=args.batch, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(
                        1, cfg.vocab, int(rng.integers(3, 12)),
                        dtype=np.int64).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    results = eng.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in results.values())
    for uid in sorted(results):
        print(f"req {uid:2d} ({len(reqs[uid].prompt)} prompt toks) "
              f"-> {results[uid]}")
    print(f"\n{len(reqs)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s) with batch={args.batch} "
          f"continuous batching")
    assert len(results) == args.requests


if __name__ == "__main__":
    main()
