"""Quickstart: EcoFlow's zero-free transposed/dilated convolutions.

Shows the paper's core contribution end to end on one layer:
  1. how much of the naive backward pass is multiplications by zero,
  2. that the zero-free dataflows compute bit-identical gradients,
  3. the compile-time mapping (symbolic outer product -> PE schedules)
     functionally simulated on a PE-array model,
  4. wall-clock of zero-free vs materialized-zero on this host.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecoflow, mapping, naive
from repro.core.conv import ecoflow_conv

# A resnet50-CONV3-like layer: 3x3 filter, stride 2.
B, N, K, S, Ci, Co = 4, 57, 3, 2, 16, 16
P = 1
O = (N + 2 * P - K) // S + 1
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)

print("== 1. padding-induced zero MACs (paper Fig. 3) ==")
print(f"layer: ifmap {N}x{N}, filter {K}x{K}, stride {S} -> error {O}x{O}")
print(f"input-grad  zero-MAC fraction: "
      f"{ecoflow.tconv_zero_mac_fraction(O, K, S):.1%}")
print(f"filter-grad zero-MAC fraction: "
      f"{ecoflow.dconv_zero_mac_fraction(O, S):.1%}")

print("\n== 2. zero-free gradients == jax.vjp of the plain conv ==")
f = lambda x_, w_: ecoflow.direct_conv(x_, w_, S, P)
_, vjp = jax.vjp(f, x, w)
dx_ref, dw_ref = vjp(dy)
dx = ecoflow.transposed_conv_zero_free(dy, w, stride=(S, S),
                                       padding=(P, P), n_out=(N, N))
dw = ecoflow.dilated_conv_filter_grad_zero_free(
    x, dy, stride=(S, S), padding=(P, P), k=(K, K))
print("max |dx - dx_ref| =", float(jnp.abs(dx - dx_ref).max()))
print("max |dw - dw_ref| =", float(jnp.abs(dw - dw_ref).max()))

print("\n== 3. the paper's compile-time mapping, simulated on a PE array ==")
m = mapping.build_tconv_mapping(err_n=2, k=3, stride=2)   # Fig. 5 example
err2 = rng.normal(size=(2, 2))
w2 = rng.normal(size=(3, 3))
out = mapping.simulate_tconv(m, err2, w2)
full = np.zeros((m.out_n, m.out_n))
for i in range(2):
    for j in range(2):
        full[2 * i:2 * i + 3, 2 * j:2 * j + 3] += err2[i, j] * w2
print(f"PE array {m.pe_rows}x{m.pe_cols}, useful MACs {m.n_useful_macs}, "
      f"schedule {m.cycle_count()} cycles")
print("mapping == ground truth:", np.allclose(out, full))

print("\n== 4. wall-clock: zero-free vs materialized-zero (this host) ==")
f_eco = jax.jit(lambda dy, w: ecoflow.transposed_conv_zero_free(
    dy, w, stride=(S, S), padding=(P, P), n_out=(N, N)))
f_nai = jax.jit(lambda dy, w: naive.transposed_conv_naive(
    dy, w, stride=(S, S), padding=(P, P), n_out=(N, N)))
for fn in (f_eco, f_nai):
    jax.block_until_ready(fn(dy, w))
t0 = time.perf_counter()
for _ in range(10):
    jax.block_until_ready(f_eco(dy, w))
t_eco = (time.perf_counter() - t0) / 10
t0 = time.perf_counter()
for _ in range(10):
    jax.block_until_ready(f_nai(dy, w))
t_nai = (time.perf_counter() - t0) / 10
print(f"zero-free {t_eco * 1e3:.2f} ms vs naive {t_nai * 1e3:.2f} ms "
      f"-> {t_nai / t_eco:.2f}x")

print("\n== 5. drop-in training conv with EcoFlow backward ==")
loss = lambda x_, w_: jnp.sum(ecoflow_conv(x_, w_, S, P) ** 2)
gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
print("grad shapes:", gx.shape, gw.shape, "-- finite:",
      bool(jnp.isfinite(gx).all() and jnp.isfinite(gw).all()))
