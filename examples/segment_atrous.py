"""End-to-end driver: train the atrous segmentation head with zero-free
dilated-forward convolutions.

The segmentation-style workload the paper motivates (Sec. 1): DeepLab's
atrous convs apply the filter at rate D without losing resolution, and a
naive accelerator lowering schedules (D*(K-1)+1)^2 / K^2 more MACs than
useful.  Every branch here routes through `ecoflow_dilated_conv`, so the
dilated filter is never materialized -- forward or backward -- on any
backend.  The branch relu tails ride the declarative epilogue slot
(DESIGN Sec. 2.8): the head requests `Epilogue(activation="relu")` per
branch, so on the pallas backend each branch's forward AND backward stay
at one launch with the activation (and its gradient mask) fused in-VMEM.
`--no-fuse-epilogue` falls back to separate XLA relu ops for comparison.

Run:  PYTHONPATH=src python examples/segment_atrous.py [--steps 120]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import vision
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update


def synth_batch(step: int, *, batch=8, size=24):
    """Deterministic synthetic segmentation set: each image carries a
    bright axis-aligned rectangle on textured noise; the per-pixel label
    is 1 inside the rectangle, else 0.  Pure function of `step`."""
    rng = np.random.default_rng(np.random.SeedSequence([11, step]))
    xs, ys = [], []
    for _ in range(batch):
        img = 0.3 * rng.standard_normal((size, size, 3))
        y = np.zeros((size, size), np.int32)
        r0, c0 = rng.integers(2, size - 10, 2)
        h, w = rng.integers(6, 10, 2)
        img[r0:r0 + h, c0:c0 + w] += 1.5
        y[r0:r0 + h, c0:c0 + w] = 1
        xs.append(img)
        ys.append(y)
    return (jnp.asarray(np.stack(xs), jnp.float32),
            jnp.asarray(np.stack(ys), jnp.int32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--backend", default="xla_zero_free",
                    choices=("reference", "xla_zero_free", "pallas"),
                    help="conv dispatch backend (repro.core.spec)")
    ap.add_argument("--no-fuse-epilogue", dest="fuse_epilogue",
                    action="store_false",
                    help="run the branch relu tails as separate XLA ops "
                         "instead of the fused epilogue slot")
    args = ap.parse_args()

    rates = (1, 2, 4)
    params = vision.atrous_head_init(jax.random.PRNGKey(0), in_ch=3,
                                     width=16, n_classes=2, rates=rates)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps,
                      weight_decay=0.01)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step_fn(params, opt, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: vision.atrous_seg_loss(
                p, x, y, rates=rates, backend=args.backend,
                fuse_epilogue=args.fuse_epilogue))(params)
        params, opt, om = adamw_update(grads, opt, params, ocfg)
        logits = vision.atrous_head_apply(
            params, x, rates=rates, backend=args.backend,
            fuse_epilogue=args.fuse_epilogue)
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        return params, opt, loss, acc

    t0 = time.perf_counter()
    for step in range(args.steps):
        x, y = synth_batch(step)
        params, opt, loss, acc = step_fn(params, opt, x, y)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"pixel-acc {float(acc):.3f}")
    dt = time.perf_counter() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({1e3 * dt / args.steps:.1f} ms/step, backend={args.backend})")


if __name__ == "__main__":
    main()
