"""End-to-end driver: train a CNN classifier with EcoFlow backward passes.

The paper's headline workload is CNN training on a spatial accelerator;
here every convolution's backward pass routes through the zero-free
transposed (input-grad) and dilated (filter-grad) dataflows.  Trains an
AllConvNet-style model (stride-2 convs instead of pooling -- the paper's
Sec. 6.1.1 optimization) on synthetic image data for a few hundred steps.

Run:  PYTHONPATH=src python examples/train_cnn_ecoflow.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cnn
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update


def synth_batch(step: int, *, batch=32, size=24, n_classes=10):
    """Deterministic synthetic 'shapes' dataset: class = dominant stripe
    frequency -- learnable by a small CNN, pure function of step."""
    rng = np.random.default_rng(np.random.SeedSequence([7, step]))
    y = rng.integers(0, n_classes, batch)
    xs = []
    for i in range(batch):
        freq = 1 + y[i]
        t = np.linspace(0, np.pi * freq, size)
        img = np.outer(np.sin(t), np.cos(t))[..., None]
        img = np.repeat(img, 3, axis=-1)
        img += 0.35 * rng.standard_normal((size, size, 3))
        xs.append(img)
    return (jnp.asarray(np.stack(xs), jnp.float32),
            jnp.asarray(y, jnp.int32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--backend", default="xla_zero_free",
                    choices=("reference", "xla_zero_free", "pallas"),
                    help="conv dispatch backend (repro.core.spec)")
    args = ap.parse_args()

    params = cnn.simple_cnn_init(jax.random.PRNGKey(0),
                                 widths=(16, 32, 64), n_classes=10)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                       total_steps=args.steps, weight_decay=0.01)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step_fn(params, opt, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: cnn.cnn_loss(p, x, y, stride=2,
                                   backend=args.backend))(params)
        params, opt, om = adamw_update(grads, opt, params, ocfg)
        acc = jnp.mean(
            jnp.argmax(cnn.simple_cnn_apply(params, x, stride=2,
                                            backend=args.backend), -1) == y)
        return params, opt, loss, acc

    t0 = time.time()
    for step in range(args.steps):
        x, y = synth_batch(step)
        params, opt, loss, acc = step_fn(params, opt, x, y)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"acc {float(acc):.2f}")
    dt = time.time() - t0
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.1f} it/s); final train acc "
          f"{float(acc):.2f}")
    assert float(acc) > 0.5, "training should beat chance comfortably"


if __name__ == "__main__":
    main()
