"""GAN training example (the paper's Sec. 6.3 evaluation domain).

The DCGAN-style generator upsamples with the zero-free transposed-conv
dataflow (its forward pass IS the paper's input-gradient dataflow); the
discriminator downsamples with stride-2 convs whose backward pass uses the
zero-free dataflows.  Alternating non-saturating updates on synthetic
data.

Run:  PYTHONPATH=src python examples/train_gan.py [--steps 120]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gan
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update


def real_batch(step, *, batch=16, size=32):
    """Synthetic 'real' distribution: smooth blobs (low-frequency)."""
    rng = np.random.default_rng(np.random.SeedSequence([11, step]))
    xy = np.linspace(-1, 1, size)
    gx, gy = np.meshgrid(xy, xy)
    imgs = []
    for _ in range(batch):
        cx, cy = rng.uniform(-0.5, 0.5, 2)
        s = rng.uniform(0.2, 0.5)
        img = np.exp(-((gx - cx) ** 2 + (gy - cy) ** 2) / s)[..., None]
        imgs.append(np.repeat(img, 3, axis=-1) * 2 - 1)
    return jnp.asarray(np.stack(imgs), jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--backend", default="xla_zero_free",
                    choices=("reference", "xla_zero_free", "pallas"),
                    help="conv dispatch backend (repro.core.spec)")
    args = ap.parse_args()
    Z, BASE, B = 32, 16, 16

    gp = gan.generator_init(jax.random.PRNGKey(0), z_dim=Z, base=BASE)
    dp = gan.discriminator_init(jax.random.PRNGKey(1), base=BASE)
    gcfg = AdamWConfig(lr=2e-4, b1=0.5, warmup_steps=0,
                       total_steps=args.steps, weight_decay=0.0)
    dcfg = AdamWConfig(lr=2e-4, b1=0.5, warmup_steps=0,
                       total_steps=args.steps, weight_decay=0.0)
    g_opt, d_opt = adamw_init(gp, gcfg), adamw_init(dp, dcfg)

    @jax.jit
    def step_fn(gp, dp, g_opt, d_opt, z, real):
        be = args.backend
        d_loss, d_grads = jax.value_and_grad(
            lambda d: gan.gan_losses(gp, d, z, real, backend=be)[1])(dp)
        dp, d_opt, _ = adamw_update(d_grads, d_opt, dp, dcfg)
        g_loss, g_grads = jax.value_and_grad(
            lambda g: gan.gan_losses(g, dp, z, real, backend=be)[0])(gp)
        gp, g_opt, _ = adamw_update(g_grads, g_opt, gp, gcfg)
        return gp, dp, g_opt, d_opt, g_loss, d_loss

    t0 = time.time()
    for step in range(args.steps):
        rng = np.random.default_rng(np.random.SeedSequence([3, step]))
        z = jnp.asarray(rng.standard_normal((B, Z)), jnp.float32)
        real = real_batch(step, batch=B)
        gp, dp, g_opt, d_opt, gl, dl = step_fn(gp, dp, g_opt, d_opt, z,
                                               real)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  g_loss {float(gl):.3f}  "
                  f"d_loss {float(dl):.3f}")
    fake = gan.generator_apply(gp, z)
    print(f"\n{args.steps} alternating steps in {time.time() - t0:.1f}s; "
          f"generator output {fake.shape}, "
          f"range [{float(fake.min()):.2f}, {float(fake.max()):.2f}]")
    assert np.isfinite(float(gl)) and np.isfinite(float(dl))


if __name__ == "__main__":
    main()
