"""End-to-end LM training driver with checkpoint/restart.

Trains a reduced-config assigned architecture for a few hundred steps on
the deterministic synthetic token pipeline, demonstrating the full
production loop: sharded train step, async checkpointing, and a simulated
failure + restart that resumes bit-identically.

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b \
          --steps 200
"""
import argparse
import tempfile
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import TokenDataset
from repro.launch.mesh import make_debug_mesh
from repro.optim.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step, then restart")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    mesh = make_debug_mesh()
    ds = TokenDataset(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0,
                      embed_dim=cfg.d_model if cfg.embed_input else None)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                             ckpt_every=max(10, args.steps // 10),
                             log_every=max(5, args.steps // 20))
        trainer = Trainer(cfg, mesh, ds,
                          AdamWConfig(lr=3e-3, warmup_steps=20,
                                      total_steps=args.steps), tcfg)
        fail_at = args.fail_at or args.steps // 2
        print(f"training {args.arch} (reduced) for {args.steps} steps; "
              f"injecting failure at step {fail_at}...")
        t0 = time.time()
        try:
            trainer.run(fail_at_step=fail_at)
        except RuntimeError as e:
            print(f"  !! {e} -- restarting from the latest checkpoint")
        # "restart": a fresh Trainer picks up the latest atomic ckpt
        trainer2 = Trainer(cfg, mesh, ds,
                           AdamWConfig(lr=3e-3, warmup_steps=20,
                                       total_steps=args.steps), tcfg)
        out = trainer2.run()
        dt = time.time() - t0
        for h in out["history"]:
            print(f"  step {h['step']:5d}  loss {h['loss']:.4f}")
        first, last = out["history"][0], out["history"][-1]
        print(f"\ndone in {dt:.1f}s; loss {first['loss']:.3f} -> "
              f"{last['loss']:.3f} (resumed across a simulated failure)")
        assert last["loss"] < first["loss"] + 1e-6
        assert all(np.isfinite(h["loss"]) for h in out["history"])


if __name__ == "__main__":
    main()
