"""Paper-table benchmarks (SASiML-lite analytical model).

One function per table/figure of the paper; each returns a list of
(name, value, derived) CSV rows.  The `derived` column carries the paper's
reference number where one exists, so the reproduction delta is visible in
bench_output.txt.
"""
from __future__ import annotations

from repro.core import dataflow_sim as ds


def fig3_zero_macs():
    rows = []
    for l in ds.TABLE5_LAYERS + ds.OPT_LAYERS:
        rows.append((f"fig3.zero_mac_frac.input_grad.{l.name}",
                     round(ds.zero_mac_fraction(l, "input_grad"), 4),
                     f"stride={l.stride};paper:>0.7 for s>=2"))
        rows.append((f"fig3.zero_mac_frac.filter_grad.{l.name}",
                     round(ds.zero_mac_fraction(l, "filter_grad"), 4),
                     f"stride={l.stride}"))
    return rows


def fig8_input_grad_speedup():
    rows = []
    paper_ref = {1: "~1.0-1.1x", 2: "~4x", 4: "~11x", 8: "~52x"}
    for l in ds.TABLE5_LAYERS + ds.OPT_LAYERS:
        for df in ("ecoflow", "rs"):
            rows.append((f"fig8.input_grad_speedup.{df}.{l.name}",
                         round(ds.speedup(l, "input_grad", df), 3),
                         f"vs=tpu;stride={l.stride};"
                         f"paper_eco={paper_ref.get(l.stride, '?')}"))
        rows.append((f"fig8.input_grad_tpu_ms.{l.name}",
                     round(ds.exec_time_s(l, "input_grad", "tpu") * 1e3, 3),
                     "absolute TPU-dataflow time"))
    return rows


def fig9_filter_grad_speedup():
    rows = []
    paper_ref = {1: "~1x", 2: ">3x", 4: "15.6x", 8: "60.1x"}
    for l in ds.TABLE5_LAYERS + ds.OPT_LAYERS:
        rows.append((f"fig9.filter_grad_speedup.ecoflow.{l.name}",
                     round(ds.speedup(l, "filter_grad", "ecoflow"), 3),
                     f"vs=tpu;stride={l.stride};"
                     f"paper={paper_ref.get(l.stride, '?')}"))
    return rows


def fig10_energy():
    rows = []
    for l in ds.TABLE5_LAYERS + ds.OPT_LAYERS:
        for op in ("input_grad", "filter_grad"):
            e_tpu = ds.energy_pj(l, op, "tpu")
            e_eco = ds.energy_pj(l, op, "ecoflow")
            rows.append((f"fig10.energy_ratio.{op}.{l.name}",
                         round(e_tpu / e_eco, 3),
                         f"tpu_uJ={e_tpu/1e6:.1f};eco_uJ={e_eco/1e6:.1f};"
                         "paper: up to 26x ig / 8.3x fg"))
        br = ds.energy_breakdown_pj(l, "input_grad", "ecoflow")
        tot = sum(br.values())
        rows.append((f"fig10.energy_breakdown.ecoflow.{l.name}",
                     round(tot / 1e6, 2),
                     ";".join(f"{k}={v/tot:.2f}" for k, v in br.items())))
    return rows


def table6_end2end_cnn():
    paper = {"alexnet": 1.83, "resnet50": 1.07, "shufflenet": 1.08,
             "inception": 1.08, "xception": 1.11, "mobilenet": 1.09}
    rows = []
    for net in ds.END2END_FRACTIONS:
        v = ds.end_to_end_speedup(net, "ecoflow")
        rows.append((f"table6.end2end_speedup.{net}", round(v, 3),
                     f"paper={paper[net]};band=7-85%"))
    return rows


def table8_gan():
    paper = {"pix2pix": 1.39, "cyclegan": 1.42}
    rows = []
    for net in ds.GAN_FRACTIONS:
        v = ds.gan_end_to_end_speedup(net, "ecoflow")
        rows.append((f"table8.gan_end2end_speedup.{net}", round(v, 3),
                     f"paper={paper[net]};band=29-42%"))
    for l in ds.TABLE7_GAN_LAYERS:
        rows.append((f"fig11.gan_layer_speedup_vs_rs.{l.name}",
                     round(ds.speedup(l, "input_grad", "ecoflow", "rs"), 3),
                     "paper: ~4x"))
    return rows


def ablation_stride_sweep():
    """Beyond-paper ablation: the stride-quadratic law on one fixed layer
    geometry (ifmap 57, K 3, ch 64) swept over strides 1..8 -- isolates
    the paper's scaling claim from layer-to-layer confounds."""
    rows = []
    for s in (1, 2, 3, 4, 6, 8):
        n_out = (57 - 3) // s + 1
        l = ds.ConvLayer(f"sweep-s{s}", 64, 57, n_out, 3, 64, s)
        rows.append((f"ablation.stride_sweep.zero_frac.s{s}",
                     round(ds.zero_mac_fraction(l, "input_grad"), 4),
                     "law: 1 - (O/(S(O-1)+1+2(K-1)))^2"))
        rows.append((f"ablation.stride_sweep.ig_speedup.s{s}",
                     round(ds.speedup(l, "input_grad", "ecoflow"), 3),
                     "vs=tpu"))
        rows.append((f"ablation.stride_sweep.fg_speedup.s{s}",
                     round(ds.speedup(l, "filter_grad", "ecoflow"), 3),
                     "vs=tpu"))
    return rows


def ablation_array_size():
    """Grouping/expansion sensitivity: EcoFlow speedup vs physical array
    size for a fixed layer (paper uses 13x15; we sweep 8x8..32x32)."""
    rows = []
    l = ds.layer_by_name("resnet50-CONV3")
    for r, c in ((8, 8), (13, 15), (16, 16), (32, 32)):
        hw = ds.ArrayConfig(pe_rows=r, pe_cols=c)
        rows.append((f"ablation.array.ig_speedup.{r}x{c}",
                     round(ds.speedup(l, "input_grad", "ecoflow",
                                      hw=hw), 3),
                     "vs=tpu;same array for both dataflows"))
    return rows
