"""Wall-clock microbenchmarks: zero-free EcoFlow vs materialized-zero
naive dataflows, executed for real in JAX on this host (CPU here; the same
code paths compile for TPU) -- plus the conv *backend* comparison
(multi-launch `xla_zero_free` vs fused single-launch `pallas`) across the
paper's Table 5/7 layer geometries, the dilated-forward (atrous)
geometries at rates d in {2, 4}, and the general strided+dilated
input-gradient geometries (S > 1 AND D > 1, the unified (phase, tap)
kernel's family), emitted to BENCH_conv.json so future PRs have a perf
trajectory.

Reported as name,us_per_call,derived -- `derived` carries the speedup and
the useful-MAC fraction from the analytical model for cross-checking.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecoflow, naive
from repro.core.spec import ConvSpec, resolve_backend


def _time(fn, *args, iters=5, warmup=2):
    """Minimum per-call latency (us) over `iters` timed calls -- the min
    is the standard robust estimator for microbenchmarks (scheduler and
    allocator noise only ever adds time), keeping BENCH_conv.json rows
    comparable across PRs."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


# (name, N_err, K, S, Cin, Cout): error-map size, filter, stride, channels.
CASES = [
    ("resnet50-CONV3-like", 28, 3, 2, 32, 32),
    ("alexnet-CONV1-like", 28, 11, 4, 3, 16),
    ("gan-gen-like", 32, 4, 2, 32, 16),
    ("stride8-like", 16, 11, 8, 8, 8),
]


def run():
    rows = []
    rng = np.random.default_rng(0)
    for name, O, K, S, Ci, Co in CASES:
        B = 2
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        N = S * (O - 1) + K
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)

        f_eco = jax.jit(lambda dy, w: ecoflow.transposed_conv_zero_free(
            dy, w, stride=(S, S), padding=(0, 0), n_out=(N, N)))
        f_nai = jax.jit(lambda dy, w: naive.transposed_conv_naive(
            dy, w, stride=(S, S), padding=(0, 0), n_out=(N, N)))
        np.testing.assert_allclose(np.asarray(f_eco(dy, w)),
                                   np.asarray(f_nai(dy, w)),
                                   rtol=1e-3, atol=1e-3)
        t_eco = _time(f_eco, dy, w)
        t_nai = _time(f_nai, dy, w)
        zf = ecoflow.tconv_zero_mac_fraction(O, K, S)
        rows.append((f"wallclock.tconv.ecoflow.{name}", round(t_eco, 1),
                     f"speedup={t_nai/t_eco:.2f}x;zero_frac={zf:.2f}"))
        rows.append((f"wallclock.tconv.naive.{name}", round(t_nai, 1), ""))

        g_eco = jax.jit(lambda x, dy:
                        ecoflow.dilated_conv_filter_grad_zero_free(
                            x, dy, stride=(S, S), padding=(0, 0), k=(K, K)))
        g_nai = jax.jit(lambda x, dy: naive.dilated_conv_filter_grad_naive(
            x, dy, stride=(S, S), padding=(0, 0), k=(K, K)))
        np.testing.assert_allclose(np.asarray(g_eco(x, dy)),
                                   np.asarray(g_nai(x, dy)),
                                   rtol=1e-2, atol=1e-2)
        t_eco = _time(g_eco, x, dy)
        t_nai = _time(g_nai, x, dy)
        rows.append((f"wallclock.filtergrad.ecoflow.{name}",
                     round(t_eco, 1), f"speedup={t_nai/t_eco:.2f}x"))
        rows.append((f"wallclock.filtergrad.naive.{name}",
                     round(t_nai, 1), ""))
    return rows


# ---------------------------------------------------------------------------
# Conv backend comparison: multi-launch xla_zero_free vs fused pallas
# ---------------------------------------------------------------------------

# Table 5/7 layer geometries (name, O, K, S, Ci, Co): filter/stride are the
# paper's; error-map spatial size and channels are capped so the
# interpret-mode Pallas path (CPU CI) finishes in seconds -- the phase
# structure (the thing the fused kernel changes) depends only on (K, S).
# On a real TPU the same code paths compile and the caps can be lifted.
CONV_BACKEND_CASES = [
    ("alexnet-CONV1",    14, 11, 4, 3, 16),
    ("resnet50-CONV3",   14, 3, 2, 32, 32),
    ("shufflenet-CONV2", 14, 3, 2, 29, 29),
    ("inception-CONV3",   8, 3, 2, 32, 32),
    ("alexnet-o-CONV1",   7, 11, 8, 3, 16),
    ("cyclegan-gen-TCONV1", 14, 3, 2, 32, 32),
    ("pix2pix-gen-TCONV4",  16, 4, 2, 32, 32),
]

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_conv.json"

# Dilated-forward (atrous) geometries: DeepLab-ASPP-style 3x3 branches at
# rates d in {2, 4}, stride 1, same-padding (P = d) -- the dilated-forward
# workload class wired through the backends.  Spatial size / channels are
# capped for interpret-mode CI, like CONV_BACKEND_CASES above.
DILATED_FORWARD_CASES = [
    # (name, N, K, S, P, D, Ci, Co)
    ("deeplab-ASPP-d2", 17, 3, 1, 2, 2, 16, 16),
    ("deeplab-ASPP-d4", 17, 3, 1, 4, 4, 16, 16),
]

# General strided+dilated (S > 1 AND D > 1) input-gradient geometries --
# the conv family the unified (phase, tap) kernel runs in one launch
# (previously the multi-launch XLA scatter fallback on the `pallas`
# backend).  Sized for interpret-mode CI like the tables above.
STRIDED_DILATED_CASES = [
    # (name, O, K, S, P, D, Ci, Co)
    ("strided-atrous-s2d2", 10, 3, 2, 1, 2, 16, 16),
    ("strided-atrous-s3d2", 7, 3, 3, 1, 2, 16, 16),
]


def conv_backend_bench(iters=5, warmup=1, write_json=True, cases=None,
                       dilated_cases=None, strided_dilated_cases=None,
                       json_path=None):
    """Time tconv + filter-grad through the xla_zero_free and pallas
    backends for each geometry -- plus the dilated-forward conv (d in
    {2, 4}) and the general strided+dilated input gradient through the
    same two zero-free backends (and, for the dilated forward, the
    materialized-filter naive baseline); write BENCH_conv.json and return
    CSV rows.  `cases`/`dilated_cases`/`strided_dilated_cases`/`json_path`
    exist for the CI smoke run (one tiny geometry per family).
    """
    rows, records = [], []
    rng = np.random.default_rng(0)
    backends = ("xla_zero_free", "pallas")
    for name, O, K, S, Ci, Co in (CONV_BACKEND_CASES if cases is None
                                  else cases):
        B, P = 1, 0
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K)
        N = spec.input_size((O, O))[0]
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
        rec = {"layer": name, "error_map": O, "k": K, "stride": S,
               "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "tconv_us": {}, "filter_grad_us": {}}
        for bname in backends:
            be = resolve_backend(bname)
            f_t = jax.jit(lambda dy_, w_, be=be: be.input_grad(
                dy_, w_, spec, (N, N)))
            f_g = jax.jit(lambda x_, dy_, be=be: be.filter_grad(
                x_, dy_, spec))
            t_t = _time(f_t, dy, w, iters=iters, warmup=warmup)
            t_g = _time(f_g, x, dy, iters=iters, warmup=warmup)
            rec["tconv_us"][bname] = round(t_t, 1)
            rec["filter_grad_us"][bname] = round(t_g, 1)
            rows.append((f"wallclock.tconv.{bname}.{name}", round(t_t, 1),
                         ""))
            rows.append((f"wallclock.filtergrad.{bname}.{name}",
                         round(t_g, 1), ""))
        records.append(rec)
    for name, N, K, S, P, D, Ci, Co in (DILATED_FORWARD_CASES
                                        if dilated_cases is None
                                        else dilated_cases):
        B = 1
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K,
                             dilation=D)
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        zf = naive.dilated_forward_zero_mac_fraction(K, D)
        rec = {"layer": name, "n_in": N, "k": K, "stride": S,
               "dilation": D, "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "zero_mac_fraction_naive": round(zf, 4),
               "dilated_forward_us": {}}
        f_nai = jax.jit(lambda x_, w_: naive.dilated_forward_naive(
            x_, w_, stride=S, padding=P, dilation=D))
        t_nai = _time(f_nai, x, w, iters=iters, warmup=warmup)
        rec["dilated_forward_us"]["naive_materialized"] = round(t_nai, 1)
        rows.append((f"wallclock.dilated_forward.naive.{name}",
                     round(t_nai, 1), f"zero_frac={zf:.2f}"))
        for bname in backends:
            be = resolve_backend(bname)
            f_d = jax.jit(lambda x_, w_, be=be: be.forward(x_, w_, spec))
            np.testing.assert_allclose(np.asarray(f_d(x, w)),
                                       np.asarray(f_nai(x, w)),
                                       rtol=1e-3, atol=1e-3)
            t_d = _time(f_d, x, w, iters=iters, warmup=warmup)
            rec["dilated_forward_us"][bname] = round(t_d, 1)
            rows.append((f"wallclock.dilated_forward.{bname}.{name}",
                         round(t_d, 1),
                         f"speedup_vs_naive={t_nai/t_d:.2f}x"))
        records.append(rec)
    for name, O, K, S, P, D, Ci, Co in (STRIDED_DILATED_CASES
                                        if strided_dilated_cases is None
                                        else strided_dilated_cases):
        B = 2
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K,
                             dilation=D)
        n_out = spec.input_size((O, O))
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        rec = {"layer": name, "error_map": O, "k": K, "stride": S,
               "dilation": D, "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "input_grad_us": {}}
        outs = {}
        for bname in backends:
            be = resolve_backend(bname)
            f_i = jax.jit(lambda dy_, w_, be=be: be.input_grad(
                dy_, w_, spec, n_out))
            outs[bname] = np.asarray(f_i(dy, w))
            t_i = _time(f_i, dy, w, iters=iters, warmup=warmup)
            rec["input_grad_us"][bname] = round(t_i, 1)
            rows.append((f"wallclock.input_grad.{bname}.{name}",
                         round(t_i, 1), ""))
        np.testing.assert_allclose(outs["pallas"], outs["xla_zero_free"],
                                   rtol=1e-3, atol=1e-3)
        records.append(rec)
    if write_json:
        path = BENCH_JSON if json_path is None else pathlib.Path(json_path)
        path.write_text(json.dumps(
            {"note": "conv backend wall-clock (us/call); pallas runs in "
                     "interpret mode off-TPU, so absolute numbers are only "
                     "comparable within a backend+host class",
             "cases": records}, indent=2) + "\n")
        rows.append(("wallclock.conv_backend.json", str(path), ""))
    return rows


# ---------------------------------------------------------------------------
# CI smoke: one tiny geometry per op family + BENCH_conv.json schema guard
# ---------------------------------------------------------------------------

# Smoke geometries: minimal sizes that still exercise every op family
# (tconv, filter-grad, dilated forward, strided+dilated input grad)
# through both zero-free backends in seconds on an interpret-mode host.
SMOKE_CASES = [("smoke-tconv", 5, 3, 2, 4, 4)]
SMOKE_DILATED_CASES = [("smoke-d2", 9, 3, 1, 2, 2, 4, 4)]
SMOKE_STRIDED_DILATED_CASES = [("smoke-s2d2", 4, 3, 2, 1, 2, 4, 4)]


def _record_schema(doc) -> set[frozenset]:
    """The set of per-record key signatures -- one frozenset per op
    family (tconv/filter-grad, dilated-forward, strided+dilated)."""
    return {frozenset(rec) for rec in doc["cases"]}


def smoke():
    """Run one tiny geometry per op family end to end and fail on
    BENCH_conv.json schema drift.

    The timed paths are the real backend entry points, so a wiring break
    in any op family fails here in CI instead of at the next perf
    comparison; the generated record schema is diffed against the
    committed BENCH_conv.json so a field rename/removal (or a new op
    family whose rows were never regenerated) is caught the same way.
    The smoke JSON is written next to BENCH_conv.json and removed after
    the check -- the committed trajectory file is never clobbered.
    """
    smoke_json = BENCH_JSON.with_name(BENCH_JSON.stem + ".smoke.json")
    try:
        rows = conv_backend_bench(
            iters=1, warmup=1, cases=SMOKE_CASES,
            dilated_cases=SMOKE_DILATED_CASES,
            strided_dilated_cases=SMOKE_STRIDED_DILATED_CASES,
            json_path=smoke_json)
        got = _record_schema(json.loads(smoke_json.read_text()))
        committed_doc = json.loads(BENCH_JSON.read_text())
        want = _record_schema(committed_doc)
        if got != want:
            only_new = [sorted(s) for s in got - want]
            only_old = [sorted(s) for s in want - got]
            raise RuntimeError(
                "BENCH_conv.json schema drift: regenerate it with "
                "`python -m benchmarks.run` (record signatures only in "
                f"smoke run: {only_new}; only in committed file: "
                f"{only_old})")
        if set(committed_doc) != {"note", "cases"}:
            raise RuntimeError(
                f"BENCH_conv.json top-level drift: {sorted(committed_doc)}")
    finally:
        smoke_json.unlink(missing_ok=True)
    rows.append(("wallclock.smoke.schema", "ok",
                 f"{len(SMOKE_CASES + SMOKE_DILATED_CASES + SMOKE_STRIDED_DILATED_CASES)}"
                 " families"))
    return rows


if __name__ == "__main__":
    for r in run() + conv_backend_bench():
        print(",".join(str(c) for c in r))
