"""Wall-clock microbenchmarks: zero-free EcoFlow vs materialized-zero
naive dataflows, executed for real in JAX on this host (CPU here; the same
code paths compile for TPU) -- plus the conv *backend* comparison
(multi-launch `xla_zero_free` vs fused single-launch `pallas`) across the
paper's Table 5/7 layer geometries, the dilated-forward (atrous)
geometries at rates d in {2, 4}, the general strided+dilated
input-gradient geometries (S > 1 AND D > 1, the unified (phase, tap)
kernel's family), the FUSED dual-gradient backward (dx + dW from one
launch vs the two-launch pair it replaced), the EPILOGUE-fused families
(layer tails -- bias/activation forward, cotangent mask + db backward --
folded into the same launches vs the identical kernels with the tail as
separate XLA ops), and end-to-end TRAINING-STEP rows (a CNN SGD step and
a GAN generator step per backend, with and without fused epilogues --
the paper's headline numbers are training-step speedups, so the
trajectory file tracks the same quantity), emitted to BENCH_conv.json so
future PRs have a perf trajectory.

Reported as name,us_per_call,derived -- `derived` carries the speedup and
the useful-MAC fraction from the analytical model for cross-checking.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecoflow, naive
from repro.core.spec import ConvSpec, Epilogue, resolve_backend


def _time(fn, *args, iters=5, warmup=2):
    """MEDIAN per-call latency (us) over `iters` timed calls.  The
    median discards warm-outlier iterations (GC pauses, scheduler
    preemption, allocator warm-up that survives the warmup calls) that
    drag a mean upward, without under-reporting steady-state cost the
    way a min does on a frequency-drifting host -- keeping
    BENCH_conv.json rows comparable across PRs and autotune sweeps
    (`kernels/tiling.py` times candidates through this same helper)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2] * 1e6  # us


def _time_interleaved(fns, iters=5, warmup=1):
    """Median per-call latency (us) for several zero-arg callables,
    measured INTERLEAVED: each sweep times one call of every callable
    before the next sweep starts.  Sequential per-backend timing folds
    slow host drift (frequency scaling, co-tenant load) straight into
    the backend *comparison* -- interleaving gives every callable the
    same drift exposure, so the ratios BENCH_conv.json exists to track
    are stable even when absolute numbers wander."""
    for f in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(f())
    samples = {k: [] for k in fns}
    for _ in range(iters):
        for k, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            samples[k].append(time.perf_counter() - t0)
    return {k: sorted(v)[len(v) // 2] * 1e6 for k, v in samples.items()}


# (name, N_err, K, S, Cin, Cout): error-map size, filter, stride, channels.
CASES = [
    ("resnet50-CONV3-like", 28, 3, 2, 32, 32),
    ("alexnet-CONV1-like", 28, 11, 4, 3, 16),
    ("gan-gen-like", 32, 4, 2, 32, 16),
    ("stride8-like", 16, 11, 8, 8, 8),
]


def run():
    rows = []
    rng = np.random.default_rng(0)
    for name, O, K, S, Ci, Co in CASES:
        B = 2
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        N = S * (O - 1) + K
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)

        f_eco = jax.jit(lambda dy, w: ecoflow.transposed_conv_zero_free(
            dy, w, stride=(S, S), padding=(0, 0), n_out=(N, N)))
        f_nai = jax.jit(lambda dy, w: naive.transposed_conv_naive(
            dy, w, stride=(S, S), padding=(0, 0), n_out=(N, N)))
        np.testing.assert_allclose(np.asarray(f_eco(dy, w)),
                                   np.asarray(f_nai(dy, w)),
                                   rtol=1e-3, atol=1e-3)
        t_eco = _time(f_eco, dy, w)
        t_nai = _time(f_nai, dy, w)
        zf = ecoflow.tconv_zero_mac_fraction(O, K, S)
        rows.append((f"wallclock.tconv.ecoflow.{name}", round(t_eco, 1),
                     f"speedup={t_nai/t_eco:.2f}x;zero_frac={zf:.2f}"))
        rows.append((f"wallclock.tconv.naive.{name}", round(t_nai, 1), ""))

        g_eco = jax.jit(lambda x, dy:
                        ecoflow.dilated_conv_filter_grad_zero_free(
                            x, dy, stride=(S, S), padding=(0, 0), k=(K, K)))
        g_nai = jax.jit(lambda x, dy: naive.dilated_conv_filter_grad_naive(
            x, dy, stride=(S, S), padding=(0, 0), k=(K, K)))
        np.testing.assert_allclose(np.asarray(g_eco(x, dy)),
                                   np.asarray(g_nai(x, dy)),
                                   rtol=1e-2, atol=1e-2)
        t_eco = _time(g_eco, x, dy)
        t_nai = _time(g_nai, x, dy)
        rows.append((f"wallclock.filtergrad.ecoflow.{name}",
                     round(t_eco, 1), f"speedup={t_nai/t_eco:.2f}x"))
        rows.append((f"wallclock.filtergrad.naive.{name}",
                     round(t_nai, 1), ""))
    return rows


# ---------------------------------------------------------------------------
# Conv backend comparison: multi-launch xla_zero_free vs fused pallas
# ---------------------------------------------------------------------------

# Table 5/7 layer geometries (name, O, K, S, Ci, Co): filter/stride are the
# paper's; error-map spatial size and channels are capped so the
# interpret-mode Pallas path (CPU CI) finishes in seconds -- the phase
# structure (the thing the fused kernel changes) depends only on (K, S).
# On a real TPU the same code paths compile and the caps can be lifted.
CONV_BACKEND_CASES = [
    ("alexnet-CONV1",    14, 11, 4, 3, 16),
    ("resnet50-CONV3",   14, 3, 2, 32, 32),
    ("shufflenet-CONV2", 14, 3, 2, 29, 29),
    ("inception-CONV3",   8, 3, 2, 32, 32),
    ("alexnet-o-CONV1",   7, 11, 8, 3, 16),
    ("cyclegan-gen-TCONV1", 14, 3, 2, 32, 32),
    ("pix2pix-gen-TCONV4",  16, 4, 2, 32, 32),
]

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_conv.json"

# Dilated-forward (atrous) geometries: DeepLab-ASPP-style 3x3 branches at
# rates d in {2, 4}, stride 1, same-padding (P = d) -- the dilated-forward
# workload class wired through the backends.  Spatial size / channels are
# capped for interpret-mode CI, like CONV_BACKEND_CASES above.
DILATED_FORWARD_CASES = [
    # (name, N, K, S, P, D, Ci, Co)
    ("deeplab-ASPP-d2", 17, 3, 1, 2, 2, 16, 16),
    ("deeplab-ASPP-d4", 17, 3, 1, 4, 4, 16, 16),
]

# General strided+dilated (S > 1 AND D > 1) input-gradient geometries --
# the conv family the unified (phase, tap) kernel runs in one launch
# (previously the multi-launch XLA scatter fallback on the `pallas`
# backend).  Sized for interpret-mode CI like the tables above.
STRIDED_DILATED_CASES = [
    # (name, O, K, S, P, D, Ci, Co)
    ("strided-atrous-s2d2", 10, 3, 2, 1, 2, 16, 16),
    ("strided-atrous-s3d2", 7, 3, 3, 1, 2, 16, 16),
]

# Epilogue-fusion families (DESIGN.md Sec. 2.8): the layer tail
# act(scale * conv + bias) folded into the fused launches.  Each direct
# case times the fused forward-with-epilogue and the fused
# backward-with-epilogue (mask + dx + dW + db from ONE launch) per
# backend, plus a `pallas_unfused` arm -- the same pallas kernels with
# the tail/mask/reduce as separate XLA ops -- so the fusion itself (not
# the kernel) is the measured quantity.  (name, O, K, S, Ci, Co, Epilogue).
EPILOGUE_CASES = [
    ("resnet50-CONV3-brelu", 14, 3, 2, 32, 32,
     Epilogue(activation="relu", bias=True)),
    ("dcgan-disc-leaky02", 14, 4, 2, 16, 32,
     Epilogue(activation="leaky_relu", slope=0.2)),
]

# Transposed-conv epilogue cases (GAN generator layer tails): fused
# tconv-with-epilogue forward and fused ct-backward (mask + ddy + dW +
# db from one launch).  (name, O, K, S, Ci, Co, Epilogue) -- Ci is the
# tconv OUTPUT side, where the bias rides.
TCONV_EPILOGUE_CASES = [
    ("dcgan-gen-TCONV2-brelu", 8, 4, 2, 16, 32,
     Epilogue(activation="relu", bias=True)),
    ("dcgan-gen-TCONV4-tanh", 16, 4, 2, 3, 16,
     Epilogue(activation="tanh")),
]

# End-to-end training-step cases: one full jit'd SGD step (forward +
# backward + update) through the real models, per backend -- the paper's
# headline metric.  `config` values stay JSON-round-trip stable (lists,
# ints) because the delta gate diffs them against the committed rows.
# The trailing flag is `fuse_epilogue`: the `-ep` variants request every
# layer tail (relu / leaky_relu / tanh) declaratively through the conv
# epilogue slot, so each layer's forward AND backward stay at one launch
# on the pallas backend (DESIGN.md Sec. 2.8).
TRAIN_STEP_CASES = [
    ("train-step-cnn", "cnn",
     {"widths": [8, 16], "batch": 2, "image": 12, "n_classes": 10}, False),
    ("train-step-cnn-ep", "cnn",
     {"widths": [8, 16], "batch": 2, "image": 12, "n_classes": 10}, True),
    ("train-step-gan-gen", "gan_gen",
     {"base": 8, "z_dim": 16, "batch": 2}, False),
    ("train-step-gan-gen-ep", "gan_gen",
     {"base": 8, "z_dim": 16, "batch": 2}, True),
]

# Multi-device training-step rows: the SAME interleaved-median train-step
# methodology executed on a ("data", "model") mesh of 1/2/4/8 forced
# host-platform devices.  Each device count runs in a SUBPROCESS (the XLA
# host device count is fixed when the backend initializes, so the parent
# cannot re-configure it per row); inside, `_train_step_fns(mesh=...)`
# shards params via the structural conv-filter rule and the batch via
# `batch_pspec`, and every conv launches through the shard_map dispatch
# layer (DESIGN.md Sec. 2.9).  Trailing list = device counts; batch 8 so
# the largest mesh still divides.  (name, kind, config, fuse, devices).
MULTIDEV_MESHES = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2)}
MULTIDEV_TRAIN_CASES = [
    ("mdev-train-cnn-ep", "cnn",
     {"widths": [8, 16], "batch": 8, "image": 12, "n_classes": 10}, True,
     [1, 2, 4, 8]),
    ("mdev-train-gan-gen-ep", "gan_gen",
     {"base": 8, "z_dim": 16, "batch": 8}, True, [1, 8]),
]

# On interpret-mode hosts each fake device re-interprets its kernels, so
# the multidev rows cap their sweep count: the per-row median stabilizes
# well below this and the delta gate still compares like against like
# (the committed rows ran under the same cap).
_MULTIDEV_MAX_ITERS = 7

# Serving rows (DESIGN.md Sec. 2.11): the geometry-bucketed
# `ConvServeEngine` end to end -- admission queue, slot-batch assembly,
# jitted launch, host materialization -- per backend arm (each arm runs a
# single-rung ladder so the timing isolates the backend), reporting
# request p50/p99 latency and sustained requests/s.  Each case also runs
# a FAULT-MODE arm: the full degradation ladder under a seeded 5%
# kernel-fault schedule on the fast rungs, gated on bounded degradation
# (every admitted request completes; every fallback is accounted to an
# injected fault).  (name, kind, config).
SERVE_CASES = [
    ("serve-gan-gen", "gan_gen",
     {"z_dim": 16, "base": 8, "out_ch": 3, "slot_batch": 2,
      "requests": 8}),
    ("serve-aspp", "aspp",
     {"in_ch": 3, "width": 8, "n_classes": 4, "image": 8,
      "slot_batch": 2, "requests": 8}),
]
_SERVE_FAULT_RATE = 0.05
_SERVE_MAX_ITERS = 7    # interpret-mode cap, same rationale as multidev

# Elastic-training rows (DESIGN.md Sec. 2.12): two arms per case.
#   * `train_step_guard_us` -- the ConvTrainer's GUARDED jitted step
#     (in-graph all-finite flag over updated params + loss) vs the same
#     step unguarded, interleaved on the pallas backend; the
#     guarded/unguarded ratio is what the delta gate pins (the guard is
#     contractually cheap -- same launch count, a few XLA reductions).
#   * `recovery` -- a seeded supervisor drill in a SUBPROCESS with
#     `n_devices` forced host devices split over `hosts` hosts: the run
#     loses a host and hits injected NaN steps per the fixed
#     `fault_seed` (host losses from `host_failure_schedule`, NaN steps
#     from `faults.training_schedule` -- the same registry), and the
#     row records steps lost, recompiles, and recovery wallclock.  Run
#     once per bench (it is an accounting row, not a timing sweep); the
#     drill uses the xla_zero_free backend so the row measures the
#     recovery machinery, not interpret-mode kernel time.
ELASTIC_TRAIN_CASES = [
    ("elastic-train-cnn", "cnn",
     {"widths": [4], "batch": 8, "image": 8, "n_classes": 4,
      "total_steps": 8, "ckpt_every": 2, "backend": "xla_zero_free",
      "n_devices": 8, "hosts": 2, "fault_seed": 4,
      "host_rate": 0.12, "nan_rate": 0.2}),
    ("elastic-train-gan-gen", "gan_gen",
     {"base": 4, "z_dim": 8, "batch": 8,
      "total_steps": 8, "ckpt_every": 2, "backend": "xla_zero_free",
      "n_devices": 8, "hosts": 2, "fault_seed": 4,
      "host_rate": 0.12, "nan_rate": 0.2}),
]
_ELASTIC_MAX_ITERS = 7   # guard-arm cap, same rationale as multidev


def _serve_engine(kind, cfg, ladder, injector=None):
    """One `ConvServeEngine` for a serve bench arm, warmed up (tile
    plans + every ladder rung pre-compiled so the timed sweeps measure
    serving, not compilation).  Returns (engine, payload_shape)."""
    from repro.serve.conv_engine import ConvServeEngine
    if kind == "gan_gen":
        from repro.models import gan
        params = gan.generator_init(jax.random.PRNGKey(0),
                                    z_dim=cfg["z_dim"], base=cfg["base"],
                                    out_ch=cfg["out_ch"])
        eng = ConvServeEngine(gan_params=params,
                              slot_batch=cfg["slot_batch"],
                              queue_limit=max(64, cfg["requests"]),
                              ladder=ladder, injector=injector)
        payload_shape = (cfg["z_dim"],)
    elif kind == "aspp":
        from repro.models import vision
        params = vision.atrous_head_init(
            jax.random.PRNGKey(0), in_ch=cfg["in_ch"], width=cfg["width"],
            n_classes=cfg["n_classes"])
        eng = ConvServeEngine(aspp_params=params,
                              slot_batch=cfg["slot_batch"],
                              queue_limit=max(64, cfg["requests"]),
                              ladder=ladder, injector=injector)
        payload_shape = (cfg["image"], cfg["image"], cfg["in_ch"])
    else:
        raise ValueError(f"unknown serve kind {kind!r}")
    eng.warmup([(kind, payload_shape)])
    bucket = eng._bucket(kind, payload_shape)
    dummy = np.zeros((eng.slot_batch,) + payload_shape, np.float32)
    for rung in ladder:
        np.asarray(eng._jitted(bucket, rung)(dummy))
    return eng, payload_shape


def _multidev_measure(payload: dict) -> dict:
    """Subprocess body for one (case, device-count) multidev row: build
    the mesh from the forced host devices and time the interleaved
    backends.  Runs in a child with XLA_FLAGS set before jax init."""
    shape = tuple(payload["mesh_shape"])
    devs = np.asarray(jax.devices()[:shape[0] * shape[1]]).reshape(shape)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    fns = _train_step_fns(payload["kind"], payload["config"],
                          tuple(payload["backends"]),
                          np.random.default_rng(0),
                          fuse_epilogue=payload["fuse"], mesh=mesh)
    return _time_interleaved(fns, iters=payload["iters"],
                             warmup=payload["warmup"])


def _multidev_time(kind, cfg, fuse, n_devices, iters, warmup,
                   backends=("xla_zero_free", "pallas")) -> dict:
    """Run `_multidev_measure` in a subprocess with the host device count
    forced to `n_devices`; returns {backend: us}."""
    payload = json.dumps({
        "kind": kind, "config": cfg, "fuse": fuse,
        "mesh_shape": list(MULTIDEV_MESHES[n_devices]),
        "backends": list(backends),
        "iters": min(iters, _MULTIDEV_MAX_ITERS), "warmup": warmup})
    root = BENCH_JSON.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count"
                          f"={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [str(root / "src"), str(root),
                    env.get("PYTHONPATH", "")] if p)
    code = ("import sys, json\n"
            "from benchmarks.wallclock import _multidev_measure\n"
            "print(json.dumps(_multidev_measure("
            "json.loads(sys.stdin.read()))))\n")
    proc = subprocess.run([sys.executable, "-c", code], input=payload,
                          capture_output=True, text=True, cwd=str(root),
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"multidev bench child (devices={n_devices}, kind={kind}) "
            f"failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _train_step_fns(kind, cfg, backends, rng, fuse_epilogue=False,
                    mesh=None):
    """Zero-arg jit'd SGD-step callables per backend for one train-step
    case: forward + backward (the FUSED dual-gradient launch on the
    pallas backend) + parameter update through the models' own step
    helpers (`cnn.sgd_step` / `gan.gen_sgd_step`), on shared params/data
    so the interleaved timing compares backends on identical work.

    `mesh` (a jax Mesh) runs the step multi-device: params are
    device_put against `sharding.tree_shardings` (conv filters carry the
    structural 4-D (.., Cin@fsdp, Cout@tp) rule), the batch against
    `sharding.batch_pspec`, and both tracing and execution happen under
    `sharding.use_mesh` so every conv dispatches to a shard_map'd launch
    (DESIGN.md Sec. 2.9)."""
    lr = 0.05
    if kind == "cnn":
        from repro.models import cnn
        params = cnn.simple_cnn_init(jax.random.PRNGKey(0), in_ch=3,
                                     widths=tuple(cfg["widths"]),
                                     n_classes=cfg["n_classes"])
        x = jnp.asarray(rng.normal(size=(cfg["batch"], cfg["image"],
                                         cfg["image"], 3)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, cfg["n_classes"],
                                          size=cfg["batch"]))
        data = (x, labels)

        def step_of(be):
            def step(p, d):
                return cnn.sgd_step(p, d[0], d[1], lr=lr, stride=2,
                                    backend=be,
                                    fuse_epilogue=fuse_epilogue)[0]
            return step
    elif kind == "gan_gen":
        from repro.models import gan
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        params = gan.generator_init(k1, z_dim=cfg["z_dim"],
                                    base=cfg["base"], out_ch=3)
        d_params = gan.discriminator_init(k2, in_ch=3, base=cfg["base"])
        z = jnp.asarray(rng.normal(size=(cfg["batch"], cfg["z_dim"])),
                        jnp.float32)
        data = (z,)

        def step_of(be):
            def step(p, d):
                return gan.gen_sgd_step(p, d_params, d[0], lr=lr,
                                        backend=be,
                                        fuse_epilogue=fuse_epilogue)[0]
            return step
    else:
        raise ValueError(f"unknown train-step kind {kind!r}")

    if mesh is not None:
        from jax.sharding import NamedSharding
        from repro.parallel import sharding as sh
        with mesh, sh.use_mesh(mesh):
            params = jax.device_put(params, sh.tree_shardings(params, mesh))
            data = tuple(jax.device_put(d, NamedSharding(
                mesh, sh.batch_pspec(mesh, d.ndim, 0, d.shape[0])))
                for d in data)
    fns = {}
    for bname in backends:
        f = jax.jit(step_of(bname))
        if mesh is None:
            fns[bname] = lambda f=f, p=params, d=data: f(p, d)
        else:
            def call(f=f, p=params, d=data, m=mesh):
                from repro.parallel import sharding as sh
                with m, sh.use_mesh(m):
                    return f(p, d)
            fns[bname] = call
    return fns


# ConvTrainerConfig fields an elastic bench config may carry; the rest
# of the config dict (n_devices, hosts, fault_seed, ...) is drill-level.
_ELASTIC_TRAINER_KEYS = ("widths", "image", "channels", "n_classes",
                         "z_dim", "base", "batch", "total_steps", "lr",
                         "stride", "ckpt_every", "backend")


def _elastic_trainer_cfg(kind, cfg, **overrides):
    from repro.train.conv_trainer import ConvTrainerConfig
    kw = {k: cfg[k] for k in _ELASTIC_TRAINER_KEYS if k in cfg}
    if "widths" in kw:
        kw["widths"] = tuple(kw["widths"])
    kw.update(overrides)
    return ConvTrainerConfig(workload=kind, fuse_epilogue=True, **kw)


def _guard_step_fns(kind, cfg):
    """Zero-arg jitted callables for the guarded vs unguarded
    ConvTrainer step on the pallas backend, shared state/batch --
    the interleaved pair behind `train_step_guard_us`."""
    from repro.train.conv_trainer import ConvTrainer
    tcfg = _elastic_trainer_cfg(kind, cfg, backend="pallas",
                                ckpt_dir=None)
    trainer = ConvTrainer(tcfg)
    state = trainer.init_state()
    data = trainer._put_batch(trainer.data.batch_at(0))
    lr = np.float32(tcfg.lr)
    fns = {}
    for label, guarded in (("pallas", True), ("pallas_unguarded", False)):
        f = jax.jit(trainer.build_step(guarded=guarded))
        fns[label] = lambda f=f: f(state, data, lr)
    return fns


def _elastic_recovery_measure(payload: dict) -> dict:
    """Subprocess body for one elastic-recovery drill: run the
    RunSupervisor storm (seeded host loss + seeded NaN steps) on the
    forced host devices and report the recovery accounting."""
    import tempfile
    from repro.serve.faults import FaultInjector, training_schedule
    from repro.train.fault_tolerance import host_failure_schedule
    from repro.train.supervisor import RunSupervisor
    kind, cfg = payload["kind"], payload["config"]
    n_dev, hosts = cfg["n_devices"], cfg["hosts"]
    host_sched = host_failure_schedule(
        cfg["fault_seed"], n_hosts=hosts, n_steps=cfg["total_steps"],
        rate=cfg["host_rate"])
    inj = FaultInjector(training_schedule(
        cfg["fault_seed"], workload=kind, n_steps=4 * cfg["total_steps"],
        rate=cfg["nan_rate"], kinds=("nan_output",)))
    with tempfile.TemporaryDirectory() as d:
        tcfg = _elastic_trainer_cfg(kind, cfg, ckpt_dir=d)
        sup = RunSupervisor(tcfg, devices_per_host=n_dev // hosts,
                            model_parallel=2, host_schedule=host_sched,
                            injector=inj)
        t0 = time.perf_counter()
        out = sup.run()
        wall = time.perf_counter() - t0
    rep = out["report"]
    return {"steps_lost": rep["steps_lost"],
            "recompiles": rep["recompiles"],
            "recovery_wallclock_s": round(rep["recovery_wallclock_s"], 3),
            "host_losses": rep["host_losses"],
            "nonfinite_steps": rep["guard"]["nonfinite_steps"],
            "meshes": rep["meshes"],
            "completed_steps": (out["history"][-1]["step"]
                                if out["history"] else 0),
            "drill_wall_s": round(wall, 3)}


def _elastic_recovery(kind, cfg) -> dict:
    """Run `_elastic_recovery_measure` in a subprocess with the host
    device count forced to the case's `n_devices` (same launcher
    pattern as `_multidev_time`)."""
    payload = json.dumps({"kind": kind, "config": cfg})
    root = BENCH_JSON.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count"
                          f"={cfg['n_devices']}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [str(root / "src"), str(root),
                    env.get("PYTHONPATH", "")] if p)
    code = ("import sys, json\n"
            "from benchmarks.wallclock import _elastic_recovery_measure\n"
            "print(json.dumps(_elastic_recovery_measure("
            "json.loads(sys.stdin.read()))))\n")
    proc = subprocess.run([sys.executable, "-c", code], input=payload,
                          capture_output=True, text=True, cwd=str(root),
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"elastic recovery drill child (kind={kind}) failed:\n"
            f"{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _plan_dict(op, spec, x_shape, dy_shape, epilogue=None):
    """The planner's decision for one (op, geometry) -- recorded per
    BENCH_conv.json row so the perf trajectory is attributable to the
    tiling AND the kernel strategy that produced it (`strategy` is the
    `plan_strategy` pick; "phase" for every op implicit-GEMM does not
    cover)."""
    from repro.kernels import tiling
    strategy, plan = tiling.plan_strategy(
        op, spec, x_shape=x_shape, dy_shape=dy_shape,
        interpret=jax.default_backend() != "tpu", epilogue=epilogue)
    return {"cin_tile": plan.cin_tile, "cout_tile": plan.cout_tile,
            "spatial_tile": plan.spatial_tile,
            "tap_unroll": plan.tap_unroll,
            "phase_unroll": plan.phase_unroll, "source": plan.source,
            "strategy": strategy}


def _race_input_grad(dy, w, spec, n_out, bias=None, epilogue=None,
                     iters=5, warmup=1):
    """Time the input gradient under BOTH forced kernel strategies
    (interleaved, same methodology as the backend arms) and name the
    measured winner -- the per-geometry ground truth the planner's
    `strategy` pick is judged against in BENCH_conv.json."""
    from repro.kernels import ops as kops
    fns = {}
    for strategy in ("phase", "implicit_gemm"):
        f = jax.jit(functools.partial(
            kops.tconv_phase, stride=spec.stride, padding=spec.padding,
            n_out=n_out, dilation=spec.dilation, epilogue=epilogue,
            strategy=strategy))
        if bias is None:
            fns[strategy] = lambda f=f: f(dy, w)
        else:
            fns[strategy] = lambda f=f: f(dy, w, bias=bias)
    t = _time_interleaved(fns, iters=iters, warmup=warmup)
    return ({k: round(v, 1) for k, v in t.items()},
            min(t, key=t.get))


def conv_backend_bench(iters=5, warmup=1, write_json=True, cases=None,
                       dilated_cases=None, strided_dilated_cases=None,
                       train_cases=None, epilogue_cases=None,
                       tconv_epilogue_cases=None, multidev_cases=None,
                       serve_cases=None, elastic_cases=None,
                       json_path=None, name_filter=None,
                       records_out=None):
    """Time tconv + filter-grad + the FUSED dual-gradient backward
    through the xla_zero_free and pallas backends for each geometry --
    plus the dilated-forward conv (d in {2, 4}), the general
    strided+dilated input gradient, and end-to-end TRAINING-STEP rows
    (CNN SGD step, GAN generator step) through the same backends (and,
    for the dilated forward, the materialized-filter naive baseline);
    write BENCH_conv.json and return CSV rows.  The backward rows carry a
    third timing, `two_launch`: the pallas input_grad + filter_grad pair
    the fused kernel replaced, timed in the same interleaved sweep -- the
    fused/two-launch ratio is the quantity the delta gate pins.  The
    EPILOGUE families time the same workloads with the layer tail (bias
    / activation / cotangent mask / db reduce) fused into the launches,
    against a `pallas_unfused` arm that runs the identical pallas
    kernels with the tail as separate XLA ops -- isolating the fusion
    itself.  The MULTIDEV family re-times the train-step rows on meshes
    of 1/2/4/8 forced host-platform devices through the shard_map conv
    dispatch layer (DESIGN.md Sec. 2.9), one subprocess per device count
    (`_multidev_time`).  `cases`/`dilated_cases`/
    `strided_dilated_cases`/`train_cases`/`epilogue_cases`/
    `tconv_epilogue_cases`/`multidev_cases`/`json_path`
    exist for the CI smoke run (one tiny geometry per family).  `name_filter` (case-name substring) reruns single rows
    cheaply during autotuning -- a filtered run never writes
    BENCH_conv.json (it would drop the unselected rows).  `records_out`,
    if a list, receives the per-case record dicts (the delta gate
    consumes them).
    """
    rows, records = [], []
    if name_filter is not None:
        write_json = False
        flt = lambda cs: [c for c in cs if name_filter in c[0]]
    else:
        flt = lambda cs: cs
    rng = np.random.default_rng(0)
    backends = ("xla_zero_free", "pallas")
    for name, O, K, S, Ci, Co in flt(CONV_BACKEND_CASES if cases is None
                                     else cases):
        B, P = 1, 0
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K)
        N = spec.input_size((O, O))[0]
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
        rec = {"layer": name, "error_map": O, "k": K, "stride": S,
               "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "epilogue": "none",
               "tiling": {
                   "input_grad": _plan_dict("input_grad", spec,
                                            x.shape, dy.shape),
                   "filter_grad": _plan_dict("filter_grad", spec,
                                             x.shape, dy.shape),
                   "backward": _plan_dict("backward", spec,
                                          x.shape, dy.shape)},
               "tconv_us": {}, "filter_grad_us": {}, "backward_us": {}}
        # The planner's strategy pick for this geometry's input gradient,
        # plus the measured per-strategy race it is judged against.
        rec["strategy"] = rec["tiling"]["input_grad"]["strategy"]
        race_us, rec["winner"] = _race_input_grad(
            dy, w, spec, (N, N), iters=iters, warmup=warmup)
        for strategy, us in race_us.items():
            rec["tconv_us"][f"pallas_{strategy}"] = us
            rows.append((f"wallclock.tconv.pallas_{strategy}.{name}",
                         us, f"winner={rec['winner']}"))
        fns_t, fns_g, fns_b = {}, {}, {}
        for bname in backends:
            be = resolve_backend(bname)
            f_t = jax.jit(lambda dy_, w_, be=be: be.input_grad(
                dy_, w_, spec, (N, N)))
            f_g = jax.jit(lambda x_, dy_, be=be: be.filter_grad(
                x_, dy_, spec))
            f_b = jax.jit(lambda x_, dy_, w_, be=be: be.backward(
                x_, dy_, w_, spec, (N, N)))
            fns_t[bname] = lambda f=f_t: f(dy, w)
            fns_g[bname] = lambda f=f_g: f(x, dy)
            fns_b[bname] = lambda f=f_b: f(x, dy, w)
        # The two-launch pair the fused backward replaced, on the SAME
        # pallas kernels, timed in the same interleaved sweep.
        be_pl = resolve_backend("pallas")
        f_two = jax.jit(lambda x_, dy_, w_: (
            be_pl.input_grad(dy_, w_, spec, (N, N)),
            be_pl.filter_grad(x_, dy_, spec)))
        fns_b["two_launch"] = lambda: f_two(x, dy, w)
        t_t = _time_interleaved(fns_t, iters=iters, warmup=warmup)
        t_g = _time_interleaved(fns_g, iters=iters, warmup=warmup)
        t_b = _time_interleaved(fns_b, iters=iters, warmup=warmup)
        for bname in backends:
            rec["tconv_us"][bname] = round(t_t[bname], 1)
            rec["filter_grad_us"][bname] = round(t_g[bname], 1)
            rows.append((f"wallclock.tconv.{bname}.{name}",
                         round(t_t[bname], 1), ""))
            rows.append((f"wallclock.filtergrad.{bname}.{name}",
                         round(t_g[bname], 1), ""))
        for bname in list(backends) + ["two_launch"]:
            rec["backward_us"][bname] = round(t_b[bname], 1)
            derived = "" if bname != "pallas" else (
                f"fused_vs_two_launch="
                f"{t_b['two_launch'] / t_b['pallas']:.2f}x")
            rows.append((f"wallclock.backward.{bname}.{name}",
                         round(t_b[bname], 1), derived))
        records.append(rec)
    for name, N, K, S, P, D, Ci, Co in flt(DILATED_FORWARD_CASES
                                           if dilated_cases is None
                                           else dilated_cases):
        B = 1
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K,
                             dilation=D)
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        Oh, Ow = spec.out_size((N, N))
        zf = naive.dilated_forward_zero_mac_fraction(K, D)
        rec = {"layer": name, "n_in": N, "k": K, "stride": S,
               "dilation": D, "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "zero_mac_fraction_naive": round(zf, 4),
               "epilogue": "none",
               "tiling": {
                   "forward": _plan_dict("forward", spec, x.shape,
                                         (B, Oh, Ow, Co))},
               "dilated_forward_us": {}}
        rec["strategy"] = rec["tiling"]["forward"]["strategy"]
        f_nai = jax.jit(lambda x_, w_: naive.dilated_forward_naive(
            x_, w_, stride=S, padding=P, dilation=D))
        fns_d = {"naive_materialized": lambda: f_nai(x, w)}
        for bname in backends:
            be = resolve_backend(bname)
            f_d = jax.jit(lambda x_, w_, be=be: be.forward(x_, w_, spec))
            np.testing.assert_allclose(np.asarray(f_d(x, w)),
                                       np.asarray(f_nai(x, w)),
                                       rtol=1e-3, atol=1e-3)
            fns_d[bname] = lambda f=f_d: f(x, w)
        t_d = _time_interleaved(fns_d, iters=iters, warmup=warmup)
        t_nai = t_d["naive_materialized"]
        rec["dilated_forward_us"]["naive_materialized"] = round(t_nai, 1)
        rows.append((f"wallclock.dilated_forward.naive.{name}",
                     round(t_nai, 1), f"zero_frac={zf:.2f}"))
        for bname in backends:
            rec["dilated_forward_us"][bname] = round(t_d[bname], 1)
            rows.append((f"wallclock.dilated_forward.{bname}.{name}",
                         round(t_d[bname], 1),
                         f"speedup_vs_naive={t_nai/t_d[bname]:.2f}x"))
        records.append(rec)
    for name, O, K, S, P, D, Ci, Co in flt(STRIDED_DILATED_CASES
                                           if strided_dilated_cases is None
                                           else strided_dilated_cases):
        B = 2
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K,
                             dilation=D)
        n_out = spec.input_size((O, O))
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        rec = {"layer": name, "error_map": O, "k": K, "stride": S,
               "dilation": D, "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "epilogue": "none",
               "tiling": {
                   "input_grad": _plan_dict(
                       "input_grad", spec,
                       (B, n_out[0], n_out[1], Ci), dy.shape)},
               "input_grad_us": {}}
        rec["strategy"] = rec["tiling"]["input_grad"]["strategy"]
        race_us, rec["winner"] = _race_input_grad(
            dy, w, spec, n_out, iters=iters, warmup=warmup)
        for strategy, us in race_us.items():
            rec["input_grad_us"][f"pallas_{strategy}"] = us
            rows.append((f"wallclock.input_grad.pallas_{strategy}.{name}",
                         us, f"winner={rec['winner']}"))
        outs, fns_i = {}, {}
        for bname in backends:
            be = resolve_backend(bname)
            f_i = jax.jit(lambda dy_, w_, be=be: be.input_grad(
                dy_, w_, spec, n_out))
            outs[bname] = np.asarray(f_i(dy, w))
            fns_i[bname] = lambda f=f_i: f(dy, w)
        t_i = _time_interleaved(fns_i, iters=iters, warmup=warmup)
        for bname in backends:
            rec["input_grad_us"][bname] = round(t_i[bname], 1)
            rows.append((f"wallclock.input_grad.{bname}.{name}",
                         round(t_i[bname], 1), ""))
        np.testing.assert_allclose(outs["pallas"], outs["xla_zero_free"],
                                   rtol=1e-3, atol=1e-3)
        records.append(rec)
    for name, O, K, S, Ci, Co, ep in flt(EPILOGUE_CASES
                                         if epilogue_cases is None
                                         else epilogue_cases):
        B, P = 1, 0
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K)
        N = spec.input_size((O, O))[0]
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        b = (jnp.asarray(rng.normal(size=(Co,)), jnp.float32)
             if ep.bias else None)
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        rec = {"layer": name, "error_map": O, "k": K, "stride": S,
               "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "epilogue": ep.tag,
               "tiling": {
                   "forward": _plan_dict("forward", spec, x.shape,
                                         dy.shape, epilogue=ep),
                   "backward": _plan_dict("backward", spec, x.shape,
                                          dy.shape, epilogue=ep)},
               "forward_ep_us": {}, "backward_ep_us": {}}
        # Fused dual-gradient backward: phase-decomposed by design.
        rec["strategy"] = rec["tiling"]["backward"]["strategy"]
        fns_f, fns_b, ys = {}, {}, {}
        for bname in backends:
            be = resolve_backend(bname)
            f_f = jax.jit(lambda x_, w_, b_, be=be: be.forward_ep(
                x_, w_, b_, spec, ep))
            ys[bname] = f_f(x, w, b)
            f_b = jax.jit(lambda x_, y_, dy_, w_, be=be: be.backward_ep(
                x_, y_, dy_, w_, spec, (N, N), ep))
            fns_f[bname] = lambda f=f_f: f(x, w, b)
            fns_b[bname] = lambda f=f_b, y=ys[bname]: f(x, y, dy, w)
        np.testing.assert_allclose(np.asarray(ys["pallas"]),
                                   np.asarray(ys["xla_zero_free"]),
                                   rtol=1e-3, atol=1e-3)
        # The tail as separate XLA ops around the SAME backend kernels:
        # clearing the fused slots drops ConvBackend onto its generic
        # mask/db-reduce composition, so this arm isolates the fusion.
        be_unf = dataclasses.replace(resolve_backend("pallas"),
                                     fused_forward_ep=None,
                                     fused_backward_ep=None)
        f_f_unf = jax.jit(lambda x_, w_, b_: be_unf.forward_ep(
            x_, w_, b_, spec, ep))
        f_b_unf = jax.jit(lambda x_, y_, dy_, w_: be_unf.backward_ep(
            x_, y_, dy_, w_, spec, (N, N), ep))
        fns_f["pallas_unfused"] = lambda: f_f_unf(x, w, b)
        fns_b["pallas_unfused"] = lambda: f_b_unf(x, ys["pallas"], dy, w)
        t_f = _time_interleaved(fns_f, iters=iters, warmup=warmup)
        t_b = _time_interleaved(fns_b, iters=iters, warmup=warmup)
        for bname in list(backends) + ["pallas_unfused"]:
            rec["forward_ep_us"][bname] = round(t_f[bname], 1)
            rec["backward_ep_us"][bname] = round(t_b[bname], 1)
            derived = "" if bname != "pallas" else (
                f"fused_vs_unfused="
                f"{t_b['pallas_unfused'] / t_b['pallas']:.2f}x")
            rows.append((f"wallclock.forward_ep.{bname}.{name}",
                         round(t_f[bname], 1), ""))
            rows.append((f"wallclock.backward_ep.{bname}.{name}",
                         round(t_b[bname], 1), derived))
        records.append(rec)
    for name, O, K, S, Ci, Co, ep in flt(TCONV_EPILOGUE_CASES
                                         if tconv_epilogue_cases is None
                                         else tconv_epilogue_cases):
        B, P = 1, 0
        spec = ConvSpec.make(stride=S, padding=P, filter_shape=K)
        n_out = spec.input_size((O, O))
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        b = (jnp.asarray(rng.normal(size=(Ci,)), jnp.float32)
             if ep.bias else None)
        g_shape = (B, n_out[0], n_out[1], Ci)
        g = jnp.asarray(rng.normal(size=g_shape), jnp.float32)
        rec = {"layer": name, "error_map": O, "k": K, "stride": S,
               "c_in": Ci, "c_out": Co, "batch": B,
               "interpret_mode": jax.default_backend() != "tpu",
               "epilogue": ep.tag,
               "tiling": {
                   "input_grad": _plan_dict("input_grad", spec, g_shape,
                                            dy.shape, epilogue=ep),
                   "ct_backward": _plan_dict("ct_backward", spec, g_shape,
                                             dy.shape, epilogue=ep)},
               "tconv_ep_us": {}, "ct_backward_ep_us": {}}
        rec["strategy"] = rec["tiling"]["input_grad"]["strategy"]
        race_us, rec["winner"] = _race_input_grad(
            dy, w, spec, n_out, bias=b, epilogue=ep,
            iters=iters, warmup=warmup)
        for strategy, us in race_us.items():
            rec["tconv_ep_us"][f"pallas_{strategy}"] = us
            rows.append((f"wallclock.tconv_ep.pallas_{strategy}.{name}",
                         us, f"winner={rec['winner']}"))
        fns_t, fns_c, zs = {}, {}, {}
        for bname in backends:
            be = resolve_backend(bname)
            f_t = jax.jit(lambda dy_, w_, b_, be=be: be.input_grad_ep(
                dy_, w_, b_, spec, n_out, ep))
            zs[bname] = f_t(dy, w, b)
            f_c = jax.jit(lambda g_, z_, dy_, w_, be=be:
                          be.ct_backward_ep(g_, z_, dy_, w_, spec, ep))
            fns_t[bname] = lambda f=f_t: f(dy, w, b)
            fns_c[bname] = lambda f=f_c, z=zs[bname]: f(g, z, dy, w)
        np.testing.assert_allclose(np.asarray(zs["pallas"]),
                                   np.asarray(zs["xla_zero_free"]),
                                   rtol=1e-3, atol=1e-3)
        be_unf = dataclasses.replace(resolve_backend("pallas"),
                                     fused_input_grad_ep=None,
                                     fused_ct_backward_ep=None)
        f_t_unf = jax.jit(lambda dy_, w_, b_: be_unf.input_grad_ep(
            dy_, w_, b_, spec, n_out, ep))
        f_c_unf = jax.jit(lambda g_, z_, dy_, w_: be_unf.ct_backward_ep(
            g_, z_, dy_, w_, spec, ep))
        fns_t["pallas_unfused"] = lambda: f_t_unf(dy, w, b)
        fns_c["pallas_unfused"] = lambda: f_c_unf(g, zs["pallas"], dy, w)
        t_t = _time_interleaved(fns_t, iters=iters, warmup=warmup)
        t_c = _time_interleaved(fns_c, iters=iters, warmup=warmup)
        for bname in list(backends) + ["pallas_unfused"]:
            rec["tconv_ep_us"][bname] = round(t_t[bname], 1)
            rec["ct_backward_ep_us"][bname] = round(t_c[bname], 1)
            derived = "" if bname != "pallas" else (
                f"fused_vs_unfused="
                f"{t_c['pallas_unfused'] / t_c['pallas']:.2f}x")
            rows.append((f"wallclock.tconv_ep.{bname}.{name}",
                         round(t_t[bname], 1), ""))
            rows.append((f"wallclock.ct_backward_ep.{bname}.{name}",
                         round(t_c[bname], 1), derived))
        records.append(rec)
    for name, kind, cfg, fuse in flt(TRAIN_STEP_CASES
                                     if train_cases is None
                                     else train_cases):
        rec = {"layer": name, "kind": kind, "config": cfg,
               "interpret_mode": jax.default_backend() != "tpu",
               "epilogue": "fused" if fuse else "none",
               # per-layer geometries resolve through the planner's race
               "strategy": "auto",
               "train_step_us": {}}
        fns_s = _train_step_fns(kind, cfg, backends, rng,
                                fuse_epilogue=fuse)
        t_s = _time_interleaved(fns_s, iters=iters, warmup=warmup)
        for bname in backends:
            rec["train_step_us"][bname] = round(t_s[bname], 1)
            derived = "" if bname == "xla_zero_free" else (
                f"vs_xla={t_s['xla_zero_free'] / t_s[bname]:.2f}x")
            rows.append((f"wallclock.train_step.{bname}.{name}",
                         round(t_s[bname], 1), derived))
        records.append(rec)
    # Multi-device train-step rows: one subprocess per (case, device
    # count) so each row gets its own forced host device count; the
    # `train_step_us` field name is shared with the single-device rows,
    # so the delta gate's pallas/xla_zero_free ratio check applies to
    # every device count automatically.
    for name, kind, cfg, fuse, dev_counts in flt(MULTIDEV_TRAIN_CASES
                                                 if multidev_cases is None
                                                 else multidev_cases):
        for n_dev in dev_counts:
            rec = {"layer": f"{name}-d{n_dev}", "kind": kind,
                   "config": cfg, "n_devices": n_dev,
                   "mesh": list(MULTIDEV_MESHES[n_dev]),
                   "interpret_mode": jax.default_backend() != "tpu",
                   "epilogue": "fused" if fuse else "none",
                   "strategy": "auto",
                   "train_step_us": {}}
            t_s = _multidev_time(kind, cfg, fuse, n_dev, iters, warmup,
                                 backends=backends)
            for bname in backends:
                rec["train_step_us"][bname] = round(t_s[bname], 1)
                derived = "" if bname == "xla_zero_free" else (
                    f"vs_xla={t_s['xla_zero_free'] / t_s[bname]:.2f}x")
                rows.append(
                    (f"wallclock.train_step_mdev.{bname}.{name}-d{n_dev}",
                     round(t_s[bname], 1), derived))
            records.append(rec)
    # Serving rows: the ConvServeEngine end to end (admission -> bucket
    # -> jitted launch -> host result), one single-rung-ladder engine per
    # backend arm so the arm isolates the backend, sweeps interleaved
    # like every other family; plus the fault-mode arm (full ladder, 5%
    # seeded kernel faults on the fast rungs) gated on bounded
    # degradation.
    for name, kind, cfg in flt(SERVE_CASES if serve_cases is None
                               else serve_cases):
        from repro.serve.conv_engine import ConvRequest
        from repro.serve.faults import FaultInjector, FaultSchedule
        s_iters = min(iters, _SERVE_MAX_ITERS)
        rec = {"layer": name, "kind": kind, "config": cfg,
               "interpret_mode": jax.default_backend() != "tpu",
               "epilogue": "fused", "strategy": "auto",
               "serve_us": {}, "serve_p99_us": {}, "serve_rps": {},
               "fault": {}}
        payloads = None
        engines = {}
        for bname in backends:
            eng, pshape = _serve_engine(kind, cfg, (bname,))
            engines[bname] = eng
            if payloads is None:
                payloads = [np.asarray(rng.normal(size=pshape), np.float32)
                            for _ in range(cfg["requests"])]
        inj = FaultInjector(FaultSchedule.seeded(
            0, sites=[f"{kind}:pallas", f"{kind}:xla_zero_free"],
            rate=_SERVE_FAULT_RATE, horizon=4096,
            kinds=("kernel_exception",)))
        eng_f, _ = _serve_engine(
            kind, cfg, ("pallas", "xla_zero_free", "reference"),
            injector=inj)
        engines["fault"] = eng_f
        walls = {k: 0.0 for k in engines}
        for _ in range(s_iters):
            for bname, eng in engines.items():
                reqs = [ConvRequest(None, kind, p) for p in payloads]
                t0 = time.perf_counter()
                res = eng.serve(reqs)
                walls[bname] += time.perf_counter() - t0
                if len(res) != len(reqs):
                    raise RuntimeError(
                        f"{name}/{bname}: {len(reqs) - len(res)} of "
                        f"{len(reqs)} requests lost")
        for bname in backends:
            h = engines[bname].health()
            rec["serve_us"][bname] = round(h["p50_us"], 1)
            rec["serve_p99_us"][bname] = round(h["p99_us"], 1)
            rec["serve_rps"][bname] = round(
                s_iters * cfg["requests"] / walls[bname], 1)
            rows.append((f"wallclock.serve.{bname}.{name}",
                         rec["serve_us"][bname],
                         f"p99={rec['serve_p99_us'][bname]}"
                         f";rps={rec['serve_rps'][bname]}"))
        # Bounded-degradation gate: every admitted request completed
        # (checked per sweep above) and every fallback is accounted to an
        # injected fault -- the ladder degrades, it never leaks work.
        h = eng_f.health()
        if h["fallbacks"] > h["kernel_faults"]:
            raise RuntimeError(
                f"{name}/fault: {h['fallbacks']} fallbacks but only "
                f"{h['kernel_faults']} injected faults -- degradation "
                f"is not bounded by the schedule")
        rec["fault"] = {
            "rate": _SERVE_FAULT_RATE,
            "p50_us": round(h["p50_us"], 1),
            "p99_us": round(h["p99_us"], 1),
            "rps": round(s_iters * cfg["requests"] / walls["fault"], 1),
            "completed": h["completed"],
            "kernel_faults": h["kernel_faults"],
            "fallbacks": h["fallbacks"],
            "quarantines": h["quarantines"],
        }
        rows.append((f"wallclock.serve.fault.{name}",
                     rec["fault"]["p50_us"],
                     f"faults={h['kernel_faults']}"
                     f";fallbacks={h['fallbacks']}"
                     f";completed={h['completed']}"))
        records.append(rec)
    # Elastic-training rows (DESIGN.md Sec. 2.12): the guarded vs
    # unguarded ConvTrainer step interleaved on pallas (the gated
    # overhead ratio), plus ONE seeded supervisor recovery drill in a
    # forced-device subprocess (accounting, not a timing sweep).
    for name, kind, cfg in flt(ELASTIC_TRAIN_CASES if elastic_cases
                               is None else elastic_cases):
        rec = {"layer": name, "kind": kind, "config": cfg,
               "interpret_mode": jax.default_backend() != "tpu",
               "epilogue": "fused", "strategy": "auto",
               "train_step_guard_us": {}, "recovery": {}}
        t_g = _time_interleaved(_guard_step_fns(kind, cfg),
                                iters=min(iters, _ELASTIC_MAX_ITERS),
                                warmup=warmup)
        for label in ("pallas", "pallas_unguarded"):
            rec["train_step_guard_us"][label] = round(t_g[label], 1)
        rows.append((f"wallclock.elastic_train.guard.{name}",
                     rec["train_step_guard_us"]["pallas"],
                     f"guard_overhead="
                     f"{t_g['pallas'] / t_g['pallas_unguarded']:.2f}x"))
        rec["recovery"] = _elastic_recovery(kind, cfg)
        rows.append((f"wallclock.elastic_train.recovery.{name}",
                     rec["recovery"]["recovery_wallclock_s"],
                     f"steps_lost={rec['recovery']['steps_lost']}"
                     f";recompiles={rec['recovery']['recompiles']}"
                     f";completed={rec['recovery']['completed_steps']}"))
        records.append(rec)
    if records_out is not None:
        records_out.extend(records)
    if write_json:
        path = BENCH_JSON if json_path is None else pathlib.Path(json_path)
        path.write_text(json.dumps(
            {"note": "conv backend wall-clock (us/call): median-of-iters, "
                     "backends interleaved per case (PR 4 methodology -- "
                     "NOT comparable to the pre-PR-4 min-of-iters rows); "
                     "pallas runs in interpret mode off-TPU, so absolute "
                     "numbers are only comparable within a backend+host "
                     "class; `tiling` records the planner decision each "
                     "pallas row ran under; `backward_us.pallas` is the "
                     "FUSED dual-gradient launch vs the `two_launch` "
                     "pallas pair it replaced; `train_step_us` rows time "
                     "one full jit'd SGD step (fwd + fused bwd + update); "
                     "`epilogue` tags each row's fused tail ('none' for "
                     "the plain families), and the *_ep_us families "
                     "carry a `pallas_unfused` arm -- the same pallas "
                     "kernels with the tail/mask/db as separate XLA ops; "
                     "`mdev-*` rows re-time the train step on a forced "
                     "host-platform device mesh (`n_devices`/`mesh`) "
                     "through the shard_map conv dispatch layer, one "
                     "subprocess per device count; `strategy` is the "
                     "strategy planner's per-geometry pick (phase vs "
                     "predicated implicit-GEMM; 'auto' on train rows "
                     "where it resolves per layer) and `winner` the "
                     "measured head-to-head of the two forced-strategy "
                     "pallas_* arms on the input-grad families; "
                     "`serve-*` rows time the geometry-bucketed "
                     "ConvServeEngine end to end (admission -> slot "
                     "batch -> jitted launch -> host result), one "
                     "single-rung ladder per backend arm "
                     "(`serve_us`=p50, plus p99 and requests/s), and "
                     "`fault` re-times the full degradation ladder "
                     "under a seeded 5% kernel-fault schedule, gated "
                     "on bounded degradation; `elastic-train-*` rows "
                     "time the ConvTrainer's GUARDED jitted step (in-"
                     "graph all-finite flag, same launch count) against "
                     "the `pallas_unguarded` step -- the gated guard-"
                     "overhead ratio -- and `recovery` records one "
                     "seeded RunSupervisor drill (host loss + NaN "
                     "steps at the row's fault_seed, forced-device "
                     "subprocess): steps lost, recompiles, recovery "
                     "wallclock",
             "cases": records}, indent=2) + "\n")
        rows.append(("wallclock.conv_backend.json", str(path), ""))
    return rows


# ---------------------------------------------------------------------------
# CI delta gate: re-time the committed geometries, fail on pallas
# regression vs BENCH_conv.json
# ---------------------------------------------------------------------------

# Per-op timing fields and the baseline each op's pallas number is
# normalized against.  Ratios -- pallas / same-row baseline -- are the
# host-class-portable quantity (the JSON's own note: absolute us are only
# comparable within a backend+host class, and CI does not run on the
# host that generated the committed file).  The fused backward gates
# against the SAME-row two-launch pallas pair (a fused/two-launch ratio
# regression > 1.5x means the fusion itself lost its reason to exist);
# the train-step rows gate against the xla_zero_free step like the
# per-op families.
_GATE_FIELDS = {
    "tconv_us": "xla_zero_free",
    "filter_grad_us": "xla_zero_free",
    "dilated_forward_us": "xla_zero_free",
    "input_grad_us": "xla_zero_free",
    "backward_us": "two_launch",
    "train_step_us": "xla_zero_free",
    # Epilogue families: forwards gate against the XLA zero-free tail
    # composition; backwards gate against the SAME pallas kernels with
    # the tail unfused -- a fused/unfused ratio regression > threshold
    # means the epilogue fusion itself stopped paying for its launch.
    "forward_ep_us": "xla_zero_free",
    "backward_ep_us": "pallas_unfused",
    "tconv_ep_us": "xla_zero_free",
    "ct_backward_ep_us": "pallas_unfused",
    # Serving p50: the pallas arm gates against the xla_zero_free arm of
    # the same row -- a ratio regression means the fused kernels lost
    # ground inside the identical engine path.
    "serve_us": "xla_zero_free",
    # Elastic training: the guarded step gates against the SAME step
    # unguarded -- the numerics guard is contractually a few fused XLA
    # reductions (same launch count), so a ratio regression means the
    # guard grew a real cost.
    "train_step_guard_us": "pallas_unguarded",
}


def delta_gate(threshold=1.5, iters=21, warmup=2):
    """Re-run every committed BENCH_conv.json geometry on this host and
    fail (RuntimeError) if any pallas timing regresses more than
    `threshold`x against its committed row.

    `iters` defaults higher than the plain bench: the gate's job is a
    stable ratio, and on noisy shared hosts the interleaved median needs
    ~20 sweeps before its run-to-run spread sits well inside the 1.5x
    threshold.

    Comparison is by pallas/baseline RATIO, and only between rows of the
    same host class (`interpret_mode` must match): a ratio regression
    means the fused kernel lost ground against the dense zero-free
    baseline *on the same host in the same run*, which is the signal a
    kernel/tiling change actually degraded -- absolute us would just
    flag every hardware difference between CI and the committing host.
    """
    committed = {rec["layer"]: rec
                 for rec in json.loads(BENCH_JSON.read_text())["cases"]}
    records = []
    rows = conv_backend_bench(iters=iters, warmup=warmup,
                              write_json=False, records_out=records)
    failures, compared, skipped = [], 0, 0
    # `strategy` (planner pick) and `winner` (measured race) are
    # host/timing-dependent, not geometry -- like `tiling`, they must
    # not trip the drift check when a model retune flips them.
    # `recovery` is wallclock/host-dependent accounting, like `fault`.
    timing_keys = set(_GATE_FIELDS) | {"tiling", "interpret_mode",
                                       "strategy", "winner",
                                       "serve_p99_us", "serve_rps",
                                       "fault", "recovery"}
    for rec in records:
        base = committed.get(rec["layer"])
        if base is None or base.get("interpret_mode") != \
                rec.get("interpret_mode"):
            skipped += 1
            continue
        # A name can only gate against the SAME conv: if the case's
        # geometry fields drifted from the committed row (edited without
        # regenerating the JSON), comparing ratios of different problems
        # would be silently meaningless -- fail loudly instead.
        geom_drift = [k for k in sorted(set(rec) & set(base) - timing_keys)
                      if rec[k] != base[k]]
        if geom_drift:
            failures.append(
                f"{rec['layer']}: geometry drift vs committed row on "
                f"{geom_drift} -- regenerate BENCH_conv.json")
            continue
        for field, baseline in _GATE_FIELDS.items():
            if field not in rec or field not in base:
                continue
            new_p, new_b = rec[field].get("pallas"), \
                rec[field].get(baseline)
            old_p, old_b = base[field].get("pallas"), \
                base[field].get(baseline)
            if None in (new_p, new_b, old_p, old_b) or not old_p \
                    or not new_b or not old_b:
                continue
            compared += 1
            new_ratio, old_ratio = new_p / new_b, old_p / old_b
            if new_ratio > threshold * old_ratio:
                failures.append(
                    f"{rec['layer']}.{field}: pallas/{baseline} ratio "
                    f"{new_ratio:.2f} vs committed {old_ratio:.2f} "
                    f"(> {threshold}x)")
    if failures:
        raise RuntimeError(
            "pallas perf regression vs BENCH_conv.json:\n  "
            + "\n  ".join(failures))
    if compared == 0:
        raise RuntimeError(
            "delta gate compared ZERO ratios (all rows skipped: "
            f"skipped={skipped}) -- a vacuous pass would hide every "
            "regression; check host class / BENCH_conv.json layer names")
    rows.append(("wallclock.delta_gate", "ok",
                 f"{compared} ratios within {threshold}x"
                 f";skipped={skipped}"))
    return rows


# ---------------------------------------------------------------------------
# CI smoke: one tiny geometry per op family + BENCH_conv.json schema guard
# ---------------------------------------------------------------------------

# Smoke geometries: minimal sizes that still exercise every op family
# (tconv, filter-grad, fused dual-gradient backward, dilated forward,
# strided+dilated input grad, epilogue-fused forward/backward for both
# direct and transposed conv, CNN/GAN train step -- the GAN one with the
# fused epilogue path on) through both zero-free backends in seconds on
# an interpret-mode host.
SMOKE_CASES = [("smoke-tconv", 5, 3, 2, 4, 4)]
SMOKE_DILATED_CASES = [("smoke-d2", 9, 3, 1, 2, 2, 4, 4)]
SMOKE_STRIDED_DILATED_CASES = [("smoke-s2d2", 4, 3, 2, 1, 2, 4, 4)]
SMOKE_TRAIN_CASES = [
    ("smoke-train-cnn", "cnn",
     {"widths": [4], "batch": 1, "image": 8, "n_classes": 4}, False),
    ("smoke-train-gan-gen-ep", "gan_gen",
     {"base": 4, "z_dim": 8, "batch": 1}, True),
]
# One 2-device row: exercises the subprocess launcher, the shard_map
# dispatch layer, and the sharded param/batch placement end to end.
SMOKE_MULTIDEV_CASES = [
    ("smoke-mdev-train-cnn-ep", "cnn",
     {"widths": [4], "batch": 4, "image": 8, "n_classes": 4}, True, [2]),
]
SMOKE_EPILOGUE_CASES = [
    ("smoke-ep-brelu", 4, 3, 2, 4, 4,
     Epilogue(activation="relu", bias=True)),
]
SMOKE_TCONV_EPILOGUE_CASES = [
    ("smoke-tconv-ep-tanh", 4, 3, 2, 4, 4,
     Epilogue(activation="tanh")),
]
# One tiny serve row: exercises admission, bucketing, the per-arm
# single-rung ladders, AND the fault-mode full-ladder arm end to end.
SMOKE_SERVE_CASES = [
    ("smoke-serve-gan-gen", "gan_gen",
     {"z_dim": 8, "base": 4, "out_ch": 3, "slot_batch": 1,
      "requests": 2}),
]
# One tiny elastic-training row: guarded-vs-unguarded step plus a
# 2-device / 2-host supervisor recovery drill in a subprocess.
SMOKE_ELASTIC_CASES = [
    ("smoke-elastic-train-cnn", "cnn",
     {"widths": [4], "batch": 4, "image": 8, "n_classes": 4,
      "total_steps": 4, "ckpt_every": 2, "backend": "xla_zero_free",
      "n_devices": 2, "hosts": 2, "fault_seed": 4,
      "host_rate": 0.12, "nan_rate": 0.2}),
]


def _record_schema(doc) -> set[frozenset]:
    """The set of per-record key signatures -- one frozenset per op
    family (tconv/filter-grad, dilated-forward, strided+dilated)."""
    return {frozenset(rec) for rec in doc["cases"]}


def smoke():
    """Run one tiny geometry per op family end to end and fail on
    BENCH_conv.json schema drift.

    The timed paths are the real backend entry points, so a wiring break
    in any op family fails here in CI instead of at the next perf
    comparison; the generated record schema is diffed against the
    committed BENCH_conv.json so a field rename/removal (or a new op
    family whose rows were never regenerated) is caught the same way.
    The smoke JSON is written next to BENCH_conv.json and removed after
    the check -- the committed trajectory file is never clobbered.
    """
    smoke_json = BENCH_JSON.with_name(BENCH_JSON.stem + ".smoke.json")
    try:
        rows = conv_backend_bench(
            iters=1, warmup=1, cases=SMOKE_CASES,
            dilated_cases=SMOKE_DILATED_CASES,
            strided_dilated_cases=SMOKE_STRIDED_DILATED_CASES,
            train_cases=SMOKE_TRAIN_CASES,
            epilogue_cases=SMOKE_EPILOGUE_CASES,
            tconv_epilogue_cases=SMOKE_TCONV_EPILOGUE_CASES,
            multidev_cases=SMOKE_MULTIDEV_CASES,
            serve_cases=SMOKE_SERVE_CASES,
            elastic_cases=SMOKE_ELASTIC_CASES,
            json_path=smoke_json)
        got = _record_schema(json.loads(smoke_json.read_text()))
        committed_doc = json.loads(BENCH_JSON.read_text())
        want = _record_schema(committed_doc)
        if got != want:
            only_new = [sorted(s) for s in got - want]
            only_old = [sorted(s) for s in want - got]
            raise RuntimeError(
                "BENCH_conv.json schema drift: regenerate it with "
                "`python -m benchmarks.run` (record signatures only in "
                f"smoke run: {only_new}; only in committed file: "
                f"{only_old})")
        if set(committed_doc) != {"note", "cases"}:
            raise RuntimeError(
                f"BENCH_conv.json top-level drift: {sorted(committed_doc)}")
    finally:
        smoke_json.unlink(missing_ok=True)
    rows.append(("wallclock.smoke.schema", "ok",
                 f"{len(SMOKE_CASES + SMOKE_DILATED_CASES + SMOKE_STRIDED_DILATED_CASES + SMOKE_TRAIN_CASES + SMOKE_MULTIDEV_CASES + SMOKE_EPILOGUE_CASES + SMOKE_TCONV_EPILOGUE_CASES + SMOKE_SERVE_CASES + SMOKE_ELASTIC_CASES)}"
                 " families"))
    return rows


if __name__ == "__main__":
    for r in run() + conv_backend_bench():
        print(",".join(str(c) for c in r))
