"""Wall-clock microbenchmarks: zero-free EcoFlow vs materialized-zero
naive dataflows, executed for real in JAX on this host (CPU here; the same
code paths compile for TPU).

Reported as name,us_per_call,derived -- `derived` carries the speedup and
the useful-MAC fraction from the analytical model for cross-checking.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecoflow, naive


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


# (name, N_err, K, S, Cin, Cout): error-map size, filter, stride, channels.
CASES = [
    ("resnet50-CONV3-like", 28, 3, 2, 32, 32),
    ("alexnet-CONV1-like", 28, 11, 4, 3, 16),
    ("gan-gen-like", 32, 4, 2, 32, 16),
    ("stride8-like", 16, 11, 8, 8, 8),
]


def run():
    rows = []
    rng = np.random.default_rng(0)
    for name, O, K, S, Ci, Co in CASES:
        B = 2
        dy = jnp.asarray(rng.normal(size=(B, O, O, Co)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, K, Ci, Co)), jnp.float32)
        N = S * (O - 1) + K
        x = jnp.asarray(rng.normal(size=(B, N, N, Ci)), jnp.float32)

        f_eco = jax.jit(lambda dy, w: ecoflow.transposed_conv_zero_free(
            dy, w, stride=(S, S), padding=(0, 0), n_out=(N, N)))
        f_nai = jax.jit(lambda dy, w: naive.transposed_conv_naive(
            dy, w, stride=(S, S), padding=(0, 0), n_out=(N, N)))
        np.testing.assert_allclose(np.asarray(f_eco(dy, w)),
                                   np.asarray(f_nai(dy, w)),
                                   rtol=1e-3, atol=1e-3)
        t_eco = _time(f_eco, dy, w)
        t_nai = _time(f_nai, dy, w)
        zf = ecoflow.tconv_zero_mac_fraction(O, K, S)
        rows.append((f"wallclock.tconv.ecoflow.{name}", round(t_eco, 1),
                     f"speedup={t_nai/t_eco:.2f}x;zero_frac={zf:.2f}"))
        rows.append((f"wallclock.tconv.naive.{name}", round(t_nai, 1), ""))

        g_eco = jax.jit(lambda x, dy:
                        ecoflow.dilated_conv_filter_grad_zero_free(
                            x, dy, stride=(S, S), padding=(0, 0), k=(K, K)))
        g_nai = jax.jit(lambda x, dy: naive.dilated_conv_filter_grad_naive(
            x, dy, stride=(S, S), padding=(0, 0), k=(K, K)))
        np.testing.assert_allclose(np.asarray(g_eco(x, dy)),
                                   np.asarray(g_nai(x, dy)),
                                   rtol=1e-2, atol=1e-2)
        t_eco = _time(g_eco, x, dy)
        t_nai = _time(g_nai, x, dy)
        rows.append((f"wallclock.filtergrad.ecoflow.{name}",
                     round(t_eco, 1), f"speedup={t_nai/t_eco:.2f}x"))
        rows.append((f"wallclock.filtergrad.naive.{name}",
                     round(t_nai, 1), ""))
    return rows
