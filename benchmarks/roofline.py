"""Roofline analysis per (arch x shape) cell on the single-pod 16x16 mesh.

Three terms (seconds per step), per the assignment:

  compute    = FLOPs            / (chips * 197e12  bf16 FLOP/s)
  memory     = HBM bytes        / (chips * 819e9   B/s)
  collective = collective bytes / (chips * 50e9    B/s per ICI link)

Sources.  XLA's `cost_analysis()` on CPU counts `while` (lax.scan) bodies
ONCE -- a 94-layer scan contributes one layer of FLOPs -- so the compiled
artifact cannot supply step-accurate totals directly.  The terms therefore
come from the analytic per-step model in `benchmarks/flops.py` (which
counts exactly what the lowered HLO schedules: remat recompute, masked
full-S attention, MoE dispatch einsums, per-microbatch weight gathers),
and every cell is cross-checked against the dry-run JSON artifact
(launch/dryrun.py): compiled FLOPs ~= analytic / (layers * microbatches),
and the collective op *schedule* (which collectives, what group sizes)
comes from the HLO parse.

Output: benchmarks/results/roofline.csv + stdout rows for bench_output.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks import flops as F
from repro.configs import ARCH_IDS, get_config, supported_shapes
from repro.models.config import SHAPES

CHIPS = 256          # single-pod 16x16 (per assignment, roofline is 1-pod)
RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _load(arch: str, shape: str, mesh: str = "16x16") -> Optional[dict]:
    p = os.path.join(RESULTS, f"{arch}.{shape}.{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def cell_roofline(arch: str, shape_name: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cc = F.cell_cost(cfg, shape)

    compute_s = cc.impl_flops / (CHIPS * F.PEAK_FLOPS)
    memory_s = cc.hbm_bytes / (CHIPS * F.HBM_BW)
    coll_s = (cc.coll_bytes_tp + cc.coll_bytes_dp) / (CHIPS * F.LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful compute time / bound time (how close the
    # step is to the pure-compute roofline of its useful FLOPs)
    ideal_s = cc.model_flops / (CHIPS * F.PEAK_FLOPS)
    rec = {
        "arch": arch, "shape": shape_name, "chips": CHIPS,
        "model_flops": cc.model_flops, "impl_flops": cc.impl_flops,
        "useful_ratio": cc.model_flops / cc.impl_flops,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "step_bound_s": bound,
        "roofline_frac": ideal_s / bound,
        "notes": cc.notes,
    }
    dj = _load(arch, shape_name)
    if dj:
        rec["hlo_flops"] = dj.get("cost", {}).get("flops", 0.0)
        rec["hlo_temp_gib"] = dj.get("temp_size_in_bytes", 0) / 2**30
        cols = dj.get("collectives", {})
        rec["hlo_coll_counts"] = {
            k: v["count"] for k, v in cols.items()
            if isinstance(v, dict) and v.get("count")}
        rec["compile_s"] = dj.get("compile_s")
    return rec


def all_cells():
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in supported_shapes(cfg):
            rows.append(cell_roofline(arch, s))
    return rows


def bench_rows():
    """name,value,derived rows for benchmarks.run."""
    out = []
    for r in all_cells():
        out.append((
            f"roofline.{r['arch']}.{r['shape']}",
            round(r["roofline_frac"], 4),
            f"dom={r['dominant']};compute_s={r['compute_s']:.4f};"
            f"memory_s={r['memory_s']:.4f};coll_s={r['collective_s']:.4f};"
            f"useful={r['useful_ratio']:.2f}"))
    return out


def write_csv(path=None):
    rows = all_cells()
    path = path or os.path.join(RESULTS, "roofline.csv")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    cols = ["arch", "shape", "dominant", "roofline_frac", "useful_ratio",
            "compute_s", "memory_s", "collective_s", "step_bound_s",
            "model_flops", "impl_flops", "hlo_flops", "hlo_temp_gib",
            "compile_s", "notes"]
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    return path, rows


def main():
    path, rows = write_csv()
    print(f"# wrote {path}")
    hdr = f"{'arch':24s} {'shape':12s} {'dom':10s} {'roofline':>8s} " \
          f"{'useful':>6s} {'comp_s':>8s} {'mem_s':>8s} {'coll_s':>8s}"
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['dominant']:10s} "
              f"{r['roofline_frac']:8.3f} {r['useful_ratio']:6.2f} "
              f"{r['compute_s']:8.4f} {r['memory_s']:8.4f} "
              f"{r['collective_s']:8.4f}")


if __name__ == "__main__":
    main()
