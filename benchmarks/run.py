"""Benchmark harness entry point: one section per paper table/figure plus
the wall-clock microbenchmarks and the (arch x shape) roofline table.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # skip wallclock
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: one tiny
        # geometry per op family (incl. the fused dual-gradient
        # backward, the epilogue-fused direct/transposed families, the
        # CNN/GAN train-step rows with epilogue fusion on and off,
        # one 2-forced-device shard_map train-step row in a subprocess,
        # one serve-* row through the geometry-bucketed ConvServeEngine
        # incl. its fault-mode degradation-ladder arm, and one
        # elastic-train-* row: guarded-vs-unguarded ConvTrainer step +
        # a 2-device RunSupervisor recovery drill in a subprocess)
        # + BENCH_conv.json schema-drift guard
  PYTHONPATH=src python -m benchmarks.run --delta-gate   # CI: re-time
        # the committed geometries, fail if a pallas/baseline ratio
        # regressed > 1.5x vs the corresponding BENCH_conv.json row
        # (incl. fused-backward/two-launch, epilogue-fused/unfused,
        # train-step, the per-device-count mdev-* train-step ratios,
        # each re-timed in its own forced-device subprocess, the
        # serve-* engine p50 ratios, and the elastic-train-*
        # guarded/unguarded step-overhead ratios)
  PYTHONPATH=src python -m benchmarks.run --filter shufflenet
        # single-row rerun (substring match; never rewrites the JSON)
  PYTHONPATH=src python -m benchmarks.run --filter strategy=implicit_gemm
        # same, with every pallas launch PINNED to one kernel strategy
        # (phase | implicit_gemm | auto) via ECOFLOW_STRATEGY; combine
        # with a name substring as strategy=NAME,SUBSTR

Output format: ``name,value,derived`` CSV rows (derived carries the
paper's reference number so the reproduction delta is visible).
"""
from __future__ import annotations

import argparse


def _emit(rows):
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the wall-clock microbenchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one tiny geometry per conv op family "
                         "(incl. fused backward, epilogue-fused "
                         "direct/transposed families, train-step rows "
                         "with epilogue fusion on/off, a 2-device "
                         "shard_map train-step row, a serve-* row "
                         "through the ConvServeEngine with its "
                         "fault-mode degradation-ladder arm, and an "
                         "elastic-train-* row with a RunSupervisor "
                         "recovery drill) through the real backend "
                         "entry points, failing on BENCH_conv.json "
                         "schema drift")
    ap.add_argument("--delta-gate", action="store_true",
                    help="CI perf gate: re-time the committed "
                         "BENCH_conv.json geometries and fail if any "
                         "pallas/baseline ratio (incl. fused-backward/"
                         "two-launch, epilogue fused/unfused, "
                         "train-step, per-device-count mdev-* "
                         "train-step, serve-* engine p50, and the "
                         "elastic-train-* guarded/unguarded step "
                         "overhead) regressed > 1.5x")
    ap.add_argument("--filter", metavar="SUBSTR", default=None,
                    help="run only conv-backend rows whose case name "
                         "contains SUBSTR (cheap single-row rerun during "
                         "autotuning; never rewrites BENCH_conv.json). "
                         "A `strategy=NAME` selector (optionally "
                         "`strategy=NAME,SUBSTR`) pins every pallas "
                         "launch to one kernel strategy -- phase | "
                         "implicit_gemm | auto -- for the rerun")
    args = ap.parse_args()

    if args.smoke or args.delta_gate:
        from benchmarks import wallclock
        if args.smoke:
            print("# === benchmark smoke: one tiny geometry per op "
                  "family ===")
            _emit(wallclock.smoke())
        if args.delta_gate:
            print("# === benchmark delta gate: pallas ratio vs committed "
                  "BENCH_conv.json ===")
            _emit(wallclock.delta_gate())
        return

    if args.filter is not None:
        name_filter = args.filter
        if name_filter.startswith("strategy="):
            # Pin the kernel strategy BEFORE importing wallclock (which
            # imports the backends): the env is read per plan_strategy
            # call, but setting it first keeps even import-time planning
            # consistent.  "strategy=NAME,SUBSTR" also name-filters.
            import os
            sel, _, rest = name_filter[len("strategy="):].partition(",")
            valid = ("phase", "implicit_gemm", "auto")
            if sel not in valid:
                raise SystemExit(
                    f"--filter strategy={sel!r}: expected one of {valid}")
            os.environ["ECOFLOW_STRATEGY"] = sel
            name_filter = rest            # "" matches every row
        from benchmarks import wallclock
        print(f"# === wall-clock: conv backends (filter={args.filter!r}; "
              "JSON not rewritten) ===")
        _emit(wallclock.conv_backend_bench(name_filter=name_filter))
        return

    from benchmarks import paper_tables as pt
    print("# === paper tables (SASiML-lite analytical model) ===")
    _emit(pt.fig3_zero_macs())
    _emit(pt.fig8_input_grad_speedup())
    _emit(pt.fig9_filter_grad_speedup())
    _emit(pt.fig10_energy())
    _emit(pt.table6_end2end_cnn())
    _emit(pt.table8_gan())
    print("# === beyond-paper ablations ===")
    _emit(pt.ablation_stride_sweep())
    _emit(pt.ablation_array_size())

    if not args.fast:
        print("# === wall-clock: zero-free vs materialized-zero (JAX) ===")
        from benchmarks import wallclock
        _emit(wallclock.run())
        print("# === wall-clock: conv backends (xla_zero_free vs fused "
              "pallas; incl. dilated-forward d in {2, 4}) ===")
        _emit(wallclock.conv_backend_bench())

    print("# === roofline per (arch x shape), single-pod 16x16 ===")
    from benchmarks import roofline
    _emit(roofline.bench_rows())
    roofline.write_csv()


if __name__ == "__main__":
    main()
