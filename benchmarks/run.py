"""Benchmark harness entry point: one section per paper table/figure plus
the wall-clock microbenchmarks and the (arch x shape) roofline table.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # skip wallclock
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: one tiny
        # geometry per op family + BENCH_conv.json schema-drift guard

Output format: ``name,value,derived`` CSV rows (derived carries the
paper's reference number so the reproduction delta is visible).
"""
from __future__ import annotations

import argparse


def _emit(rows):
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the wall-clock microbenchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one tiny geometry per conv op family "
                         "through the real backend entry points, failing "
                         "on BENCH_conv.json schema drift")
    args = ap.parse_args()

    if args.smoke:
        from benchmarks import wallclock
        print("# === benchmark smoke: one tiny geometry per op family ===")
        _emit(wallclock.smoke())
        return

    from benchmarks import paper_tables as pt
    print("# === paper tables (SASiML-lite analytical model) ===")
    _emit(pt.fig3_zero_macs())
    _emit(pt.fig8_input_grad_speedup())
    _emit(pt.fig9_filter_grad_speedup())
    _emit(pt.fig10_energy())
    _emit(pt.table6_end2end_cnn())
    _emit(pt.table8_gan())
    print("# === beyond-paper ablations ===")
    _emit(pt.ablation_stride_sweep())
    _emit(pt.ablation_array_size())

    if not args.fast:
        print("# === wall-clock: zero-free vs materialized-zero (JAX) ===")
        from benchmarks import wallclock
        _emit(wallclock.run())
        print("# === wall-clock: conv backends (xla_zero_free vs fused "
              "pallas; incl. dilated-forward d in {2, 4}) ===")
        _emit(wallclock.conv_backend_bench())

    print("# === roofline per (arch x shape), single-pod 16x16 ===")
    from benchmarks import roofline
    _emit(roofline.bench_rows())
    roofline.write_csv()


if __name__ == "__main__":
    main()
